"""End-to-end driver: serve a small model with batched requests through the
speculative runtime — REAL decode compute, real threads, real mid-stream
cancellation (not simulation).

Scenario (the §13.2 voice-bot archetype shape):
  classifier (EngineOp, slow remote upstream) -> response drafter (EngineOp)
The drafter is speculated with the modal intent while the classifier runs;
on tier failure the drafter is cancelled mid-stream and re-executed.

    PYTHONPATH=src python examples/speculative_serving.py
"""
import time

import numpy as np

from repro.configs import REGISTRY
from repro.core.posterior import BetaPosterior
from repro.core.taxonomy import DependencyType
from repro.serving import EngineConfig, EngineOp, ServingEngine, ThreadedSpeculativeRunner
from repro.serving.spec_bridge import toy_tokenize

INTENTS = ["billing", "support", "sales", "spam", "other"]
PROBS = [0.62, 0.12, 0.10, 0.09, 0.07]
UPSTREAM_NETWORK_LATENCY_S = 0.5   # the remote-API wait D1 reclaims


def main() -> None:
    cfg = REGISTRY["llama3.2-1b"].reduced()
    engine = ServingEngine(cfg, cfg=EngineConfig(max_seq=256, decode_chunk=8))
    print(f"engine up: {cfg.name}, vocab={cfg.vocab_size}")

    rng = np.random.default_rng(20260531)
    drafter = EngineOp("drafter", engine, max_new_tokens=160)
    posterior = BetaPosterior.from_dependency_type(
        DependencyType.ROUTER_K_WAY, k=len(INTENTS))

    # warm the jit caches so measured walls are decode, not compile
    engine.generate(toy_tokenize("warmup", cfg.vocab_size), 160)

    stats = {"committed": 0, "cancelled": 0, "saved_s": 0.0, "waste": 0.0,
             "spec_wall": 0.0, "seq_wall": 0.0, "n": 0}
    episodes = 10
    for ep in range(episodes):
        actual_intent = INTENTS[rng.choice(len(INTENTS), p=PROBS)]

        def upstream():
            # remote classifier: network + queueing wait, then the intent
            time.sleep(UPSTREAM_NETWORK_LATENCY_S)
            return actual_intent, None

        runner = ThreadedSpeculativeRunner(upstream, drafter)
        decision = runner.decide(posterior, alpha=0.7, lambda_usd_per_s=0.08,
                                 latency_savings_s=UPSTREAM_NETWORK_LATENCY_S)
        seq = runner.run_sequential()
        stats["seq_wall"] += seq.wall_time_s
        if decision.value == "SPECULATE":
            spec = runner.run_speculative(i_hat="billing")   # modal prediction
            posterior.update(spec.committed)
            stats["spec_wall"] += spec.wall_time_s
            stats["committed"] += spec.committed
            stats["cancelled"] += spec.cancelled
            stats["saved_s"] += spec.latency_saved_s
            stats["waste"] += spec.waste_usd
        else:
            stats["spec_wall"] += seq.wall_time_s
        stats["n"] += 1
        print(f"ep{ep}: intent={actual_intent:8s} decision={decision.value:9s} "
              f"P={posterior.mean:.2f}")

    n = stats["n"]
    print("\n=== results over", n, "episodes (real wall clock) ===")
    print(f"sequential mean wall: {stats['seq_wall']/n:.3f}s")
    print(f"speculative mean wall: {stats['spec_wall']/n:.3f}s")
    print(f"committed={stats['committed']} cancelled_mid_stream={stats['cancelled']}")
    print(f"latency reclaimed total: {stats['saved_s']:.2f}s; "
          f"speculative waste: ${stats['waste']:.5f}")
    print(f"posterior converged to P={posterior.mean:.3f} "
          f"(true mode rate {PROBS[0]})")


if __name__ == "__main__":
    main()
