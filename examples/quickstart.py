"""Quickstart: one speculation decision + one speculative workflow run.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    BetaPosterior,
    DependencyType,
    Edge,
    ExecutorConfig,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    plan_workflow,
    speculation_decision,
)
from repro.core.predictor import HistoricalModalPredictor


def single_decision() -> None:
    """The paper's §10.1 worked example, through the §6.5 API."""
    decision = speculation_decision(
        P=0.733,                      # posterior mean (App. A.4)
        alpha=0.5,                    # balanced latency/cost preference
        lambda_dollars_per_sec=0.01,  # deployment latency value
        input_tokens=500, output_tokens=1000,
        input_price=3e-6, output_price=15e-6,   # $3/M in, $15/M out
        latency_seconds=5.0,          # reclaimable upstream wait
    )
    print(f"§10.1 worked example -> {decision}")   # SPECULATE


def speculative_workflow() -> None:
    """Document-analyzer -> topic-researcher with D1 speculation."""
    wf = Workflow("doc-pipeline")
    wf.add_op(Operation(
        "analyzer", run=lambda doc: "quantum-computing",
        latency_est_s=5.0, metadata={"input": "whitepaper.pdf"},
    ))
    wf.add_op(Operation(
        "researcher", run=lambda topic: f"research-notes[{topic}]",
        latency_est_s=5.0, input_tokens_est=500, output_tokens_est=1000,
    ))
    wf.add_edge(Edge("analyzer", "researcher",
                     dep_type=DependencyType.LIST_OUTPUT_VARIABLE_LENGTH))
    wf.freeze()

    params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
    plan, candidates = plan_workflow(wf, params)       # Phase 1
    print(f"plan: concurrency={plan.concurrency} "
          f"E[latency]={plan.expected_latency_s:.2f}s "
          f"E[cost]=${plan.expected_cost_usd:.4f} "
          f"speculated={plan.speculated_edges()}")

    predictor = HistoricalModalPredictor()
    predictor.observe("whitepaper.pdf", "quantum-computing")  # logged history
    cfg = ExecutorConfig(params=params,
                         predictors={("analyzer", "researcher"): predictor})
    report = execute(wf, plan, cfg)                    # Phase 2
    print(f"executed: makespan={report.makespan_s:.2f}s "
          f"(sequential would be {wf.sequential_latency():.2f}s) "
          f"cost=${report.total_cost_usd:.4f} waste=${report.waste_usd:.4f}")
    print(f"outputs: {report.outputs}")
    post = params.posteriors[("analyzer", "researcher")]
    print(f"posterior after run: mean={post.mean:.3f} "
          f"({post.successes}s/{post.failures}f)")


if __name__ == "__main__":
    single_decision()
    speculative_workflow()
