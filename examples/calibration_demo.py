"""The full §12 calibration lifecycle on one edge, end to end:

  offline replay -> shadow mode -> canary (alpha sweep + implied-lambda
  audit) -> online calibration -> drift kill-switch.

    PYTHONPATH=src python examples/calibration_demo.py
"""
import numpy as np

from repro.core.calibration import (
    SequentialLogRecord,
    canary,
    offline_replay,
    online_calibration,
    shadow_mode,
)
from repro.core.decision import decision_threshold, expected_value
from repro.core.drift import DriftMonitor
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor
from repro.core.telemetry import SpeculationDecision, TelemetryLog

EDGE = ("intent-classifier", "reply-drafter")
INTENTS = ["billing", "support", "sales", "spam", "other"]
PROBS = [0.62, 0.12, 0.10, 0.09, 0.07]
C_SPEC, L_UP, LAM = 0.0135, 0.8, 0.08


def main() -> None:
    rng = np.random.default_rng(20260531)

    # ---- stage 1: offline replay on sequential logs (§12.1)
    intents = rng.choice(INTENTS, p=PROBS, size=500)
    logs = [SequentialLogRecord("email", i, "draft-req", "draft", L_UP, C_SPEC)
            for i in intents]
    pred = HistoricalModalPredictor()
    pred.observe_many([("email", i) for i in intents])
    replay = offline_replay(EDGE, logs, {"modal": pred})
    print(f"[replay]  k_raw={replay.k_raw} p_mode={replay.p_mode:.2f} "
          f"k_eff={replay.k_eff:.2f} dep_type={replay.dep_type.value}")
    print(f"[replay]  seeded prior P={replay.seeded_prior.mean:.3f} "
          f"go={replay.go} default_alpha={replay.default_alpha}")

    # ---- stage 2: shadow mode (§12.2)
    trials = [("billing", "billing") if rng.random() < replay.p_mode
              else (rng.choice(INTENTS[1:]), "billing") for _ in range(150)]
    shadow = shadow_mode(EDGE, replay.seeded_prior.copy(), trials,
                         graded_subset=[("refund", "refund", True),
                                        ("refund", "weather", False)] * 15,
                         output_token_counts=list(rng.normal(800, 30, 40)),
                         cancel_fractions=list(rng.uniform(0.2, 0.5, 20)))
    print(f"[shadow]  {shadow.trials} trials, converged={shadow.converged}, "
          f"P={shadow.posterior.mean:.3f}, tier2_thr={shadow.best_tier2_threshold}, "
          f"rho={shadow.rho_mean:.2f}")

    # ---- stage 3: canary with alpha sweep + implied-lambda (§12.3)
    P = shadow.posterior.mean
    sweep = {}
    for a in (0.1, 0.3, 0.5, 0.7, 0.9):
        spec = expected_value(P, L_UP * LAM, C_SPEC) >= decision_threshold(a, C_SPEC)
        lat = L_UP * (1 - P) + 0.8 if spec else 1.6        # drafter is 0.8s
        cost = 0.0165 + (1 - P) * C_SPEC * shadow.rho_mean if spec else 0.0165
        sweep[a] = (lat, cost)
    rep = canary(1.6, 0.0165, sweep, chosen_alpha=0.9, P=P, C_spec=C_SPEC,
                 L_upstream_s=L_UP, lambda_declared=LAM)
    print(f"[canary]  pareto_alphas={rep.pareto_alphas} "
          f"lambda_implied={rep.lambda_implied:.4f} vs declared {LAM} "
          f"-> audit: {rep.audit}; promote={rep.promote}")

    # ---- stage 4: online calibration (§12.4)
    log = TelemetryLog()
    for i in range(300):
        ok = bool(rng.random() < P)
        log.emit(SpeculationDecision(
            decision_id=f"d{i}", trace_id=f"t{i}", edge=EDGE,
            dep_type="router_k_way", tenant="acme", model_version=("m", "v1"),
            alpha=0.5, lambda_usd_per_s=LAM, P_mean=P, P_lower_bound=None,
            C_spec_est_usd=C_SPEC, L_est_s=L_UP, input_tokens_est=500,
            output_tokens_est=800, input_price=3e-6, output_price=15e-6,
            EV_usd=expected_value(P, L_UP * LAM, C_SPEC),
            threshold_usd=decision_threshold(0.5, C_SPEC),
            decision="SPECULATE", phase="runtime", overrode="none",
            i_hat_source="modal", uncertain_cost_flag=False, enabled=True,
            budget_remaining_usd=None, i_actual="billing" if ok else "spam",
            tier1_match=ok, tier2_match=None,
            tier3_accept=(True if ok else False) if i % 20 == 0 else None,
            C_spec_actual_usd=C_SPEC if ok else C_SPEC * 0.5,
            tokens_generated_before_cancel=800 if ok else 296,
            latency_actual_s=L_UP, committed_speculative=ok,
        ))
    online = online_calibration(log)
    print(f"[online]  buckets={[(b.midpoint, round(b.empirical_rate, 2))
                                for b in online.buckets]} "
          f"tier2_far={online.tier2_false_accept_rate} cov={online.token_cov:.3f}")

    # ---- stage 5: drift kill-switch (§12.5)
    mon = DriftMonitor(monthly_budget_usd=50.0)
    for _ in range(500):
        mon.observe_posterior_mean(EDGE, 0.62)
    for _ in range(100):
        ev = mon.observe_posterior_mean(EDGE, 0.35)
    print(f"[drift]   trigger={ev.kind.value}: {ev.action}")
    slo = mon.check_cost_slo(75.0)
    print(f"[drift]   trigger={slo.kind.value}: {slo.action}")
    print(f"[drift]   effective alpha for {EDGE} now: "
          f"{mon.effective_alpha(EDGE, 0.9)}")


if __name__ == "__main__":
    main()
