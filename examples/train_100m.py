"""End-to-end training driver: a ~100M-parameter llama-family model for a
few hundred steps on the synthetic pipeline, with checkpoints + restart.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses
import time

from repro.configs import REGISTRY
from repro.models import build_model
from repro.training.data import DataConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig


def model_100m():
    """llama3.2-family config scaled to ~100M params."""
    return dataclasses.replace(
        REGISTRY["llama3.2-1b"],
        name="llama-100m",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
        remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = model_100m()
    print(f"model: {cfg.name}, {build_model(cfg).param_count()/1e6:.1f}M params")
    tcfg = TrainerConfig(
        steps=args.steps,
        checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir,
        log_every=10,
        optimizer=OptimizerConfig(kind="adamw", peak_lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
                        markov_strength=0.4),
    )
    trainer = Trainer(cfg, tcfg)

    t0 = time.time()
    losses = []

    def on_step(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % tcfg.log_every == 0:
            toks = tcfg.data.global_batch * tcfg.data.seq_len
            dt = time.time() - t0
            print(f"step {step:4d} loss {metrics['loss']:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(step + 1) * toks / max(dt, 1e-9):,.0f} tok/s)")

    report = trainer.run(resume=True, on_step=on_step)
    print(f"\nfinished at step {report.final_step} "
          f"(resumed_from={report.resumed_from})")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(improved {losses[0] - losses[-1]:.4f} nats)")
    print(f"checkpoints: {report.checkpoints}; "
          f"stragglers flagged: {report.straggler_steps}")


if __name__ == "__main__":
    main()
