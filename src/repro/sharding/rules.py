"""Logical-axis -> mesh-axis sharding rules.

Parameters carry logical axis names (ParamDef.axes); these rules map them
onto the production mesh.  The default is 2D "FSDP x TP" sharding:
tensor-parallel dims on ``model``, the embed (d_model) dim on ``data`` —
so giant models (DeepSeek-V3 1.34 TB bf16) divide across all 256 chips of
a pod, and gradient/optimizer state inherits the same 256-way split.

Per-tensor divisibility is enforced by ``shard_if_divisible``: any dim not
divisible by its mesh-axis extent falls back to replication (e.g. batch=1
for long_500k).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = [
    "param_rules",
    "batch_spec",
    "fleet_axis_spec",
    "shard_if_divisible",
    "constrain",
    "named_sharding_tree",
    "cache_spec_tree",
]


def param_rules(cfg: ModelConfig, *, fsdp: bool = True) -> dict[Optional[str], Any]:
    """Logical-axis rules for parameters (and grads/optimizer state).

    fsdp=True  -> 2D sharding: TP dims on 'model', d_model on 'data'.
    fsdp=False -> pure TP: params replicated across 'data' (serving-style
                  for small models; a §Perf hillclimb lever).
    """
    rules: dict[Optional[str], Any] = {
        None: None,
        "vocab": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "expert": "model",
        "layers": None,
        "codebook": None,
        "q_lora": None,
        "embed": "data" if fsdp else None,
        "expert_ffn": "data" if not fsdp else None,
    }
    # expert tensors (E, d, ff): E->model + d->data is already a 256-way
    # split; expert_ffn stays unsharded in fsdp mode.
    return rules


def shard_if_divisible(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes whose extent does not divide the dim (fall back to
    replication for that dim)."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        extent = math.prod(mesh.shape[a] for a in axes_t)
        out.append(axes if dim % extent == 0 else None)
    return P(*out)


def _sanitize_tree(abstract: Any, specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda a, s: shard_if_divisible(a.shape, s, mesh), abstract, specs
    )


def named_sharding_tree(abstract: Any, specs: Any, mesh: Mesh) -> Any:
    specs = _sanitize_tree(abstract, specs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_spec(mesh: Mesh, batch: int, *, extra_dims: int = 1) -> P:
    """Input batch sharding over the data axes ('pod' + 'data' when
    present), replicating if indivisible (long_500k batch=1)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    extent = math.prod(mesh.shape[a] for a in data_axes)
    first = data_axes if batch % extent == 0 else None
    return P(first, *([None] * extra_dims))


def fleet_axis_spec(mesh: Mesh, n: int, axis: str = "fleet") -> Optional[P]:
    """Partition spec for a fleet engine's leading batch axis — the
    ``tenants x grid`` axis of ``core.fleet.multi_tenant_replay`` or the
    episode-segment axis of ``core.fleet.episode_sharded_replay``:
    ``P(axis)`` when the mesh-axis extent divides ``n``, else ``None`` —
    the caller falls back to an unsharded call, the batch-axis analogue
    of ``shard_if_divisible``'s replication fallback."""
    if axis not in mesh.shape or n % mesh.shape[axis] != 0:
        return None
    return P(axis)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint under the ambient mesh."""
    return jax.lax.with_sharding_constraint(x, spec)


def cache_spec_tree(cache: Any, mesh: Mesh, *, seq_axis_on_model: bool = True) -> Any:
    """Sharding specs for a decode-cache pytree.

    KV caches (B, C, H, D) shard batch over data and the sequence/capacity
    dim over 'model' (sequence-parallel KV cache — this is what lets a
    128 x 32k x 60-layer bf16 cache fit 16 GB chips).  Recurrent states
    (B, ...) shard batch over data and heads/width over model.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def spec_for(x) -> P:
        shape = x.shape
        dims: list[Any] = [None] * len(shape)
        if len(shape) >= 1:
            dims[0] = data_axes  # batch
        if len(shape) >= 3 and seq_axis_on_model:
            dims[1] = "model"    # capacity / sequence dim
        elif len(shape) == 2 and shape[1] > 1:
            dims[1] = "model"    # recurrent width
        if len(shape) == 4 and not seq_axis_on_model:
            dims[2] = "model"
        return shard_if_divisible(shape, P(*dims), mesh)

    return jax.tree.map(spec_for, cache)
