"""Decode-cache sharding specs, dispatched on leaf name + rank.

Cache pytrees mirror the parameter skeleton: scanned layer groups stack a
leading L dim on every leaf (never sharded); the tail/hybrid groups carry
unstacked leaves.  Per leaf kind:

  k/v     (.., B, C, Hkv, D)  batch -> data, capacity -> model (sequence-
                              parallel KV cache; updates are masked
                              elementwise writes, so everything along C is
                              local and softmax needs only stat reductions)
  ckv     (.., B, C, r)       MLA latent: capacity -> model (the expansion
  k_rope  (.., B, C, dr)      matmul is local along C)
  pos     (.., B, C)          batch -> data, capacity -> model
  ssm     (.., B, H, P, N)    batch -> data, heads -> model
  conv    (.., B, W-1, ch)    batch -> data, channels -> model
  h       (.., B, W)          batch -> data, width -> model
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P

from .rules import shard_if_divisible

__all__ = ["cache_pspecs"]


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def cache_pspecs(cache: Any, mesh: Mesh) -> Any:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def spec(path, x) -> P:
        name = _leaf_name(path)
        rank = len(x.shape)
        if name in ("k", "v"):          # (.., B, C, Hkv, D)
            dims = [None] * (rank - 4) + [data_axes, "model", None, None]
        elif name in ("ckv", "k_rope"):  # (.., B, C, r)
            dims = [None] * (rank - 3) + [data_axes, "model", None]
        elif name == "pos":             # (.., B, C)
            dims = [None] * (rank - 2) + [data_axes, "model"]
        elif name == "ssm":             # (.., B, H, P, N)
            dims = [None] * (rank - 4) + [data_axes, "model", None, None]
        elif name == "conv":            # (.., B, W-1, ch)
            dims = [None] * (rank - 3) + [data_axes, None, "model"]
        elif name == "h":               # (.., B, W)
            dims = [None] * (rank - 2) + [data_axes, "model"]
        else:
            dims = [None] * rank
            if rank >= 2:
                dims[-2] = data_axes
        # uneven kv-head sharding is fine for constraints, but explicit
        # in/out shardings must divide — drop what doesn't
        return shard_if_divisible(x.shape, P(*dims), mesh)

    return jax.tree_util.tree_map_with_path(spec, cache)
