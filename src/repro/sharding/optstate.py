"""Sharding specs for optimizer state: moments mirror their parameter's
spec exactly (no resharding between grad and update); Adafactor's factored
stats drop the reduced dim's axis."""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["opt_state_pspecs"]


def _pad(spec: P, ndim: int) -> tuple:
    t = tuple(spec)
    return t + (None,) * (ndim - len(t))


def opt_state_pspecs(opt_abstract: Any, param_pspecs: Any, kind: str) -> Any:
    """Build a spec tree matching the optimizer state structure."""
    if kind == "adamw":
        return {
            "m": param_pspecs,
            "v": param_pspecs,
            "step": P(),
        }
    if kind == "adafactor":
        def stat_spec(stat_abstract, pspec):
            if "vr" in stat_abstract:
                vr_ndim = len(stat_abstract["vr"].shape)
                t = _pad(pspec, vr_ndim + 1)
                return {
                    "vr": P(*t[:-1]),                 # param spec minus last dim
                    "vc": P(*t[:-2], t[-1]),          # minus second-to-last
                }
            return {"v": pspec}

        is_stat = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        stats = jax.tree.map(
            stat_spec, opt_abstract["stats"], param_pspecs, is_leaf=is_stat
        )
        return {"stats": stats, "step": P()}
    if kind == "sgd":
        return {"step": P()}
    raise ValueError(f"unknown optimizer kind {kind!r}")
