"""repro.sharding — logical-axis sharding rules and helpers."""
from .rules import (
    batch_spec,
    cache_spec_tree,
    constrain,
    named_sharding_tree,
    param_rules,
    shard_if_divisible,
)

__all__ = [
    "param_rules", "batch_spec", "shard_if_divisible", "constrain",
    "named_sharding_tree", "cache_spec_tree",
]
