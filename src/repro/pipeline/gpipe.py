"""Pipeline parallelism via shard_map + lax.ppermute (GPipe schedule).

The decoder stack is split into S contiguous stages along a ``stage`` mesh
axis; a batch is split into M microbatches.  Each loop iteration every
stage processes one microbatch and ppermutes its activation to the next
stage — the standard (S + M - 1)-tick GPipe pipeline expressed as pure
collectives, so the same code runs on a 2-pod mesh with ``pod`` as the
stage axis (inter-pod pipelining: one ICI/DCN hop per microbatch).

This module is exercised by multi-device subprocess tests (8 host devices)
and available to the launcher as an alternative to pure DPxTP for very
deep models; the default production configs fit without PP (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["PipelineConfig", "pipeline_forward"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int
    stage_axis: str = "stage"


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,        # pytree whose leaves have leading dim = num_stages
    x: jax.Array,             # (B, ...) global batch
    mesh: Mesh,
    cfg: PipelineConfig,
) -> jax.Array:
    """Run x through num_stages stage_fn applications, GPipe-scheduled.

    stage_params leaves are sharded over the stage axis (leading dim = S);
    x is replicated along the stage axis and microbatched internally.
    stage_fn must preserve the activation shape (a decoder stage).
    """
    S, M = cfg.num_stages, cfg.num_microbatches
    axis = cfg.stage_axis
    B = x.shape[0]
    if B % M:
        raise ValueError(f"batch {B} not divisible by microbatches {M}")

    def local(params_s, x_full):
        params_s = jax.tree.map(lambda t: t[0], params_s)  # strip stage dim
        stage_id = lax.axis_index(axis)
        micro = x_full.reshape(M, B // M, *x_full.shape[1:])
        n_ticks = S + M - 1
        right = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 feeds microbatch t while t < M; other stages consume buf
            feed_idx = jnp.clip(t, 0, M - 1)
            take_input = (stage_id == 0) & (t < M)
            inp = jnp.where(take_input, micro[feed_idx], buf)
            # stage s is active for microbatches at ticks [s, s + M)
            active = (t - stage_id >= 0) & (t - stage_id < M)
            out = stage_fn(params_s, inp)
            out = jnp.where(active, out, buf)
            # last stage banks its finished microbatch
            mb_idx = jnp.clip(t - (S - 1), 0, M - 1)
            bank = (stage_id == S - 1) & (t - (S - 1) >= 0) & (t - (S - 1) < M)
            outputs = outputs.at[mb_idx].set(
                jnp.where(bank, out, outputs[mb_idx])
            )
            buf = lax.ppermute(out, axis, right)  # pass rightward
            return (buf, outputs), None

        buf0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros(micro.shape, micro.dtype)
        (_, outputs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(n_ticks))
        # results live on the last stage only; broadcast via masked psum
        mask = (stage_id == S - 1).astype(outputs.dtype)
        outputs = lax.psum(outputs * mask, axis)
        return outputs.reshape(B, *x_full.shape[1:])

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
