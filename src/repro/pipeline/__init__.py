"""repro.pipeline — GPipe-style pipeline parallelism (shard_map + ppermute)."""
from .gpipe import PipelineConfig, pipeline_forward

__all__ = ["PipelineConfig", "pipeline_forward"]
