"""Decoder stack assembly: layer skeletons, scan-over-layers, caches.

Layer kinds:
  attn   — (MLA or GQA) attention + (FFN | MoE) with pre-RMSNorm residuals
  rglru  — RG-LRU recurrent block + FFN
  ssd    — Mamba-2 mixer only (no separate FFN; d_ff = 0)

Uniform stacks scan over layer-stacked params (compact HLO: one layer body
compiled once).  Non-uniform stacks (DeepSeek first-3-dense, Griffin
2:1 pattern) scan over the repeating unit and unroll the remainder.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .attention import attention_apply, attention_skel, init_kv_cache
from .common import ParamDef, prepend_axis, rms_norm
from .ffn import ffn_apply, ffn_skel
from .mla import init_mla_cache, mla_apply, mla_skel
from .moe import moe_apply, moe_skel
from .rglru import init_rglru_cache, rglru_apply, rglru_skel
from .ssd import init_ssd_cache, ssd_apply, ssd_skel

__all__ = ["layer_plan", "stack_skel", "stack_apply", "stack_init_cache"]


# ------------------------------------------------------------------ planning
def layer_plan(cfg: ModelConfig) -> list[tuple[str, str, int]]:
    """Group layers into (group_name, kind, count) units.

    Uniform archs -> one scanned group.  DeepSeek -> dense(3) + moe(58).
    Hybrid -> scanned pattern blocks + unrolled tail.
    """
    L = cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.layer_pattern
        n_blocks, tail = divmod(L, len(pat))
        plan = [("blocks", "pattern", n_blocks)]
        if tail:
            plan.append(("tail", "pattern_tail", tail))
        return plan
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        nd = cfg.moe.first_dense_layers
        return [("dense_layers", "attn_ffn", nd), ("moe_layers", "attn_moe", L - nd)]
    if cfg.ssm is not None:
        return [("layers", "ssd", L)]
    if cfg.moe is not None:
        return [("layers", "attn_moe", L)]
    return [("layers", "attn_ffn", L)]


def _mixer_skel(cfg: ModelConfig, kind: str) -> dict:
    if kind == "ssd":
        return ssd_skel(cfg)
    if kind == "rglru":
        return rglru_skel(cfg)
    if cfg.attn_type == "mla":
        return mla_skel(cfg)
    return attention_skel(cfg)


def _single_layer_skel(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    norm = lambda: ParamDef((d,), ("embed",), "zeros")
    if kind == "ssd":
        return {"norm1": norm(), "mixer": _mixer_skel(cfg, "ssd")}
    mixer_kind = "rglru" if kind == "rglru" else "attn"
    skel = {"norm1": norm(), "mixer": _mixer_skel(cfg, mixer_kind), "norm2": norm()}
    if kind == "attn_moe":
        skel["mlp"] = moe_skel(cfg)
    else:
        dff = cfg.d_ff
        if kind == "attn_ffn" and cfg.moe is not None and cfg.moe.dense_d_ff:
            dff = cfg.moe.dense_d_ff
        skel["mlp"] = ffn_skel(d, dff)
    return skel


def _pattern_block_skel(cfg: ModelConfig, kinds: tuple[str, ...]) -> dict:
    out = {}
    for i, k in enumerate(kinds):
        lk = "attn_ffn" if k == "attn" else "rglru"
        out[f"l{i}_{k}"] = _single_layer_skel(cfg, lk)
    return out


def stack_skel(cfg: ModelConfig) -> dict:
    """Skeleton for all decoder layers, grouped per the plan."""
    skel: dict[str, Any] = {}
    for group, kind, count in layer_plan(cfg):
        if kind == "pattern":
            block = _pattern_block_skel(cfg, cfg.layer_pattern)
            skel[group] = prepend_axis(block, count) if cfg.scan_layers else [
                _pattern_block_skel(cfg, cfg.layer_pattern) for _ in range(count)
            ]
        elif kind == "pattern_tail":
            tail_kinds = cfg.layer_pattern[: count]
            skel[group] = _pattern_block_skel(cfg, tail_kinds)
        else:
            layer = _single_layer_skel(cfg, kind)
            skel[group] = prepend_axis(layer, count) if cfg.scan_layers else [
                _single_layer_skel(cfg, kind) for _ in range(count)
            ]
    return skel


# ------------------------------------------------------------------- caches
def _single_layer_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                        dtype) -> Optional[dict]:
    if kind == "ssd":
        return init_ssd_cache(batch, cfg, dtype)
    if kind == "rglru":
        return init_rglru_cache(batch, cfg, dtype)
    if cfg.attn_type == "mla":
        return init_mla_cache(batch, capacity, cfg, dtype)
    cap = min(capacity, cfg.local_window) if cfg.local_window else capacity
    return init_kv_cache(batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype)


def stack_init_cache(cfg: ModelConfig, batch: int, capacity: int,
                     dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree, grouped to mirror the param skeleton (stacked
    leading layer dim for scanned groups)."""
    cache: dict[str, Any] = {}
    for group, kind, count in layer_plan(cfg):
        if kind in ("pattern", "pattern_tail"):
            kinds = cfg.layer_pattern if kind == "pattern" else cfg.layer_pattern[:count]
            block = {
                f"l{i}_{k}": _single_layer_cache(
                    cfg, "rglru" if k == "rglru" else "attn", batch, capacity, dtype
                )
                for i, k in enumerate(kinds)
            }
            if kind == "pattern":
                cache[group] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (count, *x.shape)).copy(), block
                )
            else:
                cache[group] = block
        else:
            lk = "ssd" if kind == "ssd" else "attn"
            one = _single_layer_cache(cfg, lk, batch, capacity, dtype)
            cache[group] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count, *x.shape)).copy(), one
            )
    return cache


# ------------------------------------------------------------------ forward
@dataclasses.dataclass
class LayerCtx:
    cfg: ModelConfig
    sin: jax.Array
    cos: jax.Array
    position: Optional[jax.Array] = None     # (B,) decode position
    moe_impl: str = "einsum"
    triangular: bool = False
    # statically unroll inner chunk loops (exact XLA cost accounting)
    static: bool = False
    # activation sharding constraint (B, S, d), applied at every layer entry
    # so GSPMD keeps batch on the data axes through the scanned stack
    act_spec: Optional[Any] = None
    # (B, S, H, D) constraint for attention/SSD internals (heads on 'model')
    head_spec: Optional[Any] = None


def _apply_layer(kind: str, params: dict, x: jax.Array, ctx: LayerCtx,
                 cache: Optional[dict]):
    cfg = ctx.cfg
    if ctx.act_spec is not None:
        x = lax.with_sharding_constraint(x, ctx.act_spec)
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssd":
        h, new_cache = ssd_apply(
            params["mixer"], rms_norm(x, params["norm1"], cfg.norm_eps), cfg,
            cache=cache, head_spec=ctx.head_spec,
        )
        return x + h, new_cache, aux
    if kind == "rglru":
        h, new_cache = rglru_apply(
            params["mixer"], rms_norm(x, params["norm1"], cfg.norm_eps), cfg,
            cache=cache,
        )
        x = x + h
    else:  # attention
        window = cfg.local_window
        xn = rms_norm(x, params["norm1"], cfg.norm_eps)
        if cfg.attn_type == "mla":
            h, new_cache = mla_apply(
                params["mixer"], xn, cfg, ctx.sin, ctx.cos,
                cache=cache, position=ctx.position, static=ctx.static,
                head_spec=ctx.head_spec,
            )
        else:
            h, new_cache = attention_apply(
                params["mixer"], xn, cfg, ctx.sin, ctx.cos,
                cache=cache, position=ctx.position, window=window,
                triangular=ctx.triangular, static=ctx.static,
                head_spec=ctx.head_spec,
            )
        x = x + h
    xn = rms_norm(x, params["norm2"], cfg.norm_eps)
    if kind == "attn_moe":
        h, aux = moe_apply(params["mlp"], xn, cfg, impl=ctx.moe_impl,
                           static=ctx.static)
    else:
        h = ffn_apply(params["mlp"], xn)
    return x + h, new_cache, aux


def _apply_pattern_block(params: dict, x: jax.Array, ctx: LayerCtx,
                         cache: Optional[dict], kinds: tuple[str, ...]):
    new_cache = {} if cache is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i, k in enumerate(kinds):
        key = f"l{i}_{k}"
        lk = "attn_ffn" if k == "attn" else "rglru"
        c = cache[key] if cache is not None else None
        x, nc, a = _apply_layer(lk, params[key], x, ctx, c)
        if new_cache is not None:
            new_cache[key] = nc
        aux = aux + a
    return x, new_cache, aux


def stack_apply(
    params: dict,
    x: jax.Array,
    ctx: LayerCtx,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Run all decoder layers.  Returns (hidden, new_cache, moe_aux_loss)."""
    cfg = ctx.cfg
    total_aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = {} if cache is not None else None

    for group, kind, count in layer_plan(cfg):
        gparams = params[group]
        gcache = cache[group] if cache is not None else None

        if kind == "pattern_tail":
            kinds = cfg.layer_pattern[:count]
            x, nc, aux = _apply_pattern_block(gparams, x, ctx, gcache, kinds)
            total_aux += aux
            if new_cache is not None:
                new_cache[group] = nc
            continue

        kinds = cfg.layer_pattern if kind == "pattern" else None

        if not cfg.scan_layers:
            # match the scanned body's remat semantics so unrolled variants
            # (dry-run cost extrapolation) count the same recompute FLOPs
            def one_layer(lp, h, lc):
                if kind == "pattern":
                    return _apply_pattern_block(lp, h, ctx, lc, kinds)
                return _apply_layer(kind, lp, h, ctx, lc)

            layer_fn = jax.checkpoint(one_layer) if cfg.remat else one_layer
            ncs = []
            for i in range(count):
                lc = (jax.tree.map(lambda t: t[i], gcache)
                      if gcache is not None else None)
                lp = gparams[i] if isinstance(gparams, list) else jax.tree.map(
                    lambda t: t[i], gparams)
                x, nc, aux = layer_fn(lp, x, lc)
                total_aux += aux
                ncs.append(nc)
            if new_cache is not None:
                new_cache[group] = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
            continue

        def body(carry, scanned):
            h, aux_acc = carry
            lp, lc = scanned
            if kind == "pattern":
                h, nc, aux = _apply_pattern_block(lp, h, ctx, lc, kinds)
            else:
                h, nc, aux = _apply_layer(kind, lp, h, ctx, lc)
            return (h, aux_acc + aux), nc

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, total_aux), nc_stack = lax.scan(
            body_fn, (x, total_aux), (gparams, gcache)
        )
        if new_cache is not None:
            new_cache[group] = nc_stack

    return x, new_cache, total_aux
