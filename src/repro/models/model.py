"""Public model API: build, init, forward, loss, prefill, decode.

A ``Model`` is a thin namespace bound to a ModelConfig; parameters live in
plain dict pytrees derived from one skeleton (common.ParamDef), so init /
abstract (dry-run) / partition-spec views never diverge.

Batch formats
  train/prefill:  {"tokens": (B, S) int32}          — LM families
                  {"tokens": (B, S, K)}              — musicgen codebooks
                  + {"positions": (3, B, S)}         — qwen2-vl M-RoPE
                  + {"vision_embeds": (B, Nv, d)}    — qwen2-vl stub frontend
  decode:         {"token": (B, 1[, K]), "position": (B,)} (+ mrope grid)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import (
    ParamDef,
    abstract_params,
    count_params,
    init_params,
    make_mrope,
    make_rope,
    partition_specs,
    rms_norm,
)
from .transformer import LayerCtx, stack_apply, stack_init_cache, stack_skel
from . import transformer as _transformer
from .ffn import ffn_skel
from .mla import mla_skel

__all__ = ["Model", "build_model", "cross_entropy"]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE in float32.  logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.clip(mask.sum(), 1)
    return nll.mean()


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- skeleton
    def skeleton(self) -> dict:
        cfg = self.cfg
        d, V, K = cfg.d_model, cfg.vocab_size, cfg.num_codebooks
        skel: dict[str, Any] = {}
        if K > 1:
            skel["embed"] = ParamDef((K, V, d), ("codebook", "vocab", "embed"))
        else:
            skel["embed"] = ParamDef((V, d), ("vocab", "embed"))
        skel.update(stack_skel(cfg))
        skel["final_norm"] = ParamDef((d,), ("embed",), "zeros")
        if not cfg.tie_embeddings:
            if K > 1:
                skel["lm_head"] = ParamDef((K, d, V), ("codebook", "embed", "vocab"), "scaled")
            else:
                skel["lm_head"] = ParamDef((d, V), ("embed", "vocab"), "scaled")
        if cfg.mtp_depth:
            skel["mtp"] = {
                "proj": ParamDef((2 * d, d), (None, "embed"), "scaled"),
                "norm_h": ParamDef((d,), ("embed",), "zeros"),
                "norm_e": ParamDef((d,), ("embed",), "zeros"),
                "layer": {
                    "norm1": ParamDef((d,), ("embed",), "zeros"),
                    "mixer": mla_skel(cfg) if cfg.attn_type == "mla" else
                    _transformer._mixer_skel(cfg, "attn"),
                    "norm2": ParamDef((d,), ("embed",), "zeros"),
                    "mlp": ffn_skel(d, cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff),
                },
                "final_norm": ParamDef((d,), ("embed",), "zeros"),
            }
        return skel

    def init(self, key: jax.Array) -> dict:
        return init_params(self.skeleton(), key, dtype=self.cfg.dtype)

    def abstract(self) -> dict:
        return abstract_params(self.skeleton(), dtype=self.cfg.dtype)

    def pspecs(self, rules: dict) -> dict:
        return partition_specs(self.skeleton(), rules)

    def param_count(self) -> int:
        return count_params(self.skeleton())

    # ------------------------------------------------------------ embedding
    def _embed(self, params: dict, tokens: jax.Array, chunk: int = 0,
               static: bool = False) -> jax.Array:
        """Token embedding lookup.

        With a vocab-sharded table GSPMD lowers the gather to a one-hot
        matmul; unchunked that materializes a (B, S, V)-scale one-hot
        (tens of GB at 4k x 256).  ``chunk`` bounds it to (B, chunk, V).
        """
        if self.cfg.num_codebooks > 1:
            # tokens (B, S, K): sum of per-codebook embeddings (gather per book)
            K = self.cfg.num_codebooks
            parts = [params["embed"][k][tokens[..., k]] for k in range(K)]
            return sum(parts)
        table = params["embed"]
        S = tokens.shape[1]
        if not chunk or S <= chunk or tokens.ndim != 2:
            return table[tokens]
        n = -(-S // chunk)
        pad = n * chunk - S
        tk = jnp.pad(tokens, ((0, 0), (0, pad))) if pad else tokens
        tk = jnp.moveaxis(tk.reshape(tk.shape[0], n, chunk), 1, 0)
        if static:
            outs = [table[tk[i]] for i in range(n)]
            out = jnp.stack(outs)
        else:
            out = jax.lax.map(lambda t: table[t], tk)
        out = jnp.moveaxis(out, 0, 1).reshape(tokens.shape[0], n * chunk, -1)
        return out[:, :S]

    def _unembed(self, params: dict, h: jax.Array,
                 logits_spec=None) -> jax.Array:
        cfg = self.cfg
        if cfg.num_codebooks > 1:
            if cfg.tie_embeddings:
                logits = jnp.einsum("bsd,kvd->bskv", h, params["embed"])
            else:
                logits = jnp.einsum("bsd,kdv->bskv", h, params["lm_head"])
        elif cfg.tie_embeddings:
            logits = h @ params["embed"].T
        else:
            logits = h @ params["lm_head"]
        if logits_spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        return logits

    def _rope(self, batch: dict, B: int, S: int,
              position: Optional[jax.Array] = None):
        cfg = self.cfg
        if cfg.attn_type == "none":
            z = jnp.zeros((B, S, 1), jnp.float32)
            return z, z
        if cfg.mrope_sections is not None:
            grid = batch.get("positions")
            if grid is None:
                pos = (position[:, None] if position is not None
                       else jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32))
                grid = jnp.broadcast_to(pos[None], (3, B, pos.shape[-1]))
            return make_mrope(grid, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
        if position is not None:
            pos = position[:, None]                     # (B, 1) decode
        else:
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        half = (cfg.mla.qk_rope_head_dim if cfg.attn_type == "mla"
                else cfg.head_dim)
        return make_rope(pos, half, cfg.rope_theta)

    def _merge_vision(self, batch: dict, h: jax.Array) -> jax.Array:
        ve = batch.get("vision_embeds")
        if ve is None or self.cfg.vision_tokens == 0:
            return h
        n = ve.shape[1]
        return jnp.concatenate([ve.astype(h.dtype), h[:, n:]], axis=1)

    # -------------------------------------------------------------- forward
    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        cache: Optional[dict] = None,
        position: Optional[jax.Array] = None,
        moe_impl: str = "einsum",
        triangular: bool = False,
        static: bool = False,
        act_spec=None,
        head_spec=None,
        embed_chunk: int = 0,
    ) -> tuple[jax.Array, Optional[dict], jax.Array]:
        """Returns (hidden (B,S,d), new_cache, moe_aux)."""
        cfg = self.cfg
        tokens = batch["token"] if "token" in batch else batch["tokens"]
        B, S = tokens.shape[:2]
        h = self._embed(params, tokens, chunk=embed_chunk, static=static)
        if cache is None or S > 1:
            h = self._merge_vision(batch, h)
        if act_spec is not None:
            h = jax.lax.with_sharding_constraint(h, act_spec)
        sin, cos = self._rope(batch, B, S, position)
        ctx = LayerCtx(cfg=cfg, sin=sin, cos=cos, position=position,
                       moe_impl=moe_impl, triangular=triangular,
                       static=static, act_spec=act_spec, head_spec=head_spec)
        h, new_cache, aux = stack_apply(params, h, ctx, cache)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, new_cache, aux

    def logits(self, params: dict, batch: dict, logits_spec=None, **kw) -> jax.Array:
        h, _, _ = self.forward(params, batch, **kw)
        return self._unembed(params, h, logits_spec)

    def _chunked_ce(self, params: dict, h: jax.Array, labels: jax.Array,
                    chunk: int, logits_spec=None,
                    static: bool = False) -> jax.Array:
        """CE without materializing the full (B, S, V) logits: unembed +
        logsumexp one sequence chunk at a time (lax.map keeps a single
        chunk's logits live; grads rematerialize per chunk)."""
        B, S = h.shape[:2]
        n = -(-S // chunk)
        pad = n * chunk - S
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(
                labels, ((0, 0), (0, pad)) + ((0, 0),) * (labels.ndim - 2))
        mask = (jnp.arange(n * chunk) < S).astype(jnp.float32)
        hs = jnp.moveaxis(h.reshape(B, n, chunk, -1), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, n, chunk, *labels.shape[2:]), 1, 0)
        ms = mask.reshape(n, chunk)

        @jax.checkpoint
        def body(args):
            h_i, lab_i, m_i = args
            logits = self._unembed(params, h_i, logits_spec).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lab_i[..., None], axis=-1)[..., 0]
            nll = (logz - gold)
            w = jnp.broadcast_to(
                m_i[(None, slice(None)) + (None,) * (nll.ndim - 2)], nll.shape)
            return (nll * w).sum(), w.sum()

        if static:
            parts = [body((hs[i], ls[i], ms[i])) for i in range(n)]
            sums = jnp.stack([p[0] for p in parts])
            counts = jnp.stack([p[1] for p in parts])
        else:
            sums, counts = jax.lax.map(body, (hs, ls, ms))
        return sums.sum() / jnp.clip(counts.sum(), 1.0)

    # ----------------------------------------------------------------- loss
    def loss(self, params: dict, batch: dict, *, moe_impl: str = "einsum",
             triangular: bool = False, static: bool = False, act_spec=None,
             head_spec=None, logits_spec=None, ce_chunk: int = 0,
             embed_chunk: int = 0) -> tuple[jax.Array, dict]:
        """Next-token LM loss (+ MoE aux + MTP aux where configured).

        ce_chunk > 0 enables the chunked-loss path (bounded logits memory).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        h, _, aux = self.forward(params, batch, moe_impl=moe_impl,
                                 triangular=triangular, static=static,
                                 act_spec=act_spec, head_spec=head_spec,
                                 embed_chunk=embed_chunk)
        labels = tokens[:, 1:]
        if ce_chunk:
            ce = self._chunked_ce(params, h[:, :-1], labels, ce_chunk,
                                  logits_spec, static=static)
        else:
            logits = self._unembed(params, h[:, :-1], logits_spec)
            ce = cross_entropy(logits, labels)
        metrics = {"ce": ce, "moe_aux": aux}
        total = ce
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux
        if cfg.mtp_depth and "mtp" in params:
            mtp_ce = self._mtp_loss(params, batch, h, ce_chunk=ce_chunk,
                                    logits_spec=logits_spec, static=static,
                                    embed_chunk=embed_chunk)
            metrics["mtp_ce"] = mtp_ce
            total = total + 0.3 * mtp_ce
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params: dict, batch: dict, h: jax.Array,
                  ce_chunk: int = 0, logits_spec=None, static: bool = False,
                  embed_chunk: int = 0) -> jax.Array:
        """DeepSeek-V3 MTP depth-1: predict token t+2 from (h_t, emb_{t+1})."""
        cfg = self.cfg
        mtp = params["mtp"]
        tokens = batch["tokens"]
        B, S = tokens.shape[:2]
        if S < 3:
            return jnp.zeros((), jnp.float32)
        h_t = rms_norm(h[:, : S - 2], mtp["norm_h"], cfg.norm_eps)
        e_next = rms_norm(
            self._embed(params, tokens[:, 1 : S - 1], chunk=embed_chunk,
                        static=static),
            mtp["norm_e"], cfg.norm_eps,
        )
        x = jnp.concatenate([h_t, e_next], axis=-1) @ mtp["proj"]
        sin, cos = self._rope(batch, B, S - 2)
        ctx = LayerCtx(cfg=cfg, sin=sin, cos=cos)
        x, _, _ = _transformer._apply_layer("attn_ffn", mtp["layer"], x, ctx, None)
        x = rms_norm(x, mtp["final_norm"], cfg.norm_eps)
        if ce_chunk:
            return self._chunked_ce(params, x, tokens[:, 2:], ce_chunk,
                                    logits_spec, static=static)
        logits = self._unembed(params, x)
        return cross_entropy(logits, tokens[:, 2:])

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, capacity: int, dtype=jnp.bfloat16) -> dict:
        return stack_init_cache(self.cfg, batch, capacity, dtype)

    def prefill(self, params: dict, batch: dict, cache: dict,
                logits_spec=None, **kw) -> tuple[jax.Array, dict]:
        """Run the prompt; returns (last-position logits, filled cache)."""
        tokens = batch["tokens"]
        if tokens.shape[1] == 1:
            # single-token prompt routes through the decode path, which
            # needs an explicit position (slot 0)
            kw.setdefault("position", jnp.zeros(tokens.shape[0], jnp.int32))
            batch = {**batch, "token": tokens}
        h, new_cache, _ = self.forward(params, batch, cache=cache, **kw)
        return self._unembed(params, h[:, -1:], logits_spec), new_cache

    def decode_step(self, params: dict, token: jax.Array, cache: dict,
                    position: jax.Array, mrope_grid: Optional[jax.Array] = None,
                    **kw) -> tuple[jax.Array, dict]:
        """One token in, one token's logits out.  position: (B,) absolute."""
        batch = {"token": token}
        if mrope_grid is not None:
            batch["positions"] = mrope_grid
        logits_spec = kw.pop("logits_spec", None)
        h, new_cache, _ = self.forward(
            batch=batch, params=params, cache=cache, position=position, **kw
        )
        return self._unembed(params, h, logits_spec), new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
