"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrent block:   out = W_out( GeLU(W_side x)  ⊙  RGLRU(conv1d(W_main x)) )
RG-LRU recurrence (per channel, computed in float32):

    r_t = sigmoid(W_a u_t + b_a)            recurrence gate
    i_t = sigmoid(W_i u_t + b_i)            input gate
    a_t = exp(-c * softplus(lam) * r_t)     c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses an associative scan over the affine maps (a, b); decode is a
single state update.  The Pallas kernel ``repro.kernels.rglru_scan``
implements the same recurrence with blocked time tiling.

Note: Griffin uses block-diagonal gate matrices; we use full dense gates
(documented in DESIGN.md) — same recurrence, slightly larger layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .common import ParamDef

__all__ = [
    "rglru_skel",
    "rglru_apply",
    "init_rglru_cache",
    "rglru_scan",
    "causal_conv1d",
    "conv1d_step",
]

_C = 8.0  # Griffin's fixed decay sharpness


def rglru_skel(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_side": ParamDef((d, w), ("embed", "ffn"), "scaled"),
        "w_main": ParamDef((d, w), ("embed", "ffn"), "scaled"),
        "conv_w": ParamDef((4, w), (None, "ffn"), "scaled", scale=0.1),
        "w_a": ParamDef((w, w), ("ffn", None), "scaled"),
        "b_a": ParamDef((w,), (None,), "zeros"),
        "w_i": ParamDef((w, w), ("ffn", None), "scaled"),
        "b_i": ParamDef((w,), (None,), "zeros"),
        # lam init so softplus(lam) spans useful decay rates
        "lam": ParamDef((w,), (None,), "normal", scale=0.5),
        "w_out": ParamDef((w, d), ("ffn", "embed"), "scaled"),
    }


def init_rglru_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), dtype),  # last (width-1) inputs
    }


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W.  x: (B, S, C), w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i : i + x.shape[1]] * w[W - 1 - i]
    return out


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array):
    """One decode step.  x_t: (B, C); conv_state: (B, W-1, C).

    ``causal_conv1d`` computes out_t = sum_k x_{t-k} * w[k]; the window is
    ordered oldest -> newest, so the taps apply reversed.
    """
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w[::-1])
    return y, window[:, 1:]


def _gates(params: dict, u: jax.Array):
    r = jax.nn.sigmoid(u @ params["w_a"] + params["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ params["w_i"] + params["b_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated_in


def rglru_scan(params: dict, u: jax.Array, h0: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence RG-LRU via associative scan.  u: (B, S, W) -> (B, S, W)."""
    a, b = _gates(params, u)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_step(params: dict, u_t: jax.Array, h: jax.Array):
    """One decode step.  u_t: (B, W); h: (B, W) float32."""
    a, b = _gates(params, u_t[:, None, :])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(u_t.dtype), h_new


def rglru_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    """The full recurrent block.  x: (B, S, d)."""
    side = jax.nn.gelu(x @ params["w_side"])
    u = x @ params["w_main"]

    if cache is None or x.shape[1] > 1:
        u = causal_conv1d(u, params["conv_w"])
        h0 = cache["h"] if cache is not None else None
        y = rglru_scan(params, u, h0)
        new_cache = None
        if cache is not None:  # prefill: save final state + conv tail
            a, b = _gates(params, u)

            def combine(c1, c2):
                a1, b1 = c1
                a2, b2 = c2
                return a1 * a2, a2 * b1 + b2

            aT, hT = jax.tree.map(
                lambda t: t[:, -1], lax.associative_scan(combine, (a, b), axis=1)
            )
            tail = (x @ params["w_main"])[:, -3:]
            pad = 3 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_cache = {"h": hT, "conv": tail}
    else:
        u_t, conv_state = conv1d_step(u[:, 0], cache["conv"], params["conv_w"])
        y_t, h = rglru_step(params, u_t, cache["h"])
        y = y_t[:, None]
        new_cache = {"h": h, "conv": conv_state}

    out = (side * y) @ params["w_out"]
    return out, new_cache
