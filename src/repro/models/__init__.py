"""repro.models — JAX model substrate for the assigned architectures."""
from .model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
