"""SwiGLU feed-forward block (gate/up/down)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef

__all__ = ["ffn_skel", "ffn_apply"]


def ffn_skel(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "ffn"), "scaled"),
        "w_up": ParamDef((d_model, d_ff), ("embed", "ffn"), "scaled"),
        "w_down": ParamDef((d_ff, d_model), ("ffn", "embed"), "scaled"),
    }


def ffn_apply(params: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]
