"""Shared model machinery: parameter skeletons, norms, rotary embeddings.

Parameters are plain dict pytrees.  Each module builds a *skeleton* — a
pytree of ``ParamDef`` leaves carrying shape, logical axes, and init — from
which three views derive mechanically (one source of truth):

  * ``init_params(skel, key)``        -> materialized jnp arrays
  * ``abstract_params(skel)``         -> ShapeDtypeStruct (dry-run, no alloc)
  * ``partition_specs(skel, rules)``  -> PartitionSpec tree (GSPMD shardings)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef",
    "init_params",
    "abstract_params",
    "partition_specs",
    "prepend_axis",
    "rms_norm",
    "make_rope",
    "apply_rope",
    "make_mrope",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter leaf: shape + logical sharding axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]       # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: Optional[float] = None         # stddev override
    dtype: Any = jnp.bfloat16

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "scaled":
        # fan-in scaled truncated normal (default for projections)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, d.shape)).astype(d.dtype)
    std = d.scale if d.scale is not None else 0.02
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, d.shape)).astype(d.dtype)


def _with_dtype(skel: Any, dtype: Any) -> Any:
    if dtype is None:
        return skel
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda d: dataclasses.replace(d, dtype=dt), skel, is_leaf=_is_def
    )


def init_params(skel: Any, key: jax.Array, dtype: Any = None) -> Any:
    """Materialize a skeleton into parameter arrays (smoke tests/training)."""
    skel = _with_dtype(skel, dtype)
    leaves, treedef = jax.tree.flatten(skel, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(skel: Any, dtype: Any = None) -> Any:
    """ShapeDtypeStruct view — used by the dry-run; allocates nothing."""
    skel = _with_dtype(skel, dtype)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), skel, is_leaf=_is_def
    )


def partition_specs(skel: Any, rules: dict[Optional[str], Optional[Any]]) -> Any:
    """Map logical axis names -> mesh axes via ``rules``.

    rules values may be None (replicate), a mesh-axis name, or a tuple of
    mesh-axis names (sharded over both).  Missing names replicate.
    """

    def spec(d: ParamDef) -> P:
        return P(*(rules.get(a) for a in d.axes))

    return jax.tree.map(spec, skel, is_leaf=_is_def)


def prepend_axis(skel: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Stack a skeleton n times along a new leading dim (scan-over-layers)."""
    return jax.tree.map(
        lambda d: dataclasses.replace(
            d, shape=(n, *d.shape), axes=(axis_name, *d.axes)
        ),
        skel,
        is_leaf=_is_def,
    )


def count_params(skel: Any) -> int:
    total = 0
    for d in jax.tree.leaves(skel, is_leaf=_is_def):
        total += math.prod(d.shape)
    return total


# ----------------------------------------------------------------- numerics
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def make_rope(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) -> (x1 cos - x2 sin, x2 cos + x1 sin).

    x: (B, S, H, D); sin/cos: (B, S, D/2) broadcast over heads.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]  # add head axis
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def make_mrope(
    position_grid: jax.Array, head_dim: int, theta: float,
    sections: tuple[int, int, int],
) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE: the rotary half-dim is split into (t, h, w)
    sections; each section takes its angle from the matching position grid.

    position_grid: (3, B, S) int32 — temporal/height/width positions.
    Returns (sin, cos) of shape (B, S, head_dim//2).
    """
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to head_dim/2={half}")
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # angles per grid: (3, B, S, half)
    angles = position_grid.astype(jnp.float32)[..., None] * freqs
    # section select: which of the 3 grids owns each of the half dims
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )
    onehot = jax.nn.one_hot(sec_id, 3, dtype=angles.dtype)       # (half, 3)
    picked = jnp.einsum("gbsd,dg->bsd", angles, onehot)
    return jnp.sin(picked), jnp.cos(picked)
