"""GQA/MQA/MHA attention with RoPE / M-RoPE, sliding-window, KV caches.

Two XLA execution paths (the Pallas kernels in repro.kernels mirror both
for TPU):

  * ``chunked_attention`` — query-chunked with full-row softmax, bounded
    VMEM/temp footprint at long sequence; used for train/prefill.
  * ``decode_attention``  — one query token against a (possibly ring-
    buffered) KV cache; used by serve_step.

Causal masking is applied inside each query chunk.  The rectangular
iteration computes masked positions too (~2x score FLOPs at full causal);
the block-triangular variant used as a §Perf hillclimb lives in
``chunked_attention(..., triangular=True)`` which skips fully-masked KV
blocks for scores via unrolled static slicing.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .common import ParamDef, apply_rope

__all__ = [
    "attention_skel",
    "attention_apply",
    "chunked_attention",
    "decode_attention",
    "init_kv_cache",
    "KV_CHUNK",
]

KV_CHUNK = 512  # query-chunk length for the chunked path


# ------------------------------------------------------------------ skeleton
def attention_skel(cfg: ModelConfig) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    skel = {
        "wq": ParamDef((d, qd), ("embed", "q_heads"), "scaled"),
        "wk": ParamDef((d, kvd), ("embed", "kv_heads"), "scaled"),
        "wv": ParamDef((d, kvd), ("embed", "kv_heads"), "scaled"),
        "wo": ParamDef((qd, d), ("q_heads", "embed"), "scaled"),
    }
    if cfg.qkv_bias:
        skel["bq"] = ParamDef((qd,), ("q_heads",), "zeros")
        skel["bk"] = ParamDef((kvd,), ("kv_heads",), "zeros")
        skel["bv"] = ParamDef((kvd,), ("kv_heads",), "zeros")
    return skel


# ------------------------------------------------------------ core attention
def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, H, D), k: (B, Sk, Hkv, D) -> scores (B, Hkv, G, Sq, Sk)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)


def _grouped_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B, Hkv, G, Sq, Sk), v: (B, Sk, Hkv, D) -> (B, Sq, H, D)."""
    B, Hkv, G, Sq, Sk = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, Sq, Hkv * G, out.shape[-1])


def _masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen in ring buffers before fill) -> zeros
    return jnp.where(mask.any(axis=-1, keepdims=True), probs, 0.0)


def _causal_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: Optional[int]
) -> jax.Array:
    mask = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return mask


def _repeat_kv(k: jax.Array, H: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,H,D).  Full-H scores let GSPMD shard the head
    dim over 'model' (the grouped (Hkv, G) factorization leaves both dims
    smaller than the mesh axis); FLOPs are identical."""
    Hkv = k.shape[2]
    if Hkv == H:
        return k
    return jnp.repeat(k, H // Hkv, axis=2)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: Optional[int] = None,
    chunk: int = KV_CHUNK,
    triangular: bool = False,
    static: bool = False,
    head_spec=None,
) -> jax.Array:
    """Causal attention, query-chunked.  q: (B,S,H,D), k/v: (B,S,Hkv,D).

    With ``triangular=True`` each query chunk only contracts against KV
    blocks at or below its diagonal (static unrolled slicing) — removes the
    ~2x masked-score waste at the price of a larger unrolled HLO.
    ``static=True`` unrolls the rectangular query-chunk loop too (python
    loop instead of lax.map) so XLA cost analysis counts every chunk —
    required for exact dry-run FLOP accounting (lax.map bodies are counted
    once).
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    if head_spec is not None:
        q = lax.with_sharding_constraint(q, head_spec)
        k = lax.with_sharding_constraint(k, head_spec)
        v = lax.with_sharding_constraint(v, head_spec)

    @jax.checkpoint
    def attend(q_i, k_i, v_i, mask):
        # remat per chunk: scores/probs/mask are recomputed in the backward
        # instead of being stacked across chunks (GBs at long sequence)
        scores = jnp.einsum("bshd,bthd->bhst", q_i, k_i,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = jnp.where(mask[None, None].any(-1, keepdims=True), probs, 0.0)
        return jnp.einsum("bhst,bthd->bshd", probs.astype(v_i.dtype), v_i)

    if S <= chunk:
        pos = jnp.arange(S)
        return attend(q, k, v, _causal_mask(pos, pos, window))

    n_chunks = -(-S // chunk)
    if S % chunk:
        pad = n_chunks * chunk - S
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_pos_full = jnp.arange(k.shape[1])

    if triangular:
        outs = []
        for i in range(n_chunks):
            q_i = lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
            hi = min((i + 1) * chunk, k.shape[1])
            k_i = lax.slice_in_dim(k, 0, hi, axis=1)
            v_i = lax.slice_in_dim(v, 0, hi, axis=1)
            q_pos = i * chunk + jnp.arange(chunk)
            outs.append(attend(q_i, k_i, v_i,
                               _causal_mask(q_pos, k_pos_full[:hi], window)))
        out = jnp.concatenate(outs, axis=1)
        return out[:, :S]

    if static:
        outs = []
        for i in range(n_chunks):
            q_i = lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
            q_pos = i * chunk + jnp.arange(chunk)
            outs.append(attend(q_i, k, v, _causal_mask(q_pos, k_pos_full, window)))
        out = jnp.concatenate(outs, axis=1)
        return out[:, :S]

    def body(i):
        q_i = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        q_pos = i * chunk + jnp.arange(chunk)
        return attend(q_i, k, v, _causal_mask(q_pos, k_pos_full, window))

    out = lax.map(body, jnp.arange(n_chunks))          # (n, B, chunk, H, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk, H, D)
    return out[:, :S]


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_positions: jax.Array,
    current_pos: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, D); caches: (B, C, Hkv, D); cache_positions: (B, C) absolute
    token positions per slot (-1 = empty); current_pos: (B,) int32.
    """
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)
    scores = _grouped_scores(q, k_cache) * scale        # (B,Hkv,G,1,C)
    valid = cache_positions >= 0
    mask = valid & (cache_positions <= current_pos[:, None])
    if window is not None:
        mask &= (current_pos[:, None] - cache_positions) < window
    probs = _masked_softmax(scores, mask[:, None, None, None, :])
    return _grouped_out(probs.astype(v_cache.dtype), v_cache)


# ---------------------------------------------------------------- KV caches
def init_kv_cache(
    batch: int, capacity: int, num_kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> dict:
    """capacity = full seq for dense attention, = window for sliding-window
    (ring buffer).  positions carry absolute indices for masking/rope."""
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "pos": -jnp.ones((batch, capacity), jnp.int32),
    }


def update_kv_cache(cache: dict, k_new: jax.Array, v_new: jax.Array,
                    position: jax.Array) -> dict:
    """Insert one token (k_new/v_new: (B, 1, Hkv, D)) at ``position`` (B,),
    ring-buffered over capacity.

    The write is a masked elementwise select rather than a scatter: with a
    capacity-sharded cache, scatters force GSPMD into full-tensor
    rematerialization (replicate + repartition), while the select stays
    local per shard.  On real TPUs the Pallas decode kernel performs the
    slot write as an in-place VMEM DMA; the masked form is the XLA-path
    equivalent (DESIGN.md §3)."""
    C = cache["k"].shape[1]
    slot = (position % C)[:, None]                        # (B, 1)
    sel = jnp.arange(C)[None, :] == slot                  # (B, C)
    k = jnp.where(sel[..., None, None], k_new, cache["k"])
    v = jnp.where(sel[..., None, None], v_new, cache["v"])
    pos = jnp.where(sel, position[:, None], cache["pos"])
    return {"k": k, "v": v, "pos": pos}


def fill_kv_cache(cache: dict, k_seq: jax.Array, v_seq: jax.Array) -> dict:
    """Prefill: write S tokens at positions [0, S).  If S exceeds the cache
    capacity (sliding-window ring buffer), keep the last ``capacity``."""
    S, B, C = k_seq.shape[1], k_seq.shape[0], cache["k"].shape[1]
    if S > C:
        k_seq, v_seq = k_seq[:, -C:], v_seq[:, -C:]
        positions = jnp.arange(S - C, S, dtype=jnp.int32)
        S = C
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    k = lax.dynamic_update_slice_in_dim(cache["k"], k_seq, 0, axis=1)
    v = lax.dynamic_update_slice_in_dim(cache["v"], v_seq, 0, axis=1)
    pos = lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.broadcast_to(positions, (B, S)), 0, axis=1
    )
    return {"k": k, "v": v, "pos": pos}


# -------------------------------------------------------------- full module
def attention_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    sin: jax.Array,
    cos: jax.Array,
    *,
    cache: Optional[dict] = None,
    position: Optional[jax.Array] = None,
    window: Optional[int] = None,
    triangular: bool = False,
    static: bool = False,
    head_spec=None,
) -> tuple[jax.Array, Optional[dict]]:
    """Project + rope + attend.  Returns (output, updated cache or None).

    Training/prefill: cache=None -> chunked causal self-attention.
    Decode: cache given, x is (B, 1, d) and position (B,) absolute index.
    """
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    if cache is None:
        out = chunked_attention(q, k, v, window=window, triangular=triangular,
                                static=static, head_spec=head_spec)
        new_cache = None
    elif S > 1:
        # prefill: full causal self-attention + populate the cache
        out = chunked_attention(q, k, v, window=window, triangular=triangular,
                                static=static, head_spec=head_spec)
        new_cache = fill_kv_cache(cache, k, v)
    else:
        assert position is not None
        new_cache = update_kv_cache(cache, k, v, position)
        out = decode_attention(
            q, new_cache["k"], new_cache["v"], new_cache["pos"], position,
            window=window,
        )
    out = out.reshape(B, S, H * D)
    return out @ params["wo"], new_cache
