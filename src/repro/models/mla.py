"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are projected through low-rank latents; the rotary part is
decoupled (a small per-head rope slice for q, a single shared rope slice
for k).  The decode KV cache stores only the compressed latent
(kv_lora_rank + qk_rope_head_dim per token) — the memory win that makes
MLA serve long contexts cheaply.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .common import ParamDef, apply_rope, rms_norm

__all__ = ["mla_skel", "mla_apply", "init_mla_cache"]


def mla_skel(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        # query path: d -> q_lora -> heads * (nope + rope)
        "wq_a": ParamDef((d, m.q_lora_rank), ("embed", "q_lora"), "scaled"),
        "q_a_norm": ParamDef((m.q_lora_rank,), ("q_lora",), "zeros"),
        "wq_b": ParamDef((m.q_lora_rank, H * qk), ("q_lora", "q_heads"), "scaled"),
        # kv path: d -> (kv_lora + shared k rope)
        "wkv_a": ParamDef(
            (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None), "scaled"
        ),
        "kv_a_norm": ParamDef((m.kv_lora_rank,), (None,), "zeros"),
        "wkv_b": ParamDef(
            (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)),
            (None, "q_heads"),
            "scaled",
        ),
        "wo": ParamDef((H * m.v_head_dim, d), ("q_heads", "embed"), "scaled"),
    }


def init_mla_cache(batch: int, capacity: int, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        "pos": -jnp.ones((batch, capacity), jnp.int32),
    }


def _project_q(params, x, cfg, sin, cos):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rms_norm(x @ params["wq_a"], params["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ params["wq_b"]).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, sin, cos)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _expand_kv(params, ckv, cfg):
    """latent (B,T,r) -> k_nope (B,T,H,dn), v (B,T,H,dv)."""
    m = cfg.mla
    B, T, _ = ckv.shape
    H = cfg.num_heads
    kv = (ckv @ params["wkv_b"]).reshape(B, T, H, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]


@jax.checkpoint
def _attend(q, k, v, mask):
    """q: (B,Sq,H,dk), k: (B,Sk,H,dk), v: (B,Sk,H,dv), mask (Sq,Sk) or (B,1,Sq,Sk).
    Rematerialized per chunk (scores are recomputed in the backward)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask.any(-1, keepdims=True), probs, 0.0)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)


def mla_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    sin: jax.Array,
    cos: jax.Array,
    *,
    cache: Optional[dict] = None,
    position: Optional[jax.Array] = None,
    chunk: int = 512,
    static: bool = False,
    head_spec=None,
    absorbed: bool = True,   # weight-absorbed decode (H5); False = naive
) -> tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = _project_q(params, x, cfg, sin, cos)                   # (B,S,H,dn+dr)
    if head_spec is not None:
        q = lax.with_sharding_constraint(q, head_spec)

    kv_a = x @ params["wkv_a"]                                 # (B,S,r+dr)
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], params["kv_a_norm"], cfg.norm_eps)
    k_rope_shared = apply_rope(
        kv_a[..., m.kv_lora_rank :][:, :, None, :], sin, cos
    )                                                          # (B,S,1,dr)

    if cache is None or S > 1:
        k_nope, v = _expand_kv(params, ckv, cfg)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_shared, (*k_nope.shape[:3], m.qk_rope_head_dim))],
            axis=-1,
        )
        if head_spec is not None:
            k = lax.with_sharding_constraint(k, head_spec)
            v = lax.with_sharding_constraint(v, head_spec)
        # query-chunked causal attention to bound the score buffer
        if S <= chunk:
            pos = jnp.arange(S)
            mask = (pos[None, :] <= pos[:, None])[None, None]
            out = _attend(q, k, v, mask)
        elif static:
            outs = []
            for i in range(-(-S // chunk)):
                q_i = lax.slice_in_dim(q, i * chunk, min((i + 1) * chunk, S), axis=1)
                q_pos = i * chunk + jnp.arange(q_i.shape[1])
                mask = (jnp.arange(S)[None, :] <= q_pos[:, None])[None, None]
                outs.append(_attend(q_i, k, v, mask))
            out = jnp.concatenate(outs, axis=1)
        else:
            n = -(-S // chunk)

            def body(i):
                q_i = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
                q_pos = i * chunk + jnp.arange(chunk)
                mask = (jnp.arange(S)[None, :] <= q_pos[:, None])[None, None]
                return _attend(q_i, k, v, mask)

            out = lax.map(body, jnp.arange(n))
            out = jnp.moveaxis(out, 0, 1).reshape(B, n * chunk, H, m.v_head_dim)[:, :S]
        new_cache = None
        if cache is not None:  # prefill: store latents
            T = min(S, cache["ckv"].shape[1])
            new_cache = {
                "ckv": lax.dynamic_update_slice_in_dim(cache["ckv"], ckv[:, -T:], 0, 1),
                "k_rope": lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope_shared[:, -T:, 0, :], 0, 1
                ),
                "pos": lax.dynamic_update_slice_in_dim(
                    cache["pos"],
                    jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T)), 0, 1
                ),
            }
    else:
        # decode: insert latent (masked write — see attention.update_kv_cache
        # for why scatters are avoided), attend over the latent cache
        assert position is not None
        C = cache["ckv"].shape[1]
        slot = (position % C)[:, None]
        sel = jnp.arange(C)[None, :] == slot              # (B, C)
        new_cache = {
            "ckv": jnp.where(sel[..., None], ckv, cache["ckv"]),
            "k_rope": jnp.where(sel[..., None], k_rope_shared[:, :, 0, :],
                                cache["k_rope"]),
            "pos": jnp.where(sel, position[:, None], cache["pos"]),
        }
        valid = new_cache["pos"] >= 0
        mask_bc = valid & (new_cache["pos"] <= position[:, None])   # (B, C)
        if absorbed:
            # Weight absorption (the DeepSeek-V3 serving identity):
            #   q_nope . (W_k c) == (W_k^T q_nope) . c
            # scores and values run against the *latent* cache directly —
            # O(C·r) per head instead of O(C·r·(dn+dv)) cache re-expansion
            # per step.  EXPERIMENTS.md §Perf H5.
            wkv = params["wkv_b"].reshape(
                m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
            w_k = wkv[..., : m.qk_nope_head_dim]          # (r, H, dn)
            w_v = wkv[..., m.qk_nope_head_dim :]          # (r, H, dv)
            q_nope = q[..., : m.qk_nope_head_dim]         # (B,1,H,dn)
            q_rope = q[..., m.qk_nope_head_dim :]         # (B,1,H,dr)
            q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_k)
            scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
            scores = (
                jnp.einsum("bshr,bcr->bhsc", q_abs.astype(jnp.float32),
                           new_cache["ckv"].astype(jnp.float32))
                + jnp.einsum("bshd,bcd->bhsc", q_rope.astype(jnp.float32),
                             new_cache["k_rope"].astype(jnp.float32))
            ) * scale
            scores = jnp.where(mask_bc[:, None, None, :], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            probs = jnp.where(mask_bc[:, None, None, :].any(-1, keepdims=True),
                              probs, 0.0)
            ctx = jnp.einsum("bhsc,bcr->bshr", probs,
                             new_cache["ckv"].astype(jnp.float32))
            out = jnp.einsum("bshr,rhd->bshd", ctx,
                             w_v.astype(jnp.float32)).astype(x.dtype)
        else:
            k_nope, v = _expand_kv(params, new_cache["ckv"], cfg)  # (B,C,H,*)
            k_rope = jnp.broadcast_to(
                new_cache["k_rope"][:, :, None, :],
                (*k_nope.shape[:3], m.qk_rope_head_dim)
            )
            k = jnp.concatenate([k_nope, k_rope], axis=-1)
            out = _attend(q, k, v, mask_bc[:, None, None, :])

    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ params["wo"], new_cache
