"""Mixture-of-Experts layer with top-k routing and capacity dropping.

Two dispatch implementations share the grouped expert einsum:

  * ``einsum``  — GShard-style one-hot dispatch/combine einsums.  Fully
    dense dataflow (GSPMD-friendly all-to-all), but the dispatch einsums
    add O(T*E*C*d) FLOPs — comparable to the expert compute itself at
    small d_ff.  This is the paper-era baseline recorded in §Perf.
  * ``scatter`` — sort-based dispatch (argsort by expert id, scatter into
    the (E, C, d) buffer, gather back).  Near-zero extra FLOPs; the
    beyond-baseline optimization recorded in §Perf.

Variants: shared experts (DeepSeek: always-on experts added to the routed
output) and a dense-residual FFN in parallel (Arctic).  Router aux loss is
the standard load-balance term  E * sum_e f_e * P_e.

Tokens are processed in blocks via lax.map so the dispatch buffers stay
bounded at long sequence lengths.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig, MoEConfig
from .common import ParamDef
from .ffn import ffn_apply, ffn_skel

__all__ = ["moe_skel", "moe_apply", "MOE_BLOCK"]

MOE_BLOCK = 8192  # tokens per dispatch block


def moe_skel(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    E = m.num_experts
    skel = {
        "router": ParamDef((d, E), ("embed", "expert"), "scaled"),
        "experts": {
            "w_gate": ParamDef((E, d, ff), ("expert", "embed", "expert_ffn"), "scaled"),
            "w_up": ParamDef((E, d, ff), ("expert", "embed", "expert_ffn"), "scaled"),
            "w_down": ParamDef((E, ff, d), ("expert", "expert_ffn", "embed"), "scaled"),
        },
    }
    if m.num_shared_experts:
        skel["shared"] = ffn_skel(d, ff * m.num_shared_experts)
    if m.dense_residual:
        skel["dense"] = ffn_skel(d, cfg.d_ff)
    return skel


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = math.ceil(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _expert_ffn(experts: dict, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, experts["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, experts["w_up"])
    return jnp.einsum("ecf,efd->ecd", gate * up, experts["w_down"])


def _route(params: dict, x: jax.Array, m: MoEConfig):
    """x: (T, d) -> (gates (T,k), ids (T,k), probs (T,E))."""
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = lax.top_k(probs, m.top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)  # renormalize
    return gates, ids, probs


def _aux_loss(ids: jax.Array, probs: jax.Array, m: MoEConfig) -> jax.Array:
    """GShard load-balance loss: E * sum_e f_e * P_e."""
    E = m.num_experts
    f = jnp.mean(
        jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=1), axis=0
    ) / m.top_k
    P = probs.mean(axis=0)
    return E * jnp.sum(f * P)


def _dispatch_einsum(params, x, m: MoEConfig):  # noqa: D401
    """GShard one-hot dispatch: x (T, d) -> (y (T, d), aux)."""
    T = x.shape[0]
    C = _capacity(T, m)
    E = m.num_experts
    gates, ids, probs = _route(params, x, m)
    # position of each (token, k) assignment within its expert
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)            # (T, k, E)
    # fill experts in k-major order so top-1 assignments drop last
    flat = onehot.transpose(1, 0, 2).reshape(T * m.top_k, E)    # (k*T, E)
    pos_flat = jnp.cumsum(flat, axis=0) - flat                  # (k*T, E)
    pos = pos_flat.reshape(m.top_k, T, E).transpose(1, 0, 2)    # (T, k, E)
    pos = (pos * onehot).sum(-1)                                # (T, k)
    keep = pos < C
    # dispatch mask (T, E, C) as product of one-hots
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)  # (T,k,C)
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum(
        "tke,tkc,tk->tec", onehot.astype(jnp.float32), pos_oh.astype(jnp.float32),
        gates * keep,
    ).astype(x.dtype)
    xe = jnp.einsum("tec,td->ecd", disp, x)                     # (E, C, d)
    ye = _expert_ffn(params["experts"], xe)
    y = jnp.einsum("tec,ecd->td", comb, ye)
    return y, _aux_loss(ids, probs, m)


def _dispatch_scatter(params, x, m: MoEConfig):
    """Sort-based dispatch: near-zero non-expert FLOPs."""
    T, d = x.shape
    C = _capacity(T, m)
    E = m.num_experts
    gates, ids, probs = _route(params, x, m)
    ids_flat = ids.reshape(-1)                                  # (T*k,)
    gates_flat = gates.reshape(-1)
    order = jnp.argsort(ids_flat, stable=True)                  # sort by expert
    seg = ids_flat[order]
    tok = order // m.top_k
    counts = jnp.bincount(ids_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    p = jnp.arange(T * m.top_k) - starts[seg]                   # slot in expert
    keep = p < C
    dest = jnp.where(keep, seg * C + p, E * C)                  # drops -> sentinel
    xs = x[tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].add(xs)
    ye = _expert_ffn(params["experts"], buf[:-1].reshape(E, C, d))
    out_rows = ye.reshape(E * C, d)
    gathered = jnp.concatenate([out_rows, jnp.zeros((1, d), x.dtype)])[dest]
    weighted = gathered * (gates_flat * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok].add(weighted)
    return y, _aux_loss(ids, probs, m)


def moe_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    impl: str = "einsum",
    block: int = MOE_BLOCK,
    static: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).

    ``static=True`` unrolls the token-block loop (exact XLA cost analysis;
    lax.map bodies are counted once).
    """
    m = cfg.moe
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    T = flat.shape[0]
    base = _dispatch_einsum if impl == "einsum" else _dispatch_scatter
    # remat per block: one-hot dispatch/combine tensors are recomputed in
    # the backward instead of being stacked across token blocks
    dispatch = jax.checkpoint(base, static_argnums=(2,))

    if T <= block:
        y, aux = dispatch(params, flat, m)
    elif static:
        nb = -(-T // block)
        pad = nb * block - T
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))
        ys, auxs = [], []
        for i in range(nb):
            yb, ab = dispatch(params, flat[i * block:(i + 1) * block], m)
            ys.append(yb)
            auxs.append(ab)
        y = jnp.concatenate(ys)[:T]
        aux = jnp.stack(auxs).mean()
    else:
        nb = -(-T // block)
        pad = nb * block - T
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))

        def body(xb):
            return dispatch(params, xb, m)

        y, aux = lax.map(body, flat.reshape(nb, block, d))
        y = y.reshape(nb * block, d)[:T]
        aux = aux.mean()

    y = y.reshape(B, S, d)
    if m.num_shared_experts:
        y = y + ffn_apply(params["shared"], x)
    if m.dense_residual:
        y = y + ffn_apply(params["dense"], x)
    return y, aux
