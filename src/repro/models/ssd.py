"""Mamba-2 SSD (state-space duality) mixer (arXiv:2405.21060).

Chunked SSD for training/prefill (quadratic within a chunk, linear across
chunks) and a constant-memory recurrent step for decode — this is what
makes the ``long_500k`` shape serveable.  The Pallas kernel
``repro.kernels.ssd_scan`` implements the chunk scan with VMEM tiling.

Layer dataflow (Mamba-2 block):
    in_proj -> [z | x | B | C | dt]
    causal depthwise conv over [x | B | C]
    y = SSD(x * dt, A * dt, B, C) + D * x
    out = out_proj( rmsnorm(y * silu(z)) )
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .common import ParamDef, rms_norm
from .rglru import causal_conv1d, conv1d_step

__all__ = ["ssd_skel", "ssd_apply", "init_ssd_cache", "ssd_chunked", "segsum"]


def ssd_skel(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.num_heads(d)
    conv_ch = din + 2 * s.ngroups * s.d_state
    proj_out = 2 * din + 2 * s.ngroups * s.d_state + nh
    return {
        "in_proj": ParamDef((d, proj_out), ("embed", "ffn"), "scaled"),
        "conv_w": ParamDef((s.conv_width, conv_ch), (None, "ffn"), "scaled", scale=0.1),
        "dt_bias": ParamDef((nh,), (None,), "zeros"),
        "A_log": ParamDef((nh,), (None,), "normal", scale=0.5),
        "D": ParamDef((nh,), (None,), "ones"),
        "norm": ParamDef((din,), ("ffn",), "zeros"),
        "out_proj": ParamDef((din, d), ("ffn", "embed"), "scaled"),
    }


def init_ssd_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    conv_ch = din + 2 * s.ngroups * s.d_state
    return {
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k],
    -inf above the diagonal.  x: (..., L) -> (..., L, L)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P)  — pre-multiplied by dt
    A: jax.Array,       # (B, S, H)     — A * dt  (negative)
    Bm: jax.Array,      # (B, S, G, N)
    Cm: jax.Array,      # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
    head_spec=None,     # P(batch, None, 'model', None) for (B,S,H,P)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N)).

    ngroups=1 assumed (Bm/Cm broadcast over heads).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if head_spec is not None:
        x = lax.with_sharding_constraint(x, head_spec)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc = L // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    Ac = A.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)     # (B,H,c,l)
    Bc = Bm.reshape(Bsz, nc, chunk, -1, N)
    Cc = Cm.reshape(Bsz, nc, chunk, -1, N)
    Ac = Ac.astype(jnp.float32)

    if head_spec is not None:
        # decay tensors carry the head dim in axis 1: (B, H, c, l)
        hs = list(head_spec)
        Ac = lax.with_sharding_constraint(Ac, type(head_spec)(hs[0], hs[2], None, None))
    A_cum = jnp.cumsum(Ac, axis=-1)                              # (B,H,c,l)

    # 1) intra-chunk (diagonal blocks)
    Ldec = jnp.exp(segsum(Ac))                                   # (B,H,c,l,l)
    Y_diag = jnp.einsum(
        "bclgn,bcsgn,bhcls,bcshp->bclhp", Cc, Bc, Ldec.astype(x.dtype), xc
    )

    # 2) chunk states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)              # (B,H,c,l)
    states = jnp.einsum(
        "bclgn,bhcl,bclhp->bchpn", Bc, decay_states.astype(x.dtype), xc
    )

    # 3) inter-chunk recurrence: associative scan over (decay, state) pairs
    # (log-depth; counted exactly by XLA cost analysis, unlike a while loop)
    chunk_decay = jnp.exp(A_cum[..., -1]).astype(x.dtype)        # (B,H,c)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    dec_c = jnp.moveaxis(chunk_decay, -1, 1)[..., None, None]    # (B,c,H,1,1)
    st_c = states                                                # (B,c,H,P,N)
    # fold h0 into the first element: state_0' = dec_0 * h0 + st_0
    st_c = st_c.at[:, 0].add(dec_c[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h_inclusive = lax.associative_scan(
        combine, (jnp.broadcast_to(dec_c, st_c.shape), st_c), axis=1
    )
    h_final = h_inclusive[:, -1]
    # h_prev[c] = state entering chunk c (exclusive): shift right, seed h0
    h_prev = jnp.concatenate([h0[:, None], h_inclusive[:, :-1]], axis=1)

    # 4) inter-chunk output
    state_decay_out = jnp.exp(A_cum)                             # (B,H,c,l)
    Y_off = jnp.einsum(
        "bclgn,bchpn,bhcl->bclhp", Cc, h_prev, state_decay_out.astype(x.dtype)
    )

    y = (Y_diag + Y_off).reshape(Bsz, L, H, P)[:, :S]
    return y, h_final


def ssd_apply(
    params: dict,
    xin: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    head_spec=None,
) -> tuple[jax.Array, Optional[dict]]:
    """Full Mamba-2 block.  xin: (B, S, d)."""
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.num_heads(d)
    N, G, P = s.d_state, s.ngroups, s.head_dim
    B_, S, _ = xin.shape

    zxbcdt = xin @ params["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (nh,) negative

    new_conv = None
    if cache is None or S > 1:
        xbc_conv = jax.nn.silu(causal_conv1d(xbc, params["conv_w"]))
        if cache is not None:
            tail = xbc[:, -(s.conv_width - 1):]
            padn = (s.conv_width - 1) - tail.shape[1]
            if padn > 0:
                tail = jnp.pad(tail, ((0, 0), (padn, 0), (0, 0)))
            new_conv = tail
    else:
        y_t, new_conv = conv1d_step(xbc[:, 0], cache["conv"], params["conv_w"])
        xbc_conv = jax.nn.silu(y_t)[:, None]

    x, Bm, Cm = jnp.split(xbc_conv, [din, din + G * N], axis=-1)
    x = x.reshape(B_, S, nh, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)

    if cache is None or S > 1:
        h0 = cache["ssm"].astype(x.dtype) if cache is not None else None
        y, h_final = ssd_chunked(
            x * dt[..., None].astype(x.dtype), A[None, None] * dt, Bm, Cm,
            s.chunk_size, h0, head_spec=head_spec,
        )
        new_cache = None
        if cache is not None:
            new_cache = {"ssm": h_final.astype(jnp.float32), "conv": new_conv}
    else:
        # decode: h' = h * exp(A dt) + (dt x) B^T ; y = C h
        h = cache["ssm"]                                         # (B,H,P,N)
        dt0 = dt[:, 0]                                           # (B,H)
        decay = jnp.exp(A[None] * dt0)                           # (B,H)
        xdt = (x[:, 0] * dt0[..., None]).astype(jnp.float32)     # (B,H,P)
        Bn = Bm[:, 0, 0].astype(jnp.float32)                     # (B,N) (G=1)
        h = h * decay[..., None, None] + jnp.einsum("bhp,bn->bhpn", xdt, Bn)
        Cn = Cm[:, 0, 0].astype(jnp.float32)
        y = jnp.einsum("bhpn,bn->bhp", h, Cn)[:, None].astype(x.dtype)
        new_cache = {"ssm": h, "conv": new_conv}

    y = y + params["D"].astype(x.dtype)[None, None, :, None] * x
    y = y.reshape(B_, S, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], new_cache
