"""Serving launcher: --arch <id> batched generation, optionally through the
speculative-execution runtime (the paper's D1 bridged to real decode).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 4 --max-new-tokens 32 [--speculate]
"""
from __future__ import annotations

import argparse
import time

from ..configs import REGISTRY, get_config
from ..core.posterior import BetaPosterior
from ..core.taxonomy import DependencyType
from ..serving import EngineConfig, EngineOp, ServingEngine, ThreadedSpeculativeRunner
from ..serving.spec_bridge import toy_tokenize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--speculate", action="store_true",
                    help="serve each request as an upstream->downstream edge "
                         "with D1 speculation (threaded overlap)")
    ap.add_argument("--alpha", type=float, default=0.7)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.num_codebooks > 1:
        print("note: audio arch — serving raw codebook-0 tokens")
    engine = ServingEngine(cfg, cfg=EngineConfig(max_seq=args.max_seq))
    prompts = [f"request number {i} please classify and draft"
               for i in range(args.requests)]

    if not args.speculate:
        t0 = time.perf_counter()
        results = engine.generate_batch(
            [toy_tokenize(p, cfg.vocab_size) for p in prompts],
            args.max_new_tokens)
        dt = time.perf_counter() - t0
        total = sum(r.tokens_generated for r in results)
        print(f"{len(results)} requests, {total} tokens in {dt:.2f}s "
              f"({total/dt:.1f} tok/s)")
        return 0

    drafter = EngineOp("drafter", engine, max_new_tokens=args.max_new_tokens)
    post = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=5)
    engine.generate(toy_tokenize("warmup", cfg.vocab_size), args.max_new_tokens)
    saved = waste = 0.0
    for p in prompts:
        def upstream(p=p):
            time.sleep(0.3)            # remote classifier wait
            return "billing", None

        runner = ThreadedSpeculativeRunner(upstream, drafter)
        dec = runner.decide(post, args.alpha, 0.08, 0.3)
        if dec.value == "SPECULATE":
            res = runner.run_speculative("billing")
            post.update(res.committed)
            saved += res.latency_saved_s
            waste += res.waste_usd
        else:
            runner.run_sequential()
    print(f"speculative serving: latency reclaimed {saved:.2f}s, "
          f"waste ${waste:.5f}, posterior P={post.mean:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
