"""Training launcher: --arch <id> against the synthetic pipeline with
checkpoint/restart.  Full configs need the production mesh; on a CPU host
use --smoke for the reduced config.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses

from ..configs import REGISTRY, get_config
from ..training.data import DataConfig
from ..training.optimizer import OptimizerConfig
from ..training.trainer import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    opt_kind = "adafactor" if cfg.moe is not None else "adamw"
    tcfg = TrainerConfig(
        steps=args.steps,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=f"{args.ckpt_dir}/{cfg.name}",
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        optimizer=OptimizerConfig(kind=opt_kind, total_steps=args.steps),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        global_batch=args.global_batch,
                        num_codebooks=cfg.num_codebooks),
    )
    trainer = Trainer(cfg, tcfg)

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)

    report = trainer.run(resume=not args.no_resume, on_step=on_step)
    print(f"done: step {report.final_step}, resumed_from={report.resumed_from}, "
          f"checkpoints={report.checkpoints}, stragglers={report.straggler_steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
