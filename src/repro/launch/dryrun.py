import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape)
# combination on the production meshes, and extract the roofline terms.
#
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not import this module from library code.
_DOC = """Multi-pod dry-run launcher.

Per cell this produces a JSON artifact with:
  - memory_analysis (per-device argument/output/temp/code bytes) and the
    compile proof, from the FULL config (scan-over-layers, compact HLO);
  - exact FLOPs / bytes / collective-bytes per device.  XLA's cost
    analysis counts a while-loop body ONCE regardless of trip count, so
    scanned models under-report by ~L x.  We therefore lower UNROLLED
    variants at 1-2 layers per scanned group (with every inner chunk loop
    statically unrolled: attention, MoE blocks, chunked CE) — those counts
    are exact — and extrapolate linearly per group:
        metric(L) = intercept + sum_g L_g * body_g
  - the three roofline terms vs TPU v5e constants and the
    MODEL_FLOPS = 6*N(_active)*D ratio.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all          # every cell, both meshes
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import REGISTRY, get_config, shape_for
from ..configs.base import ModelConfig, ShapeConfig
from ..models import build_model
from ..models.transformer import layer_plan
from ..sharding import batch_spec, named_sharding_tree, param_rules
from ..sharding.cache_specs import cache_pspecs
from ..sharding.optstate import opt_state_pspecs
from ..sharding.rules import shard_if_divisible
from ..training.optimizer import OptimizerConfig, make_optimizer
from .mesh import V5E, make_production_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass(frozen=True)
class Knobs:
    """Hillclimb levers, exposed on the CLI."""

    moe_impl: str = "einsum"
    triangular: bool = False
    fsdp: bool = True
    remat: Optional[bool] = None   # None = per-config default
    ce_chunk: int = 512

    def apply(self, cfg: ModelConfig) -> ModelConfig:
        if self.remat is not None:
            cfg = dataclasses.replace(cfg, remat=self.remat)
        return cfg


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        specs: dict[str, Any] = {}
        if cfg.num_codebooks > 1:
            specs["token"] = tok((B, 1, cfg.num_codebooks), jnp.int32)
        else:
            specs["token"] = tok((B, 1), jnp.int32)
        specs["position"] = tok((B,), jnp.int32)
        return specs
    if cfg.num_codebooks > 1:
        specs = {"tokens": tok((B, S, cfg.num_codebooks), jnp.int32)}
    else:
        specs = {"tokens": tok((B, S), jnp.int32)}
    if cfg.vision_tokens:
        specs["vision_embeds"] = tok((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        specs["positions"] = tok((3, B, S), jnp.int32)
    return specs


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict[str, Any]:
    B = shape.global_batch
    out: dict[str, Any] = {}
    for name, spec in input_specs(cfg, shape).items():
        nd = len(spec.shape)
        if name == "positions":                       # (3, B, S)
            bs = batch_spec(mesh, B, extra_dims=1)
            out[name] = NamedSharding(mesh, P(None, *tuple(bs)))
        elif name == "position":                      # (B,)
            out[name] = NamedSharding(mesh, batch_spec(mesh, B, extra_dims=0))
        else:
            out[name] = NamedSharding(mesh, batch_spec(mesh, B, extra_dims=nd - 1))
    return out


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    """MoE giants use Adafactor (see repro.training.optimizer docstring)."""
    kind = "adafactor" if cfg.moe is not None else "adamw"
    return OptimizerConfig(kind=kind)


def _act_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(activation spec, logits spec): batch on data axes, vocab on model."""
    B = shape.global_batch
    bspec = batch_spec(mesh, B, extra_dims=0)
    bdims = tuple(bspec)[0]
    act_spec = P(bdims, None, None)
    head_spec = P(bdims, None, "model", None)
    extra = [cfg.num_codebooks] if cfg.num_codebooks > 1 else []
    nlog = 4 if cfg.num_codebooks > 1 else 3
    logits_spec = shard_if_divisible(
        (B, 1, *extra, cfg.vocab_size),
        P(bdims, *([None] * (nlog - 2)), "model"), mesh,
    )
    return act_spec, head_spec, logits_spec


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------
def build_train_step(cfg, knobs: Knobs, act_spec=None, head_spec=None,
                     logits_spec=None, static: bool = False):
    model = build_model(cfg)
    opt = make_optimizer(optimizer_for(cfg))

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(
                p, batch, moe_impl=knobs.moe_impl, triangular=knobs.triangular,
                static=static, act_spec=act_spec, head_spec=head_spec,
                logits_spec=logits_spec,
                ce_chunk=knobs.ce_chunk, embed_chunk=knobs.ce_chunk,
            )

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return model, opt, train_step


def build_prefill_step(cfg, shape, knobs: Knobs, act_spec=None, head_spec=None,
                       logits_spec=None, static: bool = False):
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch):
        cache = model.init_cache(B, S, dtype=jnp.bfloat16)
        logits, cache = model.prefill(
            params, batch, cache, moe_impl=knobs.moe_impl,
            triangular=knobs.triangular, static=static, act_spec=act_spec,
            head_spec=head_spec, logits_spec=logits_spec,
            embed_chunk=knobs.ce_chunk,
        )
        return jnp.argmax(logits, axis=-1), cache

    return model, prefill_step


def build_serve_step(cfg, knobs: Knobs, act_spec=None, logits_spec=None,
                     static: bool = False):
    model = build_model(cfg)

    def serve_step(params, cache, token, position):
        logits, cache = model.decode_step(
            params, token, cache, position, moe_impl=knobs.moe_impl,
            static=static, act_spec=act_spec, logits_spec=logits_spec,
        )
        return jnp.argmax(logits, axis=-1), cache

    return model, serve_step


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Per-collective byte totals from post-SPMD (per-device) HLO.
    bytes per op = max(result, operand) bytes; async -done halves skipped."""
    per_kind: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue  # async pairs: count the -start only
        kind = next((k for k in _COLLECTIVES if op == k or op.startswith(k + "-")), None)
        if kind is None:
            continue
        result_bytes = _shape_bytes(m.group(1))
        operand_bytes = _shape_bytes(s[m.end():])
        per_kind[kind] += max(result_bytes, operand_bytes)
        counts[kind] += 1
    return {"per_kind_bytes": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


# --------------------------------------------------------------------------
# model-FLOPs accounting
# --------------------------------------------------------------------------
def active_param_count(cfg: ModelConfig) -> int:
    model = build_model(cfg)
    total = model.param_count()
    if cfg.moe is None:
        return total
    m = cfg.moe
    expert_params_each = 3 * cfg.d_model * m.d_ff_expert
    moe_layers = cfg.num_layers - m.first_dense_layers
    routed_total = m.num_experts * expert_params_each * moe_layers
    routed_active = m.top_k * expert_params_each * moe_layers
    return total - routed_total + routed_active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int) -> float:
    """Per-device HBM traffic estimate for a TPU-grade fusion pipeline.

    The CPU XLA pipeline fuses far less than the TPU pipeline, so the
    compiled module's 'bytes accessed' overcounts HBM traffic by an order
    of magnitude.  This analytic model is the classic accounting: weights
    are read once per pass (+optimizer state read/write for training),
    activations cross HBM once per layer boundary, decode reads the KV
    cache once per step.  Reported alongside the HLO number.
    """
    model = build_model(cfg)
    p_bytes = model.param_count() * 2.0                      # bf16
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    act = B * S * d * 2.0                                    # one (B,S,d) bf16
    if shape.kind == "train":
        # fwd read + bwd read + remat re-read; grads write+read; opt state r/w
        opt_mult = 8.0 if cfg.moe is None else 2.0           # adamw f32 m,v vs adafactor
        weights = p_bytes * (3.0 + 2.0) + p_bytes * opt_mult
        acts = act * L * (2.0 + 2.0 + 2.0)                   # fwd w, bwd r, remat r/w
        logits = B * S * cfg.vocab_size * 4.0 * 2.0 / 8.0    # chunked CE r+w, f32 (amortized)
        total = weights + acts + logits
    elif shape.kind == "prefill":
        weights = p_bytes
        acts = act * L * 2.0
        cache = _cache_bytes(cfg, B, S)
        total = weights + acts + cache
    else:
        weights = p_bytes                                    # the decode classic
        cache = _cache_bytes(cfg, B, S) * 2.0                # read + write-back
        acts = act * L * 2.0 / max(1, S)                     # single token
        total = weights + cache + acts
    return total / n_chips


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.ssm is not None:
        s = cfg.ssm
        return B * s.num_heads(cfg.d_model) * s.head_dim * s.d_state * 4.0 * cfg.num_layers
    if cfg.attn_type == "mla":
        m = cfg.mla
        return B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * 2.0 * cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.layer_pattern
        n_attn = sum(1 for i in range(cfg.num_layers) if pat[i % len(pat)] == "attn")
        n_rec = cfg.num_layers - n_attn
        window = min(S, cfg.local_window or S)
        attn = B * window * 2 * cfg.kv_dim * 2.0 * n_attn
        rec = B * (cfg.lru_width or cfg.d_model) * 4.0 * n_rec
        return attn + rec
    cap = min(S, cfg.local_window) if cfg.local_window else S
    return B * cap * 2 * cfg.kv_dim * 2.0 * cfg.num_layers


# --------------------------------------------------------------------------
# lower + compile one configuration
# --------------------------------------------------------------------------
def _compile_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, knobs: Knobs,
                  static: bool = False):
    model = build_model(cfg)
    rules = param_rules(cfg, fsdp=knobs.fsdp)
    abstract = model.abstract()
    pspecs = model.pspecs(rules)
    param_sh = named_sharding_tree(abstract, pspecs, mesh)
    act_spec, head_spec, logits_spec = _act_specs(cfg, shape, mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            model, opt, step = build_train_step(cfg, knobs, act_spec, head_spec, logits_spec, static)
            opt_abstract = jax.eval_shape(opt.init, abstract)
            opt_pspecs = opt_state_pspecs(opt_abstract, pspecs, opt.config.kind)
            opt_pspecs = jax.tree.map(
                lambda a, sp: shard_if_divisible(a.shape, sp, mesh),
                opt_abstract, opt_pspecs,
            )
            opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_pspecs)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, input_shardings(cfg, shape, mesh)),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            args = (abstract, opt_abstract, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            model, step = build_prefill_step(cfg, shape, knobs, act_spec, head_spec, logits_spec, static)
            cache_abstract = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         dtype=jnp.bfloat16)
            )
            cache_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                cache_pspecs(cache_abstract, mesh),
            )
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, input_shardings(cfg, shape, mesh)),
                out_shardings=(None, cache_sh),
            )
            args = (abstract, input_specs(cfg, shape))
        else:
            model, step = build_serve_step(cfg, knobs, act_spec, logits_spec, static)
            cache_abstract = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         dtype=jnp.bfloat16)
            )
            cache_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                cache_pspecs(cache_abstract, mesh),
            )
            specs = input_specs(cfg, shape)
            in_sh = input_shardings(cfg, shape, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, in_sh["token"], in_sh["position"]),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            args = (abstract, cache_abstract, specs["token"], specs["position"])

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, {"lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}


def _metrics_of(compiled) -> dict[str, Any]:
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "_coll_detail": coll,
    }


# --------------------------------------------------------------------------
# layer-count variants for exact extrapolation
# --------------------------------------------------------------------------
def _variants(cfg: ModelConfig):
    """Return (groups=[(name, full_count)], [(variant_cfg, {group: n})]).

    Variant configs are UNROLLED (scan_layers=False) so cost analysis is
    exact; inner chunk loops are static via the step builders.
    """
    base = dataclasses.replace(cfg, scan_layers=False)
    plan = layer_plan(cfg)
    groups = [(g, c) for (g, k, c) in plan
              if k in ("attn_ffn", "attn_moe", "ssd", "pattern")]

    def with_counts(**counts) -> ModelConfig:
        if cfg.family == "hybrid":
            b = counts["blocks"]
            pat = len(cfg.layer_pattern)
            t = cfg.num_layers % pat
            return dataclasses.replace(base, num_layers=b * pat + t)
        if cfg.moe is not None and cfg.moe.first_dense_layers:
            d = counts["dense_layers"]
            m = counts["moe_layers"]
            return dataclasses.replace(
                base, num_layers=d + m,
                moe=dataclasses.replace(cfg.moe, first_dense_layers=d),
            )
        return dataclasses.replace(base, num_layers=counts["layers"])

    if len(groups) == 1:
        g = groups[0][0]
        return groups, [
            (with_counts(**{g: 1}), {g: 1}),
            (with_counts(**{g: 2}), {g: 2}),
        ]
    return groups, [
        (with_counts(dense_layers=1, moe_layers=1), {"dense_layers": 1, "moe_layers": 1}),
        (with_counts(dense_layers=2, moe_layers=1), {"dense_layers": 2, "moe_layers": 1}),
        (with_counts(dense_layers=1, moe_layers=2), {"dense_layers": 1, "moe_layers": 2}),
    ]


def _solve_layer_model(groups, measured, key):
    """Solve metric = intercept + sum_g n_g * body_g from G+1 measurements."""
    if len(groups) == 1:
        g = groups[0][0]
        f1, f2 = measured[0][1][key], measured[1][1][key]
        body = f2 - f1
        return f1 - body, {g: body}
    f11, f21, f12 = (m[1][key] for m in measured)
    bd, bm = f21 - f11, f12 - f11
    return f11 - bd - bm, {"dense_layers": bd, "moe_layers": bm}


def _moe_batch_levels(cfg: ModelConfig, shape: ShapeConfig) -> Optional[list[int]]:
    """MoE archs with many dispatch blocks: compiling the unrolled variants
    at full batch takes minutes per compile (O(100) static blocks).  Per-
    layer cost is LINEAR in batch once T > MOE_BLOCK (block capacity is
    fixed per block), so measure at two small batches and extrapolate."""
    from ..models.moe import MOE_BLOCK

    if cfg.moe is None or shape.kind == "decode":
        return None
    tokens = shape.global_batch * shape.seq_len
    if tokens <= 2 * MOE_BLOCK:
        return None
    levels = [b for b in (16, 32) if b <= shape.global_batch]
    return levels if len(levels) == 2 else None


def extrapolated_metrics(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         knobs: Knobs) -> dict[str, Any]:
    """Exact per-device metrics at full depth via unrolled small-L compiles.

    metric = intercept + sum_g L_g * body_g, with every coefficient
    additionally linear in global batch for big-MoE train/prefill cells
    (see _moe_batch_levels).
    """
    groups, variants = _variants(cfg)
    full = {g: c for g, c in groups}
    b_levels = _moe_batch_levels(cfg, shape)
    out: dict[str, Any] = {}

    if b_levels is None:
        measured, timing = [], []
        for vcfg, counts in variants:
            compiled, t = _compile_cell(vcfg, shape, mesh, knobs, static=True)
            measured.append((counts, _metrics_of(compiled)))
            timing.append(t)
        out["variant_timing"] = timing
        for key in ("flops", "bytes", "coll_bytes"):
            intercept, bodies = _solve_layer_model(groups, measured, key)
            total = intercept + sum(full[g] * b for g, b in bodies.items())
            out[key] = {
                "total_per_device": max(0.0, total),
                "intercept": intercept,
                "per_group_body": bodies,
            }
        out["coll_detail_smallest"] = dict(measured[0][1]["_coll_detail"])
        return out

    # two batch levels x (G+1) layer variants; every coefficient linear in B
    per_level: dict[int, list] = {}
    timing = []
    for b in b_levels:
        vshape = dataclasses.replace(shape, global_batch=b)
        measured = []
        for vcfg, counts in variants:
            compiled, t = _compile_cell(vcfg, vshape, mesh, knobs, static=True)
            measured.append((counts, _metrics_of(compiled)))
            timing.append(t)
        per_level[b] = measured
    out["variant_timing"] = timing
    out["batch_levels"] = b_levels
    b1, b2 = b_levels
    B_full = shape.global_batch
    for key in ("flops", "bytes", "coll_bytes"):
        i1, bod1 = _solve_layer_model(groups, per_level[b1], key)
        i2, bod2 = _solve_layer_model(groups, per_level[b2], key)

        def lin(v1, v2):  # linear in B through (b1, v1), (b2, v2)
            slope = (v2 - v1) / (b2 - b1)
            return v1 + slope * (B_full - b1)

        intercept = lin(i1, i2)
        bodies = {g: lin(bod1[g], bod2[g]) for g in bod1}
        total = intercept + sum(full[g] * bodies[g] for g in bodies)
        out[key] = {
            "total_per_device": max(0.0, total),
            "intercept": intercept,
            "per_group_body": bodies,
        }
    out["coll_detail_smallest"] = dict(per_level[b1][0][1]["_coll_detail"])
    return out


# --------------------------------------------------------------------------
# the dry run for one cell
# --------------------------------------------------------------------------
def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    knobs: Knobs = Knobs(),
    roofline: bool = True,
    save: bool = True,
    tag: str = "",
) -> dict:
    cfg = knobs.apply(get_config(arch))
    shape = shape_for(shape_name)
    suffix = ("_multipod" if multi_pod else "") + (f"_{tag}" if tag else "")
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        result = {
            "arch": arch, "shape": shape_name, "skipped": True,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "reason": "pure full-attention arch; long_500k requires a "
                      "sub-quadratic mixer (DESIGN.md §5)",
        }
        if save:
            ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
            (ARTIFACT_DIR / f"{arch}_{shape_name}{suffix}.json").write_text(
                json.dumps(result, indent=1))
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)

    compiled, timing = _compile_cell(cfg, shape, mesh, knobs)
    mem = compiled.memory_analysis()
    full_coll = collective_bytes(compiled.as_text())

    result: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "skipped": False,
        "knobs": {
            "moe_impl": knobs.moe_impl, "triangular": knobs.triangular,
            "fsdp": knobs.fsdp, "remat": cfg.remat,
            "optimizer": optimizer_for(cfg).kind, "ce_chunk": knobs.ce_chunk,
        },
        "timing": timing,
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
        # collective op schedule of the full (scanned) program; per-op bytes
        # here count scan bodies once — exact totals come from extrapolation
        "collective_schedule": full_coll["counts"],
    }
    ma = result["memory_analysis"]
    peak = ma["argument_bytes"] + ma["temp_bytes"] + ma["output_bytes"] - ma["alias_bytes"]
    ma["peak_estimate_bytes"] = int(peak)
    ma["fits_16gb"] = bool(peak <= V5E.hbm_bytes)

    if roofline and not multi_pod:
        ex = extrapolated_metrics(cfg, shape, mesh, knobs)
        flops_dev = ex["flops"]["total_per_device"]
        bytes_dev = ex["bytes"]["total_per_device"]
        coll_dev = ex["coll_bytes"]["total_per_device"]
        mf = model_flops(cfg, shape)
        bytes_analytic = analytic_hbm_bytes(cfg, shape, n_chips)
        # memory term: the CPU XLA pipeline's 'bytes accessed' lacks TPU
        # fusion and inflates HBM traffic by 1-3 orders of magnitude, so the
        # bottleneck analysis uses the analytic TPU traffic model; the HLO
        # number is recorded alongside (EXPERIMENTS.md §Roofline caveat).
        terms = {
            "compute_s": flops_dev / V5E.peak_bf16_flops,
            "memory_s": bytes_analytic / V5E.hbm_bandwidth,
            "collective_s": coll_dev / V5E.ici_link_bandwidth,
        }
        result["extrapolation"] = {
            k: v for k, v in ex.items() if k != "coll_detail_smallest"
        }
        result["collectives_smallest_variant"] = ex["coll_detail_smallest"]
        result["roofline"] = {
            **terms,
            "memory_s_hlo_cpu": bytes_dev / V5E.hbm_bandwidth,
            "hbm_bytes_analytic_per_device": bytes_analytic,
            "dominant": max(terms, key=terms.get),
            "bound_s": max(terms.values()),
            "model_flops_global": mf,
            "hlo_flops_global": flops_dev * n_chips,
            "useful_flops_ratio": mf / max(1.0, flops_dev * n_chips),
            "hardware": V5E.name,
        }

    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        out = ARTIFACT_DIR / f"{arch}_{shape_name}{suffix}.json"
        out.write_text(json.dumps(result, indent=1))
        result["artifact"] = str(out)
    return result


# --------------------------------------------------------------------------
def all_cells() -> list[tuple[str, str]]:
    return [
        (arch, s)
        for arch in REGISTRY
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", choices=sorted(REGISTRY))
    ap.add_argument("--shape", choices=["train_4k", "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="einsum", choices=["einsum", "scatter"])
    ap.add_argument("--triangular", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    knobs = Knobs(
        moe_impl=args.moe_impl,
        triangular=args.triangular,
        fsdp=not args.no_fsdp,
        remat=False if args.no_remat else None,
    )
    kw = dict(knobs=knobs, roofline=not args.no_roofline, tag=args.tag)
    if args.all:
        ok = True
        for arch, shape_name in all_cells():
            for mp in (False, True):
                t0 = time.time()
                try:
                    r = run_cell(arch, shape_name, multi_pod=mp, **kw)
                    status = "SKIP" if r.get("skipped") else "OK"
                    extra = ""
                    if "roofline" in r:
                        extra = (f" dom={r['roofline']['dominant']}"
                                 f" bound={r['roofline']['bound_s']:.3f}s")
                    print(f"[{status}] {arch} x {shape_name} "
                          f"({'2x16x16' if mp else '16x16'}) "
                          f"{time.time()-t0:.0f}s{extra}", flush=True)
                except Exception as e:  # noqa: BLE001
                    ok = False
                    print(f"[FAIL] {arch} x {shape_name} "
                          f"({'2x16x16' if mp else '16x16'}): {e}", flush=True)
        return 0 if ok else 1

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    r = run_cell(args.arch, args.shape, multi_pod=args.multi_pod, **kw)
    print(json.dumps(r, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
