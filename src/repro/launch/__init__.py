"""repro.launch — production meshes, dry-run, train/serve drivers."""
