"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The dry-run process
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "make_fleet_mesh",
    "HardwareSpec",
    "V5E",
]

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target chip (TPU v5e)."""

    name: str
    peak_bf16_flops: float      # per chip, FLOP/s
    hbm_bandwidth: float        # bytes/s
    ici_link_bandwidth: float   # bytes/s per link
    hbm_bytes: float            # per-chip capacity


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or forced) host devices exist —
    used by multi-device CPU tests, not the dry-run."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n: int | None = None, *, axis: str = "fleet"):
    """1-D mesh for the fleet replay engines.  Two batch axes ride it:
    the ``tenants x grid`` axis of
    ``repro.core.fleet.multi_tenant_replay`` and the episode-segment
    axis of ``repro.core.fleet.episode_sharded_replay`` (one tenant's
    million-episode log as C independent scan segments) — both
    shard_map'd via ``sharding.rules.fleet_axis_spec``.  Defaults to
    every visible (real or XLA_FLAGS-forced) device."""
    n = len(jax.devices()) if n is None else n
    return jax.make_mesh((n,), (axis,))
