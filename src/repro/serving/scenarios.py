"""Seeded scenario fleet: archetypes × adversarial drift traces, end to end.

The §13 archetype catalog says *where* the method fits; §12.5 says what
must happen when an edge stops fitting.  This module turns both into
executable scenarios: every production archetype from
``repro.core.archetypes`` plus the adversarial drift shapes from the
issue (sudden flips, slow ramps, oscillation at the drift-detector
frequency, heavy-tailed token counts, correlated cross-tenant drift),
each driven through the *full* serving stack —
``ServingFrontend`` → ``FaultyService`` → ``RolloutController`` →
``OnlineDecisionService`` — with per-row ``FaultInjector`` outcome
streams built from ``DriftTrace`` values.

Everything is seeded and replayed on the deadline batcher's manual-pump
path, so a scenario is a pure function of ``(Scenario, seed)``: the
same transitions at the same ticks, the same USD attribution, every
run.  ``benchmarks/rollout_fleet.py`` asserts exactly that before it
publishes the per-archetype Pareto table.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.archetypes import ARCHETYPES
from repro.core.rollout import RolloutConfig, RolloutController
from repro.core.telemetry import ResilienceLog
from repro.serving.faults import (DriftTrace, FaultInjector, FaultPlan,
                                  FaultyService, correlated_flip_traces,
                                  heavy_tail_tokens)

__all__ = ["Scenario", "ScenarioResult", "archetype_scenarios",
           "adversarial_scenarios", "all_scenarios", "run_scenario"]

LAMBDA_USD_PER_S = 0.9
PRICE_IN, PRICE_OUT = 3e-6, 15e-6
TICK_DT_S = 0.05                    # virtual seconds per scenario tick
BREAKER_COOLDOWN_S = 0.2            # 4 virtual ticks of OPEN per trip


class _Clock:
    """Injected monotonic time: the breaker's OPEN window elapses in
    virtual ticks, not wall time — runs are deterministic and the
    drift-trip → breaker → probe → recovery loop closes within a
    scenario's tick budget."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One deterministic end-to-end run: a registry shape, a success-rate
    trace per row, a request mix, and a rollout policy."""

    name: str
    traces: tuple[DriftTrace, ...]      # one per row, row-major
    n_tenants: int = 1
    edges_per_tenant: int = 1
    ticks: int = 120
    seed: int = 0
    archetype: Optional[str] = None     # ARCHETYPES key, if derived
    prior_mean: float = 0.9             # seeds the Beta prior
    prior_strength: float = 18.0        # alpha + beta
    discount: float = 0.9
    latency_s: float = 3.0
    input_tokens: float = 500.0
    output_tokens: float = 800.0
    heavy_tail: bool = False            # Lomax output tokens per request
    consecutive_n: int = 3              # in-graph trigger-2 N
    rollout: RolloutConfig = dataclasses.field(
        # staged promotion bar (CANARY < ONLINE_CAL < FULL) so archetypes
        # separate along their p_mode instead of all clearing one rate
        default_factory=lambda: RolloutConfig(
            cooldown_ticks=6, probe_budget=4, canary_period=2,
            min_obs=(4, 4, 4), promote_rate=(0.5, 0.55, 0.6)))

    @property
    def n_rows(self) -> int:
        return self.n_tenants * self.edges_per_tenant

    def __post_init__(self) -> None:
        if len(self.traces) != self.n_rows:
            raise ValueError(
                f"{self.name}: {len(self.traces)} traces for "
                f"{self.n_rows} rows")


@dataclasses.dataclass
class ScenarioResult:
    """What one scenario run produced — everything the Pareto table,
    the timelines and the determinism gate read."""

    name: str
    transitions: list            # RolloutController.transitions dicts
    events: dict                 # ResilienceLog.by_kind()
    usd_attribution: dict        # {"tenant|kind": usd}
    final_phases: list[str]      # per row
    speculate_rate: float        # served SPECULATE share of requests
    success_rate: float          # settled outcome success share
    demote_ticks: list[int]      # ticks of rollout_demote transitions
    promote_ticks: list[int]
    requests: int

    def phase_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for p in self.final_phases:
            out[p] = out.get(p, 0) + 1
        return out

    def signature(self) -> list[tuple]:
        """Order-stable transition fingerprint for determinism checks."""
        return [(t["tick"], t["row"], t["kind"], t["old"], t["new"],
                 round(t["usd"], 9)) for t in self.transitions]


# --------------------------------------------------------------------------
# scenario catalogs
# --------------------------------------------------------------------------
def archetype_scenarios(seed: int = 0, ticks: int = 90) -> list[Scenario]:
    """One scenario per production archetype: the success stream runs at
    the archetype's dominant-mode probability (speculating the modal
    branch succeeds exactly when the mode was right), priors are seeded
    from the same ``p_mode``, and token sizes come from the §13 profile.
    High-``p_mode`` archetypes should climb to FULL; flat-branching ones
    should stall in SHADOW or demote — that separation *is* the Pareto
    table."""
    out = []
    for i, (name, arch) in enumerate(sorted(ARCHETYPES.items())):
        prof = arch.profile()
        rate = min(0.98, prof.p_mode)
        out.append(Scenario(
            name=f"archetype:{name}",
            archetype=name,
            traces=(DriftTrace.constant(rate),),
            ticks=ticks,
            seed=seed + i,
            prior_mean=max(0.2, rate),
            output_tokens=float(prof.output_tokens_est),
            input_tokens=float(prof.input_tokens_est),
        ))
    return out


def adversarial_scenarios(seed: int = 0) -> list[Scenario]:
    """The §12.5 adversarial drift shapes from the issue, each as a full
    frontend→rollout run."""
    base = dict(prior_mean=0.9, ticks=140, consecutive_n=3)
    out = [
        # sudden flip at a known tick, reverting later: the acceptance
        # trace — demote within the trigger window, re-promote through
        # cooldown + probes after the revert
        Scenario(name="adversarial:sudden_flip",
                 traces=(DriftTrace.flip(25, rate1=0.02, revert_at=60),),
                 seed=seed, **base),
        # slow ramp: the shape a sudden-flip detector is worst at; the
        # credible floor still catches it, just later
        Scenario(name="adversarial:slow_ramp",
                 traces=(DriftTrace.ramp(20, 80, rate1=0.05),),
                 seed=seed + 1, **base),
        # oscillation with half-period == the detector's consecutive-N:
        # tuned to straddle the trigger frequency
        Scenario(name="adversarial:oscillation",
                 traces=(DriftTrace.oscillation(3, rate1=0.05),),
                 seed=seed + 2, **base),
        # heavy-tailed output tokens: C_spec's tail misprices a
        # mean-calibrated threshold; lifecycle must stay stable anyway
        Scenario(name="adversarial:heavy_tail_tokens",
                 traces=(DriftTrace.constant(0.9),),
                 heavy_tail=True, seed=seed + 3, **base),
    ]
    # correlated cross-tenant drift: one upstream regression hits every
    # tenant's copy of the same edge at nearly the same tick
    n_tenants = 3
    traces = correlated_flip_traces(n_tenants, 25, seed=seed + 4, jitter=3,
                                    rate1=0.02, revert_at=70)
    out.append(Scenario(
        name="adversarial:correlated_cross_tenant",
        traces=tuple(traces), n_tenants=n_tenants, seed=seed + 4, **base))
    return out


def all_scenarios(seed: int = 0) -> list[Scenario]:
    return archetype_scenarios(seed) + adversarial_scenarios(seed + 100)


# --------------------------------------------------------------------------
# the runner
# --------------------------------------------------------------------------
def _build_stack(sc: Scenario, resilience: ResilienceLog):
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior
    from repro.serving.frontend import FrontendConfig, ServingFrontend

    svc = OnlineDecisionService(credible_consecutive_n=sc.consecutive_n)
    a = sc.prior_mean * sc.prior_strength
    b = sc.prior_strength - a
    for t in range(sc.n_tenants):
        for e in range(sc.edges_per_tenant):
            svc.register_edge(
                (f"agent{e}", f"agent{e + 1}"), tenant=f"tenant{t}",
                posterior=BetaPosterior(alpha=max(a, 0.5), beta=max(b, 0.5)),
                discount=sc.discount,
                floor_alpha=0.3, floor_C_spec_usd=1.0,
                floor_L_value_usd=1.0,        # floor = 0.7 / 2 = 0.35
            )
    ctl = RolloutController(svc, sc.rollout, resilience=resilience)
    # the call-boundary injector is benign here (faults.py's matrix covers
    # raise/hang); wrapping keeps the chain the production one
    faulty = FaultyService(ctl, FaultInjector(FaultPlan(seed=sc.seed)))
    clock = _Clock()
    fe = ServingFrontend(
        faulty,
        FrontendConfig(max_batch=max(2, sc.n_rows), bulkhead_limit=4096,
                       check_drift=True,
                       breaker_cooldown_s=BREAKER_COOLDOWN_S),
        resilience_log=resilience, clock=clock, autostart=False)
    return svc, ctl, fe, clock


def run_scenario(sc: Scenario,
                 resilience: Optional[ResilienceLog] = None,
                 ) -> ScenarioResult:
    """Drive one scenario deterministically: each tick submits one
    request per row through the frontend batcher, pumps exactly one
    tick, and settles *every* ticket (WAIT tickets too — SHADOW rows
    learn from settlements without serving) against the row's seeded
    drift-trace outcome stream."""
    from repro.serving.frontend import DecisionRequest

    log = resilience if resilience is not None else ResilienceLog()
    svc, ctl, fe, clock = _build_stack(sc, log)
    outcome = [FaultInjector(FaultPlan(trace=tr, seed=sc.seed + 17 * r))
               for r, tr in enumerate(sc.traces)]
    if sc.heavy_tail:
        otok = heavy_tail_tokens(sc.seed + 5, sc.ticks * sc.n_rows)
    n_spec = n_req = n_ok = n_settled = 0
    for tick in range(sc.ticks):
        clock.advance(TICK_DT_S)
        tickets = []
        for r in range(sc.n_rows):
            tenant, edge = svc.row_key(r)
            tok = (float(otok[tick * sc.n_rows + r]) if sc.heavy_tail
                   else sc.output_tokens)
            tickets.append(fe.submit(DecisionRequest(
                row=r, tenant=tenant, edge=edge, alpha=0.5,
                lambda_usd_per_s=LAMBDA_USD_PER_S, latency_s=sc.latency_s,
                input_tokens=sc.input_tokens, output_tokens=tok,
                input_price=PRICE_IN, output_price=PRICE_OUT)))
        fe.pump()
        for r, tk in enumerate(tickets):
            res = tk.result(0)
            n_req += 1
            if res.source == "service" and res.speculate:
                n_spec += 1
            ok = outcome[r].outcome()
            n_ok += int(ok)
            n_settled += 1
            tk.settle(ok)
    phases = ctl.phases()
    return ScenarioResult(
        name=sc.name,
        transitions=list(ctl.transitions),
        events=log.by_kind(),
        usd_attribution={f"{t}|{k}": round(v, 6)
                         for (t, k), v in log.usd_attribution().items()},
        final_phases=phases,
        speculate_rate=n_spec / max(1, n_req),
        success_rate=n_ok / max(1, n_settled),
        demote_ticks=[t["tick"] for t in ctl.transitions
                      if t["kind"] == "rollout_demote"],
        promote_ticks=[t["tick"] for t in ctl.transitions
                       if t["kind"] == "rollout_promote"],
        requests=n_req,
    )
