"""Bridge between the paper's speculative runtime and the serving engine.

``EngineOp`` makes a real model call (via ServingEngine) a workflow vertex:
the op's ``run`` prefixes the (tokenized) input and generates; streaming
chunks are real decode chunks; cancellation is real (the engine stops
between chunks).  ``ThreadedSpeculativeRunner`` executes a two-op edge
with genuine wall-clock overlap: the speculative downstream runs in a
thread while the upstream generates — the latency reclaimed is measured,
not simulated (examples/speculative_serving.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from ..core.decision import Decision, DecisionInputs, DecisionResult, evaluate
from ..core.posterior import BetaPosterior
from ..core.pricing import TwoRateTokenCost, get_pricing
from ..core.streaming import fractional_waste
from ..core.success import TierPolicy, check_success
from ..core.taxonomy import DependencyType
from ..core.workflow import Operation
from .engine import GenerationResult, ServingEngine

__all__ = [
    "EngineOp",
    "SpeculationTimeout",
    "SpeculativeEdgeResult",
    "ThreadedSpeculativeRunner",
    "call_with_timeout",
    "retry_with_backoff",
    "toy_tokenize",
]


class SpeculationTimeout(TimeoutError):
    """A provider/engine call exceeded its deadline.  For a *speculative*
    call this settles as a failed speculation (feeds the breaker); for
    the sequential path it propagates."""


def call_with_timeout(fn: Callable[[], Any], timeout_s: float) -> Any:
    """Run ``fn`` with a wall-clock deadline.

    The call runs in a daemon worker; on timeout ``SpeculationTimeout``
    is raised and the worker is *abandoned* (a hung provider call cannot
    be interrupted from Python — the caller must treat the tokens as
    billed, which is exactly how the runner settles it).  Exceptions from
    ``fn`` propagate."""
    box: dict[str, Any] = {}
    done = threading.Event()

    def runner() -> None:
        try:
            box["out"] = fn()
        except BaseException as exc:  # noqa: BLE001 — propagated below
            box["err"] = exc
        finally:
            done.set()

    th = threading.Thread(target=runner, daemon=True)
    th.start()
    if not done.wait(timeout_s):
        raise SpeculationTimeout(f"call exceeded {timeout_s}s")
    if "err" in box:
        raise box["err"]
    return box["out"]


def retry_with_backoff(
    fn: Callable[[], Any],
    *,
    retries: int,
    backoff_s: float = 0.05,
    retry_on: tuple[type, ...] = (Exception,),
    sleep: Callable[[float], None] = time.sleep,
) -> Any:
    """Bounded retry with exponential backoff: up to ``retries`` extra
    attempts, sleeping ``backoff_s * 2**k`` between them.  The final
    attempt's exception propagates unmodified."""
    if retries < 0:
        raise ValueError("retries must be >= 0")
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on:
            if attempt == retries:
                raise
            sleep(backoff_s * (2.0 ** attempt))


def toy_tokenize(text: str, vocab: int, length: int = 32) -> list[int]:
    """Deterministic toy tokenizer (hash per word) — the modality frontend
    of the serving examples; real deployments plug a real tokenizer."""
    import zlib

    toks = [3 + (zlib.crc32(w.encode()) % (vocab - 3))
            for w in str(text).split()][:length]
    return toks or [3]


@dataclasses.dataclass
class EngineOp:
    """A workflow Operation backed by a real serving engine.

    ``timeout_s`` bounds each engine call (a hung provider no longer
    blocks the runner forever — it raises :class:`SpeculationTimeout`);
    ``max_retries``/``backoff_s`` retry transient failures with
    exponential backoff before giving up.  Both default off, preserving
    the historical direct-call path."""

    name: str
    engine: ServingEngine
    max_new_tokens: int = 32
    provider: str = "paper"
    model: str = "frontier-default"
    postprocess: Callable[[list[int]], Any] = lambda toks: toks
    timeout_s: Optional[float] = None
    max_retries: int = 0
    backoff_s: float = 0.05

    def operation(self, latency_est_s: float = 1.0) -> Operation:
        return Operation(
            name=self.name,
            run=self.run,
            provider=self.provider,
            model=self.model,
            input_tokens_est=32,
            output_tokens_est=self.max_new_tokens,
            latency_est_s=latency_est_s,
        )

    def run(self, upstream_output: Any,
            cancel_event: Optional[threading.Event] = None) -> Any:
        def attempt() -> Any:
            prompt = toy_tokenize(
                upstream_output, self.engine.model_cfg.vocab_size)
            result = self.engine.generate(
                prompt, self.max_new_tokens, cancel_event=cancel_event)
            return self.postprocess(result.tokens), result

        call = attempt
        if self.timeout_s is not None:
            call = lambda: call_with_timeout(attempt, self.timeout_s)  # noqa: E731
        if self.max_retries > 0:
            return retry_with_backoff(
                call, retries=self.max_retries, backoff_s=self.backoff_s)
        return call()


@dataclasses.dataclass
class SpeculativeEdgeResult:
    committed: bool
    cancelled: bool
    wall_time_s: float
    sequential_wall_time_s: float
    latency_saved_s: float
    waste_usd: float
    upstream_output: Any
    downstream_output: Any
    i_hat: Any
    timed_out: bool = False        # speculative call hit its deadline


class ThreadedSpeculativeRunner:
    """Execute one (upstream, downstream) edge with REAL overlap.

    The downstream launches in a worker thread against the predicted input
    i_hat while the upstream generates on the main thread.  On upstream
    completion the tier check decides commit / cancel+re-execute, exactly
    the D1 mechanics, with wall-clock (not simulated) latency.

    With ``service=`` (an ``repro.core.online.OnlineDecisionService``) the
    D4 gate routes through the jit'd batched decision service instead of
    the scalar ``decision.evaluate``: the runner registers (or reuses) a
    ``(tenant, edge)`` row, ``decide`` syncs the caller's posterior into
    the device table and answers via a B=1 tick.  The scalar path is kept
    (``service=None``, the default) and the two are pinned bitwise-f64
    equal — decision flag, EV, threshold and margin — by the parity
    regression in tests/test_online_service.py (EV under
    ``use_lower_bound=True`` carries the established betaincinv-vs-scipy
    quantile allowance).
    """

    def __init__(
        self,
        upstream: Callable[[], tuple[Any, GenerationResult]],
        downstream: EngineOp,
        tier_policy: TierPolicy | None = None,
        *,
        service=None,
        edge: tuple[str, str] | None = None,
        tenant: str | None = None,
        gamma: float = 0.1,
    ) -> None:
        self.upstream = upstream
        self.downstream = downstream
        self.tier_policy = tier_policy or TierPolicy()
        self.service = service
        self.tenant = tenant
        self.gamma = gamma
        self.edge = tuple(edge) if edge is not None else ("upstream", downstream.name)
        self.service_row: Optional[int] = None
        if service is not None:
            try:
                self.service_row = service.row_index(self.edge, tenant)
                row_gamma = service.row_gamma(self.service_row)
                if row_gamma != gamma:
                    # the §7.5 path gates on the ROW's gamma — a silently
                    # different runner gamma would break the scalar-route
                    # parity this bridge pins
                    raise ValueError(
                        f"edge {self.edge!r} (tenant={tenant!r}) is "
                        f"registered with gamma={row_gamma}, runner asked "
                        f"for gamma={gamma}")
            except KeyError:
                # neutral prior: decide() always syncs the caller-held
                # posterior before gating, so the registration prior never
                # reaches a decision
                self.service_row = service.register_edge(
                    self.edge, tenant=tenant,
                    dep_type=DependencyType.CONDITIONAL_OUTPUT, gamma=gamma)

    def run_speculative(self, i_hat: Any) -> SpeculativeEdgeResult:
        cancel = threading.Event()
        result_box: dict[str, Any] = {}

        def worker():
            # a worker exception must surface to the caller, not die in
            # the thread and resurface as KeyError("out") at join time
            try:
                result_box["out"] = self.downstream.run(
                    i_hat, cancel_event=cancel)
            except BaseException as exc:  # noqa: BLE001 — re-raised below
                result_box["err"] = exc

        t0 = time.perf_counter()
        th = threading.Thread(target=worker)
        th.start()
        try:
            upstream_out, up_res = self.upstream()
        except BaseException:
            # the sequential path failed: without this, the speculative
            # thread keeps generating (tokens keep billing) with nobody
            # left to cancel or join it
            cancel.set()
            th.join()
            raise
        t_up = time.perf_counter() - t0

        cm = TwoRateTokenCost.from_entry(
            get_pricing(self.downstream.provider, self.downstream.model))
        check = check_success(upstream_out, i_hat, self.tier_policy)
        if check.success:
            th.join()
            err = result_box.get("err")
            if err is None:
                out, gen = result_box["out"]
                wall = time.perf_counter() - t0
                seq = t_up + gen.wall_time_s
                return SpeculativeEdgeResult(
                    committed=True, cancelled=False, wall_time_s=wall,
                    sequential_wall_time_s=seq,
                    latency_saved_s=max(0.0, seq - wall), waste_usd=0.0,
                    upstream_output=upstream_out, downstream_output=out,
                    i_hat=i_hat,
                )
            if not isinstance(err, SpeculationTimeout):
                raise err
            # timed-out speculation: settle as a *failed* speculation
            # (feeds the breaker via observe) and fall through to the
            # sequential re-execution below.  The hung call's tokens are
            # unknowable from here — bill the full planned output, the
            # conservative §9.3 stance.
            self.observe(False)
            out, gen = self.downstream.run(upstream_out)
            wall = time.perf_counter() - t0
            return SpeculativeEdgeResult(
                committed=False, cancelled=True, wall_time_s=wall,
                sequential_wall_time_s=t_up + gen.wall_time_s,
                latency_saved_s=0.0,
                waste_usd=fractional_waste(
                    cm, 32, self.downstream.max_new_tokens,
                    self.downstream.max_new_tokens),
                upstream_output=upstream_out, downstream_output=out,
                i_hat=i_hat, timed_out=True,
            )
        # tier failure: cancel mid-stream and re-execute with the real input
        cancel.set()
        th.join()
        err = result_box.get("err")
        timed_out = isinstance(err, SpeculationTimeout)
        if err is not None and not timed_out:
            raise err
        if timed_out:
            # no generation record survived the deadline — bill the plan
            cancelled, waste = True, fractional_waste(
                cm, 32, self.downstream.max_new_tokens,
                self.downstream.max_new_tokens)
        else:
            _, spec_gen = result_box["out"]
            cancelled = spec_gen.cancelled
            waste = fractional_waste(
                cm, 32, self.downstream.max_new_tokens,
                spec_gen.tokens_generated)
        out, gen = self.downstream.run(upstream_out)
        wall = time.perf_counter() - t0
        seq = t_up + gen.wall_time_s
        return SpeculativeEdgeResult(
            committed=False, cancelled=cancelled, wall_time_s=wall,
            sequential_wall_time_s=seq, latency_saved_s=0.0,
            waste_usd=waste, upstream_output=upstream_out,
            downstream_output=out, i_hat=i_hat, timed_out=timed_out,
        )

    def observe(self, success: bool) -> None:
        """Report a settled edge outcome to the attached decision service
        (queued host-side; the service applies it on its next tick)."""
        if self.service is not None and self.service_row is not None:
            self.service.observe(self.service_row, success)

    def run_sequential(self) -> SpeculativeEdgeResult:
        t0 = time.perf_counter()
        upstream_out, _ = self.upstream()
        out, gen = self.downstream.run(upstream_out)
        wall = time.perf_counter() - t0
        return SpeculativeEdgeResult(
            committed=False, cancelled=False, wall_time_s=wall,
            sequential_wall_time_s=wall, latency_saved_s=0.0, waste_usd=0.0,
            upstream_output=upstream_out, downstream_output=out, i_hat=None,
        )

    def decide_full(self, posterior: BetaPosterior, alpha: float,
                    lambda_usd_per_s: float, latency_savings_s: float,
                    *, use_lower_bound: bool = False) -> DecisionResult:
        """The D4 gate with the full result row (EV / threshold / margin in
        USD).  Routed through the attached online decision service when one
        was given at construction; the scalar ``decision.evaluate`` path
        otherwise.  ``use_lower_bound`` gates on the §7.5 one-sided
        (1-gamma) lower credible bound instead of the posterior mean."""
        pricing = get_pricing(self.downstream.provider, self.downstream.model)
        if self.service is not None:
            return self.service.decide(
                row=self.service_row,
                posterior=posterior,
                alpha=alpha,
                lambda_usd_per_s=lambda_usd_per_s,
                latency_s=latency_savings_s,
                input_tokens=32,
                output_tokens=self.downstream.max_new_tokens,
                input_price=pricing.input_price_per_token,
                output_price=pricing.output_price_per_token,
                use_lower_bound=use_lower_bound,
            )
        return evaluate(DecisionInputs(
            P=posterior.mean,
            alpha=alpha,
            lambda_usd_per_s=lambda_usd_per_s,
            latency_seconds=latency_savings_s,
            input_tokens=32,
            output_tokens=self.downstream.max_new_tokens,
            input_price=pricing.input_price_per_token,
            output_price=pricing.output_price_per_token,
            P_lower_bound=(posterior.lower_bound(self.gamma)
                           if use_lower_bound else None),
        ), use_lower_bound=use_lower_bound)

    def decide(self, posterior: BetaPosterior, alpha: float,
               lambda_usd_per_s: float, latency_savings_s: float,
               *, use_lower_bound: bool = False) -> Decision:
        return self.decide_full(
            posterior, alpha, lambda_usd_per_s, latency_savings_s,
            use_lower_bound=use_lower_bound).decision
