"""Fault-injection harness at the EngineOp / decision-service boundary.

The §12 safety story is only credible if the serving stack is exercised
*under* misbehaving dependencies: providers that hang, raise, slow down,
or whose prediction success rate drifts out from under the calibrated
posterior.  This module injects exactly those faults, deterministically
(seeded, call-indexed), at the two boundaries the front-end crosses:

* ``FaultInjector.wrap(fn)`` — wraps any callable (an ``EngineOp.run``,
  an upstream thunk, a provider client) with scheduled delays, exceptions
  and simulated hangs;
* ``FaultyService`` — proxies an ``OnlineDecisionService`` and applies
  the injector to ``tick_packed`` / ``tick`` / ``decide``, so the
  front-end's circuit breaker and fallback chain can be driven through
  real (not monkeypatched) failure sequences;
* ``FaultInjector.outcome()`` — a drifting Bernoulli success stream for
  settling speculations, flipping from ``success_rate0`` to
  ``success_rate1`` at ``drift_at`` (the §12.5 sudden-flip trace).

A "hang" is simulated as a bounded sleep (``hang_s``): long enough to
trip any sane timeout, short enough that abandoned daemon threads drain
during a test run.  All scheduling is by call index against explicit
sets and/or a seeded RNG — two injectors with the same plan replay the
same fault sequence.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, FrozenSet, Optional

import numpy as np

__all__ = ["InjectedFault", "DriftTrace", "FaultPlan", "FaultInjector",
           "FaultyService", "heavy_tail_tokens", "correlated_flip_traces"]


class InjectedFault(RuntimeError):
    """The exception the harness raises on scheduled failure calls."""


@dataclasses.dataclass(frozen=True)
class DriftTrace:
    """Deterministic success-rate trace over the outcome index.

    One frozen value object per adversarial shape from the issue —
    sudden flip, slow ramp, oscillation at the drift-detector frequency —
    shared by ``FaultPlan.trace`` (settlement stream), the scenario fleet
    (``repro.serving.scenarios``) and the fault-tolerance tests, so every
    layer replays the *same* trace from the same constructor call.

    ``rate_at(i)`` is a pure function of the index: no RNG lives here
    (sampling stays in ``FaultInjector.outcome`` against the plan seed),
    which is what makes scalar-reference parity checks possible.
    """

    kind: str = "constant"           # constant | flip | ramp | oscillation
    rate0: float = 0.95              # healthy success rate
    rate1: float = 0.15              # degraded success rate
    at: int = 0                      # onset index (flip/ramp) or phase shift
    until: Optional[int] = None      # flip revert / ramp end (exclusive)
    period: int = 0                  # oscillation half-period in outcomes

    def rate_at(self, i: int) -> float:
        """Success probability for outcome index ``i`` (0-based)."""
        if self.kind == "constant":
            return self.rate0
        if self.kind == "flip":
            if i < self.at:
                return self.rate0
            if self.until is not None and i >= self.until:
                return self.rate0        # trace reverted — healthy again
            return self.rate1
        if self.kind == "ramp":
            end = self.until if self.until is not None else self.at + 1
            if i < self.at:
                return self.rate0
            if i >= end:
                return self.rate1
            frac = (i - self.at) / max(1, end - self.at)
            return self.rate0 + frac * (self.rate1 - self.rate0)
        if self.kind == "oscillation":
            if self.period <= 0:
                raise ValueError("oscillation trace needs period > 0")
            half = ((i - self.at) // self.period) % 2
            return self.rate1 if half == 1 else self.rate0
        raise ValueError(f"unknown DriftTrace kind: {self.kind!r}")

    # -- constructors (the names the scenarios/tests use) ----------------
    @classmethod
    def constant(cls, rate: float = 0.95) -> "DriftTrace":
        return cls(kind="constant", rate0=rate)

    @classmethod
    def flip(cls, at: int, *, rate0: float = 0.95, rate1: float = 0.15,
             revert_at: Optional[int] = None) -> "DriftTrace":
        """§12.5 sudden flip at ``at``; optionally reverts at
        ``revert_at`` (the demote→cooldown→re-promote acceptance trace)."""
        return cls(kind="flip", rate0=rate0, rate1=rate1, at=at,
                   until=revert_at)

    @classmethod
    def ramp(cls, start: int, end: int, *, rate0: float = 0.95,
             rate1: float = 0.15) -> "DriftTrace":
        """Slow linear degradation from ``rate0`` at ``start`` to
        ``rate1`` at ``end`` — the trace a sudden-flip detector is worst
        at."""
        if end <= start:
            raise ValueError("ramp needs end > start")
        return cls(kind="ramp", rate0=rate0, rate1=rate1, at=start,
                   until=end)

    @classmethod
    def oscillation(cls, period: int, *, rate0: float = 0.95,
                    rate1: float = 0.15, phase: int = 0) -> "DriftTrace":
        """Square wave alternating every ``period`` outcomes — tuned to
        the drift-detector frequency it tries to straddle."""
        return cls(kind="oscillation", rate0=rate0, rate1=rate1,
                   at=phase, period=period)


def heavy_tail_tokens(seed: int, size: int, *, median: float = 256.0,
                      tail_alpha: float = 1.2,
                      cap: float = 65536.0) -> np.ndarray:
    """Seeded heavy-tailed output-token sampler (Lomax/Pareto-II tail).

    ``tail_alpha`` <= 2 gives infinite variance — the regime where a few
    monster completions dominate C_spec and a mean-calibrated threshold
    misprices the tail.  Capped at ``cap`` (providers enforce max_tokens)
    so USD sums stay finite and reproducible.
    """
    if size <= 0:
        raise ValueError("size must be positive")
    rng = np.random.default_rng(seed)
    # Lomax: median = scale * (2**(1/alpha) - 1)  =>  solve for scale
    scale = median / (2.0 ** (1.0 / tail_alpha) - 1.0)
    draws = scale * (rng.pareto(tail_alpha, size=size))
    return np.minimum(np.maximum(draws, 1.0), cap)


def correlated_flip_traces(n: int, at: int, *, seed: int = 0,
                           jitter: int = 0, rate0: float = 0.95,
                           rate1: float = 0.15,
                           revert_at: Optional[int] = None,
                           ) -> list[DriftTrace]:
    """``n`` flip traces with a *common* onset ± seeded per-trace jitter —
    the correlated cross-tenant drift shape (one upstream provider
    regression hits every tenant at nearly the same time).  ``jitter=0``
    is perfect correlation."""
    if n <= 0:
        raise ValueError("n must be positive")
    rng = np.random.default_rng(seed)
    offs = (rng.integers(-jitter, jitter + 1, size=n) if jitter > 0
            else np.zeros(n, dtype=int))
    out = []
    for k in range(n):
        onset = max(0, at + int(offs[k]))
        rev = None if revert_at is None else max(onset + 1,
                                                 revert_at + int(offs[k]))
        out.append(DriftTrace.flip(onset, rate0=rate0, rate1=rate1,
                                   revert_at=rev))
    return out


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-call fault schedule.

    Explicit call-index sets fire exactly; the ``*_rate`` fields draw
    from the seeded RNG per call (reproducible).  Call indices are
    0-based and counted per injector.
    """

    delay_s: float = 0.0                      # added latency on every call
    raise_calls: FrozenSet[int] = frozenset() # calls that raise InjectedFault
    hang_calls: FrozenSet[int] = frozenset()  # calls that sleep hang_s
    raise_rate: float = 0.0
    hang_rate: float = 0.0
    hang_s: float = 0.5
    raise_from: Optional[int] = None          # every call >= this raises
    raise_until: Optional[int] = None         # ...until this (exclusive)
    # drifting success stream for outcome settlement (§12.5 sudden flip)
    success_rate0: float = 0.95
    success_rate1: float = 0.15
    drift_at: Optional[int] = None
    # a DriftTrace supersedes the legacy flip fields above when present
    trace: Optional[DriftTrace] = None
    seed: int = 0


class FaultInjector:
    """Applies a FaultPlan before each wrapped call; thread-safe."""

    def __init__(self, plan: FaultPlan = FaultPlan(), *,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        self.plan = plan
        self.calls = 0
        self.outcomes = 0
        self.faults_fired = 0
        self._sleep = sleep
        self._rng = np.random.default_rng(plan.seed)
        self._lock = threading.Lock()

    def _schedule(self) -> tuple[int, bool, bool, float]:
        """Atomically claim a call index and its fault draws."""
        with self._lock:
            i = self.calls
            self.calls += 1
            p = self.plan
            do_raise = i in p.raise_calls
            if p.raise_from is not None and i >= p.raise_from and (
                    p.raise_until is None or i < p.raise_until):
                do_raise = True
            if p.raise_rate > 0.0:
                do_raise |= bool(self._rng.random() < p.raise_rate)
            do_hang = i in p.hang_calls
            if p.hang_rate > 0.0:
                do_hang |= bool(self._rng.random() < p.hang_rate)
            return i, do_raise, do_hang, p.delay_s

    def before_call(self) -> int:
        """Apply this call's scheduled fault; returns the call index."""
        i, do_raise, do_hang, delay = self._schedule()
        if delay > 0.0:
            self._sleep(delay)
        if do_hang:
            self.faults_fired += 1
            self._sleep(self.plan.hang_s)
        if do_raise:
            self.faults_fired += 1
            raise InjectedFault(f"injected fault at call {i}")
        return i

    def wrap(self, fn: Callable[..., Any]) -> Callable[..., Any]:
        def wrapped(*args: Any, **kwargs: Any) -> Any:
            self.before_call()
            return fn(*args, **kwargs)

        wrapped.__name__ = getattr(fn, "__name__", "wrapped")
        return wrapped

    def outcome(self) -> bool:
        """Next sample of the drifting speculation-success stream."""
        with self._lock:
            i = self.outcomes
            self.outcomes += 1
            p = self.plan
            if p.trace is not None:
                rate = p.trace.rate_at(i)
            else:
                rate = p.success_rate0
                if p.drift_at is not None and i >= p.drift_at:
                    rate = p.success_rate1
            return bool(self._rng.random() < rate)


class FaultyService:
    """An ``OnlineDecisionService`` proxy with faults at the tick boundary.

    Only the decision entry points are faulted; registry/telemetry reads
    pass through untouched so the harness can still observe state.
    """

    _FAULTED = ("tick", "tick_packed", "decide")

    def __init__(self, service, injector: FaultInjector) -> None:
        self._service = service
        self.injector = injector

    def __getattr__(self, name: str):
        attr = getattr(self._service, name)
        if name in self._FAULTED and callable(attr):
            return self.injector.wrap(attr)
        return attr
