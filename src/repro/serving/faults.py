"""Fault-injection harness at the EngineOp / decision-service boundary.

The §12 safety story is only credible if the serving stack is exercised
*under* misbehaving dependencies: providers that hang, raise, slow down,
or whose prediction success rate drifts out from under the calibrated
posterior.  This module injects exactly those faults, deterministically
(seeded, call-indexed), at the two boundaries the front-end crosses:

* ``FaultInjector.wrap(fn)`` — wraps any callable (an ``EngineOp.run``,
  an upstream thunk, a provider client) with scheduled delays, exceptions
  and simulated hangs;
* ``FaultyService`` — proxies an ``OnlineDecisionService`` and applies
  the injector to ``tick_packed`` / ``tick`` / ``decide``, so the
  front-end's circuit breaker and fallback chain can be driven through
  real (not monkeypatched) failure sequences;
* ``FaultInjector.outcome()`` — a drifting Bernoulli success stream for
  settling speculations, flipping from ``success_rate0`` to
  ``success_rate1`` at ``drift_at`` (the §12.5 sudden-flip trace).

A "hang" is simulated as a bounded sleep (``hang_s``): long enough to
trip any sane timeout, short enough that abandoned daemon threads drain
during a test run.  All scheduling is by call index against explicit
sets and/or a seeded RNG — two injectors with the same plan replay the
same fault sequence.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, FrozenSet, Optional

import numpy as np

__all__ = ["InjectedFault", "FaultPlan", "FaultInjector", "FaultyService"]


class InjectedFault(RuntimeError):
    """The exception the harness raises on scheduled failure calls."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-call fault schedule.

    Explicit call-index sets fire exactly; the ``*_rate`` fields draw
    from the seeded RNG per call (reproducible).  Call indices are
    0-based and counted per injector.
    """

    delay_s: float = 0.0                      # added latency on every call
    raise_calls: FrozenSet[int] = frozenset() # calls that raise InjectedFault
    hang_calls: FrozenSet[int] = frozenset()  # calls that sleep hang_s
    raise_rate: float = 0.0
    hang_rate: float = 0.0
    hang_s: float = 0.5
    raise_from: Optional[int] = None          # every call >= this raises
    raise_until: Optional[int] = None         # ...until this (exclusive)
    # drifting success stream for outcome settlement (§12.5 sudden flip)
    success_rate0: float = 0.95
    success_rate1: float = 0.15
    drift_at: Optional[int] = None
    seed: int = 0


class FaultInjector:
    """Applies a FaultPlan before each wrapped call; thread-safe."""

    def __init__(self, plan: FaultPlan = FaultPlan(), *,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        self.plan = plan
        self.calls = 0
        self.outcomes = 0
        self.faults_fired = 0
        self._sleep = sleep
        self._rng = np.random.default_rng(plan.seed)
        self._lock = threading.Lock()

    def _schedule(self) -> tuple[int, bool, bool, float]:
        """Atomically claim a call index and its fault draws."""
        with self._lock:
            i = self.calls
            self.calls += 1
            p = self.plan
            do_raise = i in p.raise_calls
            if p.raise_from is not None and i >= p.raise_from and (
                    p.raise_until is None or i < p.raise_until):
                do_raise = True
            if p.raise_rate > 0.0:
                do_raise |= bool(self._rng.random() < p.raise_rate)
            do_hang = i in p.hang_calls
            if p.hang_rate > 0.0:
                do_hang |= bool(self._rng.random() < p.hang_rate)
            return i, do_raise, do_hang, p.delay_s

    def before_call(self) -> int:
        """Apply this call's scheduled fault; returns the call index."""
        i, do_raise, do_hang, delay = self._schedule()
        if delay > 0.0:
            self._sleep(delay)
        if do_hang:
            self.faults_fired += 1
            self._sleep(self.plan.hang_s)
        if do_raise:
            self.faults_fired += 1
            raise InjectedFault(f"injected fault at call {i}")
        return i

    def wrap(self, fn: Callable[..., Any]) -> Callable[..., Any]:
        def wrapped(*args: Any, **kwargs: Any) -> Any:
            self.before_call()
            return fn(*args, **kwargs)

        wrapped.__name__ = getattr(fn, "__name__", "wrapped")
        return wrapped

    def outcome(self) -> bool:
        """Next sample of the drifting speculation-success stream."""
        with self._lock:
            i = self.outcomes
            self.outcomes += 1
            p = self.plan
            rate = p.success_rate0
            if p.drift_at is not None and i >= p.drift_at:
                rate = p.success_rate1
            return bool(self._rng.random() < rate)


class FaultyService:
    """An ``OnlineDecisionService`` proxy with faults at the tick boundary.

    Only the decision entry points are faulted; registry/telemetry reads
    pass through untouched so the harness can still observe state.
    """

    _FAULTED = ("tick", "tick_packed", "decide")

    def __init__(self, service, injector: FaultInjector) -> None:
        self._service = service
        self.injector = injector

    def __getattr__(self, name: str):
        attr = getattr(self._service, name)
        if name in self._FAULTED and callable(attr):
            return self.injector.wrap(attr)
        return attr
