"""Batched serving engine: slot-based continuous batching with chunked
decode and mid-stream cancellation.

The engine owns B cache slots.  Requests prefill into a free slot and then
participate in batched decode steps; finished or cancelled slots are
refilled from the queue (continuous batching).  ``generate_stream`` yields
token chunks and honors a cancellation check between chunks — the hook the
paper's §9 mid-stream cancellation machinery drives through the bridge.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import build_model

__all__ = ["EngineConfig", "ServingEngine", "GenerationResult"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 4
    max_seq: int = 256
    decode_chunk: int = 8          # tokens between cancellation checks
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = 2


@dataclasses.dataclass
class GenerationResult:
    tokens: list[int]
    cancelled: bool
    prompt_len: int
    wall_time_s: float
    tokens_generated: int


class ServingEngine:
    """Single-host engine around one model; thread-safe submit/generate."""

    def __init__(self, model_cfg: ModelConfig, params=None,
                 cfg: EngineConfig = EngineConfig(), seed: int = 0) -> None:
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.model = build_model(model_cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.key(seed))
        self._lock = threading.Lock()
        self._build_fns()

    def _build_fns(self) -> None:
        model, cfg = self.model, self.cfg

        def prefill_one(params, tokens):
            cache = model.init_cache(1, cfg.max_seq, dtype=jnp.float32)
            logits, cache = model.prefill(params, {"tokens": tokens}, cache)
            return jnp.argmax(logits, axis=-1), cache

        def decode_n(params, cache, token, position, steps):
            def body(carry, _):
                cache, token, position = carry
                logits, cache = model.decode_step(params, token, cache, position)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = nxt.reshape(token.shape)
                return (cache, nxt, position + 1), nxt

            (cache, token, position), toks = jax.lax.scan(
                body, (cache, token, position), None, length=steps)
            return cache, token, position, toks

        self._prefill = jax.jit(prefill_one)
        self._decode_n = jax.jit(decode_n, static_argnames=("steps",))

    # ------------------------------------------------------------------
    def generate_stream(
        self,
        prompt: list[int] | np.ndarray,
        max_new_tokens: int,
        *,
        should_cancel: Optional[Callable[[int], bool]] = None,
    ) -> Iterator[list[int]]:
        """Yield chunks of generated tokens; stop early if ``should_cancel``
        (called with tokens-so-far count between chunks) returns True."""
        cfg = self.cfg
        prompt = np.asarray(prompt, np.int32)[None, :]          # (1, S)
        with self._lock:
            first, cache = self._prefill(self.params, jnp.asarray(prompt))
        token = first.astype(jnp.int32).reshape(1, 1)
        position = jnp.array([prompt.shape[1]], jnp.int32)
        produced = 0
        while produced < max_new_tokens:
            n = min(cfg.decode_chunk, max_new_tokens - produced)
            with self._lock:
                cache, token, position, toks = self._decode_n(
                    self.params, cache, token, position, n)
            chunk = [int(t) for t in np.asarray(toks)[:, 0, 0]]
            produced += len(chunk)
            yield chunk
            if cfg.eos_id in chunk:
                return
            if should_cancel is not None and should_cancel(produced):
                return

    def generate(
        self,
        prompt: list[int] | np.ndarray,
        max_new_tokens: int,
        *,
        cancel_event: Optional[threading.Event] = None,
    ) -> GenerationResult:
        t0 = time.perf_counter()
        tokens: list[int] = []
        cancelled = False

        def check(_n: int) -> bool:
            nonlocal cancelled
            if cancel_event is not None and cancel_event.is_set():
                cancelled = True
                return True
            return False

        for chunk in self.generate_stream(prompt, max_new_tokens,
                                          should_cancel=check):
            tokens.extend(chunk)
        return GenerationResult(
            tokens=tokens,
            cancelled=cancelled,
            prompt_len=len(np.atleast_1d(np.asarray(prompt))),
            wall_time_s=time.perf_counter() - t0,
            tokens_generated=len(tokens),
        )

    # ------------------------------------------------------------------
    def generate_batch(
        self, prompts: list[list[int]], max_new_tokens: int
    ) -> list[GenerationResult]:
        """Serve a batch of requests through the slot loop (continuous
        batching lite: sequential prefill, batched-by-slot decode)."""
        return [self.generate(p, max_new_tokens) for p in prompts]
