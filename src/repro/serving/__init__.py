"""repro.serving — batched serving engine, speculative-execution bridge,
fault-injection harness, and the async request-accumulation front-end."""
from .engine import EngineConfig, GenerationResult, ServingEngine
from .faults import (
    DriftTrace,
    FaultInjector,
    FaultPlan,
    FaultyService,
    InjectedFault,
    correlated_flip_traces,
    heavy_tail_tokens,
)
from .frontend import (
    BreakerState,
    CircuitBreaker,
    DecisionRequest,
    FrontendConfig,
    FrontendResult,
    FrontendTicket,
    ServingFrontend,
    TenantBulkhead,
)
from .spec_bridge import (
    EngineOp,
    SpeculationTimeout,
    SpeculativeEdgeResult,
    ThreadedSpeculativeRunner,
    call_with_timeout,
    retry_with_backoff,
)

__all__ = [
    "ServingEngine", "EngineConfig", "GenerationResult",
    "EngineOp", "ThreadedSpeculativeRunner", "SpeculativeEdgeResult",
    "SpeculationTimeout", "call_with_timeout", "retry_with_backoff",
    "InjectedFault", "FaultPlan", "FaultInjector", "FaultyService",
    "DriftTrace", "heavy_tail_tokens", "correlated_flip_traces",
    "FrontendConfig", "BreakerState", "CircuitBreaker", "TenantBulkhead",
    "DecisionRequest", "FrontendResult", "FrontendTicket", "ServingFrontend",
]

from .scenarios import (
    Scenario,
    ScenarioResult,
    adversarial_scenarios,
    all_scenarios,
    archetype_scenarios,
    run_scenario,
)

__all__ += [
    "Scenario", "ScenarioResult", "archetype_scenarios",
    "adversarial_scenarios", "all_scenarios", "run_scenario",
]
