"""repro.serving — batched serving engine + speculative-execution bridge."""
from .engine import EngineConfig, GenerationResult, ServingEngine
from .spec_bridge import EngineOp, SpeculativeEdgeResult, ThreadedSpeculativeRunner

__all__ = [
    "ServingEngine", "EngineConfig", "GenerationResult",
    "EngineOp", "ThreadedSpeculativeRunner", "SpeculativeEdgeResult",
]
