"""repro.serving — batched serving engine, speculative-execution bridge,
fault-injection harness, and the async request-accumulation front-end."""
from .engine import EngineConfig, GenerationResult, ServingEngine
from .faults import FaultInjector, FaultPlan, FaultyService, InjectedFault
from .frontend import (
    BreakerState,
    CircuitBreaker,
    DecisionRequest,
    FrontendConfig,
    FrontendResult,
    FrontendTicket,
    ServingFrontend,
    TenantBulkhead,
)
from .spec_bridge import (
    EngineOp,
    SpeculationTimeout,
    SpeculativeEdgeResult,
    ThreadedSpeculativeRunner,
    call_with_timeout,
    retry_with_backoff,
)

__all__ = [
    "ServingEngine", "EngineConfig", "GenerationResult",
    "EngineOp", "ThreadedSpeculativeRunner", "SpeculativeEdgeResult",
    "SpeculationTimeout", "call_with_timeout", "retry_with_backoff",
    "InjectedFault", "FaultPlan", "FaultInjector", "FaultyService",
    "FrontendConfig", "BreakerState", "CircuitBreaker", "TenantBulkhead",
    "DecisionRequest", "FrontendResult", "FrontendTicket", "ServingFrontend",
]
