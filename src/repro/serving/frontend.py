"""Async serving front-end for the jit'd online decision service.

PR 5 made the D4 decision path fast (``OnlineDecisionService.tick_packed``
answers B=1024 in one XLA call) but nothing *formed* the batch or survived
a misbehaving dependency.  This module is that missing layer — the piece
that turns the decision core into something that can face open-loop
traffic:

* **Deadline-driven batcher** — requests accumulate host-side and a tick
  fires on *batch-full OR deadline, whichever first* (``max_batch`` /
  ``deadline_s``).  Submission never blocks: the caller gets a
  :class:`FrontendTicket` immediately and the sequential path proceeds
  regardless of what the speculative machinery does.
* **Per-tenant bulkheads** — at most ``bulkhead_limit`` in-flight
  speculations per tenant; beyond it requests are *shed* with a
  conservative no-speculate answer (never queued, never blocking).  One
  flooding tenant cannot starve the fleet.
* **Circuit breaker + fallback chain** — a per-(tenant, edge)
  closed/open/half-open state machine folds host-side faults (tick
  exceptions, timeouts) and the service's in-graph kill-switch breach
  bits into one view.  Every request is answered through the chain
  *service tick → scalar ``decision.evaluate`` → conservative
  no-speculate*: an open breaker or failed tick degrades to the host
  scalar path over the last-known posterior mirror (bitwise-f64 the
  scalar rule — the same parity contract the service itself pins), and
  if even that is impossible the terminal stage answers WAIT.
* **Resilience telemetry** — every shed / trip / fallback emits a
  USD-attributed :class:`~repro.core.telemetry.ResilienceEvent` (host
  log) and an encoded event row on the service's device telemetry ring
  (``OnlineDecisionService.log_events``), so the cost of running
  degraded is an exportable number, not a log line.

Admissibility note: all of this decides *whether to launch* speculations;
a wrong answer in degraded mode can only cost money or latency, never
un-send an irreversible side effect — the paper's §4 admissibility
argument is exactly why shed-with-no-speculate is always safe.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np

from ..core.decision import Decision, DecisionInputs, evaluate
from ..core.posterior import BetaPosterior
from ..core.telemetry import ResilienceEvent, ResilienceLog
from .spec_bridge import SpeculationTimeout, call_with_timeout

__all__ = [
    "BreakerState",
    "CircuitBreaker",
    "DecisionRequest",
    "FrontendConfig",
    "FrontendResult",
    "FrontendTicket",
    "ServingFrontend",
    "TenantBulkhead",
]


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Knobs of the serving front-end (all host-side)."""

    max_batch: int = 256              # tick fires at this many pending...
    deadline_s: float = 0.005         # ...or this long after the first
    max_queue: int = 4096             # admission bound on pending requests
    bulkhead_limit: int = 8           # in-flight speculations per tenant
    breaker_failure_threshold: int = 3
    breaker_cooldown_s: float = 0.5
    breaker_half_open_probes: int = 1
    tick_timeout_s: Optional[float] = None   # watchdog around the tick
    check_drift: bool = True          # run the in-graph kill-switch step
    snapshot_refresh_ticks: int = 8   # posterior-mirror refresh cadence
    ring_events: bool = True          # mirror events onto the device ring


class BreakerState(enum.Enum):
    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"


class _Breaker:
    __slots__ = ("state", "failures", "opened_at", "probes")

    def __init__(self) -> None:
        self.state = BreakerState.CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self.probes = 0


class CircuitBreaker:
    """Per-key closed/open/half-open state machine with cooldown.

    ``allow`` is the admission check: CLOSED always passes; OPEN rejects
    until ``cooldown_s`` has elapsed, then transitions to HALF_OPEN and
    admits up to ``half_open_probes`` probe calls; a probe success closes
    the circuit, a probe failure re-opens it (cooldown restarts).  The
    clock is injectable so cooldown semantics are testable without real
    sleeps.  Thread-safe.
    """

    def __init__(self, *, failure_threshold: int = 3, cooldown_s: float = 0.5,
                 half_open_probes: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Optional[Callable[[Any, BreakerState], None]]
                 = None) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if half_open_probes < 1:
            raise ValueError("half_open_probes must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.half_open_probes = half_open_probes
        self.clock = clock
        self.on_transition = on_transition
        self._keys: dict[Any, _Breaker] = {}
        self._lock = threading.Lock()
        self.trips = 0

    def _get(self, key: Any) -> _Breaker:
        b = self._keys.get(key)
        if b is None:
            b = self._keys[key] = _Breaker()
        return b

    def _set_state(self, key: Any, b: _Breaker, state: BreakerState) -> None:
        if b.state is not state:
            b.state = state
            if self.on_transition is not None:
                self.on_transition(key, state)

    def state(self, key: Any) -> BreakerState:
        with self._lock:
            return self._get(key).state

    def allow(self, key: Any) -> bool:
        with self._lock:
            b = self._get(key)
            if b.state is BreakerState.CLOSED:
                return True
            if b.state is BreakerState.OPEN:
                if self.clock() - b.opened_at < self.cooldown_s:
                    return False
                self._set_state(key, b, BreakerState.HALF_OPEN)
                b.probes = 0
            # HALF_OPEN: admit a bounded number of probes
            if b.probes < self.half_open_probes:
                b.probes += 1
                return True
            return False

    def record_success(self, key: Any) -> None:
        with self._lock:
            b = self._get(key)
            b.failures = 0
            if b.state is not BreakerState.CLOSED:
                self._set_state(key, b, BreakerState.CLOSED)

    def record_failure(self, key: Any) -> None:
        with self._lock:
            b = self._get(key)
            if b.state is BreakerState.HALF_OPEN:
                self._open(key, b)
                return
            b.failures += 1
            if b.state is BreakerState.CLOSED and \
                    b.failures >= self.failure_threshold:
                self._open(key, b)

    def trip(self, key: Any) -> None:
        """Open immediately (kill-switch breach semantics)."""
        with self._lock:
            self._open(key, self._get(key))

    def _open(self, key: Any, b: _Breaker) -> None:
        b.failures = 0
        b.opened_at = self.clock()
        self.trips += 1
        self._set_state(key, b, BreakerState.OPEN)


class TenantBulkhead:
    """Bounded in-flight speculation slots per tenant (thread-safe)."""

    def __init__(self, limit: int) -> None:
        if limit < 1:
            raise ValueError("bulkhead limit must be >= 1")
        self.limit = limit
        self._in_flight: dict[Optional[str], int] = {}
        self._lock = threading.Lock()

    def try_acquire(self, tenant: Optional[str]) -> bool:
        with self._lock:
            n = self._in_flight.get(tenant, 0)
            if n >= self.limit:
                return False
            self._in_flight[tenant] = n + 1
            return True

    def release(self, tenant: Optional[str]) -> None:
        with self._lock:
            n = self._in_flight.get(tenant, 0)
            if n <= 0:
                raise RuntimeError(f"release without acquire: {tenant!r}")
            self._in_flight[tenant] = n - 1

    def in_flight(self, tenant: Optional[str]) -> int:
        with self._lock:
            return self._in_flight.get(tenant, 0)


@dataclasses.dataclass(frozen=True)
class DecisionRequest:
    """One decision ask: which (tenant, edge) row, plus the D4 inputs."""

    row: int
    tenant: Optional[str]
    edge: tuple[str, str]
    alpha: float
    lambda_usd_per_s: float
    latency_s: float
    input_tokens: float
    output_tokens: float
    input_price: float
    output_price: float

    @property
    def key(self) -> tuple[Optional[str], tuple[str, str]]:
        return (self.tenant, self.edge)

    @property
    def L_value_usd(self) -> float:
        return self.latency_s * self.lambda_usd_per_s

    @property
    def C_spec_usd(self) -> float:
        return (self.input_tokens * self.input_price
                + self.output_tokens * self.output_price)


@dataclasses.dataclass(frozen=True)
class FrontendResult:
    """The answer a ticket resolves to.  ``source`` names the chain stage
    that produced it: "service" | "scalar" | "conservative" | "shed"."""

    decision: Decision
    source: str
    EV_usd: float = 0.0
    threshold_usd: float = 0.0
    C_spec_usd: float = 0.0
    L_value_usd: float = 0.0
    P_used: float = 0.0

    @property
    def speculate(self) -> bool:
        return self.decision is Decision.SPECULATE

    @property
    def margin_usd(self) -> float:
        return self.EV_usd - self.threshold_usd


class FrontendTicket:
    """Handle for one submitted request.  ``result()`` blocks the *caller
    that wants the answer*; submission itself never blocks.  A SPECULATE
    answer holds the tenant's bulkhead slot until :meth:`settle`."""

    def __init__(self, frontend: "ServingFrontend",
                 request: DecisionRequest) -> None:
        self.request = request
        self._frontend = frontend
        self._event = threading.Event()
        self._result: Optional[FrontendResult] = None
        self.t_submit = frontend._clock()
        self.t_resolve: Optional[float] = None
        self._holds_slot = False
        self._settled = False

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> FrontendResult:
        if not self._event.wait(timeout):
            raise SpeculationTimeout("ticket unresolved within timeout")
        assert self._result is not None
        return self._result

    @property
    def latency_s(self) -> float:
        if self.t_resolve is None:
            raise RuntimeError("ticket not resolved yet")
        return self.t_resolve - self.t_submit

    def settle(self, success: bool) -> None:
        """Report the launched speculation's outcome: releases the
        bulkhead slot and queues the Bernoulli observation for the
        service's next tick."""
        if self._settled:
            raise RuntimeError("ticket already settled")
        self._settled = True
        if self._holds_slot:
            self._frontend._bulkhead.release(self.request.tenant)
            self._holds_slot = False
        self._frontend._observe(self.request.row, success)

    def release(self) -> None:
        """Give back the bulkhead slot without an observation (the caller
        decided not to launch despite a SPECULATE answer)."""
        if self._holds_slot:
            self._frontend._bulkhead.release(self.request.tenant)
            self._holds_slot = False

    # internal
    def _resolve(self, result: FrontendResult) -> None:
        self._result = result
        self.t_resolve = self._frontend._clock()
        self._event.set()


_CONSERVATIVE = FrontendResult(decision=Decision.WAIT, source="conservative")


class ServingFrontend:
    """Request-accumulation layer in front of an ``OnlineDecisionService``.

    Construct with ``autostart=True`` (default) to run the batcher
    thread, or ``autostart=False`` and drive :meth:`pump` manually — the
    deterministic mode the fault-matrix tests and benchmarks use.  The
    ``service`` may be wrapped (e.g. ``faults.FaultyService``); only the
    ``tick_packed`` / ``posterior_snapshot`` / ``row_gamma`` /
    ``use_lower_bound`` / ``observe`` / ``row_key`` surface is touched
    (plus the optional ``rows_snapshot`` lazy mirror-miss read, skipped
    when the wrapper does not expose it).
    """

    def __init__(
        self,
        service,
        config: FrontendConfig = FrontendConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
        resilience_log: Optional[ResilienceLog] = None,
        autostart: bool = True,
    ) -> None:
        self.service = service
        self.config = config
        self._clock = clock
        # identity check, not truthiness: an injected-but-still-empty log
        # is falsy (``__len__``) and ``or`` would silently replace it
        self.resilience = (resilience_log if resilience_log is not None
                           else ResilienceLog())
        self._bulkhead = TenantBulkhead(config.bulkhead_limit)
        self.breaker = CircuitBreaker(
            failure_threshold=config.breaker_failure_threshold,
            cooldown_s=config.breaker_cooldown_s,
            half_open_probes=config.breaker_half_open_probes,
            clock=clock,
            on_transition=self._on_breaker_transition,
        )
        self._cv = threading.Condition()
        self._pending: list[FrontendTicket] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._ticks = 0
        self._ticks_since_snapshot = 0
        self._breached: set[int] = set()
        self._settles: list[tuple[int, bool]] = []
        self._settle_lock = threading.Lock()
        # the scalar-fallback posterior mirror: last-known (n, 2) copy of
        # the service's composed store snapshot (device-resident, shelf
        # -spilled and unborn rows alike), refreshed while the service is
        # healthy.  Degraded-mode decisions run the scalar rule over this
        # mirror — stale beliefs, exact arithmetic.  Rows registered
        # after the last refresh fall through to a lazy per-row
        # ``rows_snapshot`` read (see _mirror_row).
        self._snapshot = np.asarray(service.posterior_snapshot(), np.float64)
        self.stats = {
            "submitted": 0, "service": 0, "scalar": 0, "conservative": 0,
            "shed": 0, "tick_faults": 0, "deadline_ticks": 0,
            "full_ticks": 0,
        }
        if autostart:
            self.start()

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="frontend-batcher", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Flush what's pending and join the batcher thread."""
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.pump()                   # drain anything that raced the stop

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # ------------------------------------------------------------ submission
    def submit(self, request: DecisionRequest) -> FrontendTicket:
        """Non-blocking admission: shed (bulkhead/queue bound) and
        breaker-open requests resolve immediately; everything else joins
        the current accumulation window."""
        ticket = FrontendTicket(self, request)
        self.stats["submitted"] += 1

        # -- bulkhead admission: a tenant at its in-flight limit is shed
        if not self._bulkhead.try_acquire(request.tenant):
            self._shed(ticket, "bulkhead at limit")
            return ticket
        ticket._holds_slot = True

        # -- breaker: an open circuit skips the service entirely and
        # degrades straight to the scalar stage of the chain
        if not self.breaker.allow(request.key):
            self._emit(request, "fallback_scalar", request.C_spec_usd,
                       detail="breaker open")
            self._resolve_fallback(ticket)
            return ticket

        with self._cv:
            if len(self._pending) < self.config.max_queue:
                self._pending.append(ticket)
                if len(self._pending) >= self.config.max_batch:
                    self._cv.notify_all()
                else:
                    self._cv.notify()
                return ticket
        # admission control: a full queue sheds rather than grows
        self._shed(ticket, "queue at limit")
        return ticket

    def submit_edge(self, edge: tuple[str, str], *, tenant: Optional[str]
                    = None, **params: float) -> FrontendTicket:
        """Convenience: look up the (tenant, edge) row and submit."""
        row = self.service.row_index(edge, tenant)
        return self.submit(DecisionRequest(
            row=row, tenant=tenant, edge=tuple(edge), **params))

    # ------------------------------------------------------------- the chain
    def _shed(self, ticket: FrontendTicket, detail: str) -> None:
        req = ticket.request
        ticket.release()
        self.stats["shed"] += 1
        # USD attribution: shedding forgoes the latency value at stake
        self._emit(req, "shed", req.L_value_usd, detail=detail)
        ticket._resolve(dataclasses.replace(_CONSERVATIVE, source="shed"))

    def _resolve_fallback(self, ticket: FrontendTicket) -> None:
        """Stages 2 and 3 of the chain: host scalar rule, then terminal
        conservative WAIT."""
        req = ticket.request
        try:
            res = self._scalar_decide(req)
        except Exception:
            self.stats["conservative"] += 1
            self._emit(req, "fallback_conservative", req.C_spec_usd)
            ticket.release()
            ticket._resolve(_CONSERVATIVE)
            return
        self.stats["scalar"] += 1
        if res.decision is not Decision.SPECULATE:
            ticket.release()
        ticket._resolve(res)

    def _mirror_row(self, row: int) -> np.ndarray:
        """The mirror's alpha/beta for one row, falling back to a lazy
        store read for rows registered after the last refresh (the mirror
        is a point-in-time copy; a paged store still answers any logical
        row without residency changes)."""
        if row < self._snapshot.shape[0]:
            return self._snapshot[row]
        rows_snapshot = getattr(self.service, "rows_snapshot", None)
        if rows_snapshot is None:
            return self._snapshot[row]      # historical IndexError contract
        return np.asarray(rows_snapshot([row]), np.float64)[0]

    def _scalar_decide(self, req: DecisionRequest) -> FrontendResult:
        """The paper-faithful scalar D4 gate over the posterior mirror —
        bitwise-f64 ``decision.evaluate`` by construction."""
        a, b = self._mirror_row(req.row)
        post = BetaPosterior(alpha=float(a), beta=float(b))
        use_lb = bool(getattr(self.service, "use_lower_bound", False))
        res = evaluate(DecisionInputs(
            P=post.mean,
            alpha=req.alpha,
            lambda_usd_per_s=req.lambda_usd_per_s,
            latency_seconds=req.latency_s,
            input_tokens=req.input_tokens,
            output_tokens=req.output_tokens,
            input_price=req.input_price,
            output_price=req.output_price,
            P_lower_bound=(post.lower_bound(self.service.row_gamma(req.row))
                           if use_lb else None),
        ), use_lower_bound=use_lb)
        return FrontendResult(
            decision=res.decision, source="scalar", EV_usd=res.EV_usd,
            threshold_usd=res.threshold_usd, C_spec_usd=res.C_spec_usd,
            L_value_usd=res.L_value_usd, P_used=res.P_used)

    # -------------------------------------------------------------- batching
    def _loop(self) -> None:
        cfg = self.config
        while not self._stop.is_set():
            with self._cv:
                while not self._pending and not self._stop.is_set():
                    self._cv.wait(timeout=0.1)
                if self._stop.is_set() and not self._pending:
                    return
                t_first = self._pending[0].t_submit
                while (len(self._pending) < cfg.max_batch
                       and not self._stop.is_set()):
                    remaining = cfg.deadline_s - (self._clock() - t_first)
                    if remaining <= 0.0:
                        break
                    self._cv.wait(timeout=remaining)
            self.pump()

    def pump(self, max_batch: Optional[int] = None) -> int:
        """Form one batch from the pending queue and tick it through the
        chain synchronously.  Returns the number of requests answered.
        This is the single flush path — the batcher thread calls it on
        batch-full/deadline; tests and benchmarks call it directly."""
        with self._cv:
            if not self._pending:
                return 0
            n = min(len(self._pending),
                    max_batch if max_batch is not None else
                    self.config.max_batch)
            batch, self._pending = self._pending[:n], self._pending[n:]
        if len(batch) >= self.config.max_batch:
            self.stats["full_ticks"] += 1
        else:
            self.stats["deadline_ticks"] += 1
        self._flush(batch)
        return len(batch)

    def _pack(self, batch: Sequence[FrontendTicket]):
        # pad to max_batch (not the nearest power of two): partial
        # deadline batches then share ONE tick executable with full
        # batches instead of compiling per bucket — under open-loop load
        # a mid-run XLA compile stalls the batcher and cascades into
        # sheds, so shape stability beats the padded FLOPs
        B = len(batch)
        Bp = max(self.config.max_batch, 1 << max(0, (B - 1).bit_length()))
        dtype = getattr(self.service, "_np_dtype", np.dtype(np.float64))
        row = np.full(Bp, -1, np.int32)
        reqs = np.zeros((Bp, 7), dtype)
        for i, t in enumerate(batch):
            r = t.request
            row[i] = r.row
            reqs[i] = (r.alpha, r.lambda_usd_per_s, r.latency_s,
                       r.input_tokens, r.output_tokens, r.input_price,
                       r.output_price)
        return row, reqs, B

    def _pack_settles(self, dtype):
        """Pop queued outcomes into a fixed-shape (Sp,) block — same
        shape-stability argument as :meth:`_pack`."""
        with self._settle_lock:
            if not self._settles:
                return None, None, []
            settles, self._settles = self._settles, []
        n = len(settles)
        Sp = max(self.config.max_batch, 1 << max(0, (n - 1).bit_length()))
        out_row = np.full(Sp, -1, np.int32)
        out_x = np.zeros(Sp, dtype)
        for i, (r, s) in enumerate(settles):
            out_row[i], out_x[i] = r, float(s)
        return out_row, out_x, settles

    def _flush(self, batch: Sequence[FrontendTicket]) -> None:
        cfg = self.config
        row, reqs, B = self._pack(batch)
        out_row, out_x, settles = self._pack_settles(reqs.dtype)
        tick = lambda: self.service.tick_packed(  # noqa: E731
            row, reqs, batch=B, out_row=out_row, out_x=out_x,
            check_drift=cfg.check_drift)
        fault_kind: Optional[str] = None
        decisions = None
        try:
            if cfg.tick_timeout_s is not None:
                decisions = call_with_timeout(tick, cfg.tick_timeout_s)
            else:
                decisions = tick()
        except SpeculationTimeout:
            fault_kind = "timeout"
        except Exception:
            fault_kind = "exception"

        self._ticks += 1
        if decisions is None:
            # tick-level fault: the unsettled outcomes go back on the
            # queue (applied by the next healthy tick), every key
            # involved records one failure, every request degrades down
            # the chain
            if settles:
                with self._settle_lock:
                    self._settles[:0] = settles
            self.stats["tick_faults"] += 1
            keys = {t.request.key for t in batch}
            for t in batch:
                self._emit(t.request, fault_kind, t.request.C_spec_usd)
            for key in keys:
                self.breaker.record_failure(key)
            for t in batch:
                self._emit(t.request, "fallback_scalar",
                           t.request.C_spec_usd, detail=f"tick {fault_kind}")
                self._resolve_fallback(t)
            return

        # healthy tick: distribute answers, close half-open circuits
        for key in {t.request.key for t in batch}:
            self.breaker.record_success(key)
        # in-graph kill-switch breaches fold into the breaker as trips
        # (once per breach onset, not re-tripped every tick while down).
        # ``drift_triggered`` is a pulse — the in-graph run resets after
        # firing — so the breached set must accumulate across ticks, and
        # a row only leaves it once it is observed serving enabled again
        # (host re-enable / rollout re-entry).  That way a *second*
        # breach after a recovery re-emits a fresh trip instead of being
        # swallowed as a duplicate.
        tripped = {int(r) for r in np.flatnonzero(decisions.drift_triggered)}
        if self._breached:
            en = decisions.enabled
            for i in range(B):
                r = int(row[i])
                if (r >= 0 and r not in tripped and bool(en[i])
                        and r in self._breached):
                    self._breached.discard(r)
        for r in sorted(tripped - self._breached):
            tenant, edge = self.service.row_key(r)
            self.breaker.trip((tenant, edge))
            self._emit_raw(tenant, edge, r, "drift_trip", 0.0,
                           detail="kill-switch breach")
        self._breached |= tripped
        spec = decisions.speculate
        for i, t in enumerate(batch):
            self.stats["service"] += 1
            res = FrontendResult(
                decision=(Decision.SPECULATE if bool(spec[i])
                          else Decision.WAIT),
                source="service",
                EV_usd=float(decisions.EV_usd[i]),
                threshold_usd=float(decisions.threshold_usd[i]),
                C_spec_usd=float(decisions.C_spec_usd[i]),
                L_value_usd=float(decisions.L_value_usd[i]),
                P_used=float(decisions.P_used[i]),
            )
            if res.decision is not Decision.SPECULATE:
                t.release()
            t._resolve(res)
        self._ticks_since_snapshot += 1
        if self._ticks_since_snapshot >= cfg.snapshot_refresh_ticks:
            self._refresh_snapshot()

    def _refresh_snapshot(self) -> None:
        try:
            self._snapshot = np.asarray(
                self.service.posterior_snapshot(), np.float64)
            self._ticks_since_snapshot = 0
        except Exception:
            # a failing service keeps the stale mirror — that is the point
            pass

    # ------------------------------------------------------------- telemetry
    def _on_breaker_transition(self, key: Any, state: BreakerState) -> None:
        tenant, edge = key
        kind = {
            BreakerState.OPEN: "breaker_open",
            BreakerState.HALF_OPEN: "breaker_half_open",
            BreakerState.CLOSED: "breaker_close",
        }[state]
        self._emit_raw(tenant, edge, None, kind, 0.0)

    def _emit(self, req: DecisionRequest, kind: str, usd: float,
              detail: str = "") -> None:
        self._emit_raw(req.tenant, req.edge, req.row, kind, usd, detail)

    def _emit_raw(self, tenant, edge, row, kind: str, usd: float,
                  detail: str = "") -> None:
        self.resilience.emit(ResilienceEvent(
            kind=kind, tenant=tenant, edge=edge, row=row, usd=usd,
            detail=detail))
        if self.config.ring_events:
            try:
                self.service.log_events([(row, kind, usd)])
            except Exception:
                pass              # the host log stays authoritative

    def _observe(self, row: int, success: bool) -> None:
        # settles queue frontend-side (not service.observe) so the flush
        # can hand them to the tick as one fixed-shape packed block
        if not (0 <= int(row) < self.service.n_rows):
            raise IndexError("outcome row out of range")
        with self._settle_lock:
            self._settles.append((int(row), bool(success)))

    # --------------------------------------------------------------- queries
    def in_flight(self, tenant: Optional[str]) -> int:
        return self._bulkhead.in_flight(tenant)

    @property
    def ticks(self) -> int:
        return self._ticks

    @property
    def pending_count(self) -> int:
        with self._cv:
            return len(self._pending)

    @property
    def oldest_pending_t(self) -> Optional[float]:
        """Submit time of the oldest queued request (deadline anchor)."""
        with self._cv:
            return self._pending[0].t_submit if self._pending else None
