"""§13 — workload fit and the archetype catalog.

The four-point fit rubric, the eight production archetypes, the four
explicit non-fit shapes, and the pilot-picking scorer.  These are
machine-checkable: ``fit_rubric`` evaluates a WorkloadProfile and
``pilot_score`` ranks candidates, so a deployment can run the §13.4 rubric
programmatically against §12.1 offline-replay statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "WorkloadProfile",
    "FitResult",
    "fit_rubric",
    "pilot_score",
    "ARCHETYPES",
    "NON_FIT_SHAPES",
    "Archetype",
]


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """What §12.1 offline replay measures about a candidate workload."""

    name: str
    num_stages: int                   # LLM/tool calls on the critical path
    k_raw: int                        # raw upstream branching factor
    p_mode: float                     # dominant-mode probability
    output_tokens_est: float          # downstream generation size
    input_tokens_est: float
    lambda_defensible: bool           # someone can defend a USD/s figure
    latency_pain: bool = True         # §13.4 point 1
    observable_before_enable: bool = True  # §13.4 point 4 (replay/shadow possible)

    @property
    def k_eff(self) -> float:
        return 1.0 / self.p_mode if self.p_mode > 0 else float("inf")

    @property
    def output_heavy(self) -> bool:
        return self.output_tokens_est >= self.input_tokens_est


@dataclasses.dataclass(frozen=True)
class FitResult:
    fits: bool
    points: dict[str, bool]

    @property
    def failures(self) -> list[str]:
        return [k for k, v in self.points.items() if not v]


def fit_rubric(w: WorkloadProfile) -> FitResult:
    """§13.1 four-point rubric — a workload is a good fit when ALL hold."""
    points = {
        "multi_stage_with_upstream_latency": w.num_stages >= 2,
        "small_effective_branching": w.k_raw <= 5 or w.p_mode >= 0.5,
        "output_heavy_downstream": w.output_heavy,
        "defensible_lambda": w.lambda_defensible,
    }
    return FitResult(fits=all(points.values()), points=points)


def pilot_score(w: WorkloadProfile) -> int:
    """§13.4 pilot-picking rubric: 0-4 points."""
    return sum(
        [
            w.latency_pain,
            w.p_mode >= 0.5,                       # single mode above 50%
            w.output_heavy,                        # two-rate pricing moves the decision
            w.observable_before_enable,            # replay/shadow instrumentable
        ]
    )


@dataclasses.dataclass(frozen=True)
class Archetype:
    name: str
    domain: str
    shape: str
    speculate: str
    k_eff_range: tuple[float, float]
    stakes: str
    watch_out: str
    needs_streaming_cancel: bool = False
    needs_credible_bound_day_one: bool = False

    def profile(self) -> WorkloadProfile:
        k_mid = sum(self.k_eff_range) / 2
        return WorkloadProfile(
            name=self.name,
            num_stages=3,
            k_raw=min(5, max(2, int(round(k_mid)) + 2)),
            p_mode=1.0 / k_mid,
            output_tokens_est=800,
            input_tokens_est=500,
            lambda_defensible=True,
        )


ARCHETYPES: dict[str, Archetype] = {
    a.name: a
    for a in [
        Archetype(
            "voice_bot_ivr", "customer-facing",
            "STT -> intent classifier -> response synthesizer -> TTS",
            "response synthesizer with the modal intent's template while the classifier runs",
            (1.5, 2.0),
            "each additional 400 ms raises call abandonment; telcos pay per minute",
            "tier-2 equivalence must accept paraphrases (invest in the semantic-match predicate)",
        ),
        Archetype(
            "ide_autocomplete", "customer-facing",
            "context classifier -> generator",
            "generator with the modal intent while the classifier inspects surrounding code",
            (1.4, 1.4),
            "sub-200 ms feel is the product; aggregate GPU hours are real",
            "operators run alpha near 1 and rely on streaming cancellation (§9)",
            needs_streaming_cancel=True,
        ),
        Archetype(
            "insurance_claims_triage", "enterprise",
            "OCR + claim-type classifier -> next-action drafter",
            "drafter for the modal next-action per claim type",
            (2.0, 3.0),
            "adjuster time at $50-100/hr; 20% cycle-time reduction scales to seven figures",
            "tier-3 offline validation mandatory (regulatory); credible-bound gating day one",
            needs_credible_bound_day_one=True,
        ),
        Archetype(
            "content_moderation", "enterprise",
            "safety classifier -> action drafter (allow/warn/remove/escalate)",
            "the 'allow' path with its user-facing message",
            (1.05, 1.05),
            "billions of items/day; unit wins compound",
            "rare non-allow paths are where quality matters most; never soften tier-2 for them",
        ),
        Archetype(
            "medical_prior_auth", "enterprise",
            "document extraction -> procedure-code classifier -> policy retrieval -> drafter",
            "retrieval + drafter path for the modal code",
            (3.0, 5.0),
            "prior-auth backlogs delay hospital revenue; each day shaved is monetizable",
            "cold-start on new payers is high-risk; credible-bound gating + shadow runway per payer",
            needs_credible_bound_day_one=True,
        ),
        Archetype(
            "pr_review_bot", "developer-tooling",
            "diff analyzer -> change-type classifier -> review-strategy selector -> reviewer prompt",
            "reviewer prompt for the modal change type per repo",
            (2.0, 2.0),
            "reviewer wait time is engineering velocity; multi-million-dollar lever at org scale",
            "cross-repo generalization is weak; rely on per-repo posteriors (default behavior)",
        ),
        Archetype(
            "rag_pipeline", "developer-tooling",
            "intent classifier -> retriever strategy -> answer synthesizer",
            "synthesizer with the most-likely intent's retrieval path",
            (1.5, 2.0),
            "user-facing latency drives engagement; output-heavy synthesis is the expensive stage",
            "the retriever is itself a tool call and may be slow; consider separate speculation there",
            needs_streaming_cancel=True,
        ),
        Archetype(
            "security_triage", "high-stakes",
            "alert enricher -> alert-type classifier -> runbook selector -> remediation drafter",
            "remediation drafter for the most-likely runbook",
            (2.0, 3.0),
            "MTTR has dollar value in breach exposure; incident-minutes are expensive",
            "low volume per unique alert -> posterior converges slowly; lean on the structural prior",
            needs_credible_bound_day_one=True,
        ),
    ]
}


# §13.3 — where the method does not fit (no amount of tuning helps)
NON_FIT_SHAPES: dict[str, str] = {
    "open_ended_creative": "single-call long-form generation: the downstream IS the workflow; "
    "no upstream to speculate against (fails rubric point 1)",
    "runtime_determined_topology": "reflection loops / dynamic spawning: each expansion requires "
    "re-planning and the §8.1 planner assumptions do not hold (out of scope, §1.4)",
    "high_k_flat": "high k_eff with flat distribution: single-shot EV collapses below threshold "
    "(§7.6); remedies are richer conditioning, top-m multi-shot, or not speculating",
    "cheap_downstream": "C_spec and L*lambda both small: EV is small by construction and rarely "
    "clears (1-alpha)*C_spec; the rule correctly WAITs but instrumentation has no payoff",
}
