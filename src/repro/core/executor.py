"""§8.2 — Phase 2: runtime execution with bidirectional override.

Immediately before launching any operation marked SPECULATE (and before
WAIT-marked edges whose upstream is starting), the runtime re-runs the §6
decision rule with *current* parameters: posterior-updated P, EMA latency
estimates, possibly-changed alpha, recomputed C_spec.  The runtime decision
can differ from the plan in either direction (upgrade and downgrade).

This module implements a deterministic discrete-event executor: simulated
time is advanced analytically along the DAG, operations have simulated (or
measured) durations, upstream streams are delivered as chunks, and the §9
machinery (re-estimation, mid-stream cancel, fractional waste) runs against
them.  A wall-clock threaded executor backed by the serving engine lives in
``repro.serving.spec_bridge``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from .admissibility import AdmissibilityTag, CommitBarrier, NonSpeculableError
from .decision import Decision, DecisionInputs, DecisionResult, evaluate
from .planner import Plan, PlannerParams
from .posterior import BetaPosterior
from .predictor import InputPredictor, Prediction
from .pricing import TwoRateTokenCost, get_pricing
from .streaming import RhoEstimator, fractional_waste
from .success import check_success
from .telemetry import SpeculationDecision, TelemetryLog, new_decision_id
from .workflow import Edge, Workflow

__all__ = ["ExecutorConfig", "SpeculationOutcome", "ExecutionReport", "execute"]


@dataclasses.dataclass
class ExecutorConfig:
    params: PlannerParams
    telemetry: TelemetryLog = dataclasses.field(default_factory=TelemetryLog)
    # i_hat predictors per edge (§3.2); edges without one cannot speculate
    predictors: dict[tuple[str, str], InputPredictor] = dataclasses.field(default_factory=dict)
    # streaming refiners per edge: (upstream_input, partial_chunks) -> (i_hat, P_k)
    stream_refiners: dict[tuple[str, str], Callable[[Any, list], tuple[Any, float]]] = (
        dataclasses.field(default_factory=dict)
    )
    # runtime-mutable alpha (§5.2): a function of simulated time
    alpha_fn: Optional[Callable[[float], float]] = None
    # §9.1 throttling: re-estimate every N chunks
    throttle_every: int = 1
    rho_estimators: dict[tuple[str, str], RhoEstimator] = dataclasses.field(default_factory=dict)
    tenant: str = "default"
    trace_id: str = "trace-0"
    # chunk count for simulated streams
    default_chunks: int = 10
    use_lower_bound: bool = False
    gamma: float = 0.1

    def alpha_at(self, t: float) -> float:
        return self.alpha_fn(t) if self.alpha_fn is not None else self.params.alpha


@dataclasses.dataclass
class SpeculationOutcome:
    edge: tuple[str, str]
    launched: bool
    committed: bool
    cancelled_mid_stream: bool
    cancel_fraction: Optional[float]
    waste_usd: float
    latency_saved_s: float
    i_hat: Any = None
    i_actual: Any = None
    decision_row: Optional[SpeculationDecision] = None


@dataclasses.dataclass
class ExecutionReport:
    outputs: dict[str, Any]
    finish_times_s: dict[str, float]
    makespan_s: float
    base_cost_usd: float
    waste_usd: float
    outcomes: list[SpeculationOutcome]
    overrides: list[tuple[tuple[str, str], str]]  # (edge, "upgrade"/"downgrade")

    @property
    def total_cost_usd(self) -> float:
        return self.base_cost_usd + self.waste_usd


def _op_duration(wf: Workflow, name: str) -> float:
    op = wf.ops[name]
    return float(op.metadata.get("sim_latency_s", op.latency_est_s))


def _op_cost(wf: Workflow, name: str) -> tuple[float, TwoRateTokenCost]:
    op = wf.ops[name]
    pricing = get_pricing(op.provider, op.model)
    cm = TwoRateTokenCost.from_entry(pricing)
    return cm.cost(op.input_tokens_est, op.output_tokens_est), cm


def _decision_inputs(
    wf: Workflow, edge: Edge, post: BetaPosterior, cfg: ExecutorConfig, t: float
) -> DecisionInputs:
    op = wf.ops[edge.downstream]
    up = wf.ops[edge.upstream]
    pricing = get_pricing(op.provider, op.model)
    L = cfg.params.latency_savings_s.get(
        edge.key, min(up.latency_est_s, op.latency_est_s)
    )
    return DecisionInputs(
        P=post.mean,
        alpha=cfg.alpha_at(t),
        lambda_usd_per_s=cfg.params.lambda_usd_per_s,
        latency_seconds=L,
        input_tokens=op.input_tokens_est,
        output_tokens=op.output_tokens_est,
        input_price=pricing.input_price_per_token,
        output_price=pricing.output_price_per_token,
        P_lower_bound=post.lower_bound(cfg.gamma) if cfg.use_lower_bound else None,
    )


def _emit_row(
    cfg: ExecutorConfig,
    wf: Workflow,
    edge: Edge,
    post: BetaPosterior,
    res: DecisionResult,
    inputs: DecisionInputs,
    phase: str,
    overrode: str,
    i_hat_source: str,
) -> SpeculationDecision:
    op = wf.ops[edge.downstream]
    row = SpeculationDecision(
        decision_id=new_decision_id(),
        trace_id=cfg.trace_id,
        edge=edge.key,
        dep_type=edge.dep_type.value,
        tenant=cfg.tenant,
        model_version=(op.model, op.metadata.get("model_version", "v1")),
        alpha=inputs.alpha,
        lambda_usd_per_s=inputs.lambda_usd_per_s,
        P_mean=post.mean,
        P_lower_bound=inputs.P_lower_bound,
        C_spec_est_usd=res.C_spec_usd,
        L_est_s=inputs.latency_seconds,
        input_tokens_est=inputs.input_tokens,
        output_tokens_est=int(inputs.output_tokens),
        input_price=inputs.input_price,
        output_price=inputs.output_price,
        EV_usd=res.EV_usd,
        threshold_usd=res.threshold_usd,
        decision=res.decision.value,
        phase=phase,  # type: ignore[arg-type]
        overrode=overrode,  # type: ignore[arg-type]
        i_hat_source=i_hat_source,  # type: ignore[arg-type]
        uncertain_cost_flag=bool(op.metadata.get("uncertain_cost", False)),
        enabled=edge.enabled,
        budget_remaining_usd=None,
    )
    return cfg.telemetry.emit(row)


def execute(wf: Workflow, plan: Plan, cfg: ExecutorConfig) -> ExecutionReport:
    """Run the workflow under the plan with Phase-2 re-evaluation.

    Deterministic: same workflow + plan + config -> same report.
    """
    if not wf.frozen:
        raise ValueError("execute requires a frozen workflow")
    params = cfg.params
    outputs: dict[str, Any] = {}
    start: dict[str, float] = {}
    finish: dict[str, float] = {}
    base_cost = 0.0
    waste = 0.0
    outcomes: list[SpeculationOutcome] = []
    overrides: list[tuple[tuple[str, str], str]] = []

    # map: downstream op -> the edge the plan considered for speculation
    plan_edges: dict[str, Edge] = {}
    for key in plan.decisions:
        plan_edges[key[1]] = wf.edges[key]

    for name in wf.topo_order():
        op = wf.ops[name]
        parents = wf.parents(name)
        dur = _op_duration(wf, name)
        cost, cost_model = _op_cost(wf, name)

        edge = plan_edges.get(name)
        spec_edge: Optional[Edge] = None
        if edge is not None and edge.enabled:
            # Phase-2 re-evaluation at the moment u starts (launch point for v)
            u = edge.upstream
            t_eval = start[u]
            post = params.posterior_for(edge)
            inputs = _decision_inputs(wf, edge, post, cfg, t_eval)
            res = evaluate(inputs, use_lower_bound=cfg.use_lower_bound)
            plan_decision = plan.decisions[edge.key].decision
            overrode = "none"
            if res.decision != plan_decision:
                overrode = (
                    "upgrade" if res.decision == Decision.SPECULATE else "downgrade"
                )
                overrides.append((edge.key, overrode))
            predictor = cfg.predictors.get(edge.key)
            i_hat_source = "modal"
            row = None
            if res.decision == Decision.SPECULATE and predictor is not None:
                if op.admissibility == AdmissibilityTag.NON_SPECULABLE:
                    raise NonSpeculableError(
                        f"edge {edge.key} tagged non_speculable reached launch"
                    )
                spec_edge = edge
            if predictor is not None:
                i_hat_source = getattr(predictor, "source", None) or "modal"
            row = _emit_row(
                cfg, wf, edge, post, res, inputs, "runtime", overrode,
                i_hat_source if i_hat_source in (
                    "modal", "regex", "historical", "stream_k", "auxiliary_model"
                ) else "modal",
            )

        if spec_edge is None:
            # plain execution: start when all parents finished
            t0 = max((finish[p] for p in parents), default=0.0)
            args = [outputs[p] for p in parents]
            outputs[name] = op.run(*args) if args else op.run(op.metadata.get("input"))
            start[name], finish[name] = t0, t0 + dur
            base_cost += cost
            _release_effect(op, outputs[name])
            if edge is not None:
                # WAIT decision resolved: record the realized i for replay and
                # label the trial if a prediction existed (counterfactual).
                row.i_actual = _safe(outputs[edge.upstream])
                row.latency_actual_s = dur
                row.committed_speculative = False
            continue

        # ---------------------------------------------------- speculative path
        u = spec_edge.upstream
        post = params.posterior_for(spec_edge)
        predictor = cfg.predictors[spec_edge.key]
        upstream_input = wf.ops[u].metadata.get("input")
        prediction: Optional[Prediction] = predictor.predict(upstream_input)
        other_ready = max(
            (finish[p] for p in parents if p != u), default=0.0
        )
        if prediction is None:
            # no i_hat available at launch time -> out of scope for this edge
            # (§1.4); fall back to waiting.
            t0 = max(finish[p] for p in parents)
            outputs[name] = op.run(*[outputs[p] for p in parents])
            start[name], finish[name] = t0, t0 + dur
            base_cost += cost
            _release_effect(op, outputs[name])
            continue

        t_launch = max(start[u] + predictor.cost_estimate_s, other_ready)
        i_hat = prediction.i_hat
        u_dur = finish[u] - start[u]
        n_chunks = int(wf.ops[u].metadata.get("chunks", cfg.default_chunks))
        refine = cfg.stream_refiners.get(spec_edge.key)

        # run the speculative downstream against i_hat (staged if barriered)
        spec_args = [i_hat if p == u else outputs[p] for p in parents]
        barrier = _make_barrier(op)
        spec_output = _run_maybe_staged(op, barrier, *spec_args)

        # §9: streaming re-estimation while u generates
        cancelled, cancel_t, cancel_frac = False, None, None
        if wf.ops[u].streams and refine is not None and n_chunks > 0:
            u_out = outputs[u] if u in outputs else None
            chunks = _chunk(u_out, n_chunks)
            partial: list[Any] = []
            for ci, chunk in enumerate(chunks):
                partial.append(chunk)
                if ci % cfg.throttle_every != 0:
                    continue
                t_chunk = start[u] + (ci + 1) / n_chunks * u_dur
                i_hat_k, P_k = refine(upstream_input, partial)
                inputs_k = dataclasses.replace(
                    _decision_inputs(wf, spec_edge, post, cfg, t_chunk), P=P_k
                )
                res_k = evaluate(inputs_k)
                if res_k.decision == Decision.WAIT:
                    cancelled, cancel_t = True, t_chunk
                    elapsed = max(0.0, t_chunk - t_launch)
                    cancel_frac = min(1.0, elapsed / dur) if dur > 0 else 1.0
                    break
                if i_hat_k is not None:
                    i_hat = i_hat_k  # refined prediction carries forward

        i_actual = outputs[u]
        check = check_success(i_actual, i_hat, spec_edge.tier_policy)

        out_tokens = op.output_tokens_est
        if cancelled:
            frac = cancel_frac if cancel_frac is not None else 1.0
            w = fractional_waste(
                cost_model, op.input_tokens_est, out_tokens, frac * out_tokens
            )
            if spec_edge.key in cfg.rho_estimators:
                cfg.rho_estimators[spec_edge.key].observe(frac)
            waste += w
            if barrier is not None:
                barrier.drop()
            post.update(False)  # cancelled failures are real failures (§10.3)
            t0 = finish[u]
            outputs[name] = op.run(*[outputs[p] for p in parents])
            start[name], finish[name] = t0, t0 + dur
            base_cost += cost
            _release_effect(op, outputs[name])
            outcomes.append(
                SpeculationOutcome(
                    spec_edge.key, True, False, True, cancel_frac, w, 0.0,
                    i_hat, _safe(i_actual), row,
                )
            )
            _fill_row(row, i_actual, check, False, w, frac * out_tokens, dur)
            continue

        if check.success:
            # commit: speculative result reused; cost would be paid either way
            outputs[name] = spec_output
            commit_t = max(t_launch + dur, finish[u])
            saved = (finish[u] + dur) - commit_t
            start[name], finish[name] = t_launch, commit_t
            base_cost += cost
            if barrier is not None:
                barrier.commit()
            else:
                _release_effect(op, outputs[name])
            post.update(True)
            outcomes.append(
                SpeculationOutcome(
                    spec_edge.key, True, True, False, None, 0.0, saved,
                    i_hat, _safe(i_actual), row,
                )
            )
            _fill_row(row, i_actual, check, True, cost, out_tokens, commit_t - t_launch)
        else:
            # tier failure at u's completion: cancel + re-execute with i
            elapsed = max(0.0, finish[u] - t_launch)
            frac = min(1.0, elapsed / dur) if dur > 0 else 1.0
            if not op.streams:
                frac = 1.0  # no mid-stream cancel -> full C_spec (§14.1)
            w = fractional_waste(
                cost_model, op.input_tokens_est, out_tokens, frac * out_tokens
            )
            if spec_edge.key in cfg.rho_estimators and op.streams:
                cfg.rho_estimators[spec_edge.key].observe(frac)
            waste += w
            if barrier is not None:
                barrier.drop()
            post.update(False)
            t0 = finish[u]
            outputs[name] = op.run(*[outputs[p] for p in parents])
            start[name], finish[name] = t0, t0 + dur
            base_cost += cost
            _release_effect(op, outputs[name])
            outcomes.append(
                SpeculationOutcome(
                    spec_edge.key, True, False, False, frac, w, 0.0,
                    i_hat, _safe(i_actual), row,
                )
            )
            _fill_row(row, i_actual, check, False, w, frac * out_tokens, dur)

    makespan = max(finish.values(), default=0.0)
    return ExecutionReport(
        outputs=outputs,
        finish_times_s=finish,
        makespan_s=makespan,
        base_cost_usd=base_cost,
        waste_usd=waste,
        outcomes=outcomes,
        overrides=overrides,
    )


# --------------------------------------------------------------------- helpers
def _chunk(output: Any, n: int) -> list[Any]:
    if isinstance(output, str) and len(output) >= n:
        size = max(1, len(output) // n)
        return [output[i : i + size] for i in range(0, len(output), size)][:n]
    if isinstance(output, (list, tuple)) and len(output) >= n:
        return list(output)[:n]
    return [output] * n  # opaque outputs: n identical progress ticks


def _make_barrier(op) -> Optional[CommitBarrier]:
    if op.admissibility != AdmissibilityTag.COMMIT_BARRIER:
        return None
    effect = op.metadata.get("effect")
    sink = op.metadata.setdefault("released_effects", [])
    release = effect if callable(effect) else sink.append
    return CommitBarrier(release=release)


def _run_maybe_staged(op, barrier: Optional[CommitBarrier], *args: Any) -> Any:
    out = op.run(*args)
    if barrier is not None:
        barrier.stage(out)
    return out


def _release_effect(op, output: Any) -> None:
    effect = op.metadata.get("effect")
    if callable(effect):
        effect(output)
    elif op.admissibility == AdmissibilityTag.COMMIT_BARRIER:
        op.metadata.setdefault("released_effects", []).append(output)


def _safe(o: Any) -> Any:
    return o


def _fill_row(
    row: Optional[SpeculationDecision],
    i_actual: Any,
    check,
    committed: bool,
    c_actual: float,
    tokens_generated: float,
    latency_s: float,
) -> None:
    if row is None:
        return
    row.i_actual = i_actual
    row.tier1_match = check.tier1_match
    row.tier2_match = check.tier2_match
    row.tier3_accept = check.tier3_accept
    row.committed_speculative = committed
    row.C_spec_actual_usd = c_actual
    row.tokens_generated_before_cancel = int(tokens_generated)
    row.latency_actual_s = latency_s
