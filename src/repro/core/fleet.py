"""Vectorized fleet-scale replay engine (beyond-paper fast path).

The paper's calibration pipeline (§12: offline replay, shadow, canary,
online calibration) replays millions of logged decisions across an
(alpha, lambda) grid.  The paper-faithful discrete-event executor
(``repro.core.executor``) walks one episode at a time in Python; this
module lowers a frozen :class:`~repro.core.workflow.Workflow` DAG into
dense arrays and simulates

    episodes x (alpha, lambda) grid points x DAG ops

in a **single jit-compiled XLA call**: ``lax.scan`` over episodes (the
per-edge Beta posterior is the sequential carry, exactly as the scalar
path threads one ``BetaPosterior`` through a sweep), ``vmap`` over grid
points, and an inner ``lax.scan`` over ops in topological order (a
topological schedule of the DAG).

Semantics mirror ``executor.execute`` exactly — Phase-2 re-evaluation at
the upstream's start time, speculative launch/commit/cancel timing,
per-chunk streaming re-estimation (§9.1), fractional waste (§9.3),
discounted Beta updates (§14.3 / posterior.py) — and the parity suite
(tests/test_fleet_parity.py) asserts float64 agreement with the scalar
path on randomized DAGs: decisions, counts, event times and posterior
trajectories bitwise; EV/waste to 1 ULP (XLA contracts a*b + c into a
single FMA where CPython rounds twice).

Scope (checked at lowering time):

* at most one speculation-candidate edge per downstream op (the scalar
  executor has the same single-edge-per-op structure via its
  ``plan_edges`` map);
* constant alpha per grid point (no ``alpha_fn``); gating on either the
  posterior mean or the §7.5 one-sided credible bound
  (``use_lower_bound=True`` replaces ``a / (a + b)`` with the jax-native
  ``betaincinv(a, b, gamma)`` from ``repro.core.betainc`` inside the
  episode carry, so conservative-mode calibration sweeps stay one XLA
  call);
* predictions are summarized per episode as (exists, tier-success)
  booleans plus optional per-chunk confidences P_k — i.e. the replay
  consumes §7.4-labelled logs, it does not re-run predictors.

Recorded in EXPERIMENTS.md §Perf next to the scalar path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .admissibility import AdmissibilityTag
from .batch_decision import _f  # widest-enabled-float coercion, shared
from .betainc import betaincinv
from .planner import PlannerParams
from .workflow import Workflow

__all__ = ["FleetLowered", "FleetReport", "lower_workflow", "fleet_replay"]


# ----------------------------------------------------------------- lowering
@dataclasses.dataclass(frozen=True)
class FleetLowered:
    """A frozen Workflow as dense arrays, ops indexed in topological order.

    Per-op edge fields describe the (unique) speculation-candidate edge
    into that op; ``has_edge`` masks ops without one.
    """

    names: tuple[str, ...]
    dur: np.ndarray            # (V,) simulated op duration (s)
    op_cost: np.ndarray        # (V,) base op cost (USD)
    parent_mask: np.ndarray    # (V, V) bool; parent_mask[v, u] = u -> v
    has_edge: np.ndarray       # (V,) bool: candidate edge into v exists
    u_onehot: np.ndarray       # (V, V) bool one-hot of the spec upstream
    u_streams: np.ndarray      # (V,) bool: upstream streams (enables §9)
    lat_save: np.ndarray       # (V,) latency savings L for the edge (s)
    in_tok: np.ndarray         # (V,) downstream input tokens
    out_tok: np.ndarray        # (V,) downstream output tokens
    in_price: np.ndarray       # (V,) USD / input token
    out_price: np.ndarray      # (V,) USD / output token
    pred_cost: np.ndarray      # (V,) predictor cost_estimate_s
    has_pred: np.ndarray       # (V,) bool: a predictor is attached
    streams: np.ndarray        # (V,) bool: downstream streams (cancel -> frac)
    has_refiner: np.ndarray    # (V,) bool: stream refiner attached (§9.1)
    n_chunks: np.ndarray       # (V,) upstream chunk count
    a0: np.ndarray             # (V,) prior Beta alpha per edge
    b0: np.ndarray             # (V,) prior Beta beta per edge
    discount: np.ndarray       # (V,) exponential forgetting factor
    # §7.5 credible-bound gating (from PlannerParams): gate the D4 rule on
    # Beta^{-1}(gamma; a, b) instead of the posterior mean
    use_lower_bound: bool = False
    gamma: float = 0.1

    @property
    def n_ops(self) -> int:
        return len(self.names)

    def edge_ops(self) -> list[int]:
        """Indices of ops with a speculation-candidate edge."""
        return [i for i in range(self.n_ops) if self.has_edge[i]]


def lower_workflow(
    wf: Workflow,
    params: PlannerParams,
    predictors: Optional[dict] = None,
    stream_refiners: Optional[dict] = None,
    *,
    default_chunks: int = 10,
) -> FleetLowered:
    """Lower a frozen workflow + planner params to dense episode arrays.

    Mirrors the scalar path's per-edge inputs: latency savings default to
    min(lat_u, lat_v), prices come from the downstream op's pricing entry,
    priors from ``params.posterior_for`` (so data-seeded / discounted
    posteriors carry over).

    §7.5 gating is taken from ``params.use_lower_bound`` / ``params.gamma``
    (the planner-side knobs).  The scalar executor reads its *own*
    ``ExecutorConfig.use_lower_bound`` / ``gamma`` for Phase-2, so when
    comparing fleet output against ``execute`` keep both objects set to
    the same values — the parity suite and benchmarks thread them in
    tandem.
    """
    from .pricing import get_pricing

    if not wf.frozen:
        raise ValueError("lower_workflow requires a frozen workflow")
    predictors = predictors or {}
    stream_refiners = stream_refiners or {}
    topo = wf.topo_order()
    idx = {n: i for i, n in enumerate(topo)}
    V = len(topo)

    dur = np.zeros(V)
    op_cost = np.zeros(V)
    parent_mask = np.zeros((V, V), bool)
    has_edge = np.zeros(V, bool)
    u_onehot = np.zeros((V, V), bool)
    u_streams = np.zeros(V, bool)
    lat_save = np.zeros(V)
    in_tok = np.zeros(V)
    out_tok = np.zeros(V)
    in_price = np.zeros(V)
    out_price = np.zeros(V)
    pred_cost = np.zeros(V)
    has_pred = np.zeros(V, bool)
    streams = np.zeros(V, bool)
    has_refiner = np.zeros(V, bool)
    n_chunks = np.zeros(V)
    a0 = np.ones(V)
    b0 = np.ones(V)
    discount = np.ones(V)

    candidates = {}
    for edge in wf.speculation_candidates():
        v = edge.downstream
        if v in candidates:
            raise NotImplementedError(
                f"op {v!r} has multiple speculation-candidate edges; the "
                "fleet lowering (like the scalar executor's plan_edges map) "
                "supports one per downstream op"
            )
        candidates[v] = edge

    for name, i in idx.items():
        op = wf.ops[name]
        dur[i] = float(op.metadata.get("sim_latency_s", op.latency_est_s))
        pricing = get_pricing(op.provider, op.model)
        op_cost[i] = (
            op.input_tokens_est * pricing.input_price_per_token
            + op.output_tokens_est * pricing.output_price_per_token
        )
        for p in wf.parents(name):
            parent_mask[i, idx[p]] = True
        edge = candidates.get(name)
        if edge is None:
            continue
        if op.admissibility == AdmissibilityTag.NON_SPECULABLE:
            continue  # speculation_candidates already excludes these
        u = edge.upstream
        up = wf.ops[u]
        has_edge[i] = True
        u_onehot[i, idx[u]] = True
        u_streams[i] = up.streams
        lat_save[i] = params.latency_savings_s.get(
            edge.key, min(up.latency_est_s, op.latency_est_s)
        )
        in_tok[i] = op.input_tokens_est
        out_tok[i] = op.output_tokens_est
        in_price[i] = pricing.input_price_per_token
        out_price[i] = pricing.output_price_per_token
        pred = predictors.get(edge.key)
        has_pred[i] = pred is not None
        pred_cost[i] = getattr(pred, "cost_estimate_s", 0.0) if pred else 0.0
        streams[i] = op.streams
        has_refiner[i] = edge.key in stream_refiners
        n_chunks[i] = float(up.metadata.get("chunks", default_chunks))
        post = params.posterior_for(edge)
        a0[i], b0[i] = post.alpha, post.beta
        discount[i] = post.discount

    return FleetLowered(
        names=tuple(topo), dur=dur, op_cost=op_cost, parent_mask=parent_mask,
        has_edge=has_edge, u_onehot=u_onehot, u_streams=u_streams,
        lat_save=lat_save, in_tok=in_tok, out_tok=out_tok, in_price=in_price,
        out_price=out_price, pred_cost=pred_cost, has_pred=has_pred,
        streams=streams, has_refiner=has_refiner, n_chunks=n_chunks,
        a0=a0, b0=b0, discount=discount,
        use_lower_bound=bool(params.use_lower_bound),
        gamma=float(params.gamma),
    )


# -------------------------------------------------------------------- report
@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Aggregates plus full per-episode trajectories.

    All arrays are numpy; shapes use G = grid points, E = episodes,
    V = ops in topo order (per-edge fields valid where ``has_edge``).
    """

    alphas: np.ndarray          # (G,)
    lambdas: np.ndarray         # (G,)
    makespan_s: np.ndarray      # (E, G)
    total_cost_usd: np.ndarray  # (E, G)
    waste_usd: np.ndarray       # (E, G)
    launched: np.ndarray        # (E, G)
    committed: np.ndarray       # (E, G)
    cancelled: np.ndarray       # (E, G)
    EV_usd: np.ndarray          # (E, G, V) Phase-2 EV per candidate edge
    threshold_usd: np.ndarray   # (E, G, V)
    speculate: np.ndarray       # (E, G, V) Phase-2 D4 verdict
    edge_launched: np.ndarray   # (E, G, V)
    edge_committed: np.ndarray  # (E, G, V)
    edge_waste_usd: np.ndarray  # (E, G, V)
    start_s: np.ndarray         # (E, G, V)
    finish_s: np.ndarray        # (E, G, V)
    post_alpha: np.ndarray      # (E, G, V) posterior after each episode
    post_beta: np.ndarray       # (E, G, V)

    def pareto(self) -> dict:
        """Per-grid-point mean (latency, cost, waste) — the §12.3 canary
        Pareto the calibration stage consumes."""
        return {
            "alphas": self.alphas,
            "lambdas": self.lambdas,
            "latency_s": self.makespan_s.mean(0),
            "cost_usd": self.total_cost_usd.mean(0),
            "waste_usd": self.waste_usd.mean(0),
            "launched": self.launched.sum(0),
            "committed": self.committed.sum(0),
        }


# -------------------------------------------------------------- fleet sweep
def fleet_replay(
    lowered: FleetLowered,
    success: np.ndarray,
    alphas,
    lambdas,
    *,
    pred_ok: Optional[np.ndarray] = None,
    chunk_P: Optional[np.ndarray] = None,
    throttle_every: int = 1,
) -> FleetReport:
    """Replay E episodes x G grid points in one jit'd XLA call.

    Args:
      lowered: output of :func:`lower_workflow`.
      success: (E, V) bool — per-episode tier success of the candidate
        edge into op v (§7.4 label); ignored where ``has_edge`` is False.
      alphas / lambdas: length-G paired grid points (mesh them for a full
        §12.1 cross product); a scalar lambda broadcasts over alphas.
      pred_ok: (E, V) bool — a prediction existed at launch (default: the
        lowering's ``has_pred``).
      chunk_P: (E, V, K) refined per-chunk confidences P_k for §9.1
        mid-stream re-estimation; omit to disable streaming cancels.
      throttle_every: §9.1 throttling — re-evaluate every N chunks.

    The per-edge Beta posterior is carried sequentially across episodes
    (scan), independently per grid point (vmap), exactly like running the
    scalar sweep once per grid point.  When the lowering carries
    ``use_lower_bound=True`` (§7.5), the Phase-2 gate inverts the carried
    posterior — ``betaincinv(a, b, gamma)`` — in place of the mean, so
    the conservative mode tracks the evolving counts exactly like the
    scalar executor's ``post.lower_bound(gamma)``.
    """
    success = np.asarray(success, bool)
    E, V = success.shape
    if V != lowered.n_ops:
        raise ValueError(f"success has {V} ops, workflow has {lowered.n_ops}")
    alphas = np.atleast_1d(np.asarray(alphas, float))
    lambdas = np.atleast_1d(np.asarray(lambdas, float))
    if lambdas.shape[0] == 1 and alphas.shape[0] > 1:
        lambdas = np.broadcast_to(lambdas, alphas.shape).copy()
    if alphas.shape != lambdas.shape:
        raise ValueError("alphas and lambdas must be paired (same length)")
    if pred_ok is None:
        pred_ok = np.broadcast_to(lowered.has_pred, (E, V)).copy()
    if chunk_P is None:
        K = 1
        chunk_P = np.ones((E, V, 1))
        has_refiner = np.zeros(V, bool)
    else:
        chunk_P = np.asarray(chunk_P, float)
        K = chunk_P.shape[-1]
        has_refiner = lowered.has_refiner

    ys = _fleet_scan(
        _pack_static(lowered, has_refiner),
        _f(lowered.a0), _f(lowered.b0), _f(lowered.discount),
        _f(alphas), _f(lambdas), _f(lowered.gamma),
        jnp.asarray(success), jnp.asarray(pred_ok, bool),
        _f(chunk_P), int(throttle_every), int(K),
        bool(lowered.use_lower_bound),
    )
    np_out = {k: np.asarray(v) for k, v in ys.items()}
    return FleetReport(alphas=alphas, lambdas=lambdas, **np_out)


def _pack_static(lowered: FleetLowered, has_refiner: np.ndarray):
    return (
        jnp.asarray(lowered.parent_mask),
        jnp.asarray(lowered.u_onehot),
        _f(lowered.dur), _f(lowered.op_cost),
        jnp.asarray(lowered.has_edge),
        jnp.asarray(lowered.u_streams),
        _f(lowered.lat_save), _f(lowered.in_tok), _f(lowered.out_tok),
        _f(lowered.in_price), _f(lowered.out_price), _f(lowered.pred_cost),
        jnp.asarray(lowered.has_pred),
        jnp.asarray(lowered.streams),
        jnp.asarray(has_refiner),
        _f(lowered.n_chunks),
    )


@functools.partial(
    jax.jit, static_argnames=("throttle_every", "K", "use_lower_bound")
)
def _fleet_scan(static, a0, b0, discount, alphas, lambdas, gamma,
                success, pred_ok, chunk_P, throttle_every, K,
                use_lower_bound):
    G = alphas.shape[0]
    V = a0.shape[0]
    post0 = jnp.broadcast_to(jnp.stack([a0, b0], -1)[None], (G, V, 2))

    episode = functools.partial(
        _episode, static, discount, (K, throttle_every),
        use_lower_bound, gamma,
    )

    def ep_step(post_ab, xs):
        succ_e, pred_e, chunks_e = xs
        # vmap over grid points: independent posterior trajectory each
        post_new, stats = jax.vmap(
            episode, in_axes=(0, 0, 0, None, None, None)
        )(post_ab, alphas, lambdas, succ_e, pred_e, chunks_e)
        return post_new, stats

    _, ys = jax.lax.scan(ep_step, post0, (success, pred_ok, chunk_P))
    return ys


def _episode(static, discount, chunk_cfg, use_lower_bound, gamma,
             post_ab, alpha, lam, succ, pred_ok, chunk_P):
    """One episode at one grid point.  All per-op arrays have length V."""
    (parent_mask, u_onehot, dur, op_cost, has_edge, u_streams, lat_save,
     in_tok, out_tok, in_price, out_price, pred_cost, has_pred, streams,
     has_refiner, n_chunks) = static
    K, throttle_every = chunk_cfg
    V = dur.shape[0]
    a, b = post_ab[:, 0], post_ab[:, 1]
    if use_lower_bound:
        # §7.5 conservative gate: one-sided (1-gamma) lower credible
        # bound, inverted from the carried counts inside the scan —
        # mirrors the scalar path's post.lower_bound(gamma) per episode.
        P = betaincinv(a, b, gamma)
    else:
        P = a / (a + b)
    neg = jnp.asarray(-jnp.inf, dur.dtype)

    # Phase-2 D4 gate, identical expression order to decision.evaluate
    # (§6.1) so float64 results match the scalar path bitwise
    C_spec = in_tok * in_price + out_tok * out_price
    L_value = lat_save * lam
    EV = P * L_value - (1.0 - P) * C_spec
    threshold = (1.0 - alpha) * C_spec
    spec_dec = EV >= threshold
    c_in = in_tok * in_price

    k_idx = jnp.arange(K)

    def step(carry, xs):
        start, finish = carry
        (pmask, umask, dur_v, spec_v, pc_v, launch_gate_v, streams_v,
         u_streams_v, has_ref_v, nch_v, c_in_v, out_tok_v, out_price_v,
         Lval_v, Cspec_v, thr_v, succ_v, pred_ok_v, P_chunks_v, vmask) = xs
        # plain-path ready time: all parents finished
        t_ready = jnp.max(jnp.where(pmask, finish, neg), initial=0.0)
        start_u = jnp.sum(jnp.where(umask, start, 0.0))
        finish_u = jnp.sum(jnp.where(umask, finish, 0.0))
        other_ready = jnp.max(jnp.where(pmask & ~umask, finish, neg),
                              initial=0.0)
        launched = spec_v & launch_gate_v & pred_ok_v
        t_launch = jnp.maximum(start_u + pc_v, other_ready)

        # §9.1 vectorized per-chunk re-estimation: EV_k with refined P_k,
        # same L_value / C_spec / threshold; first WAIT verdict cancels
        u_dur = finish_u - start_u
        valid_k = (
            (k_idx < nch_v) & (k_idx % throttle_every == 0)
            & launched & u_streams_v & has_ref_v
        )
        EV_k = P_chunks_v * Lval_v - (1.0 - P_chunks_v) * Cspec_v
        cancel_k = valid_k & (EV_k < thr_v)
        cancelled = cancel_k.any()
        first_k = jnp.argmax(cancel_k)
        t_chunk = start_u + (first_k + 1.0) / jnp.maximum(nch_v, 1.0) * u_dur
        elapsed_c = jnp.maximum(0.0, t_chunk - t_launch)
        frac_c = jnp.where(dur_v > 0.0,
                           jnp.minimum(1.0, elapsed_c / dur_v), 1.0)

        committed = launched & succ_v & ~cancelled
        # timing mirrors executor.execute: commit at max(spec finish,
        # u finish); failure / cancel re-executes after u
        t1_commit = jnp.maximum(t_launch + dur_v, finish_u)
        t0 = jnp.where(committed, t_launch,
                       jnp.where(launched, finish_u, t_ready))
        t1 = jnp.where(committed, t1_commit,
                       jnp.where(launched, finish_u + dur_v,
                                 t_ready + dur_v))

        # §9.3 fractional waste (fractional_waste expression order:
        # c_in + (frac * out_tok) * out_price); non-streaming downstream
        # cannot cancel mid-generation -> full C_spec on tier failure
        elapsed_f = jnp.maximum(0.0, finish_u - t_launch)
        frac_f = jnp.where(dur_v > 0.0,
                           jnp.minimum(1.0, elapsed_f / dur_v), 1.0)
        frac_f = jnp.where(streams_v, frac_f, 1.0)
        frac = jnp.where(cancelled, frac_c, frac_f)
        waste_v = c_in_v + (frac * out_tok_v) * out_price_v
        waste_v = jnp.where(launched & ~committed, waste_v, 0.0)

        start = jnp.where(vmask, t0, start)
        finish = jnp.where(vmask, t1, finish)
        outs = (launched, committed, launched & cancelled, waste_v, t0, t1)
        return (start, finish), outs

    xs = (
        parent_mask, u_onehot, dur, spec_dec, pred_cost,
        has_edge & has_pred, streams, u_streams, has_refiner, n_chunks,
        c_in, out_tok, out_price, L_value, C_spec, threshold,
        succ, pred_ok, chunk_P, jnp.eye(V, dtype=bool),
    )
    init = (jnp.zeros(V, dur.dtype), jnp.zeros(V, dur.dtype))
    (start, finish), (launched, committed, cancelled, waste,
                      t0s, t1s) = jax.lax.scan(step, init, xs)

    # discounted conjugate update (BetaPosterior.update, §14.3): only
    # launched edges observe a Bernoulli trial; d=1 reduces to a+1 / b+1
    suc_f = committed.astype(a.dtype)
    a_new = jnp.where(launched, a * discount + suc_f, a)
    b_new = jnp.where(launched, b * discount + (1.0 - suc_f), b)
    post_new = jnp.stack([a_new, b_new], -1)

    waste_total = waste.sum()
    stats = {
        "makespan_s": jnp.max(finish, initial=0.0),
        "total_cost_usd": op_cost.sum() + waste_total,
        "waste_usd": waste_total,
        "launched": launched.sum(),
        "committed": committed.sum(),
        "cancelled": cancelled.sum(),
        "EV_usd": EV,
        "threshold_usd": threshold,
        "speculate": spec_dec,
        "edge_launched": launched,
        "edge_committed": committed,
        "edge_waste_usd": waste,
        "start_s": t0s,
        "finish_s": t1s,
        "post_alpha": a_new,
        "post_beta": b_new,
    }
    return post_new, stats
