"""Vectorized fleet-scale replay engine (beyond-paper fast path).

The paper's calibration pipeline (§12: offline replay, shadow, canary,
online calibration) replays millions of logged decisions across an
(alpha, lambda) grid.  The paper-faithful discrete-event executor
(``repro.core.executor``) walks one episode at a time in Python; this
module lowers a frozen :class:`~repro.core.workflow.Workflow` DAG into
dense arrays and simulates

    episodes x (alpha, lambda) grid points x DAG ops

in a **single jit-compiled XLA call**: ``lax.scan`` over episodes (the
per-edge Beta posterior is the sequential carry, exactly as the scalar
path threads one ``BetaPosterior`` through a sweep), ``vmap`` over grid
points, and an inner ``lax.scan`` over ops in topological order (a
topological schedule of the DAG).

Semantics mirror ``executor.execute`` exactly — Phase-2 re-evaluation at
the upstream's start time, speculative launch/commit/cancel timing,
per-chunk streaming re-estimation (§9.1), fractional waste (§9.3),
discounted Beta updates (§14.3 / posterior.py) — and the parity suite
(tests/test_fleet_parity.py) asserts float64 agreement with the scalar
path on randomized DAGs: decisions, counts, event times and posterior
trajectories bitwise; EV/waste to 1 ULP (XLA contracts a*b + c into a
single FMA where CPython rounds twice).

Scope (checked at lowering time):

* at most one speculation-candidate edge per downstream op (the scalar
  executor has the same single-edge-per-op structure via its
  ``plan_edges`` map);
* constant alpha per grid point (no ``alpha_fn``); gating on either the
  posterior mean or the §7.5 one-sided credible bound
  (``use_lower_bound=True`` replaces ``a / (a + b)`` with the jax-native
  ``betaincinv(a, b, gamma)`` from ``repro.core.betainc`` inside the
  episode carry, so conservative-mode calibration sweeps stay one XLA
  call);
* predictions are summarized per episode as (exists, tier-success)
  booleans plus optional per-chunk confidences P_k — i.e. the replay
  consumes §7.4-labelled logs, it does not re-run predictors.

Recorded in EXPERIMENTS.md §Perf next to the scalar path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .admissibility import AdmissibilityTag
from .batch_decision import _f  # widest-enabled-float coercion, shared
from .betainc import betaincinv
from .planner import PlannerParams
from .workflow import Workflow

__all__ = [
    "EpisodeChunks",
    "FleetLowered",
    "FleetReport",
    "FleetStack",
    "MultiTenantReport",
    "lower_workflow",
    "fleet_replay",
    "chunk_episodes",
    "compose_segment_posteriors",
    "episode_sharded_replay",
    "stack_tenants",
    "multi_tenant_replay",
]


# ----------------------------------------------------------------- lowering
@dataclasses.dataclass(frozen=True)
class FleetLowered:
    """A frozen Workflow as dense arrays, ops indexed in topological order.

    Per-op edge fields describe the (unique) speculation-candidate edge
    into that op; ``has_edge`` masks ops without one.
    """

    names: tuple[str, ...]
    dur: np.ndarray            # (V,) simulated op duration (s)
    op_cost: np.ndarray        # (V,) base op cost (USD)
    parent_mask: np.ndarray    # (V, V) bool; parent_mask[v, u] = u -> v
    has_edge: np.ndarray       # (V,) bool: candidate edge into v exists
    u_onehot: np.ndarray       # (V, V) bool one-hot of the spec upstream
    u_streams: np.ndarray      # (V,) bool: upstream streams (enables §9)
    lat_save: np.ndarray       # (V,) latency savings L for the edge (s)
    in_tok: np.ndarray         # (V,) downstream input tokens
    out_tok: np.ndarray        # (V,) downstream output tokens
    in_price: np.ndarray       # (V,) USD / input token
    out_price: np.ndarray      # (V,) USD / output token
    pred_cost: np.ndarray      # (V,) predictor cost_estimate_s
    has_pred: np.ndarray       # (V,) bool: a predictor is attached
    streams: np.ndarray        # (V,) bool: downstream streams (cancel -> frac)
    has_refiner: np.ndarray    # (V,) bool: stream refiner attached (§9.1)
    n_chunks: np.ndarray       # (V,) upstream chunk count
    a0: np.ndarray             # (V,) prior Beta alpha per edge
    b0: np.ndarray             # (V,) prior Beta beta per edge
    discount: np.ndarray       # (V,) exponential forgetting factor
    # §7.5 credible-bound gating (from PlannerParams): gate the D4 rule on
    # Beta^{-1}(gamma; a, b) instead of the posterior mean
    use_lower_bound: bool = False
    gamma: float = 0.1
    # top-k beam speculation (repro.core.beam): per-op candidate predictor
    # confidences, sorted non-increasing along the row; None = the
    # single-candidate engine (equivalent to one certain candidate)
    beam_conf: Optional[np.ndarray] = None  # (V, W) or None

    @property
    def n_ops(self) -> int:
        return len(self.names)

    def edge_ops(self) -> list[int]:
        """Indices of ops with a speculation-candidate edge."""
        return [i for i in range(self.n_ops) if self.has_edge[i]]


def lower_workflow(
    wf: Workflow,
    params: PlannerParams,
    predictors: Optional[dict] = None,
    stream_refiners: Optional[dict] = None,
    *,
    default_chunks: int = 10,
    beam_confidences: Optional[dict] = None,
) -> FleetLowered:
    """Lower a frozen workflow + planner params to dense episode arrays.

    Mirrors the scalar path's per-edge inputs: latency savings default to
    min(lat_u, lat_v), prices come from the downstream op's pricing entry,
    priors from ``params.posterior_for`` (so data-seeded / discounted
    posteriors carry over).

    ``beam_confidences`` maps edge keys to per-candidate predictor
    confidence vectors (sorted non-increasing, summing to <= 1) for the
    top-k beam engine (``repro.core.beam``); edges without an entry keep
    the single-candidate default ``[1.0]``.  Omitting the mapping leaves
    ``beam_conf`` as None — the classic single-candidate lowering.

    §7.5 gating is taken from ``params.use_lower_bound`` / ``params.gamma``
    (the planner-side knobs).  The scalar executor reads its *own*
    ``ExecutorConfig.use_lower_bound`` / ``gamma`` for Phase-2, so when
    comparing fleet output against ``execute`` keep both objects set to
    the same values — the parity suite and benchmarks thread them in
    tandem.
    """
    from .pricing import get_pricing

    if not wf.frozen:
        raise ValueError("lower_workflow requires a frozen workflow")
    predictors = predictors or {}
    stream_refiners = stream_refiners or {}
    topo = wf.topo_order()
    idx = {n: i for i, n in enumerate(topo)}
    V = len(topo)

    dur = np.zeros(V)
    op_cost = np.zeros(V)
    parent_mask = np.zeros((V, V), bool)
    has_edge = np.zeros(V, bool)
    u_onehot = np.zeros((V, V), bool)
    u_streams = np.zeros(V, bool)
    lat_save = np.zeros(V)
    in_tok = np.zeros(V)
    out_tok = np.zeros(V)
    in_price = np.zeros(V)
    out_price = np.zeros(V)
    pred_cost = np.zeros(V)
    has_pred = np.zeros(V, bool)
    streams = np.zeros(V, bool)
    has_refiner = np.zeros(V, bool)
    n_chunks = np.zeros(V)
    a0 = np.ones(V)
    b0 = np.ones(V)
    discount = np.ones(V)

    candidates = {}
    for edge in wf.speculation_candidates():
        v = edge.downstream
        if v in candidates:
            raise NotImplementedError(
                f"op {v!r} has multiple speculation-candidate edges; the "
                "fleet lowering (like the scalar executor's plan_edges map) "
                "supports one per downstream op"
            )
        candidates[v] = edge

    for name, i in idx.items():
        op = wf.ops[name]
        dur[i] = float(op.metadata.get("sim_latency_s", op.latency_est_s))
        pricing = get_pricing(op.provider, op.model)
        op_cost[i] = (
            op.input_tokens_est * pricing.input_price_per_token
            + op.output_tokens_est * pricing.output_price_per_token
        )
        for p in wf.parents(name):
            parent_mask[i, idx[p]] = True
        edge = candidates.get(name)
        if edge is None:
            continue
        if op.admissibility == AdmissibilityTag.NON_SPECULABLE:
            continue  # speculation_candidates already excludes these
        u = edge.upstream
        up = wf.ops[u]
        has_edge[i] = True
        u_onehot[i, idx[u]] = True
        u_streams[i] = up.streams
        lat_save[i] = params.latency_savings_s.get(
            edge.key, min(up.latency_est_s, op.latency_est_s)
        )
        in_tok[i] = op.input_tokens_est
        out_tok[i] = op.output_tokens_est
        in_price[i] = pricing.input_price_per_token
        out_price[i] = pricing.output_price_per_token
        pred = predictors.get(edge.key)
        has_pred[i] = pred is not None
        pred_cost[i] = getattr(pred, "cost_estimate_s", 0.0) if pred else 0.0
        streams[i] = op.streams
        has_refiner[i] = edge.key in stream_refiners
        n_chunks[i] = float(up.metadata.get("chunks", default_chunks))
        post = params.posterior_for(edge)
        a0[i], b0[i] = post.alpha, post.beta
        discount[i] = post.discount

    beam_conf = None
    if beam_confidences:
        from .beam import validate_confidences

        rows = {}
        for key, confs in beam_confidences.items():
            v = key[1] if isinstance(key, tuple) else key
            if v not in idx:
                raise KeyError(f"beam_confidences names unknown op {v!r}")
            rows[idx[v]] = validate_confidences(confs)
        W = max(len(c) for c in rows.values())
        beam_conf = np.zeros((V, W))
        beam_conf[:, 0] = 1.0  # single certain candidate by default
        for i, confs in rows.items():
            beam_conf[i, : len(confs)] = confs

    return FleetLowered(
        names=tuple(topo), dur=dur, op_cost=op_cost, parent_mask=parent_mask,
        has_edge=has_edge, u_onehot=u_onehot, u_streams=u_streams,
        lat_save=lat_save, in_tok=in_tok, out_tok=out_tok, in_price=in_price,
        out_price=out_price, pred_cost=pred_cost, has_pred=has_pred,
        streams=streams, has_refiner=has_refiner, n_chunks=n_chunks,
        a0=a0, b0=b0, discount=discount,
        use_lower_bound=bool(params.use_lower_bound),
        gamma=float(params.gamma),
        beam_conf=beam_conf,
    )


# -------------------------------------------------------------------- report
@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Aggregates plus full per-episode trajectories.

    All arrays are numpy; shapes use G = grid points, E = episodes,
    V = ops in topo order (per-edge fields valid where ``has_edge``).
    """

    alphas: np.ndarray          # (G,)
    lambdas: np.ndarray         # (G,)
    makespan_s: np.ndarray      # (E, G)
    total_cost_usd: np.ndarray  # (E, G)
    waste_usd: np.ndarray       # (E, G)
    launched: np.ndarray        # (E, G)
    committed: np.ndarray       # (E, G)
    cancelled: np.ndarray       # (E, G)
    EV_usd: np.ndarray          # (E, G, V) Phase-2 EV per candidate edge
    threshold_usd: np.ndarray   # (E, G, V)
    speculate: np.ndarray       # (E, G, V) Phase-2 D4 verdict
    edge_launched: np.ndarray   # (E, G, V)
    edge_committed: np.ndarray  # (E, G, V)
    edge_waste_usd: np.ndarray  # (E, G, V)
    start_s: np.ndarray         # (E, G, V)
    finish_s: np.ndarray        # (E, G, V)
    post_alpha: np.ndarray      # (E, G, V) posterior after each episode
    post_beta: np.ndarray       # (E, G, V)
    ep_mask: np.ndarray = None  # (E,) bool; False rows were identity
                                # (padded) episodes with zeroed stats

    def pareto(self) -> dict:
        """Per-grid-point mean (latency, cost, waste) — the §12.3 canary
        Pareto the calibration stage consumes.  Means are taken over the
        real episodes only (``ep_mask``), so padded identity rows do not
        dilute the statistics."""
        rows = slice(None) if self.ep_mask is None else np.asarray(
            self.ep_mask, bool)
        return {
            "alphas": self.alphas,
            "lambdas": self.lambdas,
            "latency_s": self.makespan_s[rows].mean(0),
            "cost_usd": self.total_cost_usd[rows].mean(0),
            "waste_usd": self.waste_usd[rows].mean(0),
            "launched": self.launched[rows].sum(0),
            "committed": self.committed[rows].sum(0),
        }


# -------------------------------------------------------------- fleet sweep
def _normalize_grid(alphas, lambdas):
    """Paired (alpha, lambda) grid points; a scalar lambda broadcasts."""
    alphas = np.atleast_1d(np.asarray(alphas, float))
    lambdas = np.atleast_1d(np.asarray(lambdas, float))
    if lambdas.shape[0] == 1 and alphas.shape[0] > 1:
        lambdas = np.broadcast_to(lambdas, alphas.shape).copy()
    if alphas.shape != lambdas.shape:
        raise ValueError("alphas and lambdas must be paired (same length)")
    return alphas, lambdas


def _normalize_episodes(lowered, success, pred_ok, chunk_P, ep_mask):
    """Defaulted / validated episode arrays shared by ``fleet_replay``,
    :func:`chunk_episodes` and :func:`episode_sharded_replay`: ``pred_ok``
    defaults to the lowering's predictor mask, ``chunk_P`` to a single
    unit chunk with streaming refiners disabled, ``ep_mask`` to all-real
    episodes."""
    success = np.asarray(success, bool)
    if success.ndim != 2:
        raise ValueError("success must have shape (E, V)")
    E, V = success.shape
    if V != lowered.n_ops:
        raise ValueError(f"success has {V} ops, workflow has {lowered.n_ops}")
    if pred_ok is None:
        pred_ok = np.broadcast_to(lowered.has_pred, (E, V)).copy()
    if chunk_P is None:
        K = 1
        chunk_P = np.ones((E, V, 1))
        has_refiner = np.zeros(V, bool)
    else:
        chunk_P = np.asarray(chunk_P, float)
        K = chunk_P.shape[-1]
        has_refiner = lowered.has_refiner
    if ep_mask is None:
        ep_mask = np.ones(E, bool)
    else:
        ep_mask = np.asarray(ep_mask, bool)
        if ep_mask.shape != (E,):
            raise ValueError(f"ep_mask must have shape ({E},)")
    return (success, np.asarray(pred_ok, bool), chunk_P, ep_mask,
            has_refiner, K)


def _normalize_replay_args(lowered, success, alphas, lambdas, pred_ok,
                           chunk_P, ep_mask):
    alphas, lambdas = _normalize_grid(alphas, lambdas)
    (success, pred_ok, chunk_P, ep_mask, has_refiner,
     K) = _normalize_episodes(lowered, success, pred_ok, chunk_P, ep_mask)
    return (alphas, lambdas, success, pred_ok, chunk_P, ep_mask,
            has_refiner, K)


def fleet_replay(
    lowered: FleetLowered,
    success: np.ndarray,
    alphas,
    lambdas,
    *,
    pred_ok: Optional[np.ndarray] = None,
    chunk_P: Optional[np.ndarray] = None,
    throttle_every: int = 1,
    ep_mask: Optional[np.ndarray] = None,
) -> FleetReport:
    """Replay E episodes x G grid points in one jit'd XLA call.

    Args:
      lowered: output of :func:`lower_workflow`.
      success: (E, V) bool — per-episode tier success of the candidate
        edge into op v (§7.4 label); ignored where ``has_edge`` is False.
      alphas / lambdas: length-G paired grid points (mesh them for a full
        §12.1 cross product); a scalar lambda broadcasts over alphas.
      pred_ok: (E, V) bool — a prediction existed at launch (default: the
        lowering's ``has_pred``).
      chunk_P: (E, V, K) refined per-chunk confidences P_k for §9.1
        mid-stream re-estimation; omit to disable streaming cancels.
      throttle_every: §9.1 throttling — re-evaluate every N chunks.
      ep_mask: (E,) bool — episodes with a False mask are identity scan
        steps: the posterior carry passes through unchanged, per-episode
        stats report as zero (posterior columns report the carried
        values).  This is what lets ragged per-tenant episode logs pad to
        a common length without perturbing anyone's trajectory.

    The per-edge Beta posterior is carried sequentially across episodes
    (scan), independently per grid point (vmap), exactly like running the
    scalar sweep once per grid point.  When the lowering carries
    ``use_lower_bound=True`` (§7.5), the Phase-2 gate inverts the carried
    posterior — ``betaincinv(a, b, gamma)`` — in place of the mean, so
    the conservative mode tracks the evolving counts exactly like the
    scalar executor's ``post.lower_bound(gamma)``.
    """
    (alphas, lambdas, success, pred_ok, chunk_P, ep_mask, has_refiner,
     K) = _normalize_replay_args(
        lowered, success, alphas, lambdas, pred_ok, chunk_P, ep_mask)
    ys = _fleet_scan(
        _pack_static(lowered, has_refiner),
        _f(lowered.a0), _f(lowered.b0), _f(lowered.discount),
        _f(alphas), _f(lambdas), _f(lowered.gamma),
        jnp.asarray(success), jnp.asarray(pred_ok, bool),
        _f(chunk_P), jnp.asarray(ep_mask), int(throttle_every), int(K),
        bool(lowered.use_lower_bound),
    )
    np_out = {k: np.asarray(v) for k, v in ys.items()}
    return FleetReport(alphas=alphas, lambdas=lambdas, ep_mask=ep_mask,
                       **np_out)


def _pack_static(lowered: FleetLowered, has_refiner: np.ndarray):
    return (
        jnp.asarray(lowered.parent_mask),
        jnp.asarray(lowered.u_onehot),
        _f(lowered.dur), _f(lowered.op_cost),
        jnp.asarray(lowered.has_edge),
        jnp.asarray(lowered.u_streams),
        _f(lowered.lat_save), _f(lowered.in_tok), _f(lowered.out_tok),
        _f(lowered.in_price), _f(lowered.out_price), _f(lowered.pred_cost),
        jnp.asarray(lowered.has_pred),
        jnp.asarray(lowered.streams),
        jnp.asarray(has_refiner),
        _f(lowered.n_chunks),
    )


def _scan_core(static, post0, discount, alphas, lambdas, gamma,
               success, pred_ok, chunk_P, ep_mask, throttle_every, K,
               use_lower_bound):
    """Episode scan for one workflow: carry (G, V, 2) posteriors across E
    episodes, vmapped over the G grid points.  ``ep_mask`` turns padded
    episodes into identity steps (unchanged carry, zeroed stats) so ragged
    per-tenant logs can share one scan length.  Returns the final carry —
    the donation target for repeated calibration rounds — plus the stats.
    """
    episode = functools.partial(
        _episode, static, discount, (K, throttle_every),
        use_lower_bound, gamma,
    )

    def ep_step(post_ab, xs):
        succ_e, pred_e, chunks_e, mask_e = xs
        # vmap over grid points: independent posterior trajectory each
        post_new, stats = jax.vmap(
            episode, in_axes=(0, 0, 0, None, None, None)
        )(post_ab, alphas, lambdas, succ_e, pred_e, chunks_e)
        post_new = jnp.where(mask_e, post_new, post_ab)
        # masked steps are identity updates: stats zero out, the posterior
        # columns keep reporting the carried (unchanged) values
        stats = {
            k: jnp.where(mask_e, v, jnp.zeros_like(v))
            for k, v in stats.items()
        }
        stats["post_alpha"] = jnp.where(mask_e, stats["post_alpha"],
                                        post_ab[..., 0])
        stats["post_beta"] = jnp.where(mask_e, stats["post_beta"],
                                       post_ab[..., 1])
        return post_new, stats

    return jax.lax.scan(ep_step, post0, (success, pred_ok, chunk_P, ep_mask))


@functools.partial(
    jax.jit, static_argnames=("throttle_every", "K", "use_lower_bound")
)
def _fleet_scan(static, a0, b0, discount, alphas, lambdas, gamma,
                success, pred_ok, chunk_P, ep_mask, throttle_every, K,
                use_lower_bound):
    G = alphas.shape[0]
    V = a0.shape[0]
    post0 = jnp.broadcast_to(jnp.stack([a0, b0], -1)[None], (G, V, 2))
    _, ys = _scan_core(static, post0, discount, alphas, lambdas, gamma,
                       success, pred_ok, chunk_P, ep_mask, throttle_every,
                       K, use_lower_bound)
    return ys


def _episode(static, discount, chunk_cfg, use_lower_bound, gamma,
             post_ab, alpha, lam, succ, pred_ok, chunk_P):
    """One episode at one grid point.  All per-op arrays have length V."""
    (parent_mask, u_onehot, dur, op_cost, has_edge, u_streams, lat_save,
     in_tok, out_tok, in_price, out_price, pred_cost, has_pred, streams,
     has_refiner, n_chunks) = static
    K, throttle_every = chunk_cfg
    V = dur.shape[0]
    a, b = post_ab[:, 0], post_ab[:, 1]
    if use_lower_bound:
        # §7.5 conservative gate: one-sided (1-gamma) lower credible
        # bound, inverted from the carried counts inside the scan —
        # mirrors the scalar path's post.lower_bound(gamma) per episode.
        P = betaincinv(a, b, gamma)
    else:
        P = a / (a + b)
    neg = jnp.asarray(-jnp.inf, dur.dtype)

    # Phase-2 D4 gate, identical expression order to decision.evaluate
    # (§6.1) so float64 results match the scalar path bitwise
    C_spec = in_tok * in_price + out_tok * out_price
    L_value = lat_save * lam
    EV = P * L_value - (1.0 - P) * C_spec
    threshold = (1.0 - alpha) * C_spec
    spec_dec = EV >= threshold
    c_in = in_tok * in_price

    k_idx = jnp.arange(K)

    def step(carry, xs):
        start, finish = carry
        (pmask, umask, dur_v, spec_v, pc_v, launch_gate_v, streams_v,
         u_streams_v, has_ref_v, nch_v, c_in_v, out_tok_v, out_price_v,
         Lval_v, Cspec_v, thr_v, succ_v, pred_ok_v, P_chunks_v, vmask) = xs
        # plain-path ready time: all parents finished
        t_ready = jnp.max(jnp.where(pmask, finish, neg), initial=0.0)
        start_u = jnp.sum(jnp.where(umask, start, 0.0))
        finish_u = jnp.sum(jnp.where(umask, finish, 0.0))
        other_ready = jnp.max(jnp.where(pmask & ~umask, finish, neg),
                              initial=0.0)
        launched = spec_v & launch_gate_v & pred_ok_v
        t_launch = jnp.maximum(start_u + pc_v, other_ready)

        # §9.1 vectorized per-chunk re-estimation: EV_k with refined P_k,
        # same L_value / C_spec / threshold; first WAIT verdict cancels
        u_dur = finish_u - start_u
        valid_k = (
            (k_idx < nch_v) & (k_idx % throttle_every == 0)
            & launched & u_streams_v & has_ref_v
        )
        EV_k = P_chunks_v * Lval_v - (1.0 - P_chunks_v) * Cspec_v
        cancel_k = valid_k & (EV_k < thr_v)
        cancelled = cancel_k.any()
        first_k = jnp.argmax(cancel_k)
        t_chunk = start_u + (first_k + 1.0) / jnp.maximum(nch_v, 1.0) * u_dur
        elapsed_c = jnp.maximum(0.0, t_chunk - t_launch)
        frac_c = jnp.where(dur_v > 0.0,
                           jnp.minimum(1.0, elapsed_c / dur_v), 1.0)

        committed = launched & succ_v & ~cancelled
        # timing mirrors executor.execute: commit at max(spec finish,
        # u finish); failure / cancel re-executes after u
        t1_commit = jnp.maximum(t_launch + dur_v, finish_u)
        t0 = jnp.where(committed, t_launch,
                       jnp.where(launched, finish_u, t_ready))
        t1 = jnp.where(committed, t1_commit,
                       jnp.where(launched, finish_u + dur_v,
                                 t_ready + dur_v))

        # §9.3 fractional waste (fractional_waste expression order:
        # c_in + (frac * out_tok) * out_price); non-streaming downstream
        # cannot cancel mid-generation -> full C_spec on tier failure
        elapsed_f = jnp.maximum(0.0, finish_u - t_launch)
        frac_f = jnp.where(dur_v > 0.0,
                           jnp.minimum(1.0, elapsed_f / dur_v), 1.0)
        frac_f = jnp.where(streams_v, frac_f, 1.0)
        frac = jnp.where(cancelled, frac_c, frac_f)
        waste_v = c_in_v + (frac * out_tok_v) * out_price_v
        waste_v = jnp.where(launched & ~committed, waste_v, 0.0)

        start = jnp.where(vmask, t0, start)
        finish = jnp.where(vmask, t1, finish)
        outs = (launched, committed, launched & cancelled, waste_v, t0, t1)
        return (start, finish), outs

    xs = (
        parent_mask, u_onehot, dur, spec_dec, pred_cost,
        has_edge & has_pred, streams, u_streams, has_refiner, n_chunks,
        c_in, out_tok, out_price, L_value, C_spec, threshold,
        succ, pred_ok, chunk_P, jnp.eye(V, dtype=bool),
    )
    init = (jnp.zeros(V, dur.dtype), jnp.zeros(V, dur.dtype))
    (start, finish), (launched, committed, cancelled, waste,
                      t0s, t1s) = jax.lax.scan(step, init, xs)

    # discounted conjugate update (BetaPosterior.update, §14.3): only
    # launched edges observe a Bernoulli trial; d=1 reduces to a+1 / b+1
    suc_f = committed.astype(a.dtype)
    a_new = jnp.where(launched, a * discount + suc_f, a)
    b_new = jnp.where(launched, b * discount + (1.0 - suc_f), b)
    post_new = jnp.stack([a_new, b_new], -1)

    waste_total = waste.sum()
    stats = {
        "makespan_s": jnp.max(finish, initial=0.0),
        "total_cost_usd": op_cost.sum() + waste_total,
        "waste_usd": waste_total,
        "launched": launched.sum(),
        "committed": committed.sum(),
        "cancelled": cancelled.sum(),
        "EV_usd": EV,
        "threshold_usd": threshold,
        "speculate": spec_dec,
        "edge_launched": launched,
        "edge_committed": committed,
        "edge_waste_usd": waste,
        "start_s": t0s,
        "finish_s": t1s,
        "post_alpha": a_new,
        "post_beta": b_new,
    }
    return post_new, stats


# ---------------------------------------------------------- multi-tenant
def _pad_lowered(lowered: FleetLowered, V: int) -> FleetLowered:
    """Pad a lowering to V ops with inert slots.

    Padded ops have zero duration/cost, no parents, no candidate edge and
    a unit Beta prior, so they never launch, never contribute to makespan,
    cost or waste, and their posterior carry is a fixed point — a tenant
    padded to a larger ``V_max`` replays bitwise-identically to its
    unpadded lowering on the real op columns.
    """
    pad = V - lowered.n_ops
    if pad < 0:
        raise ValueError(f"cannot pad {lowered.n_ops} ops down to {V}")
    if pad == 0:
        return lowered

    def zeros(x):
        return np.concatenate([x, np.zeros(pad, x.dtype)])

    def fill(x, value):
        return np.concatenate([x, np.full(pad, value, x.dtype)])

    def square(x):
        out = np.zeros((V, V), x.dtype)
        out[: lowered.n_ops, : lowered.n_ops] = x
        return out

    return FleetLowered(
        names=lowered.names + tuple(f"__pad{i}" for i in range(pad)),
        dur=zeros(lowered.dur), op_cost=zeros(lowered.op_cost),
        parent_mask=square(lowered.parent_mask),
        has_edge=zeros(lowered.has_edge),
        u_onehot=square(lowered.u_onehot),
        u_streams=zeros(lowered.u_streams),
        lat_save=zeros(lowered.lat_save),
        in_tok=zeros(lowered.in_tok), out_tok=zeros(lowered.out_tok),
        in_price=zeros(lowered.in_price), out_price=zeros(lowered.out_price),
        pred_cost=zeros(lowered.pred_cost), has_pred=zeros(lowered.has_pred),
        streams=zeros(lowered.streams), has_refiner=zeros(lowered.has_refiner),
        n_chunks=fill(lowered.n_chunks, 1.0),
        a0=fill(lowered.a0, 1.0), b0=fill(lowered.b0, 1.0),
        discount=fill(lowered.discount, 1.0),
        use_lower_bound=lowered.use_lower_bound, gamma=lowered.gamma,
        beam_conf=None if lowered.beam_conf is None else np.concatenate(
            [lowered.beam_conf,
             np.concatenate(
                 [np.ones((pad, 1)),
                  np.zeros((pad, lowered.beam_conf.shape[1] - 1))], axis=1)]
        ),
    )


@dataclasses.dataclass(frozen=True)
class FleetStack:
    """T tenants stacked along a new leading batch axis.

    Each tenant is a :class:`FleetLowered` padded to the common ``V_max``
    plus its episode log padded to the common ``E_max`` (``ep_mask`` marks
    the real episodes; padded ones are identity scan steps).  The stack is
    what :func:`multi_tenant_replay` partitions across devices.
    """

    tenants: tuple[str, ...]
    lowered: tuple[FleetLowered, ...]   # padded to the common V_max
    n_ops: tuple[int, ...]              # pre-padding op counts
    n_episodes: tuple[int, ...]         # pre-padding episode counts
    success: np.ndarray                 # (T, E_max, V_max) bool
    pred_ok: np.ndarray                 # (T, E_max, V_max) bool
    chunk_P: np.ndarray                 # (T, E_max, V_max, K)
    ep_mask: np.ndarray                 # (T, E_max) bool
    has_refiner: np.ndarray             # (T, V_max) bool (zeroed where the
                                        # tenant supplied no chunk_P)
    use_lower_bound: bool

    @property
    def T(self) -> int:
        return len(self.tenants)

    @property
    def V(self) -> int:
        return self.success.shape[2]

    @property
    def E(self) -> int:
        return self.success.shape[1]

    @property
    def K(self) -> int:
        return self.chunk_P.shape[-1]

    @property
    def gammas(self) -> np.ndarray:
        return np.array([l.gamma for l in self.lowered])

    def edge_keys(self) -> tuple[tuple[tuple[int, tuple[str, str]], ...], ...]:
        """Per tenant: (op index, (upstream, downstream)) for each
        speculation-candidate edge — the taxonomy keys the drift monitor
        and calibration stages address posteriors by."""
        out = []
        for low in self.lowered:
            keys = []
            for v in low.edge_ops():
                u = int(np.argmax(low.u_onehot[v]))
                keys.append((v, (low.names[u], low.names[v])))
            out.append(tuple(keys))
        return tuple(out)

    def device_args(self):
        """Device-side argument tuple for the replay executable, memoized
        per float dtype (the ``_f`` convention resolves f32/f64 from
        ``jax_enable_x64`` at call time).

        Repeated calibration rounds over an unchanged stack — the
        replay / re-gate / replay loop the donated posterior carry exists
        for — would otherwise re-run ~20 ``np.stack`` copies and
        host->device transfers per round (two V_max x V_max matrices per
        tenant among them); memoizing here makes every round after the
        first reuse the staged buffers.  The memo writes straight into
        ``__dict__`` (allowed on frozen dataclasses) and pins the arrays
        for the stack's lifetime.
        """
        key = f"_device_args_{jnp.result_type(float).name}"
        cached = self.__dict__.get(key)
        if cached is not None:
            return cached
        lows = self.lowered
        static = (
            jnp.asarray(np.stack([l.parent_mask for l in lows])),
            jnp.asarray(np.stack([l.u_onehot for l in lows])),
            _f(np.stack([l.dur for l in lows])),
            _f(np.stack([l.op_cost for l in lows])),
            jnp.asarray(np.stack([l.has_edge for l in lows])),
            jnp.asarray(np.stack([l.u_streams for l in lows])),
            _f(np.stack([l.lat_save for l in lows])),
            _f(np.stack([l.in_tok for l in lows])),
            _f(np.stack([l.out_tok for l in lows])),
            _f(np.stack([l.in_price for l in lows])),
            _f(np.stack([l.out_price for l in lows])),
            _f(np.stack([l.pred_cost for l in lows])),
            jnp.asarray(np.stack([l.has_pred for l in lows])),
            jnp.asarray(np.stack([l.streams for l in lows])),
            jnp.asarray(self.has_refiner),
            _f(np.stack([l.n_chunks for l in lows])),
        )
        cached = (
            static,
            _f(np.stack([l.a0 for l in lows])),
            _f(np.stack([l.b0 for l in lows])),
            _f(np.stack([l.discount for l in lows])),
            _f(self.gammas),
            jnp.asarray(self.success),
            jnp.asarray(self.pred_ok),
            _f(self.chunk_P),
            jnp.asarray(self.ep_mask),
        )
        self.__dict__[key] = cached
        return cached


def stack_tenants(
    lowereds,
    successes,
    *,
    pred_oks=None,
    chunk_Ps=None,
    tenants=None,
) -> FleetStack:
    """Stack per-tenant (lowering, episode log) pairs into one batch.

    Ragged shapes are padded: ops to ``V_max`` (inert slots, see
    :func:`_pad_lowered`), episodes to ``E_max`` (masked identity steps).
    Every tenant keeps its own taxonomy-keyed prior ``(a0, b0)``, discount
    and §7.5 gamma; ``use_lower_bound`` must agree across tenants because
    it selects the compiled gate expression.
    """
    T = len(lowereds)
    if T == 0:
        raise ValueError("stack_tenants requires at least one tenant")
    if len(successes) != T:
        raise ValueError("one success array per tenant required")
    if tenants is None:
        tenants = tuple(f"tenant{t}" for t in range(T))
    tenants = tuple(tenants)
    if len(set(tenants)) != T:
        raise ValueError("tenant names must be unique")
    pred_oks = list(pred_oks) if pred_oks is not None else [None] * T
    chunk_Ps = list(chunk_Ps) if chunk_Ps is not None else [None] * T
    if len(pred_oks) != T or len(chunk_Ps) != T:
        raise ValueError("pred_oks / chunk_Ps must align with tenants")
    use_lb = {bool(l.use_lower_bound) for l in lowereds}
    if len(use_lb) != 1:
        raise ValueError(
            "use_lower_bound must agree across stacked tenants (it selects "
            "the compiled §7.5 gate); split mixed fleets into two stacks"
        )

    n_ops = tuple(l.n_ops for l in lowereds)
    successes = [np.asarray(s, bool) for s in successes]
    for t, (low, suc) in enumerate(zip(lowereds, successes)):
        if suc.ndim != 2 or suc.shape[1] != low.n_ops:
            raise ValueError(
                f"tenant {tenants[t]!r}: success must be (E, {low.n_ops})"
            )
    n_eps = tuple(s.shape[0] for s in successes)
    V = max(n_ops)
    E = max(n_eps)
    provided_K = {np.asarray(c).shape[-1] for c in chunk_Ps if c is not None}
    if len(provided_K) > 1:
        raise ValueError("chunk_P K must agree across tenants that stream")
    K = provided_K.pop() if provided_K else 1

    padded = tuple(_pad_lowered(l, V) for l in lowereds)
    success = np.zeros((T, E, V), bool)
    pred_ok = np.zeros((T, E, V), bool)
    chunk_P = np.ones((T, E, V, K))
    ep_mask = np.zeros((T, E), bool)
    has_refiner = np.zeros((T, V), bool)
    for t, low in enumerate(lowereds):
        e_t, v_t = n_eps[t], n_ops[t]
        success[t, :e_t, :v_t] = successes[t]
        po = pred_oks[t]
        if po is None:
            po = np.broadcast_to(low.has_pred, (e_t, v_t))
        pred_ok[t, :e_t, :v_t] = np.asarray(po, bool)
        cp = chunk_Ps[t]
        if cp is not None:
            cp = np.asarray(cp, float)
            if cp.shape != (e_t, v_t, K):
                raise ValueError(
                    f"tenant {tenants[t]!r}: chunk_P must be "
                    f"({e_t}, {v_t}, {K})"
                )
            chunk_P[t, :e_t, :v_t] = cp
            has_refiner[t, :v_t] = low.has_refiner
        ep_mask[t, :e_t] = True

    return FleetStack(
        tenants=tenants, lowered=padded, n_ops=n_ops, n_episodes=n_eps,
        success=success, pred_ok=pred_ok, chunk_P=chunk_P, ep_mask=ep_mask,
        has_refiner=has_refiner, use_lower_bound=use_lb.pop(),
    )


@dataclasses.dataclass(frozen=True)
class MultiTenantReport:
    """Per-tenant fleet reports plus the donatable posterior carry.

    Stat arrays are numpy with a leading T axis over the stacked tenants
    (then E episodes, G grid points, V_max ops as in
    :class:`FleetReport`); rows past a tenant's real episode count are
    identity steps (zero stats, carried posteriors).  ``post_final`` stays
    a jax array — feed it back as ``post0`` (with ``donate=True``) so
    repeated calibration rounds reuse the same device buffer.
    """

    tenants: tuple[str, ...]
    alphas: np.ndarray
    lambdas: np.ndarray
    n_ops: tuple[int, ...]
    n_episodes: tuple[int, ...]
    ep_mask: np.ndarray
    edge_keys: tuple
    post_final: object          # jax (T, G, V, 2)
    makespan_s: np.ndarray      # (T, E, G)
    total_cost_usd: np.ndarray
    waste_usd: np.ndarray
    launched: np.ndarray
    committed: np.ndarray
    cancelled: np.ndarray
    EV_usd: np.ndarray          # (T, E, G, V)
    threshold_usd: np.ndarray
    speculate: np.ndarray
    edge_launched: np.ndarray
    edge_committed: np.ndarray
    edge_waste_usd: np.ndarray
    start_s: np.ndarray
    finish_s: np.ndarray
    post_alpha: np.ndarray
    post_beta: np.ndarray

    def tenant_report(self, t: int) -> FleetReport:
        """Slice tenant ``t`` back to a single-workflow :class:`FleetReport`
        (real episodes and ops only)."""
        e_t, v_t = self.n_episodes[t], self.n_ops[t]
        kw = {}
        for f in dataclasses.fields(FleetReport):
            if f.name in ("alphas", "lambdas"):
                continue
            arr = getattr(self, f.name)[t]
            kw[f.name] = arr[:e_t, :, :v_t] if arr.ndim == 3 else arr[:e_t]
        return FleetReport(alphas=self.alphas, lambdas=self.lambdas, **kw)

    def final_posterior_rows(self, grid_index: int = 0):
        """Flatten the final per-(tenant, edge) posteriors at one grid
        point into the row layout
        ``DriftMonitor.check_credible_bound_batch`` consumes:
        ``([(tenant, edge), ...], post_alpha, post_beta)``.

        ``grid_index`` must address one of the replay's G grid points;
        out-of-range (or negative) indices raise instead of silently
        wrapping — a wrapped index would hand the drift monitor a
        *different operating point's* posteriors, which is exactly the
        kind of row mixup the kill-switch exists to prevent."""
        G = self.post_final.shape[1]
        if not (0 <= int(grid_index) < G):
            raise IndexError(
                f"grid_index {grid_index} out of range: this report has "
                f"{G} grid point(s) (valid: 0..{G - 1})")
        post = np.asarray(self.post_final)
        tenant_edges, a, b = [], [], []
        for t, keys in enumerate(self.edge_keys):
            for v, key in keys:
                tenant_edges.append((self.tenants[t], key))
                a.append(post[t, grid_index, v, 0])
                b.append(post[t, grid_index, v, 1])
        return tenant_edges, np.asarray(a), np.asarray(b)

    def pareto(self) -> dict:
        """Per-tenant §12.3 Pareto dicts keyed by tenant name."""
        return {
            name: self.tenant_report(t).pareto()
            for t, name in enumerate(self.tenants)
        }


@functools.lru_cache(maxsize=None)
def _mt_executable(mesh, axis_name, throttle_every, K, use_lower_bound,
                   donate):
    """Compile (and cache) the tenant-vmapped, optionally shard_map'd
    episode scan.  The cache key carries the mesh object itself, so one
    process can serve sharded and unsharded fleets side by side."""

    def run(static, post0, discount, alphas, lambdas, gamma,
            success, pred_ok, chunk_P, ep_mask):
        def one(st, p0, d, g, s, pk, cp, em):
            return _scan_core(st, p0, d, alphas, lambdas, g, s, pk, cp, em,
                              throttle_every, K, use_lower_bound)

        return jax.vmap(one)(static, post0, discount, gamma,
                             success, pred_ok, chunk_P, ep_mask)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        t = PartitionSpec(axis_name)
        r = PartitionSpec()
        run = shard_map(
            run, mesh=mesh,
            # leading tenant axis partitioned; the (alpha, lambda) grid is
            # replicated and rides along under the per-shard vmap
            in_specs=(t, t, t, r, r, t, t, t, t, t),
            out_specs=t,
            check_rep=False,
        )
    return jax.jit(run, donate_argnums=(1,) if donate else ())


def multi_tenant_replay(
    stack: FleetStack,
    alphas,
    lambdas,
    *,
    throttle_every: int = 1,
    mesh=None,
    axis_name: str = "fleet",
    post0=None,
    donate: bool = False,
) -> MultiTenantReport:
    """Replay T tenants x E episodes x G grid points in one XLA call.

    The tenant axis is vmapped over per-tenant DAGs, priors, gammas and
    episode logs; with ``mesh`` (a 1-D mesh such as
    ``repro.launch.mesh.make_fleet_mesh()``) the ``tenants x grid`` work
    is partitioned across devices via ``shard_map`` along the tenant
    axis, each shard carrying its tenants' full grid sweep.  When T does
    not divide the mesh extent the call falls back to the unsharded
    executable (mirroring ``sharding.rules.shard_if_divisible``).

    ``post0`` (a previous report's ``post_final``) replaces the stacked
    priors as the scan carry; with ``donate=True`` its device buffer is
    donated to the new carry, so repeated calibration rounds — replay,
    re-gate, replay — update posteriors in place instead of reallocating
    per round.  Donation consumes the passed-in array: the previous
    report's ``post_final`` (including ``final_posterior_rows``) becomes
    unreadable afterwards, which is why it is opt-in — read the old
    round's posteriors (drift gating) *before* donating them into the
    next round.

    Per-tenant results are bitwise-identical (float64) to T independent
    :func:`fleet_replay` calls — pinned by tests/test_fleet_multitenant.py
    and the 8-device case in tests/test_multidevice.py.
    """
    alphas = np.atleast_1d(np.asarray(alphas, float))
    lambdas = np.atleast_1d(np.asarray(lambdas, float))
    if lambdas.shape[0] == 1 and alphas.shape[0] > 1:
        lambdas = np.broadcast_to(lambdas, alphas.shape).copy()
    if alphas.shape != lambdas.shape:
        raise ValueError("alphas and lambdas must be paired (same length)")
    T, G, V = stack.T, alphas.shape[0], stack.V

    if mesh is not None:
        from ..sharding.rules import fleet_axis_spec

        if fleet_axis_spec(mesh, T, axis=axis_name) is None:
            mesh = None  # indivisible tenant axis: replicate = don't shard

    (static, a0, b0, discount, gammas,
     success, pred_ok, chunk_P, ep_mask) = stack.device_args()
    if post0 is None:
        post0 = jnp.broadcast_to(
            jnp.stack([a0, b0], -1)[:, None], (T, G, V, 2)
        )
    else:
        if tuple(post0.shape) != (T, G, V, 2):
            raise ValueError(f"post0 must have shape ({T}, {G}, {V}, 2)")
        post0 = _f(post0)

    fn = _mt_executable(
        mesh, axis_name, int(throttle_every), int(stack.K),
        bool(stack.use_lower_bound), bool(donate),
    )
    post_final, ys = fn(
        static, post0, discount, _f(alphas), _f(lambdas), gammas,
        success, pred_ok, chunk_P, ep_mask,
    )
    np_out = {k: np.asarray(v) for k, v in ys.items()}
    return MultiTenantReport(
        tenants=stack.tenants, alphas=alphas, lambdas=lambdas,
        n_ops=stack.n_ops, n_episodes=stack.n_episodes,
        ep_mask=stack.ep_mask, edge_keys=stack.edge_keys(),
        post_final=post_final, **np_out,
    )


# -------------------------------------------------------- episode sharding
@dataclasses.dataclass(frozen=True)
class EpisodeChunks:
    """One tenant's episode log split into C contiguous segments.

    Segments share a common padded length S = ceil(E / C); the ragged
    tail is padded with masked identity scan steps (``ep_mask`` False),
    the same move :func:`stack_tenants` uses for ragged per-tenant logs —
    so every segment is a fixed-shape scan and the segment axis can be
    partitioned across devices.
    """

    n_episodes: int            # E, pre-padding
    success: np.ndarray        # (C, S, V) bool
    pred_ok: np.ndarray        # (C, S, V) bool
    chunk_P: np.ndarray        # (C, S, V, K)
    ep_mask: np.ndarray        # (C, S) bool; False rows are padding /
                               # caller-masked identity steps
    has_refiner: np.ndarray    # (V,) bool (zeroed when no chunk_P given)

    @property
    def n_segments(self) -> int:
        return self.success.shape[0]

    @property
    def seg_len(self) -> int:
        return self.success.shape[1]

    @property
    def K(self) -> int:
        return self.chunk_P.shape[-1]


def chunk_episodes(
    lowered: FleetLowered,
    success,
    n_segments: int,
    *,
    pred_ok=None,
    chunk_P=None,
    ep_mask=None,
) -> EpisodeChunks:
    """Split an (E, V) episode log into C contiguous fixed-shape segments.

    Defaults mirror :func:`fleet_replay` (``pred_ok`` from the lowering's
    predictor mask, streaming disabled without ``chunk_P``).  E = 0 is
    rejected outright: an empty log would chunk into an all-identity
    segment whose replay silently reports zero stats — callers with no
    episodes should not be replaying at all.
    """
    (success, pred_ok, chunk_P, ep_mask, has_refiner,
     _K) = _normalize_episodes(lowered, success, pred_ok, chunk_P, ep_mask)
    E = success.shape[0]
    if E == 0:
        raise ValueError(
            "chunk_episodes requires at least one episode: an E=0 log "
            "would emit an all-identity (fully masked) segment that "
            "replays to zero stats instead of failing loudly")
    C = int(n_segments)
    if C < 1:
        raise ValueError(f"n_segments must be >= 1, got {n_segments}")
    S = -(-E // C)
    pad = C * S - E

    def seg(x, fill):
        if pad:
            x = np.concatenate(
                [x, np.full((pad,) + x.shape[1:], fill, x.dtype)])
        return x.reshape((C, S) + x.shape[1:])

    return EpisodeChunks(
        n_episodes=E,
        success=seg(success, False),
        pred_ok=seg(pred_ok, False),
        chunk_P=seg(chunk_P, 1.0),
        ep_mask=seg(ep_mask, False),
        has_refiner=has_refiner,
    )


def _scan_posterior_only(static, post0, discount, alphas, lambdas, gamma,
                         success, pred_ok, chunk_P, ep_mask, throttle_every,
                         K, use_lower_bound):
    """The episode scan reduced to its carry: the identical per-episode
    arithmetic as ``_scan_core`` (same ``_episode`` body, same masked
    identity steps, so the carry evolves bitwise-equally), but no
    per-episode stats are stacked — jit DCE prunes the unused stat
    outputs, so a boundary pass over E episodes materializes O(G x V)
    instead of O(E x G x V)."""
    episode = functools.partial(
        _episode, static, discount, (K, throttle_every),
        use_lower_bound, gamma,
    )

    def ep_step(post_ab, xs):
        succ_e, pred_e, chunks_e, mask_e = xs
        post_new, _ = jax.vmap(
            episode, in_axes=(0, 0, 0, None, None, None)
        )(post_ab, alphas, lambdas, succ_e, pred_e, chunks_e)
        return jnp.where(mask_e, post_new, post_ab), None

    post, _ = jax.lax.scan(
        ep_step, post0, (success, pred_ok, chunk_P, ep_mask))
    return post


@functools.partial(
    jax.jit, static_argnames=("throttle_every", "K", "use_lower_bound")
)
def _boundary_scan(static, post0, discount, alphas, lambdas, gamma,
                   success, pred_ok, chunk_P, ep_mask, throttle_every, K,
                   use_lower_bound):
    """Posterior-handoff pass: a sequential ``lax.scan`` over the C
    segments, emitting the exact posterior carry at each segment *start*
    (plus the final carry).  Exact for every discount — see
    :func:`episode_sharded_replay` for why the handoff must be
    sequential when bitwise parity with the unsharded scan is the
    contract."""

    def seg_step(post_ab, xs):
        succ_c, pred_c, chunks_c, mask_c = xs
        post_end = _scan_posterior_only(
            static, post_ab, discount, alphas, lambdas, gamma,
            succ_c, pred_c, chunks_c, mask_c, throttle_every, K,
            use_lower_bound)
        return post_end, post_ab

    post_final, starts = jax.lax.scan(
        seg_step, post0, (success, pred_ok, chunk_P, ep_mask))
    return starts, post_final


# Per-segment entry points for the pipelined replay: the SAME scan bodies
# as the two-pass engine (`_scan_core` / `_scan_posterior_only`), jitted
# unvmapped so a host loop can interleave one segment's stats with the
# next segment's posterior handoff (see episode_sharded_replay).
_seg_stats_one = functools.partial(
    jax.jit, static_argnames=("throttle_every", "K", "use_lower_bound")
)(_scan_core)
_seg_posterior_one = functools.partial(
    jax.jit, static_argnames=("throttle_every", "K", "use_lower_bound")
)(_scan_posterior_only)


@functools.lru_cache(maxsize=None)
def _seg_executable(mesh, axis_name, throttle_every, K, use_lower_bound):
    """Compile (and cache) the segment-vmapped, optionally shard_map'd
    stats pass of the episode-sharded replay.  Mirrors ``_mt_executable``
    with segments in place of tenants: the workflow statics, grid and
    per-op discounts are replicated; the segment axis (boundary carries +
    episode arrays) is partitioned."""

    def run(static, starts, discount, alphas, lambdas, gamma,
            success, pred_ok, chunk_P, ep_mask):
        def one(p0, s, pk, cp, em):
            return _scan_core(static, p0, discount, alphas, lambdas, gamma,
                              s, pk, cp, em, throttle_every, K,
                              use_lower_bound)

        return jax.vmap(one)(starts, success, pred_ok, chunk_P, ep_mask)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        c = PartitionSpec(axis_name)
        r = PartitionSpec()
        run = shard_map(
            run, mesh=mesh,
            in_specs=(r, c, r, r, r, r, c, c, c, c),
            out_specs=c,
            check_rep=False,
        )
    return jax.jit(run)


def compose_segment_posteriors(a0, b0, seg_s, seg_f):
    """Closed-form conjugate composition of segment posteriors
    (``discount=1`` only).

    Under the undiscounted update the posterior after a segment is
    ``Beta(a + Δs, b + Δf)`` where (Δs, Δf) are the segment's success /
    failure *counts* on launched episodes — pure sufficient statistics.
    Composition is therefore associative, and one
    ``lax.associative_scan`` over the per-segment (Δs, Δf) rebuilds
    every segment-boundary posterior from the prior in O(log C) depth.

    This is the analytical cross-check for the sequential handoff pass,
    not its replacement: (1) the D4 gate reads the carry, so (Δs, Δf)
    themselves depend on the incoming posterior and must be *collected*
    along the exact trajectory, and (2) ``prior + Σcounts`` rounds once
    where the in-scan carry rounds per episode, so the composition
    matches the handoff to 1 ULP rather than bitwise (exact when the
    prior is integer-valued).  tests/test_episode_sharding.py pins the
    agreement.

    Args: ``a0`` / ``b0`` broadcastable to ``seg_s[0]``; ``seg_s`` /
    ``seg_f`` with a leading segment axis.  Returns the (C, ..., 2)
    posterior at each segment *start* (prior + exclusive prefix sums).
    """
    deltas = jnp.stack([_f(seg_s), _f(seg_f)], axis=-1)
    prefix = jax.lax.associative_scan(jnp.add, deltas, axis=0)
    excl = jnp.concatenate(
        [jnp.zeros_like(prefix[:1]), prefix[:-1]], axis=0)
    prior = jnp.stack(
        [jnp.broadcast_to(_f(a0), deltas.shape[1:-1]),
         jnp.broadcast_to(_f(b0), deltas.shape[1:-1])], axis=-1)
    return np.asarray(prior[None] + excl)


def episode_sharded_replay(
    lowered: FleetLowered,
    success,
    alphas,
    lambdas,
    *,
    n_segments: Optional[int] = None,
    pred_ok=None,
    chunk_P=None,
    throttle_every: int = 1,
    ep_mask=None,
    mesh=None,
    axis_name: str = "fleet",
    return_boundaries: bool = False,
    pipelined: bool = False,
) -> "FleetReport | tuple[FleetReport, np.ndarray]":
    """Replay a single tenant's E-episode log as C independent scan
    segments — the fleet engine's episode-axis analogue of
    :func:`multi_tenant_replay`'s tenant axis, for million-episode §12.1
    logs that one sequential scan would serialize.

    Two passes:

    1. **Posterior handoff** (:func:`_boundary_scan`): a sequential scan
       over segments carrying only the (G, V, 2) posterior, emitting the
       exact carry at every segment boundary.  O(E) sequential work but
       O(C·G·V) memory — none of the ~17 per-episode stat arrays are
       materialized, which is what dominates an unsharded million-episode
       replay.
    2. **Stats pass** (:func:`_seg_executable`): given its boundary
       carry, each segment is independent; the C segments run vmapped
       (and, with ``mesh`` — e.g. ``repro.launch.mesh.make_fleet_mesh()``
       — ``shard_map``'d along the 1-D fleet axis via
       ``sharding.rules.fleet_axis_spec``, falling back to the unsharded
       executable when C is indivisible) and materialize the full
       per-episode trajectories in parallel.

    Why the handoff is sequential in *both* discount regimes: the D4
    gate reads the carried posterior, so each segment's sufficient
    statistics depend on its incoming carry — a one-shot parallel
    composition would speculate on decisions and break the bitwise
    contract.  Under ``discount=1`` the conjugate closed form *does*
    compose associatively (:func:`compose_segment_posteriors`, one
    ``lax.associative_scan`` over per-segment (Δs, Δf)) and is pinned to
    the handoff to 1 ULP; under ``discount<1`` the forgetting recurrence
    makes the handoff of the (a, b) carry the only exact route, so the
    engine documents and uses this two-pass scheme for every discount.

    ``pipelined=True`` removes the two-pass *latency* without touching
    the handoff's sequential semantics: a host loop walks the segments
    in order, dispatching segment c's stats scan (``_seg_stats_one`` —
    the same ``_scan_core`` body the vmapped stats pass runs) the moment
    c's boundary carry exists, then immediately advancing the carry for
    segment c+1 (``_seg_posterior_one``).  JAX's async dispatch lets
    segment c's stats overlap segment c+1's handoff instead of
    completing ALL boundaries first, and the final segment needs no
    handoff at all — the boundary pass shrinks from C to C-1 segments
    and stops gating the first stats launch.  The carries are the same
    ``_scan_posterior_only`` recurrence, so boundaries and stats stay
    bitwise identical to the two-pass engine (asserted across the full
    C/discount/lower-bound/cancel matrix in
    tests/test_episode_sharding.py); ``mesh`` is ignored in this mode
    (the overlap already owns the device queue).  The trade: stats run
    one executable per segment rather than vmapped across segments, so
    on a single device with spare vector lanes (this container) the
    two-pass engine is faster — the mode pays off when segments can
    land on separate devices and the handoff is the critical path
    (EXPERIMENTS.md §Episode sharding).

    Parity contract (tests/test_episode_sharding.py): bitwise-f64 equal
    to :func:`fleet_replay` on the same log — decisions, flags, times,
    posteriors exactly; EV/waste to the established 1-ULP FMA allowance
    — for every (C, discount, lower-bound, streaming) combination.

    ``n_segments`` defaults to the mesh extent (or the visible device
    count without a mesh).  ``return_boundaries=True`` additionally
    returns the (C, G, V, 2) segment-start posteriors.
    """
    alphas, lambdas = _normalize_grid(alphas, lambdas)
    if n_segments is None:
        if mesh is not None and axis_name in mesh.shape:
            n_segments = mesh.shape[axis_name]
        else:
            n_segments = max(1, len(jax.devices()))
    chunks = chunk_episodes(
        lowered, success, n_segments,
        pred_ok=pred_ok, chunk_P=chunk_P, ep_mask=ep_mask)
    E, C = chunks.n_episodes, chunks.n_segments
    # the report's ep_mask keeps the caller's (E,) view, not the padded one
    ep_mask_full = chunks.ep_mask.reshape(-1)[:E]

    if mesh is not None:
        from ..sharding.rules import fleet_axis_spec

        if fleet_axis_spec(mesh, C, axis=axis_name) is None:
            mesh = None  # indivisible segment axis: run unsharded

    static = _pack_static(lowered, chunks.has_refiner)
    G = alphas.shape[0]
    V = lowered.n_ops
    post0 = jnp.broadcast_to(
        jnp.stack([_f(lowered.a0), _f(lowered.b0)], -1)[None], (G, V, 2))
    args = (
        _f(lowered.discount), _f(alphas), _f(lambdas), _f(lowered.gamma),
        jnp.asarray(chunks.success), jnp.asarray(chunks.pred_ok),
        _f(chunks.chunk_P), jnp.asarray(chunks.ep_mask),
    )
    throttle_every = int(throttle_every)
    K = int(chunks.K)
    use_lb = bool(lowered.use_lower_bound)

    if pipelined:
        (discount_j, alphas_j, lambdas_j, gamma_j,
         succ_j, pok_j, cP_j, em_j) = args
        carry = post0
        starts_list: list = []
        stats_list: list = []
        for c in range(C):
            xs = (succ_j[c], pok_j[c], cP_j[c], em_j[c])
            starts_list.append(carry)
            # dispatch the stats scan first (async — it runs while the
            # host enqueues the next handoff), then advance the carry,
            # which is all segment c+1 is actually waiting on
            _, ys_c = _seg_stats_one(
                static, carry, discount_j, alphas_j, lambdas_j, gamma_j,
                *xs, throttle_every=throttle_every, K=K,
                use_lower_bound=use_lb)
            stats_list.append(ys_c)
            if c + 1 < C:
                carry = _seg_posterior_one(
                    static, carry, discount_j, alphas_j, lambdas_j,
                    gamma_j, *xs, throttle_every=throttle_every, K=K,
                    use_lower_bound=use_lb)
        out = {}
        for k in stats_list[0]:
            out[k] = np.concatenate(
                [np.asarray(ys_c[k]) for ys_c in stats_list], axis=0)[:E]
        report = FleetReport(alphas=alphas, lambdas=lambdas,
                             ep_mask=ep_mask_full, **out)
        if return_boundaries:
            return report, np.asarray(jnp.stack(starts_list))
        return report

    starts, _ = _boundary_scan(static, post0, *args,
                               throttle_every=throttle_every, K=K,
                               use_lower_bound=use_lb)
    fn = _seg_executable(mesh, axis_name, throttle_every, K, use_lb)
    _, ys = fn(static, starts, *args)

    out = {}
    for k, v in ys.items():
        v = np.asarray(v)
        out[k] = v.reshape((C * chunks.seg_len,) + v.shape[2:])[:E]
    report = FleetReport(alphas=alphas, lambdas=lambdas,
                         ep_mask=ep_mask_full, **out)
    if return_boundaries:
        return report, np.asarray(starts)
    return report
