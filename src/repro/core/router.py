"""§6.6 — multi-provider routing under alpha.

An operation may be routed to different provider/model tiers based on
alpha: cost-sensitive preferences favor cheaper models; latency-sensitive
preferences favor faster ones.  Routing evaluates the decision rule
independently per (operation, provider, model) candidate and selects the
best per alpha.  Sits at the boundary of D2 (pricing) and D3 (alpha).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .decision import DecisionInputs, DecisionResult, evaluate
from .pricing import PricingEntry, get_pricing

__all__ = ["RouteCandidate", "RoutedChoice", "route"]


@dataclasses.dataclass(frozen=True)
class RouteCandidate:
    """One (provider, model) tier an operation could be served by."""

    provider: str
    model: str
    latency_est_s: float          # expected operation latency on this tier
    output_tokens_est: float      # tier-specific verbosity estimate
    input_tokens_est: int
    P: float                      # success probability on this tier

    def pricing(self) -> PricingEntry:
        return get_pricing(self.provider, self.model)


@dataclasses.dataclass(frozen=True)
class RoutedChoice:
    candidate: RouteCandidate
    result: DecisionResult
    score: float                  # alpha-weighted objective (lower is better)


def route(
    candidates: list[RouteCandidate],
    alpha: float,
    lambda_usd_per_s: float,
    baseline_latency_s: Optional[float] = None,
) -> RoutedChoice:
    """Pick the tier minimizing the alpha-weighted latency/cost objective

        score = alpha * latency * lambda + (1 - alpha) * expected_cost

    where expected_cost = C_spec + (1-P) * C_spec (the failure-weighted
    waste the D4 rule charges).  Ties broken toward lower latency.
    The D4 decision itself is evaluated per candidate against the slowest
    tier's latency as the savings baseline (latency saved by *this* tier
    relative to the worst), matching "evaluating the decision rule
    independently per candidate" (§6.6).
    """
    if not candidates:
        raise ValueError("no routing candidates")
    base = baseline_latency_s or max(c.latency_est_s for c in candidates)
    scored: list[RoutedChoice] = []
    for c in candidates:
        pr = c.pricing()
        latency_saved = max(0.0, base - c.latency_est_s)
        res = evaluate(
            DecisionInputs(
                P=c.P,
                alpha=alpha,
                lambda_usd_per_s=lambda_usd_per_s,
                latency_seconds=latency_saved,
                input_tokens=c.input_tokens_est,
                output_tokens=c.output_tokens_est,
                input_price=pr.input_price_per_token,
                output_price=pr.output_price_per_token,
            )
        )
        expected_cost = res.C_spec_usd + (1.0 - c.P) * res.C_spec_usd
        score = alpha * c.latency_est_s * lambda_usd_per_s + (1.0 - alpha) * expected_cost
        scored.append(RoutedChoice(c, res, score))
    return min(scored, key=lambda rc: (rc.score, rc.candidate.latency_est_s))
