"""The workflow model: a static DAG of LLM operations (paper §2.1).

W = (V, E): each vertex is an LLM call or tool invocation; each edge
(u, v) means v consumes u's output.  The topology is fixed before
execution (runtime-determined topologies are out of scope, §1.4 — mutation
after freeze raises).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

from .admissibility import AdmissibilityTag
from .success import TierPolicy
from .taxonomy import DependencyType

__all__ = ["Operation", "Edge", "Workflow", "WorkflowError"]


class WorkflowError(ValueError):
    pass


@dataclasses.dataclass
class Operation:
    """One vertex: an LLM call or tool invocation.

    ``run`` executes the op given its (joined) upstream inputs and returns
    the output; in simulation it is a deterministic function, in production
    it is a serving-engine call (repro.serving.spec_bridge.EngineOp).
    """

    name: str
    run: Callable[..., Any] = None  # type: ignore[assignment]
    provider: str = "paper"
    model: str = "frontier-default"
    # estimates consumed by the decision rule / planner
    input_tokens_est: int = 500
    output_tokens_est: int = 1000
    latency_est_s: float = 1.0
    # admissibility (§3.3): default side-effect-free (pure generation /
    # read-only tool).  Ops that fail all three routes are non-speculable.
    admissibility: AdmissibilityTag = AdmissibilityTag.SIDE_EFFECT_FREE
    # whether the op streams output tokens (enables §9 machinery)
    streams: bool = True
    metadata: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.run is None:
            # default: identity-ish echo (useful for simulation-only DAGs)
            self.run = lambda *inputs: inputs[0] if len(inputs) == 1 else tuple(inputs)


@dataclasses.dataclass
class Edge:
    """One dependency (u, v) with its speculation-relevant annotations."""

    upstream: str
    downstream: str
    dep_type: DependencyType = DependencyType.CONDITIONAL_OUTPUT
    k: Optional[int] = None                 # for router_k_way priors
    rare_event_p: Optional[float] = None    # for rare_event_trigger priors
    tier_policy: TierPolicy = dataclasses.field(default_factory=TierPolicy)
    # §12 per-edge enable bit — the method's most consequential operational
    # knob; set by §12.1 go/no-go, flipped by §12.5 kill-switch.
    enabled: bool = True

    @property
    def key(self) -> tuple[str, str]:
        return (self.upstream, self.downstream)


class Workflow:
    """A static DAG.  Construction API then ``freeze()``; the planner and
    executor only accept frozen workflows."""

    def __init__(self, name: str = "workflow") -> None:
        self.name = name
        self.ops: dict[str, Operation] = {}
        self.edges: dict[tuple[str, str], Edge] = {}
        self._frozen = False

    # ------------------------------------------------------------- building
    def add_op(self, op: Operation) -> Operation:
        self._check_mutable()
        if op.name in self.ops:
            raise WorkflowError(f"duplicate operation {op.name!r}")
        self.ops[op.name] = op
        return op

    def add_edge(self, edge: Edge) -> Edge:
        self._check_mutable()
        for end in (edge.upstream, edge.downstream):
            if end not in self.ops:
                raise WorkflowError(f"edge references unknown op {end!r}")
        if edge.upstream == edge.downstream:
            raise WorkflowError("self-loops are not a DAG")
        if edge.key in self.edges:
            raise WorkflowError(f"duplicate edge {edge.key}")
        self.edges[edge.key] = edge
        return edge

    def chain(self, *ops: Operation, dep_type=DependencyType.CONDITIONAL_OUTPUT) -> None:
        """Convenience: a linear chain op1 -> op2 -> ... ."""
        for op in ops:
            if op.name not in self.ops:
                self.add_op(op)
        for u, v in zip(ops, ops[1:]):
            self.add_edge(Edge(u.name, v.name, dep_type=dep_type))

    def freeze(self) -> "Workflow":
        """Validate acyclicity and lock the topology (§1.4 static-DAG scope)."""
        self._topo_order()  # raises on cycles
        self._frozen = True
        return self

    def _check_mutable(self) -> None:
        if self._frozen:
            raise WorkflowError(
                "workflow topology is frozen; runtime-determined topologies "
                "are out of scope (paper §1.4)"
            )

    # -------------------------------------------------------------- queries
    @property
    def frozen(self) -> bool:
        return self._frozen

    def parents(self, name: str) -> list[str]:
        return [u for (u, v) in self.edges if v == name]

    def children(self, name: str) -> list[str]:
        return [v for (u, v) in self.edges if u == name]

    def sources(self) -> list[str]:
        return [n for n in self.ops if not self.parents(n)]

    def sinks(self) -> list[str]:
        return [n for n in self.ops if not self.children(n)]

    def _topo_order(self) -> list[str]:
        indeg = {n: len(self.parents(n)) for n in self.ops}
        frontier = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while frontier:
            n = frontier.pop(0)
            order.append(n)
            for c in sorted(self.children(n)):
                indeg[c] -= 1
                if indeg[c] == 0:
                    frontier.append(c)
        if len(order) != len(self.ops):
            raise WorkflowError("workflow graph has a cycle")
        return order

    def topo_order(self) -> list[str]:
        return self._topo_order()

    def speculation_candidates(self) -> list[Edge]:
        """Edges eligible for the EV gate: enabled AND admissible (§3.3).

        The admissibility precondition runs *before* the EV rule — a
        non-speculable edge never reaches the gate.
        """
        out = []
        for edge in self.edges.values():
            op = self.ops[edge.downstream]
            if edge.enabled and op.admissibility != AdmissibilityTag.NON_SPECULABLE:
                out.append(edge)
        return out

    # ------------------------------------------------------ latency accounting
    def critical_path_latency(self, overrides: dict[str, float] | None = None) -> float:
        """Sequential-wave critical path: sum over waves of the max latency in
        each wave (paper §8.1 Latency(plan) for the maximally-parallel plan)."""
        overrides = overrides or {}
        lat = lambda n: overrides.get(n, self.ops[n].latency_est_s)
        finish: dict[str, float] = {}
        for n in self._topo_order():
            start = max((finish[p] for p in self.parents(n)), default=0.0)
            finish[n] = start + lat(n)
        return max(finish.values(), default=0.0)

    def sequential_latency(self, overrides: dict[str, float] | None = None) -> float:
        overrides = overrides or {}
        return sum(overrides.get(n, op.latency_est_s) for n, op in self.ops.items())

    def validate(self) -> None:
        self._topo_order()
