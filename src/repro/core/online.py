"""Jit'd online decision service — the serving-side twin of the fleet
replay engine.

Four PRs vectorized the *offline* paths (fleet replay, multi-tenant and
episode sharding, §12.1 grids); this module is the first end-to-end jit'd
*request* path.  Posterior and drift state live on device as structure-of-
arrays tables instead of per-edge Python objects:

* the ``(N, 2)`` alpha/beta posterior table, per-row config (§7.5 gammas,
  §14.3 discounts, trigger-2 credible floors) and kill-switch flags are
  owned by :class:`repro.core.store.PosteriorStore` — the shared
  (tenant, edge) registry with free-list eviction, power-of-two capacity,
  LRU spill of cold rows to a host shelf and empirical-Bayes bucket
  hyperpriors.  The service holds a store (dense auto-grow by default;
  pass ``resident_rows=`` for the paged fixed-shape mode) and translates
  logical row ids to device slots per tick,
* drift bookkeeping (consecutive-breach run lengths, enable bits) rides
  in the store's flags table and spills/faults with the row,
* a fixed-size per-decision telemetry ring buffer (USD rows, flushed per
  tick — D2 without a host sync per decision) stays service-owned.

One double-buffered ``tick(requests) -> (decisions, state')`` call
(donation of the state buffers is opt-in, the same policy as
``multi_tenant_replay``) batches B concurrent decision requests: the D4
expected-value gate
(the :func:`repro.core.batch_decision.d4_gate` core, contraction-pinned so
EV / threshold / margin are **bitwise-f64 equal** to the scalar
``decision.evaluate``), the optional §7.5 lower bound via one vmapped
``betaincinv``, posterior updates from the tick's settled outcomes (the
exact discount recurrence of ``BetaPosterior.update``), and in-graph
kill-switch checks with ``DriftMonitor.check_credible_bound_batch``
semantics.  The row axis shards over the 1-D "fleet" mesh via
``sharding.rules.fleet_axis_spec`` with the established unsharded
fallback.

The §12.2–12.4 calibration stages fold onto the same table:
:func:`shadow_mode_batch`, :func:`canary_batch` and
:func:`online_calibration_batch` run a whole fleet's calibration round as
array ops over a posterior snapshot instead of per-record Python, with
results that match the scalar ``calibration.shadow_mode`` / ``canary`` /
``online_calibration`` bitwise at f64 (posteriors, implied lambdas) and
exactly (promotion / trigger flags).
"""
from __future__ import annotations

import dataclasses
import functools
import statistics
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .batch_decision import _f, beam_gate, d4_gate
from .betainc import betaincinv
from .calibration import (
    CanaryReport,
    OnlineReport,
    ShadowReport,
    TokenEstimator,
    _calibration_bucket,
    _canary_sweep_eval,
    _stability_converged,
    _tier2_threshold_sweep,
)
from .decision import Decision, DecisionResult
from .posterior import BetaPosterior
from .rollout import rollout_advance, rollout_allow
from .store import PosteriorStore, _RowConfig
from .success import TierPolicy, check_success
from .taxonomy import DEFAULT_N0, DependencyType
from .telemetry import RESILIENCE_KINDS, bucket_key

__all__ = [
    "OnlineDecisionService",
    "ServiceState",
    "TickDecisions",
    "TelemetryBatch",
    "TELEMETRY_FIELDS",
    "shadow_mode_batch",
    "canary_batch",
    "online_calibration_batch",
]

# Per-decision USD telemetry row layout (Appendix C distilled to the D2
# essentials): every served decision logged in dollars, one ring slot each.
TELEMETRY_FIELDS = (
    "row", "speculate", "P_used", "P_mean", "EV_usd", "threshold_usd",
    "margin_usd", "C_spec_usd", "L_value_usd", "launched",
)


_COL = {name: i for i, name in enumerate(TELEMETRY_FIELDS)}

# Resilience events share the ring with decision rows.  The "row" column
# is the discriminator: >= 0 is a decision, -1 an empty/padding slot, and
# <= -2 a resilience event for table row (-v - 3), with -2 meaning "no
# specific row".  Event rows reuse the "speculate" column for the kind
# code (1-based index into telemetry.RESILIENCE_KINDS) and the
# "C_spec_usd" column for the event's attributed USD.
_EVENT_CODE = {k: i + 1 for i, k in enumerate(RESILIENCE_KINDS)}
_EVENT_KIND = {i + 1: k for i, k in enumerate(RESILIENCE_KINDS)}


def _encode_event_row(row: Optional[int]) -> float:
    return -2.0 if row is None else float(-3 - int(row))


def _decode_event_row(v: float) -> Optional[int]:
    return None if v == -2.0 else int(-v) - 3


class ServiceState(NamedTuple):
    """Device-resident service state (a pytree of six packed arrays —
    few, large leaves keep per-tick dispatch overhead low on CPU)."""

    post: jax.Array      # (N, 2) posterior alpha/beta rows
    rowcfg: jax.Array    # (N, 3) per-row [gamma, discount, trigger-2 floor]
    flags: jax.Array     # (N, 2) int32 [enabled, breach_run]
    roll: jax.Array      # (N, 6) int32 rollout lifecycle columns
    tel: jax.Array       # (R, F) telemetry ring (last R slots, oldest first)
    counters: jax.Array  # (2,)   int32 [slots ever appended, real rows ever]


def _tick_impl(state, zero, row, logrow, reqs, bconf, bwidth, out_row,
               out_x, consecutive_n, rollcfg, use_lower_bound, check_drift,
               use_rollout, use_beam):
    """One service tick, entirely in-graph.

    ``row`` / ``out_row`` use -1 as the padding sentinel (shape buckets)
    and index the *physical* table; ``logrow`` carries the corresponding
    logical row ids for the telemetry rows (identical to ``row`` in the
    store's dense identity mode — the paged mode passes the pre-translate
    ids so drained telemetry reports stable logical rows).  ``reqs``
    packs the per-request floats as columns
    [alpha, lambda, latency_s, in_tok, out_tok, in_price, out_price].

    ``use_beam`` swaps the gate for the top-k beam rule
    (``batch_decision.beam_gate``): ``bconf`` (Bp, W) carries per-request
    candidate confidences and ``bwidth`` (Bp,) the beam width caps; the
    telemetry "P_used" column then reports the beam-cumulative commit
    probability the gate ran on, and "launched" the candidates launched
    (``w_eff`` on served rows).  Non-beam ticks pass fixed zero-size
    placeholders (never traced into the graph — the decision section of
    the default executable is exactly the pre-beam one) and log
    ``launched`` = served.

    Order (documented contract, mirrored by the parity tests):

      1. settle outcomes — sequential discount recurrence over the tick's
         settled (row, success) pairs, exactly ``BetaPosterior.update``
         applied in arrival order (same-row outcomes compose correctly);
      2. answer decisions against the settled table — D4 gate via the
         contraction-pinned :func:`batch_decision.d4_gate`, optionally on
         the §7.5 lower bound (one vmapped ``betaincinv``);
      3. drift/kill-switch — one ``check_credible_bound_batch``-semantics
         breach step per *touched* row (post-settlement posteriors);
      3b. rollout lifecycle (``use_rollout``) — the staged-rollout state
         machine advances on the same touched mask: serving is gated by
         the *pre-tick* phase (``rollout.rollout_allow``), demotion by
         this tick's kill-switch triggers, promotion by the accumulated
         outcome evidence (``rollout.rollout_advance``; ``rollcfg`` is
         the encoded RolloutConfig vector — dynamic, never a recompile);
      4. telemetry — the tick's decision rows (which double as the
         returned decisions) appended to the ring, oldest slots evicted.
    """
    post, rowcfg, flags, roll, tel, counters = state

    # ---- 1. settle this tick's outcomes (exact discount recurrence).
    # ``a*d + zero`` pins round(a*d) (or is the identity fma), so the
    # update is bitwise the scalar two-step ``a *= d; a += x``.
    def step(p, o):
        r, x = o
        ri = jnp.maximum(r, 0)
        a, b = p[ri, 0], p[ri, 1]
        d = rowcfg[ri, 1]
        a2 = (a * d + zero) + x
        b2 = (b * d + zero) + (1.0 - x)
        new = jnp.where(r >= 0, jnp.stack([a2, b2]), jnp.stack([a, b]))
        return p.at[ri].set(new), None

    if out_row.shape[0]:          # static: the S=0 executable has no scan
        post, _ = jax.lax.scan(step, post, (out_row, out_x))

    # ---- 2. batched D4 decisions against the settled table
    valid = row >= 0
    ri = jnp.maximum(row, 0)
    g = post[ri]
    P_mean = g[:, 0] / (g[:, 0] + g[:, 1])
    if use_lower_bound:
        P_used = betaincinv(g[:, 0], g[:, 1], rowcfg[ri, 0])
    else:
        P_used = P_mean
    if use_beam:
        EV, thr, flag, C_spec, L_value, w_eff, p_cum = beam_gate(
            P_used, bconf, bwidth, reqs[:, 0], reqs[:, 1], reqs[:, 2],
            reqs[:, 3], reqs[:, 4], reqs[:, 5], reqs[:, 6], zero)
        # the telemetry P_used column reports what the gate ran on — for
        # a beam that is the cumulative commit probability
        P_used = p_cum
        w_eff_f = w_eff.astype(post.dtype)
    else:
        EV, thr, flag, C_spec, L_value = d4_gate(
            P_used, reqs[:, 0], reqs[:, 1], reqs[:, 2], reqs[:, 3],
            reqs[:, 4], reqs[:, 5], reqs[:, 6], zero)
    enabled_req = flags[ri, 0] > 0
    if use_rollout:
        # serving gated by the PRE-tick lifecycle state: SHADOW rows are
        # decided + logged but answer WAIT; CANARY serves its period tick.
        # The lifecycle gate folds into the per-request enabled bit so
        # TickDecisions.speculate (and the frontend reading it) agrees
        # with the telemetry "speculate" column.
        enabled_req = enabled_req & rollout_allow(roll, rollcfg)[ri]
    served = flag & enabled_req

    # ---- 3. drift / kill-switch (trigger 2 semantics, per touched row)
    n_rows = post.shape[0]
    if check_drift or use_rollout:
        touched = jnp.zeros(n_rows, jnp.int32).at[ri].add(
            valid.astype(jnp.int32)) > 0
    if check_drift:
        run = flags[:, 1]
        P_low = betaincinv(post[:, 0], post[:, 1], rowcfg[:, 0])
        breached = touched & (P_low < rowcfg[:, 2])
        run = jnp.where(touched, jnp.where(breached, run + 1, 0), run)
        triggered = touched & (run >= consecutive_n)
        enabled = (flags[:, 0] > 0) & ~triggered
        run = jnp.where(triggered, 0, run)
        flags = jnp.stack([enabled.astype(jnp.int32), run], 1)
    else:
        triggered = jnp.zeros(n_rows, bool)

    # ---- 3b. rollout lifecycle advance over the post-drift state
    if use_rollout:
        if out_row.shape[0]:      # static: the S=0 executable skips it
            ovalid = (out_row >= 0).astype(jnp.int32)
            ori = jnp.maximum(out_row, 0)
            n_out = jnp.zeros(n_rows, jnp.int32).at[ori].add(ovalid)
            s_out = jnp.zeros(n_rows, jnp.int32).at[ori].add(
                ovalid * (out_x > 0.5).astype(jnp.int32))
        else:
            n_out = s_out = jnp.zeros(n_rows, jnp.int32)
        # per-row L_value sums: the latency value a demotion walks away
        # from this tick — the USD the transition event is billed
        row_L = jnp.zeros(n_rows, post.dtype).at[ri].add(
            jnp.where(valid, L_value, 0.0))
        roll, flags, transitions = rollout_advance(
            roll, flags, triggered, touched, n_out, s_out, rollcfg)
    else:
        transitions = jnp.zeros(0, jnp.int32)
        row_L = jnp.zeros(0, post.dtype)

    # ---- 4. telemetry: the decision rows ARE the ring rows.  The ring
    # holds the most recent R slots in order (append + evict is two
    # memcpys — far cheaper than a modulo scatter on CPU); sentinel rows
    # (row == -1) are dropped at drain time.
    dt = post.dtype
    served_f = served.astype(dt)
    launched_col = served_f * w_eff_f if use_beam else served_f
    rows_out = jnp.stack([
        logrow.astype(dt), served_f, P_used, P_mean,
        EV, thr, EV - thr, C_spec, L_value, launched_col,
    ], axis=1)
    Bp = rows_out.shape[0]
    R = tel.shape[0]
    if Bp >= R:
        tel = rows_out[Bp - R:]
    else:
        tel = jnp.concatenate([tel[Bp:], rows_out], 0)
    counters = counters + jnp.stack(
        [jnp.asarray(Bp, jnp.int32), valid.sum(dtype=jnp.int32)])

    new_state = ServiceState(post=post, rowcfg=rowcfg, flags=flags,
                             roll=roll, tel=tel, counters=counters)
    bools = jnp.stack([flag, enabled_req], 1)
    return new_state, rows_out, bools, triggered, transitions, row_L


# Donation is opt-in (OnlineDecisionService(donate=True)): aliasing the
# state buffers caps memory at two table copies — the double-buffer story
# for HBM-resident million-row tables — but measurably slows CPU dispatch,
# so the default follows multi_tenant_replay(donate=False).
_TICK_STATICS = ("use_lower_bound", "check_drift", "use_rollout",
                 "use_beam")
_tick = functools.partial(jax.jit, static_argnames=_TICK_STATICS)(_tick_impl)
_tick_donated = functools.partial(
    jax.jit, static_argnames=_TICK_STATICS, donate_argnums=(0,))(_tick_impl)


def _fused_tick_impl(state, zero, row, logrow, reqs, out_row, out_x,
                     consecutive_n, use_lower_bound, check_drift, block_n,
                     interpret):
    """The tick's steps 1-3 as ONE Pallas launch over the SoA row axis
    (``repro.kernels.online_tick``: settle + D4 gate + drift fused),
    plus the same step-4 telemetry append as ``_tick_impl``.

    Contract: on the mean path (``use_lower_bound=False``) every output
    — settled posteriors, decisions, drift runs, telemetry rows — is
    bitwise-f64 equal to ``_tick_impl`` (the kernel preserves the traced
    runtime-zero FMA pin and the arrival-order settle recurrence); the
    lower-bound / drift quantile paths sit at the <= 1e-10 betaincinv
    tier because the kernel carries its own betainc evaluator.  Rollout
    and beam ticks are not fused — ``tick_packed`` falls back to
    ``_tick_impl`` for those.
    """
    # trace-time import: keeps repro.core free of any module-level
    # dependency on the kernels package (which imports back into core)
    from ..kernels.online_tick import online_tick_kernel_call

    post, rowcfg, flags, roll, tel, counters = state
    (post, flags, P_used, P_mean, EV, thr, C_spec, L_value,
     flagv, enreqv, trig) = online_tick_kernel_call(
        post, rowcfg, flags, zero, row, reqs, out_row, out_x,
        consecutive_n, use_lower_bound=use_lower_bound,
        check_drift=check_drift, block_n=block_n, interpret=interpret)
    flag = flagv > 0
    enabled_req = enreqv > 0
    served = flag & enabled_req
    triggered = trig > 0

    # ---- step 4 verbatim from _tick_impl (non-beam: launched = served)
    dt = post.dtype
    served_f = served.astype(dt)
    rows_out = jnp.stack([
        logrow.astype(dt), served_f, P_used, P_mean,
        EV, thr, EV - thr, C_spec, L_value, served_f,
    ], axis=1)
    Bp = rows_out.shape[0]
    R = tel.shape[0]
    if Bp >= R:
        tel = rows_out[Bp - R:]
    else:
        tel = jnp.concatenate([tel[Bp:], rows_out], 0)
    counters = counters + jnp.stack(
        [jnp.asarray(Bp, jnp.int32),
         (row >= 0).sum(dtype=jnp.int32)])

    new_state = ServiceState(post=post, rowcfg=rowcfg, flags=flags,
                             roll=roll, tel=tel, counters=counters)
    bools = jnp.stack([flag, enabled_req], 1)
    return (new_state, rows_out, bools, triggered,
            jnp.zeros(0, jnp.int32), jnp.zeros(0, dt))


_FUSED_TICK_STATICS = ("use_lower_bound", "check_drift", "block_n",
                       "interpret")
_fused_tick = functools.partial(
    jax.jit, static_argnames=_FUSED_TICK_STATICS)(_fused_tick_impl)
_fused_tick_donated = functools.partial(
    jax.jit, static_argnames=_FUSED_TICK_STATICS,
    donate_argnums=(0,))(_fused_tick_impl)


@jax.jit
def _append_tel(tel, rows):
    """Append pre-encoded rows to the slide-buffer ring (same append +
    evict semantics as the tick's step 4) — the out-of-tick path the
    front-end's resilience events take."""
    E, R = rows.shape[0], tel.shape[0]
    if E >= R:
        return rows[E - R:]
    return jnp.concatenate([tel[E:], rows], 0)


def _bucket(n: int, lo: int = 1) -> int:
    """Power-of-two shape bucket (compile-cache stability across ticks)."""
    if n <= 0:
        return 0
    return max(lo, 1 << (n - 1).bit_length())


class _RowsView:
    """Sequence view over the store registry exposing per-row
    :class:`repro.core.store._RowConfig` records (the pre-store
    ``service._rows`` list surface, preserved for callers)."""

    def __init__(self, store: PosteriorStore) -> None:
        self._store = store

    def __len__(self) -> int:
        return self._store.n_rows

    def __getitem__(self, i: int) -> _RowConfig:
        return self._store.row_config(i)


@dataclasses.dataclass
class TickDecisions:
    """One tick's batched answers.  The device outputs are pulled to host
    lazily and at most once — reading any field is the tick's single
    host sync (the decision block is the same (B, F) matrix the
    telemetry ring stores)."""

    batch: int
    _rows: Any                # (Bp, F) decision/telemetry block
    _bools: Any               # (Bp, 2) [raw D4 flag, enabled]
    _drift: Any               # (N,) bool, over *physical* slots
    # paged-store ticks: the tick's slot -> logical-id map (None in the
    # dense identity mode, where slot == logical row) and the logical
    # high-water mark, so drift_triggered reads in logical coordinates
    _slot_logical: Any = None
    _n_logical: int = 0
    # rollout ticks: packed per-slot transition codes and per-slot
    # L_value sums (None when the tick ran without the rollout static)
    _transitions: Any = None
    _row_L: Any = None
    _cache: dict = dataclasses.field(default_factory=dict)

    def _col(self, name: str) -> np.ndarray:
        if "rows" not in self._cache:
            self._cache["rows"] = np.asarray(self._rows)[: self.batch]
        return self._cache["rows"][:, _COL[name]]

    def _bool(self, j: int) -> np.ndarray:
        if "bools" not in self._cache:
            self._cache["bools"] = np.asarray(self._bools)[: self.batch]
        return self._cache["bools"][:, j]

    @property
    def speculate(self) -> np.ndarray:      # D4 flag AND kill-switch
        # identical to the telemetry "speculate" column, but served from
        # the small bool block (the common flags-only flush stays cheap)
        return self._bool(0) & self._bool(1)

    @property
    def flag(self) -> np.ndarray:           # raw D4 flag (parity-pinned)
        return self._bool(0)

    @property
    def enabled(self) -> np.ndarray:
        return self._bool(1)

    @property
    def EV_usd(self) -> np.ndarray:
        return self._col("EV_usd")

    @property
    def threshold_usd(self) -> np.ndarray:
        return self._col("threshold_usd")

    @property
    def margin_usd(self) -> np.ndarray:
        return self._col("margin_usd")

    @property
    def C_spec_usd(self) -> np.ndarray:
        return self._col("C_spec_usd")

    @property
    def L_value_usd(self) -> np.ndarray:
        return self._col("L_value_usd")

    @property
    def P_used(self) -> np.ndarray:
        return self._col("P_used")

    @property
    def P_mean(self) -> np.ndarray:
        return self._col("P_mean")

    @property
    def launched(self) -> np.ndarray:
        """Candidates launched per served decision: ``w_eff`` on beam
        ticks, 0/1 on single-candidate ticks — the per-candidate USD
        attribution column."""
        return self._col("launched")

    @property
    def drift_triggered(self) -> np.ndarray:
        if "drift" not in self._cache:
            mask = np.asarray(self._drift)
            if self._slot_logical is not None:
                # paged store: compose the per-slot trip mask back into
                # logical row coordinates (unoccupied slots drop out)
                out = np.zeros(self._n_logical, bool)
                sl = self._slot_logical
                res = sl >= 0
                out[sl[res]] = mask[: sl.shape[0]][res]
                mask = out
            self._cache["drift"] = mask
        return self._cache["drift"]

    def _logical_vec(self, arr, dtype) -> np.ndarray:
        """Compose a per-physical-slot vector into logical coordinates
        (identity mode truncates; paged mode maps through the tick's
        slot -> logical snapshot)."""
        out = np.zeros(self._n_logical, dtype)
        if arr is None:
            return out
        vec = np.asarray(arr)
        if self._slot_logical is None:
            n = min(vec.shape[0], self._n_logical)
            out[:n] = vec[:n]
            return out
        sl = self._slot_logical
        res = sl >= 0
        out[sl[res]] = vec[: sl.shape[0]][res]
        return out

    @property
    def rollout_transitions(self) -> np.ndarray:
        """(n_logical,) int32 packed lifecycle transition codes
        (``rollout.decode_transition``; 0 = no transition) — zeros when
        the tick ran without the rollout machine."""
        if "trans" not in self._cache:
            self._cache["trans"] = self._logical_vec(
                self._transitions, np.int32)
        return self._cache["trans"]

    @property
    def rollout_usd(self) -> np.ndarray:
        """(n_logical,) summed L_value USD over each row's requests this
        tick — the demotion-billing vector."""
        if "row_L" not in self._cache:
            self._cache["row_L"] = self._logical_vec(self._row_L, np.float64)
        return self._cache["row_L"]


@dataclasses.dataclass(frozen=True)
class TelemetryBatch:
    """Rows drained from the device telemetry ring, oldest first."""

    fields: dict[str, np.ndarray]
    dropped: int                     # rows overwritten before this drain
    # resilience event rows that shared the drained window (see
    # log_events): [{"kind", "row", "usd"}], oldest first
    events: list = dataclasses.field(default_factory=list)
    events_dropped: int = 0

    def __len__(self) -> int:
        return int(next(iter(self.fields.values())).shape[0]) if self.fields else 0

    def rows(self) -> list[dict]:
        n = len(self)
        return [
            {k: (int(v[i]) if k in ("row", "speculate") else float(v[i]))
             for k, v in self.fields.items()}
            for i in range(n)
        ]


class OnlineDecisionService:
    """Device-resident batched decision service over the shared
    :class:`repro.core.store.PosteriorStore` row registry.

    Registration is host-side and O(1) amortized; the first tick (or the
    first one after a registration / dtype change) materializes pending
    rows into the store's device table — one batched scatter, padded to a
    power-of-two row count so registries grow without retracing and
    without per-row host rebuilds.  Passing ``resident_rows=R`` selects
    the store's *paged* mode: the physical table shape is fixed forever
    (zero recompiles under unbounded registry growth) and cold rows spill
    LRU-first to the store's host shelf, faulting back in transparently
    when a tick touches them.  When a ``mesh`` with a ``fleet`` axis
    divides the padded row count, the table's row axis is sharded across
    it (``sharding.rules.fleet_axis_spec``); otherwise the established
    unsharded fallback applies.
    """

    def __init__(
        self,
        *,
        use_lower_bound: bool = False,
        credible_consecutive_n: int = 5,
        telemetry_capacity: int = 4096,
        mesh=None,
        axis_name: str = "fleet",
        min_rows: int = 16,
        donate: bool = False,
        resident_rows: Optional[int] = None,
        store: Optional[PosteriorStore] = None,
        use_fused_tick: bool = False,
        fused_block_n: int = 1024,
    ) -> None:
        if telemetry_capacity < 1:
            raise ValueError("telemetry_capacity must be >= 1")
        self.use_lower_bound = use_lower_bound
        # Pallas fused-tick dispatch (settle + gate + drift in one kernel
        # launch; repro.kernels.online_tick).  Off by default: rollout /
        # beam ticks always take the XLA path, and the mean path is the
        # only fully bitwise tier (see _fused_tick_impl).
        self.use_fused_tick = bool(use_fused_tick)
        self.fused_block_n = int(fused_block_n)
        self.credible_consecutive_n = int(credible_consecutive_n)
        self.telemetry_capacity = int(telemetry_capacity)
        self.mesh = mesh
        self.axis_name = axis_name
        self.min_rows = int(min_rows)
        self.donate = bool(donate)
        self.store = store if store is not None else PosteriorStore(
            resident_rows=resident_rows, min_rows=min_rows, mesh=mesh,
            axis_name=axis_name)
        self._tel = None
        self._counters = None
        self._state_dtype: Optional[str] = None
        self._pending: list[tuple[int, bool]] = []
        # telemetry totals tracked host-side in unbounded Python ints —
        # the device-side ServiceState.counters are int32 and would wrap
        # within hours of sustained serving, silently emptying drains
        self._slots_total = 0
        self._rows_total = 0
        self._drained_slots = 0
        self._drained_rows = 0
        self._events_total = 0
        self._drained_events = 0
        # idle ticks (B=0, S=0, no drift check) short-circuit host-side —
        # the deadline-driven batcher hits this path constantly, and even
        # an empty jit'd tick costs ~0.1 ms of dispatch
        self.idle_ticks_skipped = 0
        # all-padding settle buckets downgrade to the S=0 executable at
        # the trace key (the settle scan is a provable no-op on them);
        # counts how often the cheaper executable was substituted
        self.empty_settles_skipped = 0

    # ------------------------------------------------------------- registry
    def register_edge(
        self,
        edge: tuple[str, str],
        *,
        tenant: Optional[str] = None,
        dep_type: Optional[DependencyType] = None,
        k: Optional[int] = None,
        rare_event_p: Optional[float] = None,
        n0: float = DEFAULT_N0,
        posterior: Optional[BetaPosterior] = None,
        gamma: float = 0.1,
        discount: float = 1.0,
        floor_alpha: float = 0.5,
        floor_C_spec_usd: Optional[float] = None,
        floor_L_value_usd: Optional[float] = None,
        bucket: Optional[str] = None,
        pooled: bool = True,
    ) -> int:
        """Add one (tenant, edge) row; returns its stable logical id.

        Delegates to :meth:`PosteriorStore.register`: the prior is
        taxonomy-keyed (``prior_params(dep_type, k=...)``) — or, when the
        store has a fitted empirical-Bayes hyperprior for the row's
        taxonomy ``bucket`` and ``pooled`` is left on, the bucket's
        *learned* prior — unless an explicit ``posterior`` seeds the row
        (§12.1 data-seeded deployment).  ``floor_*`` pin the row's
        trigger-2 credible floor ``(1 - alpha) * C / (L_value + C)`` from
        its canonical decision context; rows without one never breach.
        Host-only and O(1) amortized — the row materializes on device in
        the next tick's batched pending scatter.
        """
        return self.store.register(
            edge, tenant=tenant, dep_type=dep_type, k=k,
            rare_event_p=rare_event_p, n0=n0, posterior=posterior,
            gamma=gamma, discount=discount, floor_alpha=floor_alpha,
            floor_C_spec_usd=floor_C_spec_usd,
            floor_L_value_usd=floor_L_value_usd,
            bucket=bucket, pooled=pooled)

    def evict_edge(self, edge: tuple[str, str],
                   tenant: Optional[str] = None) -> None:
        """Drop a (tenant, edge) row entirely (free-list recycling; any
        attached drift monitor's host state is dropped via the store's
        ``on_evict`` hook)."""
        self.store.evict(edge, tenant)

    def attach_drift_monitor(self, monitor) -> None:
        """Wire a ``DriftMonitor``'s host-side bookkeeping to the store's
        row lifecycle: eviction drops the monitor's per-row state and a
        spilled row faulting back in re-seeds its trigger-1 baseline
        (the device-resident flags stay authoritative for trigger 2)."""
        self.store.on_evict = monitor.evict_state
        self.store.on_fault_in = monitor.reseed_baseline

    def row_index(self, edge: tuple[str, str],
                  tenant: Optional[str] = None) -> int:
        return self.store.row_index(edge, tenant)

    def row_key(self, row: int) -> tuple[Optional[str], tuple[str, str]]:
        return self.store.row_key(row)

    def row_gamma(self, row: int) -> float:
        """The §7.5 gamma the row's lower-bound gate uses."""
        return self.store.row_config(row).gamma

    @property
    def n_rows(self) -> int:
        return self.store.n_rows

    @property
    def _rows(self) -> _RowsView:
        return _RowsView(self.store)

    def fit_hyperpriors(self, **kwargs) -> dict:
        """Run the store's jit'd empirical-Bayes bucket fit over the
        device-resident rows (see :meth:`PosteriorStore.fit_hyperpriors`);
        subsequent registrations in fitted buckets are born pooled."""
        self._ensure_ready()
        return self.store.fit_hyperpriors(**kwargs)

    # ------------------------------------------------------------ state mgmt
    def _ensure_ready(self) -> None:
        """Materialize the store's device tables for the working dtype
        (applying pending registrations in one batched scatter) and the
        service-owned telemetry ring."""
        # config read (~0.2us) instead of jnp.result_type (~5us): the
        # working float dtype only ever changes through jax_enable_x64
        dtype = "float64" if jax.config.jax_enable_x64 else "float32"
        if self.store.n_rows == 0:
            raise ValueError("no edges registered")
        self.store.device_tables(dtype)
        if self._tel is None or self._state_dtype != dtype:
            if self._tel is not None:
                # dtype switch: f64 round-trip is value-exact for the f32
                # case; the f64 -> f32 direction re-rounds, as any dtype
                # change must
                tel = np.asarray(self._tel, np.float64)
                counters = np.asarray(self._counters)
            else:
                tel = np.zeros((self.telemetry_capacity,
                                len(TELEMETRY_FIELDS)))
                tel[:, _COL["row"]] = -1.0    # empty slots drop at drain
                counters = np.zeros(2, np.int32)
            if self.store.row_sharding is not None:
                from jax.sharding import NamedSharding, PartitionSpec

                rep = NamedSharding(self.mesh, PartitionSpec())
                self._tel = jax.device_put(_f(tel), rep)
                self._counters = jax.device_put(jnp.asarray(counters), rep)
            else:
                self._tel = _f(tel)
                self._counters = jnp.asarray(counters)
            self._state_dtype = dtype
            # per-tick constants, rebuilt only here (hot-path dispatch
            # stays free of dtype machinery)
            self._np_dtype = np.dtype(dtype)
            self._zero = self._np_dtype.type(0.0)
            self._cn = np.int32(self.credible_consecutive_n)
            self._empty_out = (np.full(0, -1, np.int32),
                               np.zeros(0, self._np_dtype))
            # placeholder rollout config operand for non-rollout ticks
            # (one fixed array — never churns the executable's operands)
            self._null_rollcfg = np.ones(9, np.int32)
            # placeholder beam operands for non-beam ticks (zero-size and
            # shape-stable: the use_beam=False executable never reads them)
            self._null_beam = (np.zeros((0, 1), self._np_dtype),
                               np.zeros(0, np.int32))

    def _ensure_state(self) -> ServiceState:
        self._ensure_ready()
        post, rowcfg, flags, roll = self.store.tables()
        return ServiceState(post=post, rowcfg=rowcfg, flags=flags,
                            roll=roll, tel=self._tel,
                            counters=self._counters)

    @property
    def state(self) -> ServiceState:
        return self._ensure_state()

    # -------------------------------------------------------------- queries
    def posterior_snapshot(self) -> np.ndarray:
        """(n_rows, 2) alpha/beta view composed across the store's tiers
        (device-resident rows, spilled shelf rows, unborn priors)."""
        self._ensure_ready()
        return self.store.snapshot(self._np_dtype)

    def rows_snapshot(self, rows) -> np.ndarray:
        """(k, 2) f64 alpha/beta values for specific logical rows without
        touching residency — the front-end mirror-miss read path."""
        self._ensure_ready()
        return self.store.rows_snapshot(rows, np.float64)

    def posterior(self, row: int) -> BetaPosterior:
        self._ensure_ready()
        a, b = self.store.rows_snapshot([row], self._np_dtype)[0]
        return BetaPosterior.from_row(
            a, b, discount=self.store.row_config(row).discount)

    def set_posterior(self, row: int, alpha: float, beta: float) -> None:
        if alpha <= 0 or beta <= 0:
            raise ValueError("Beta parameters must be positive")
        self._ensure_ready()
        self.store.set_rows(np.asarray([row]),
                            np.asarray([[alpha, beta]], np.float64))

    def enabled_snapshot(self) -> np.ndarray:
        self._ensure_ready()
        return self.store.flags_snapshot()[:, 0] > 0

    def breach_runs(self) -> np.ndarray:
        self._ensure_ready()
        return self.store.flags_snapshot()[:, 1].copy()

    # ---------------------------------------------------------------- ticks
    def observe(self, row: int, success: bool) -> None:
        """Queue a settled outcome; applied (in order) on the next tick."""
        row = int(row)
        # same contract as tick(outcomes=...): a bad (or evicted) row must
        # raise here, not silently scatter onto padding at the next tick
        self.store.check_rows(np.asarray([row]), "outcome")
        self._pending.append((row, bool(success)))

    def tick(
        self,
        rows,
        *,
        alpha,
        lambda_usd_per_s,
        latency_s,
        input_tokens,
        output_tokens,
        input_price,
        output_price,
        outcomes: Optional[Sequence[tuple[int, bool]]] = None,
        use_lower_bound: Optional[bool] = None,
        check_drift: bool = False,
        use_rollout: bool = False,
        rollout_cfg: Optional[np.ndarray] = None,
        beam_confidences=None,
        beam_width=None,
    ) -> TickDecisions:
        """Answer B decision requests in one donated XLA call.

        ``rows`` indexes the table; every other request field broadcasts
        against it.  ``outcomes`` (plus anything queued via
        :meth:`observe`) settle *before* the decisions are answered —
        freshest-belief serving.  ``check_drift`` runs the in-graph
        trigger-2 breach step on every touched row.

        Request shapes bucket to powers of two (padding rows carry the -1
        sentinel), so variable batch sizes share executables.  Host
        arrays are handed to the jit'd call directly in the working dtype
        — per-tick overhead is dispatch-bound, not transfer-bound.

        ``beam_confidences`` (B, W) switches the tick to the top-k beam
        gate (repro.core.beam): each request row carries its candidate
        confidences (sorted non-increasing, summing to <= 1) and
        ``beam_width`` (scalar or per-request) caps launches; the
        telemetry "launched" column then attributes every launched
        candidate in USD-traceable form.
        """
        self._ensure_ready()
        fdtype = self._np_dtype
        rows = np.atleast_1d(np.asarray(rows, np.int32))
        B = int(rows.shape[0])
        self.store.check_rows(rows, "request")
        Bp = _bucket(B)
        req_row = np.full(Bp, -1, np.int32)
        req_row[:B] = rows
        reqs = np.zeros((Bp, 7), fdtype)
        for j, x in enumerate((alpha, lambda_usd_per_s, latency_s,
                               input_tokens, output_tokens, input_price,
                               output_price)):
            reqs[:B, j] = np.asarray(x, fdtype)

        bconf = bwidth = None
        if beam_confidences is not None:
            bc = np.asarray(beam_confidences, fdtype)
            if bc.ndim != 2 or bc.shape[0] != B:
                raise ValueError(
                    f"beam_confidences must be ({B}, W), got {bc.shape}")
            if (bc < 0).any() or (bc > 1).any():
                raise ValueError("candidate confidences must be in [0, 1]")
            if (bc[:, 1:] > bc[:, :-1]).any():
                raise ValueError(
                    "beam_confidences rows must be sorted non-increasing")
            if (bc.sum(1) > 1.0 + 1e-9).any():
                raise ValueError("beam_confidences rows must sum to <= 1")
            if beam_width is None:
                beam_width = bc.shape[1]
            # padding rows: one certain candidate, width 1 (inert — the
            # -1 row sentinel already drops their decisions)
            bconf = np.zeros((Bp, bc.shape[1]), fdtype)
            bconf[:, 0] = 1.0
            bconf[:B] = bc
            bwidth = np.ones(Bp, np.int32)
            bwidth[:B] = np.asarray(beam_width, np.int32)
            if (bwidth < 1).any():
                raise ValueError("beam_width must be >= 1")
        elif beam_width is not None:
            raise ValueError("beam_width requires beam_confidences")

        out_row = out_x = None
        if outcomes is not None:
            outs = [(int(r), bool(s)) for r, s in outcomes]
            if outs:
                self.store.check_rows(
                    np.fromiter((r for r, _ in outs), np.int64, len(outs)),
                    "outcome")
            Sp = _bucket(len(outs), lo=1) if outs else 0
            out_row = np.full(Sp, -1, np.int32)
            out_x = np.zeros(Sp, fdtype)
            for i, (r, s) in enumerate(outs):
                out_row[i], out_x[i] = r, float(s)
        return self.tick_packed(
            req_row, reqs, batch=B, out_row=out_row, out_x=out_x,
            use_lower_bound=use_lower_bound, check_drift=check_drift,
            use_rollout=use_rollout, rollout_cfg=rollout_cfg,
            bconf=bconf, bwidth=bwidth)

    def tick_packed(
        self,
        row: np.ndarray,
        reqs: np.ndarray,
        *,
        batch: Optional[int] = None,
        out_row: Optional[np.ndarray] = None,
        out_x: Optional[np.ndarray] = None,
        use_lower_bound: Optional[bool] = None,
        check_drift: bool = False,
        use_rollout: bool = False,
        rollout_cfg: Optional[np.ndarray] = None,
        bconf: Optional[np.ndarray] = None,
        bwidth: Optional[np.ndarray] = None,
    ) -> TickDecisions:
        """The zero-copy hot path: the caller hands the packed request
        block its batcher accumulated between ticks — ``row`` (Bp,) int32
        with -1 padding sentinels, ``reqs`` (Bp, 7) in the working float
        dtype with columns [alpha, lambda_usd_per_s, latency_s, in_tok,
        out_tok, in_price, out_price] — and the tick dispatches with no
        per-request conversion or validation (out-of-range rows clamp;
        :meth:`tick` is the validating wrapper).  ``out_row``/``out_x``
        are the equivalently packed settled outcomes.  ``bconf`` (Bp, W)
        / ``bwidth`` (Bp,) switch the tick to the beam gate (see
        :meth:`tick`); both pre-packed to the bucket shape."""
        self._ensure_ready()
        if (not check_drift and not self._pending and row.shape[0] == 0
                and (out_row is None or out_row.shape[0] == 0)):
            # idle tick: nothing to settle, decide or drift-check.  The
            # jit'd tick would be a provable no-op (the S=0 executable
            # already skips its scan at trace time) yet still costs ~0.1ms
            # of dispatch — the deadline batcher fires these constantly,
            # so skip the XLA call entirely.  State, counters and the
            # telemetry ring are bitwise what the dispatched no-op leaves.
            self.idle_ticks_skipped += 1
            F = len(TELEMETRY_FIELDS)
            return TickDecisions(
                batch=0 if batch is None else batch,
                _rows=np.zeros((0, F), self._np_dtype),
                _bools=np.zeros((0, 2), bool),
                _drift=np.zeros(self.store.capacity, bool),
                _slot_logical=self.store.logical_map(),
                _n_logical=self.store.n_rows)
        if self._pending:
            # outcomes queued via observe() settle first (arrival order),
            # ahead of this call's packed outcomes
            pend, self._pending = self._pending, []
            extra_r = np.fromiter((r for r, _ in pend), np.int32, len(pend))
            extra_x = np.fromiter((float(s) for _, s in pend),
                                  self._np_dtype, len(pend))
            if out_row is None:
                out_row, out_x = self._empty_out
            out_row = np.concatenate([extra_r, out_row])
            out_x = np.concatenate([extra_x, out_x])
            Sp = _bucket(out_row.shape[0], lo=1)
            if Sp != out_row.shape[0]:
                pad_r = np.full(Sp, -1, np.int32)
                pad_r[: out_row.shape[0]] = out_row
                pad_x = np.zeros(Sp, self._np_dtype)
                pad_x[: out_x.shape[0]] = out_x
                out_row, out_x = pad_r, pad_x
        elif out_row is None:
            out_row, out_x = self._empty_out
        if out_row.shape[0] and not (out_row >= 0).any():
            # all-padding settle bucket: the S>0 executable's settle scan
            # would be a provable no-op (every lane masked), but S is part
            # of the trace key, so substituting the S=0 bucket here skips
            # both the scan trace and its per-tick dispatch cost — bitwise
            # the same state (mirrors the idle_ticks_skipped fast path)
            out_row, out_x = self._empty_out
            self.empty_settles_skipped += 1
        if self.store.identity:
            srow, sout = row, out_row
        else:
            # paged store: fault every row this tick touches onto the
            # device (LRU-spilling victims), then run the jit'd tick in
            # slot coordinates — the executable never sees logical ids,
            # so unbounded registry growth never retraces it
            touched = np.concatenate(
                [row[row >= 0].astype(np.int64),
                 out_row[out_row >= 0].astype(np.int64)])
            self.store.ensure_resident(touched)
            srow = self.store.translate(row)
            sout = self.store.translate(out_row)
        state = self._ensure_state()
        ulb = self.use_lower_bound if use_lower_bound is None else bool(use_lower_bound)
        rcfg = (self._null_rollcfg if rollout_cfg is None
                else np.asarray(rollout_cfg, np.int32))
        use_beam = bconf is not None
        if use_beam:
            if bwidth is None:
                raise ValueError("bconf requires bwidth")
            if bconf.shape[0] != row.shape[0] or bwidth.shape[0] != row.shape[0]:
                raise ValueError("bconf/bwidth must match the packed batch")
        else:
            bconf, bwidth = self._null_beam
        # fused Pallas tick: only the settle+gate+drift core is fused, so
        # rollout / beam ticks always fall back to the XLA executable
        use_fused = (self.use_fused_tick and not use_rollout
                     and not use_beam)
        if use_fused:
            from ..kernels.ops import _interpret

            fn = _fused_tick_donated if self.donate else _fused_tick
            new_state, rows_out, bools, drift, transitions, row_L = fn(
                state, self._zero, srow, row, reqs, sout, out_x,
                self._cn, use_lower_bound=ulb, check_drift=check_drift,
                block_n=self.fused_block_n, interpret=_interpret(),
            )
        else:
            fn = _tick_donated if self.donate else _tick
            new_state, rows_out, bools, drift, transitions, row_L = fn(
                state, self._zero, srow, row, reqs, bconf, bwidth, sout,
                out_x, self._cn, rcfg, use_lower_bound=ulb,
                check_drift=check_drift, use_rollout=bool(use_rollout),
                use_beam=use_beam,
            )
        self.store.adopt(new_state.post, new_state.rowcfg, new_state.flags,
                         new_state.roll)
        self._tel = new_state.tel
        self._counters = new_state.counters
        n_real = int((row >= 0).sum())
        self._slots_total += int(row.shape[0])
        self._rows_total += n_real
        # sentinels are tail-only by the packing convention, so the real
        # batch defaults to the valid count — never report padding slots
        # as decisions
        return TickDecisions(
            batch=n_real if batch is None else batch,
            _rows=rows_out, _bools=bools, _drift=drift,
            _slot_logical=self.store.logical_map(),
            _n_logical=self.store.n_rows,
            _transitions=transitions if use_rollout else None,
            _row_L=row_L if use_rollout else None)

    def apply_outcomes(
        self, outcomes: Optional[Sequence[tuple[int, bool]]] = None
    ) -> None:
        """Settle outcomes without answering any requests (a B=0 tick)."""
        self.tick(
            np.zeros(0, np.int32), alpha=0.0, lambda_usd_per_s=0.0,
            latency_s=0.0, input_tokens=0, output_tokens=0,
            input_price=0.0, output_price=0.0, outcomes=outcomes,
        )

    def decide(
        self,
        edge: Optional[tuple[str, str]] = None,
        *,
        tenant: Optional[str] = None,
        row: Optional[int] = None,
        posterior: Optional[BetaPosterior] = None,
        alpha: float,
        lambda_usd_per_s: float,
        latency_s: float,
        input_tokens: int,
        output_tokens: float,
        input_price: float,
        output_price: float,
        use_lower_bound: Optional[bool] = None,
    ) -> DecisionResult:
        """Single-request convenience (the ``serving.spec_bridge`` route):
        a B=1 tick returning a scalar ``DecisionResult`` whose floats are
        bitwise-f64 equal to ``decision.evaluate``.  ``posterior=`` syncs
        the row's table params first (the bridge keeps the caller-held
        ``BetaPosterior`` authoritative; a disabled row answers WAIT)."""
        if row is None:
            if edge is None:
                raise ValueError("decide needs edge or row")
            row = self.row_index(edge, tenant)
        if posterior is not None:
            self.set_posterior(row, posterior.alpha, posterior.beta)
        d = self.tick(
            [row], alpha=alpha, lambda_usd_per_s=lambda_usd_per_s,
            latency_s=latency_s, input_tokens=input_tokens,
            output_tokens=output_tokens, input_price=input_price,
            output_price=output_price, use_lower_bound=use_lower_bound,
        )
        return DecisionResult(
            decision=Decision.SPECULATE if bool(d.speculate[0]) else Decision.WAIT,
            EV_usd=float(d.EV_usd[0]),
            threshold_usd=float(d.threshold_usd[0]),
            C_spec_usd=float(d.C_spec_usd[0]),
            L_value_usd=float(d.L_value_usd[0]),
            P_used=float(d.P_used[0]),
        )

    def decide_beam(
        self,
        edge: Optional[tuple[str, str]] = None,
        *,
        tenant: Optional[str] = None,
        row: Optional[int] = None,
        confidences,
        width: int,
        alpha: float,
        lambda_usd_per_s: float,
        latency_s: float,
        input_tokens: int,
        output_tokens: float,
        input_price: float,
        output_price: float,
        use_lower_bound: Optional[bool] = None,
    ):
        """Single-request top-k convenience: a B=1 beam tick returning a
        scalar ``repro.core.beam.BeamDecisionResult`` whose floats are
        bitwise-f64 equal to ``beam_evaluate`` on the row's posterior
        (same contraction-pinned lowering as :meth:`decide`)."""
        from .beam import BeamDecisionResult

        self._ensure_ready()
        if row is None:
            if edge is None:
                raise ValueError("decide_beam needs edge or row")
            row = self.row_index(edge, tenant)
        d = self.tick(
            [row], alpha=alpha, lambda_usd_per_s=lambda_usd_per_s,
            latency_s=latency_s, input_tokens=input_tokens,
            output_tokens=output_tokens, input_price=input_price,
            output_price=output_price, use_lower_bound=use_lower_bound,
            beam_confidences=np.asarray(confidences, self._np_dtype)[None, :],
            beam_width=int(width),
        )
        speculate = bool(d.speculate[0])
        launched = int(d.launched[0])
        return BeamDecisionResult(
            decision=Decision.SPECULATE if speculate else Decision.WAIT,
            EV_usd=float(d.EV_usd[0]),
            threshold_usd=float(d.threshold_usd[0]),
            C_spec_usd=float(d.C_spec_usd[0]),
            L_value_usd=float(d.L_value_usd[0]),
            P_used=float(d.P_used[0]),
            width=int(width),
            w_eff=launched if speculate else 0,
            launched=launched,
        )

    # ------------------------------------------------------------ telemetry
    def log_events(
        self, events: Sequence[tuple[Optional[int], str, float]]
    ) -> None:
        """Append resilience event rows — ``(row_or_None, kind, usd)``
        with ``kind`` from ``telemetry.RESILIENCE_KINDS`` — to the device
        telemetry ring (breaker trips, bulkhead sheds, fallback hops from
        the serving front-end ride the same D2 flush path as decisions).

        Event rows are encoded via the "row" column discriminator (see
        the module-level note) and surface as ``TelemetryBatch.events``
        at drain time; decision fields are unaffected.  The event batch
        shape buckets to a power of two so bursts share executables.
        """
        if not events:
            return
        self._ensure_ready()
        n = len(events)
        Ep = _bucket(n, lo=1)
        rows = np.zeros((Ep, len(TELEMETRY_FIELDS)), self._np_dtype)
        rows[:, _COL["row"]] = -1.0            # padding slots stay empty
        for i, (row, kind, usd) in enumerate(events):
            if row is not None and not (0 <= int(row) < self.n_rows):
                raise IndexError("event row out of range")
            rows[i, _COL["row"]] = _encode_event_row(row)
            rows[i, _COL["speculate"]] = float(_EVENT_CODE[kind])
            rows[i, _COL["C_spec_usd"]] = float(usd)
        self._tel = _append_tel(self._tel, rows)
        self._slots_total += Ep
        self._events_total += n

    def drain_telemetry(self) -> TelemetryBatch:
        """Pull the per-decision USD rows written since the last drain
        (one device sync total — the D2 flush path).  The ring holds the
        most recent ``telemetry_capacity`` *slots* (a ragged tick consumes
        its padded bucket; sentinel slots are filtered here); real rows
        evicted before this drain are counted as ``dropped`` — size the
        ring to the tick cadence.  Resilience event rows sharing the
        window (see :meth:`log_events`) are decoded into ``events``."""
        self._ensure_ready()
        tel = np.asarray(self._tel)
        # host-side unbounded totals (the device counters are int32 and
        # may wrap on long-lived services; they remain for in-graph use)
        slots, total_rows = self._slots_total, self._rows_total
        total_events = self._events_total
        R = tel.shape[0]
        new_slots = slots - self._drained_slots
        take = min(new_slots, R)
        window = tel[R - take:] if take else tel[:0]
        valid = window[:, _COL["row"]] >= 0
        new_rows = total_rows - self._drained_rows
        new_events = total_events - self._drained_events
        self._drained_slots = slots
        self._drained_rows = total_rows
        self._drained_events = total_events
        fields = {
            name: window[valid, j].copy()
            for j, name in enumerate(TELEMETRY_FIELDS)
        }
        ev_rows = window[window[:, _COL["row"]] <= -2.0]
        events = [
            {"kind": _EVENT_KIND[int(r[_COL["speculate"]])],
             "row": _decode_event_row(float(r[_COL["row"]])),
             "usd": float(r[_COL["C_spec_usd"]])}
            for r in ev_rows
        ]
        return TelemetryBatch(fields=fields,
                              dropped=new_rows - int(valid.sum()),
                              events=events,
                              events_dropped=new_events - len(events))

    # ----------------------------------------------------------- drift fold
    def drift_rows(self, decisions: TickDecisions) -> list[
            tuple[Optional[str], tuple[str, str]]]:
        """(tenant, edge) labels of rows the tick's drift check tripped."""
        mask = decisions.drift_triggered[: self.n_rows]
        return [self.row_key(i) for i in np.flatnonzero(mask)]


# ---------------------------------------------------------------------------
# §12.2–12.4 folded onto the posterior table: a calibration round as array
# ops over a snapshot instead of per-record Python.
# ---------------------------------------------------------------------------
def _posterior_rows(posteriors, n: int):
    """(a0, b0, discount, s0, f0) arrays from BetaPosterior objects or a
    raw (n, 2) snapshot."""
    if isinstance(posteriors, np.ndarray) or (
            posteriors and not isinstance(posteriors[0], BetaPosterior)):
        rows = np.asarray(posteriors, float).reshape(n, 2)
        return (rows[:, 0].copy(), rows[:, 1].copy(), np.ones(n),
                np.zeros(n, int), np.zeros(n, int))
    a = np.array([p.alpha for p in posteriors], float)
    b = np.array([p.beta for p in posteriors], float)
    d = np.array([p.discount for p in posteriors], float)
    s = np.array([p.successes for p in posteriors], int)
    f = np.array([p.failures for p in posteriors], int)
    return a, b, d, s, f


def shadow_mode_batch(
    edges: Sequence[tuple[str, str]],
    posteriors,
    trials: Sequence[Sequence[tuple[Any, Any]]],
    *,
    discounts=None,
    graded_subsets: Optional[Sequence[Sequence[tuple[Any, Any, bool]]]] = None,
    thresholds: Sequence[float] = (0.80, 0.85, 0.90, 0.95, 0.99),
    output_token_counts: Optional[Sequence[Sequence[float]]] = None,
    cancel_fractions: Optional[Sequence[Sequence[float]]] = None,
    n_shadow: int = 100,
    stability_window: int = 50,
    stability_tol: float = 0.05,
    tenants: Optional[Sequence[Optional[str]]] = None,
) -> list[ShadowReport]:
    """§12.2 shadow mode for a whole fleet of edges in one pass.

    ``posteriors`` is either a list of ``BetaPosterior`` (never mutated —
    the same zero-exposure contract as the scalar stage), a raw ``(R, 2)``
    snapshot of the online service's table (then ``discounts`` supplies
    the per-row forgetting factors), or a :class:`PosteriorStore` / an
    object holding one as ``.store`` — then each edge's alpha/beta and
    discount are read through the store snapshot API (``tenants`` keys
    multi-tenant rows), spilled rows included, without touching
    residency.  Tier checks call the same ``check_success`` per trial as
    the scalar stage; the posterior recurrence, convergence windows and
    token-EMA run as array ops across all R rows at once.  Per-row
    reports match scalar ``shadow_mode`` bitwise at f64 (posteriors,
    means, F1) and exactly (flags).
    """
    R = len(edges)
    if len(trials) != R:
        raise ValueError("trials must align with edges")
    store = getattr(posteriors, "store", posteriors)
    if isinstance(store, PosteriorStore):
        tens = tenants if tenants is not None else [None] * R
        ids = [store.row_index(e, t) for e, t in zip(edges, tens)]
        posteriors = store.rows_snapshot(np.asarray(ids, np.int64))
        discounts = np.array([store.row_config(i).discount for i in ids])
    a, b, d, s0, f0 = _posterior_rows(posteriors, R)
    if discounts is not None:
        d = np.broadcast_to(np.asarray(discounts, float), (R,)).copy()
    policy = TierPolicy()
    T = max((len(t) for t in trials), default=0)
    ok = np.zeros((R, max(T, 1)))
    mask = np.zeros((R, max(T, 1)), bool)
    for r, tr in enumerate(trials):
        for t, (i_actual, i_hat) in enumerate(tr):
            ok[r, t] = float(check_success(i_actual, i_hat, policy).success)
            mask[r, t] = True

    # vectorized discount recurrence (bitwise the scalar two-step update)
    means = np.zeros((R, max(T, 1)))
    for t in range(T):
        mt = mask[:, t]
        x = ok[:, t]
        a2 = a * d + x
        b2 = b * d + (1.0 - x)
        a = np.where(mt, a2, a)
        b = np.where(mt, b2, b)
        means[:, t] = a / (a + b)

    reports = []
    for r, edge in enumerate(edges):
        n_t = len(trials[r])
        row_means = list(means[r, :n_t])
        converged = n_t >= n_shadow and _stability_converged(
            row_means, stability_window, stability_tol)
        graded = graded_subsets[r] if graded_subsets else ()
        best_thr, best_f1 = _tier2_threshold_sweep(graded, thresholds)
        est = TokenEstimator()
        for tok in (output_token_counts[r] if output_token_counts else ()):
            est.observe(tok)
        cancels = cancel_fractions[r] if cancel_fractions else ()
        rho_mean = statistics.fmean(cancels) if cancels else 0.5
        s_new = int(ok[r, :n_t].sum())
        reports.append(ShadowReport(
            edge=tuple(edge),
            trials=n_t,
            posterior=BetaPosterior(
                alpha=float(a[r]), beta=float(b[r]),
                successes=int(s0[r]) + s_new,
                failures=int(f0[r]) + (n_t - s_new),
                discount=float(d[r]),
            ),
            converged=converged,
            best_tier2_threshold=best_thr,
            tier2_f1=max(best_f1, 0.0),
            token_estimator=est,
            rho_mean=rho_mean,
        ))
    return reports


def canary_batch(
    control_latency_s,
    control_cost_usd,
    sweeps: Sequence[dict[float, tuple[float, float]]],
    chosen_alphas,
    P,
    C_spec,
    L_upstream_s,
    lambda_declared,
    *,
    budget_guardrail_usd=None,
    consistency_band: float = 0.5,
) -> list[CanaryReport]:
    """§12.3 canary for R edges in one pass: the implied-lambda recovery
    and audit verdicts vectorize over the fleet (``P`` typically the
    posterior-snapshot means of the online table); the per-arm Pareto /
    promotion logic reuses the scalar code per row.  Reports match scalar
    ``canary`` bitwise at f64 (``lambda_implied``) and exactly (audit
    strings, promote flags, Pareto sets).
    """
    R = len(sweeps)

    def rvec(x):
        return np.broadcast_to(np.asarray(x, float), (R,))

    ctrl_lat, ctrl_cost = rvec(control_latency_s), rvec(control_cost_usd)
    ca, P = rvec(chosen_alphas), rvec(P)
    C, L, lam_dec = rvec(C_spec), rvec(L_upstream_s), rvec(lambda_declared)
    if np.any((P < 0.0) | (P > 1.0)):
        raise ValueError("P must be in [0, 1]")
    if np.any((ca < 0.0) | (ca > 1.0)):
        raise ValueError("alpha must be in [0, 1]")
    if np.any(P <= 0.0) or np.any(L <= 0.0):
        raise ValueError("implied lambda requires P > 0 and L > 0")
    # same expression order as decision.implied_lambda -> bitwise at f64
    lam_imp = ((1.0 - ca) * C + (1.0 - P) * C) / (P * L)
    # divide only where declared > 0 (the scalar guard, warning-free)
    ratio = np.divide(lam_imp, lam_dec, where=lam_dec > 0.0,
                      out=np.full(R, np.inf))
    audit = np.where(
        ratio > 1.0 + consistency_band, "refresh_lambda",
        np.where(ratio < 1.0 - consistency_band, "inspect_declared",
                 "consistent"))

    guard = None if budget_guardrail_usd is None else rvec(budget_guardrail_usd)
    reports = []
    for r in range(R):
        # per-arm logic is the scalar stage's own helper — only the
        # implied-lambda / audit math above is worth vectorizing
        arms, pareto, promote = _canary_sweep_eval(
            sweeps[r], float(ca[r]), float(ctrl_lat[r]), float(ctrl_cost[r]),
            None if guard is None else float(guard[r]))
        reports.append(CanaryReport(
            arms=arms,
            pareto_alphas=pareto,
            lambda_implied=float(lam_imp[r]),
            lambda_declared=float(lam_dec[r]),
            audit=str(audit[r]),
            promote=promote,
        ))
    return reports


def online_calibration_batch(
    n_rows: int,
    row_index,
    P_mean,
    has_outcome,
    success,
    *,
    committed=None,
    tier3_sampled=None,
    tier3_accept=None,
    tokens_generated=None,
    output_tokens_est=None,
    bucket_width: float = 0.1,
    tier2_tolerance: float = 0.05,
    cov_threshold: float = 0.5,
    quarters_since_lambda_refresh=0,
) -> list[OnlineReport]:
    """§12.4 continuous checks for R edges over one flat decision-row
    batch (the online service's telemetry layout: ``row_index`` maps each
    decision row onto the posterior table).  ``n_rows`` may be a
    :class:`PosteriorStore` (or a service holding one) — the row space is
    then the store's logical id range, so drained telemetry from a paged
    service feeds straight in.

    The per-record work — calibration bucketing, success-rate sums,
    tier-2 false-accept and token-CoV masks — runs as array ops over all
    M rows at once; per-(row, bucket) statistics then reduce via
    ``np.add.at``.  Reports match scalar ``online_calibration`` on the
    equivalent per-edge ``TelemetryLog`` bitwise (rates, CIs, CoV) and
    exactly (flags).
    """
    if not isinstance(n_rows, int):
        n_rows = getattr(n_rows, "store", n_rows).n_rows
    row_index = np.asarray(row_index, int)
    M = row_index.shape[0]
    if M and (row_index.min() < 0 or row_index.max() >= n_rows):
        # same contract as tick()/observe(): a bad row (including the
        # ring's -1 padding sentinels — filter a drained batch first)
        # must raise, not wrap into the last edge's stats
        raise IndexError("row_index out of range")

    def mvec(x, fill=0.0, dtype=float):
        if x is None:
            return np.full(M, fill, dtype)
        return np.broadcast_to(np.asarray(x, dtype), (M,))

    P_mean = mvec(P_mean)
    has_outcome = mvec(has_outcome, False, bool)
    success = mvec(success, False, bool)
    committed = mvec(committed, False, bool)
    tier3_sampled = mvec(tier3_sampled, False, bool)
    tier3_accept = mvec(tier3_accept, False, bool)
    toks = mvec(tokens_generated, np.nan)
    toks_est = mvec(output_tokens_est, 0.0)
    quarters = np.broadcast_to(np.asarray(quarters_since_lambda_refresh, int),
                               (n_rows,))

    # ---- calibration buckets: vectorized bucket ids, merged through the
    # same rounded-midpoint key as TelemetryLog.calibration_buckets
    n_ids = int(1.0 / bucket_width) + 2
    # (i + 0.5) * width is a robust representative P for integer id i
    keys = np.array([bucket_key((i + 0.5) * bucket_width, bucket_width)
                     for i in range(n_ids)])
    # ids computed exactly as the scalar int() truncation (P_mean >= 0)
    ids = np.minimum((P_mean / bucket_width + 1e-9).astype(int), n_ids - 1)
    uniq_keys = np.unique(keys)
    key_of_id = np.searchsorted(uniq_keys, keys)
    K = uniq_keys.shape[0]
    succ_mat = np.zeros((n_rows, K), np.int64)
    n_mat = np.zeros((n_rows, K), np.int64)
    sel = has_outcome
    np.add.at(n_mat, (row_index[sel], key_of_id[ids[sel]]), 1)
    np.add.at(succ_mat, (row_index[sel], key_of_id[ids[sel]]),
              success[sel].astype(np.int64))

    # ---- tier-2 false accepts / token CoV, masked per row
    far_sel = committed & tier3_sampled
    far_num = np.zeros(n_rows, np.int64)
    far_den = np.zeros(n_rows, np.int64)
    np.add.at(far_den, row_index[far_sel], 1)
    np.add.at(far_num, row_index[far_sel],
              (~tier3_accept[far_sel]).astype(np.int64))
    tok_sel = ~np.isnan(toks) & (toks_est > 0)
    # group token ratios per row once (stable sort preserves log order
    # within a row, keeping np.std bitwise vs the scalar twin) — the per
    # -row report loop then slices instead of re-scanning all M records
    tok_rows = row_index[tok_sel]
    tok_ratios = toks[tok_sel] / toks_est[tok_sel]
    tok_order = np.argsort(tok_rows, kind="stable")
    tok_rows = tok_rows[tok_order]
    tok_ratios = tok_ratios[tok_order]
    tok_start = np.searchsorted(tok_rows, np.arange(n_rows))
    tok_end = np.searchsorted(tok_rows, np.arange(n_rows), side="right")

    reports = []
    for r in range(n_rows):
        buckets = []
        overpredicted = []
        for kk in range(K):
            n = int(n_mat[r, kk])
            if n == 0:
                continue
            bucket, over = _calibration_bucket(
                float(uniq_keys[kk]), int(succ_mat[r, kk]) / n, n,
                bucket_width)
            buckets.append(bucket)
            overpredicted.append(over)
        monotonic_over = len(overpredicted) >= 2 and all(overpredicted)
        den = int(far_den[r])
        far = (int(far_num[r]) / den) if den else None
        row_ratios = tok_ratios[tok_start[r]:tok_end[r]]
        cov = float(np.std(row_ratios, ddof=1)) if row_ratios.shape[0] >= 2 else None
        reports.append(OnlineReport(
            buckets=buckets,
            monotonic_overprediction=monotonic_over,
            tier2_false_accept_rate=far,
            tier2_needs_tightening=far is not None and far > tier2_tolerance,
            token_cov=cov,
            uncertain_cost=cov is not None and cov > cov_threshold,
            lambda_refresh_due=int(quarters[r]) >= 1,
        ))
    return reports
