"""Autonomous staged-rollout lifecycle over the online-service tables.

The paper's §12 pipeline (offline replay → shadow → canary → online
calibration → drift kill-switch) exists in this repo as separately
invoked batch stages; production runs it as a *lifecycle*: every
(tenant, edge) row advances SHADOW → CANARY → ONLINE_CAL → FULL on
promotion criteria, demotes on any in-graph kill-switch breach or a
host-side tier-2 false-accept verdict, sits out a cooldown, and re-enters
through a bounded probe window before it may promote again — the
frontend ``CircuitBreaker``'s CLOSED/OPEN/HALF_OPEN discipline, but
per-row and device-resident.

The state machine's columns live in ``PosteriorStore``'s ``_roll`` table
([phase, cooldown, probes, ticks_in_phase, n_obs, s_obs], int32), so
phase state pages with the posterior (paged spill/fault-in round-trips
bitwise) and the jit'd tick never recompiles across phase churn: the
whole lifecycle folds into ``_tick_impl`` behind one static flag, and
the :class:`RolloutConfig` rides as a small dynamic int vector.

Promotion is integer-only — ``s_obs * 1000 >= rate_milli * n_obs`` with
per-phase minimum-observation floors — which makes the in-graph machine
*exactly* reproducible by the pure-Python :class:`ReferenceLifecycle`
(asserted per tick in tests and benchmarks/rollout_fleet.py) and makes
promotion monotone in the observed success rate by construction.

Per-tick transition order (the contract both machines implement):

  1. ``dem``      — kill-switch trigger with the cooldown expired (the
                    post-decrement counter): phase → SHADOW, cooldown
                    restarts, counters reset.  Triggers landing mid-
                    cooldown are absorbed (the breaker analogy: an OPEN
                    circuit doesn't re-open).
  2. ``reenter``  — cooldown just expired on a touched tick: the row is
                    re-enabled (kill-switch flag cleared), granted
                    ``probe_budget`` probes.
  3. evidence     — settled outcomes accumulate into n_obs/s_obs only
                    while the cooldown is expired (observations during
                    cooldown don't count toward re-promotion: the probe
                    window is the trial, as HALF_OPEN is for the breaker).
  4. ``promote``  — touched, open, enough evidence, success bar met:
                    phase += 1, per-phase counters reset.
  5. ``probe_fail`` — the probe window ran dry without promotion: the
                    cooldown restarts.

SHADOW rows never serve speculations (decisions are computed and logged,
answers forced WAIT) but still learn from settled outcomes — §12.2
shadow observability.  CANARY serves every ``canary_period``-th touched
tick (§12.3 partial exposure).  ONLINE_CAL and FULL serve every tick.
DISABLED only ever exits through the host ``revive`` path — it is the
tier-2 page-an-operator terminal state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = [
    "DISABLED", "SHADOW", "CANARY", "ONLINE_CAL", "FULL", "PHASE_NAMES",
    "RolloutConfig", "RolloutController", "ReferenceLifecycle",
    "TRANSITION_KINDS", "decode_transition", "rollout_allow",
    "rollout_advance",
]

# Phase codes — stored in the roll table's column 0.  Order is the
# promotion order; comparisons below rely on it.
DISABLED, SHADOW, CANARY, ONLINE_CAL, FULL = 0, 1, 2, 3, 4
PHASE_NAMES = ("DISABLED", "SHADOW", "CANARY", "ONLINE_CAL", "FULL")

# Packed transition encoding: code * 64 + old_phase * 8 + new_phase
# (0 = no transition this tick).  Codes map onto the resilience-event
# kinds appended to telemetry.RESILIENCE_KINDS.
TRANSITION_KINDS = {
    1: "rollout_promote",
    2: "rollout_demote",
    3: "rollout_reenter",
    4: "rollout_probe_fail",
}

# n_obs/s_obs saturate here so the integer promotion comparison
# (s * 1000 vs rate_milli * n) never overflows int32 even without x64.
_OBS_CAP = 1_000_000
_NEVER = np.int32(2 ** 30)       # min-obs sentinel for non-promoting phases


def decode_transition(code: int) -> tuple[str, int, int]:
    """(kind, old_phase, new_phase) from a packed transition code."""
    c = int(code)
    if c <= 0:
        raise ValueError("no transition encoded")
    return TRANSITION_KINDS[c // 64], (c // 8) % 8, c % 8


@dataclasses.dataclass(frozen=True)
class RolloutConfig:
    """Promotion/demotion policy, encoded to a small int vector so a
    config change is a new operand — never a recompile.

    ``min_obs`` / ``promote_rate`` are per *promoting* phase
    (SHADOW, CANARY, ONLINE_CAL); the rate is quantized to milli-units
    (integer promotion rule — exact scalar parity).
    """

    cooldown_ticks: int = 8
    probe_budget: int = 16
    canary_period: int = 2
    min_obs: tuple[int, int, int] = (8, 8, 8)
    promote_rate: tuple[float, float, float] = (0.7, 0.7, 0.7)

    def __post_init__(self) -> None:
        if self.cooldown_ticks < 1:
            raise ValueError("cooldown_ticks must be >= 1")
        if self.probe_budget < 1:
            raise ValueError("probe_budget must be >= 1")
        if self.canary_period < 1:
            raise ValueError("canary_period must be >= 1")
        if len(self.min_obs) != 3 or len(self.promote_rate) != 3:
            raise ValueError("min_obs/promote_rate are per promoting phase "
                             "(SHADOW, CANARY, ONLINE_CAL)")
        if any(m < 1 for m in self.min_obs):
            raise ValueError("min_obs entries must be >= 1")
        if any(not (0.0 <= r <= 1.0) for r in self.promote_rate):
            raise ValueError("promote_rate entries must be in [0, 1]")

    def rate_milli(self) -> tuple[int, int, int]:
        return tuple(int(round(r * 1000)) for r in self.promote_rate)

    def encode(self) -> np.ndarray:
        """(9,) int32 [cooldown, probe_budget, canary_period,
        min_obs x3, rate_milli x3] — the tick's dynamic operand."""
        return np.array(
            [self.cooldown_ticks, self.probe_budget, self.canary_period,
             *self.min_obs, *self.rate_milli()], np.int32)


# --------------------------------------------------------------------------
# traced helpers — called from inside repro.core.online._tick_impl (this
# module imports nothing from online, so the dependency is one-way)
# --------------------------------------------------------------------------
def rollout_allow(roll, cfg):
    """(N,) bool serve mask from the *pre-tick* lifecycle state: cooldown
    expired AND (FULL | ONLINE_CAL | CANARY on its period tick)."""
    phase, cd, tip = roll[:, 0], roll[:, 1], roll[:, 3]
    period = jnp.maximum(cfg[2], 1)
    canary_on = (phase == CANARY) & (tip % period == 0)
    return (cd == 0) & (canary_on | (phase >= ONLINE_CAL))


def rollout_advance(roll, flags, triggered, touched, n_out, s_out, cfg):
    """One lifecycle step over every row (the module-docstring order).

    ``triggered`` is the tick's kill-switch trigger mask (drift step
    output), ``touched`` the request-touched mask, ``n_out``/``s_out``
    the tick's settled outcome / success counts per row.  Returns
    ``(roll', flags', transitions)`` with ``transitions`` the packed
    per-row codes.
    """
    i32 = jnp.int32
    phase = roll[:, 0]
    cd, pb, tip = roll[:, 1], roll[:, 2], roll[:, 3]
    n, s = roll[:, 4], roll[:, 5]

    # 1-2. cooldown countdown on touched ticks; a trigger landing with
    # the (post-decrement) cooldown expired demotes, one landing exactly
    # on the expiry tick demotes instead of re-entering
    cd1 = jnp.where(touched & (cd > 0), cd - 1, cd)
    dem = triggered & (cd1 == 0)
    reenter = touched & ~dem & (cd > 0) & (cd1 == 0)

    # 3. evidence accumulates only while the cooldown is expired
    open_ = cd1 == 0
    n1 = jnp.minimum(jnp.where(open_ & ~dem, n + n_out, n), _OBS_CAP)
    s1 = jnp.minimum(jnp.where(open_ & ~dem, s + s_out, s), _OBS_CAP)
    pb1 = jnp.where(reenter, cfg[1], pb)

    # 4. integer promotion rule against the per-phase bars
    never = jnp.full(1, _NEVER, i32)
    zero1 = jnp.zeros(1, i32)
    min_obs = jnp.concatenate([never, cfg[3:6], never])[phase]
    rate_m = jnp.concatenate([zero1, cfg[6:9], zero1])[phase]
    promote = (touched & ~dem & open_ & (n1 >= min_obs)
               & (s1 * 1000 >= rate_m * n1))

    # 5. probe consumption (granted probes are spent from the next
    # touched tick on; promotion closes the window)
    probing = touched & ~dem & ~reenter & open_ & (pb1 > 0) & ~promote
    pb2 = jnp.where(probing, pb1 - 1, pb1)
    probe_fail = probing & (pb2 == 0)

    new_phase = jnp.where(promote, phase + 1, phase)
    new_phase = jnp.where(dem & (phase > DISABLED), SHADOW, new_phase)
    reset = dem | promote | probe_fail | reenter
    tip1 = jnp.where(reset, 0, jnp.where(touched, tip + 1, tip))
    cd2 = jnp.where(dem | probe_fail, cfg[0], cd1)
    pb3 = jnp.where(dem | promote, 0, pb2)
    n2 = jnp.where(dem | promote | probe_fail, 0, n1)
    s2 = jnp.where(dem | promote | probe_fail, 0, s1)

    # re-entry clears the kill-switch disable and the breach run — the
    # in-graph analogue of CircuitBreaker entering HALF_OPEN
    enabled = jnp.where(reenter, True, flags[:, 0] > 0)
    run = jnp.where(reenter, 0, flags[:, 1])
    flags1 = jnp.stack([enabled.astype(i32), run], 1)

    code = jnp.where(reenter, 3, 0)
    code = jnp.where(probe_fail, 4, code)
    code = jnp.where(promote, 1, code)
    code = jnp.where(dem, 2, code)
    transitions = jnp.where(
        code > 0, code * 64 + phase * 8 + new_phase, 0).astype(i32)

    roll1 = jnp.stack([new_phase, cd2, pb3, tip1, n2, s2], 1).astype(i32)
    return roll1, flags1, transitions


# --------------------------------------------------------------------------
# scalar reference — the same machine in plain ints, fed from the host's
# own view of the tick (touched rows, outcome counts, trigger mask)
# --------------------------------------------------------------------------
class ReferenceLifecycle:
    """Pure-Python twin of :func:`rollout_advance`.

    Consumes per tick exactly what the in-graph machine consumes —
    which logical rows this tick's requests touched, how many outcomes
    (and successes) settled per row, and which rows the kill-switch
    triggered — and reproduces the transitions *exactly* (integer state,
    integer rules; no floats anywhere).  The parity harness runs it next
    to the service and asserts per-tick transition equality.
    """

    def __init__(self, n_rows: int, config: RolloutConfig) -> None:
        self.config = config
        # [phase, cooldown, probes, ticks_in_phase, n_obs, s_obs]
        self.rows = [[SHADOW, 0, 0, 0, 0, 0] for _ in range(n_rows)]
        self.enabled = [True] * n_rows

    def ensure_rows(self, n_rows: int) -> None:
        while len(self.rows) < n_rows:
            self.rows.append([SHADOW, 0, 0, 0, 0, 0])
            self.enabled.append(True)

    def allow(self, r: int) -> bool:
        phase, cd, _, tip, _, _ = self.rows[r]
        if cd != 0:
            return False
        if phase == CANARY:
            return tip % self.config.canary_period == 0
        return phase >= ONLINE_CAL

    def override(self, r: int, state) -> None:
        """Mirror a host-side roll override (demote-to-DISABLED, revive)."""
        self.rows[r] = [int(v) for v in state]

    def tick(self, touched, outcomes, triggered_rows,
             drift_touched=None) -> dict[int, int]:
        """Advance one tick; returns {row: packed transition code}.

        ``touched``: logical rows this tick's requests hit;
        ``outcomes``: {row: (n_settled, n_success)};
        ``triggered_rows``: rows the in-graph kill-switch tripped.
        ``drift_touched`` defaults to ``touched`` (the kill-switch's
        disable/run bookkeeping also runs on request-touched rows).
        """
        cfg = self.config
        rate_m = cfg.rate_milli()
        touched = set(int(r) for r in touched)
        triggered = set(int(r) for r in triggered_rows)
        out: dict[int, int] = {}
        # the drift step's own flag bookkeeping (disable + run reset) —
        # mirrored so self.enabled tracks the device flags
        for r in triggered:
            self.enabled[r] = False
        rows_to_step = touched | set(outcomes)
        for r in sorted(rows_to_step):
            st = self.rows[r]
            phase, cd, pb, tip, n, s = st
            is_touched = r in touched
            n_add, s_add = outcomes.get(r, (0, 0))

            cd1 = cd - 1 if (is_touched and cd > 0) else cd
            dem = (r in triggered) and cd1 == 0
            reenter = is_touched and not dem and cd > 0 and cd1 == 0
            open_ = cd1 == 0
            n1 = min(n + n_add, _OBS_CAP) if (open_ and not dem) else n
            s1 = min(s + s_add, _OBS_CAP) if (open_ and not dem) else s
            pb1 = cfg.probe_budget if reenter else pb
            if SHADOW <= phase <= ONLINE_CAL:
                mo = cfg.min_obs[phase - 1]
                rm = rate_m[phase - 1]
            else:
                mo, rm = int(_NEVER), 0
            promote = (is_touched and not dem and open_
                       and n1 >= mo and s1 * 1000 >= rm * n1)
            probing = (is_touched and not dem and not reenter and open_
                       and pb1 > 0 and not promote)
            pb2 = pb1 - 1 if probing else pb1
            probe_fail = probing and pb2 == 0

            new_phase = phase + 1 if promote else phase
            if dem and phase > DISABLED:
                new_phase = SHADOW
            reset = dem or promote or probe_fail or reenter
            tip1 = 0 if reset else (tip + 1 if is_touched else tip)
            cd2 = cfg.cooldown_ticks if (dem or probe_fail) else cd1
            pb3 = 0 if (dem or promote) else pb2
            n2 = 0 if (dem or promote or probe_fail) else n1
            s2 = 0 if (dem or promote or probe_fail) else s1
            if reenter:
                self.enabled[r] = True
            self.rows[r] = [new_phase, cd2, pb3, tip1, n2, s2]

            code = 3 if reenter else 0
            if probe_fail:
                code = 4
            if promote:
                code = 1
            if dem:
                code = 2
            if code:
                out[r] = code * 64 + phase * 8 + new_phase
        return out


class RolloutController:
    """Host wrapper driving the in-graph lifecycle through a service.

    Duck-types ``OnlineDecisionService`` (``__getattr__`` passthrough),
    so it slots between ``FaultyService`` and the raw service under the
    serving front-end unchanged:

        frontend -> FaultyService -> RolloutController -> service

    Every ``tick_packed``/``tick`` runs with the rollout static on and
    the drift check forced (demotion is kill-switch-driven), then folds
    the tick's packed transitions into host telemetry: one
    USD-attributed event per transition in the shared ``ResilienceLog``
    *and* the device event ring.  Demotions are billed the tick's
    summed L_value over the row's requests — the latency value the
    disabled row stops protecting.
    """

    def __init__(self, service, config: Optional[RolloutConfig] = None, *,
                 resilience=None, ring_events: bool = True) -> None:
        self.service = service
        self.config = config if config is not None else RolloutConfig()
        self._cfg_arr = self.config.encode()
        self.resilience = resilience
        self.ring_events = bool(ring_events)
        self.ticks = 0
        # host transition history: dicts the scenario fleet aggregates
        self.transitions: list[dict] = []

    # ------------------------------------------------------------- ticks
    def tick_packed(self, row, reqs, **kw):
        kw.setdefault("check_drift", True)
        d = self.service.tick_packed(
            row, reqs, use_rollout=True, rollout_cfg=self._cfg_arr, **kw)
        self._fold(d)
        return d

    def tick(self, rows, **kw):
        kw.setdefault("check_drift", True)
        d = self.service.tick(
            rows, use_rollout=True, rollout_cfg=self._cfg_arr, **kw)
        self._fold(d)
        return d

    def __getattr__(self, name: str):
        return getattr(self.service, name)

    def _fold(self, decisions) -> None:
        self.ticks += 1
        trans = decisions.rollout_transitions
        hit = np.flatnonzero(trans)
        if hit.size == 0:
            return
        usd_rows = decisions.rollout_usd
        events = []
        for r in hit:
            kind, old, new = decode_transition(int(trans[r]))
            usd = float(usd_rows[r]) if kind == "rollout_demote" else 0.0
            tenant, edge = self.service.row_key(int(r))
            self.transitions.append({
                "tick": self.ticks, "row": int(r), "kind": kind,
                "tenant": tenant, "edge": edge,
                "old": PHASE_NAMES[old], "new": PHASE_NAMES[new],
                "usd": usd,
            })
            if self.resilience is not None:
                from .telemetry import ResilienceEvent

                self.resilience.emit(ResilienceEvent(
                    kind=kind, tenant=tenant, edge=edge, row=int(r),
                    usd=usd, detail=f"{PHASE_NAMES[old]}->{PHASE_NAMES[new]}"))
            events.append((int(r), kind, usd))
        if self.ring_events and events:
            self.service.log_events(events)

    # --------------------------------------------------------- host APIs
    def phase_snapshot(self) -> np.ndarray:
        """(n_rows, 6) composed lifecycle view (see store.roll_snapshot)."""
        return self.service.store.roll_snapshot()

    def phases(self) -> list[str]:
        return [PHASE_NAMES[int(p)] for p in self.phase_snapshot()[:, 0]]

    def demote_tier2(self, row: int, *, disable: bool = True,
                     usd: float = 0.0) -> None:
        """Host-side tier-2 false-accept demotion (§12.5 trigger 3): the
        in-graph machine only ever demotes to SHADOW; a tier-2 verdict is
        the page-an-operator path and may land the row in DISABLED, which
        no in-graph transition exits."""
        phase = DISABLED if disable else SHADOW
        state = [[phase, self.config.cooldown_ticks, 0, 0, 0, 0]]
        self.service.store.set_roll_rows(np.asarray([row]),
                                         np.asarray(state, np.int32))
        tenant, edge = self.service.row_key(int(row))
        self.transitions.append({
            "tick": self.ticks, "row": int(row), "kind": "rollout_demote",
            "tenant": tenant, "edge": edge, "old": None,
            "new": PHASE_NAMES[phase], "usd": float(usd),
        })
        if self.resilience is not None:
            from .telemetry import ResilienceEvent

            self.resilience.emit(ResilienceEvent(
                kind="rollout_demote", tenant=tenant, edge=edge,
                row=int(row), usd=float(usd),
                detail=f"tier2->{PHASE_NAMES[phase]}"))
        if self.ring_events:
            self.service.log_events(
                [(int(row), "rollout_demote", float(usd))])

    def revive(self, row: int) -> None:
        """Operator revive: DISABLED -> fresh SHADOW (counters zeroed)."""
        self.service.store.set_roll_rows(
            np.asarray([row]),
            np.asarray([[SHADOW, 0, 0, 0, 0, 0]], np.int32))
