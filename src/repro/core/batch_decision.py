"""Vectorized JAX decision engine — the beyond-paper fast path.

The paper's D4 rule is a handful of multiplies per decision (§6.5).  At
fleet scale the hot paths are *batched*: the §12.1 counterfactual replay
over millions of logged decisions x an (alpha, lambda) grid, per-chunk
streaming re-evaluation across thousands of in-flight edges, and bulk
posterior updates.  This module jit-compiles those as single XLA calls.

Recorded in EXPERIMENTS.md §Perf as the optimized implementation next to
the paper-faithful scalar path (repro.core.decision), with identical
numerics (tests assert bitwise-comparable float64 results).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .betainc import betaincinv

__all__ = [
    "d4_gate",
    "batch_evaluate",
    "batch_lower_bound",
    "counterfactual_grid",
    "counterfactual_grid_sharded",
    "counterfactual_grid_tenants",
    "batch_posterior_update",
    "batch_implied_lambda",
    "critical_k_grid",
    "batch_chunk_cancel",
    "batch_fractional_waste",
    "beam_gate",
    "beam_counterfactual_grid",
    "critical_k_surface",
]


def d4_gate(P_gate, alpha, lam, latency_s, in_tok, out_tok, in_price,
            out_price, zero=None):
    """Traceable D4 gate core (§6.1): the one expression both the batch
    path and the online decision service lower.

    With ``zero=None`` the expressions match the historical fused lowering
    (XLA CPU contracts ``a*b + c`` into one FMA, so EV / threshold agree
    with the scalar ``decision.evaluate`` only to 1 ULP — the established
    fleet-parity tolerance).  With ``zero`` a *traced* runtime 0.0 scalar,
    every product feeding an add is pinned to its correctly-rounded value:
    ``x + zero`` either survives as ``round(x) + 0`` or contracts to
    ``fma(a, b, 0) == round(a*b)`` — either way the twice-rounded scalar
    result — making EV / threshold / margin **bitwise-f64 equal** to the
    scalar path.  (``zero`` must be traced; a literal would be folded
    away.  All products here are >= +0.0 in the decision domain, so the
    ``-0.0 + 0.0 -> +0.0`` edge of the trick cannot bite.)
    """
    rnd = (lambda x: x) if zero is None else (lambda x: x + zero)
    C_spec = rnd(in_tok * in_price) + rnd(out_tok * out_price)
    L_value = latency_s * lam
    EV = rnd(P_gate * L_value) - rnd((1.0 - P_gate) * C_spec)
    threshold = rnd((1.0 - alpha) * C_spec)
    return EV, threshold, EV >= threshold, C_spec, L_value


@functools.partial(jax.jit, static_argnames=())
def _batch_evaluate(P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price):
    return d4_gate(P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price)


@jax.jit
def _batch_evaluate_exact(P, alpha, lam, latency_s, in_tok, out_tok,
                          in_price, out_price, zero):
    return d4_gate(P, alpha, lam, latency_s, in_tok, out_tok, in_price,
                   out_price, zero)


def _f(x):
    """float array at the widest enabled precision (f64 under jax_enable_x64,
    f32 otherwise) — keeps numerics comparable to the scalar path."""
    return jnp.asarray(x, dtype=jnp.result_type(float))


def batch_evaluate(
    P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price,
    *, P_lower=None, exact=False,
):
    """Vectorized D4 gate.  All inputs broadcastable arrays.  Returns
    (EV, threshold, speculate_mask, C_spec, L_value).

    ``P_lower`` enables the §7.5 credible-bound variant: the gate (and the
    reported EV — matching ``decision.evaluate(use_lower_bound=True)``,
    whose ``P_used`` is the bound) runs on the one-sided lower credible
    bound instead of the posterior mean.  Compute it in bulk with
    :func:`batch_lower_bound`.

    ``exact=True`` runs the contraction-pinned lowering (see
    :func:`d4_gate`): EV / threshold / decision flags come out
    **bitwise-f64 equal** to the scalar ``decision.evaluate`` instead of
    the default 1-ULP FMA tolerance — the contract the online decision
    service (``repro.core.online``) serves under.
    """
    gate_P = P if P_lower is None else P_lower
    args = [_f(x) for x in (
        gate_P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price
    )]
    if exact:
        return _batch_evaluate_exact(*args, _f(0.0))
    return _batch_evaluate(*args)


@jax.jit
def _lower_bound(alpha, beta, gamma):
    return betaincinv(alpha, beta, gamma)


def _lower_bound_pallas(alpha, beta, gamma):
    # Not jitted here: betaincinv(use_pallas=True) dispatches to the
    # already-jitted kernel op, which resolves interpret/native outside
    # the trace (kernels.ops._interpret()).
    return betaincinv(alpha, beta, gamma, use_pallas=True)


def batch_lower_bound(alpha, beta, gamma=0.1, use_pallas: bool = False):
    """§7.5 one-sided (1-gamma) lower credible bound, vectorized.

    ``Beta^{-1}(gamma; alpha, beta)`` across whole fleets of posterior
    parameters in one XLA call — the jax-native equivalent of
    ``BetaPosterior.lower_bound`` / ``scipy.stats.beta.ppf`` (agreement
    pinned to <= 1e-10 relative by tests/test_betaincinv.py).

    ``use_pallas=True`` routes the inversion through the tiled Pallas
    kernel (``repro.kernels.betaincinv_pallas``) — same <= 1e-10 tier vs
    scipy, not bitwise vs the default path.
    """
    fn = _lower_bound_pallas if use_pallas else _lower_bound
    return np.asarray(fn(_f(alpha), _f(beta), _f(gamma)))


@jax.jit
def _grid(P, P_gate, lat, cost, alphas, lams, rho):
    # decisions[a, l, n] for n log rows at each (alpha, lambda) grid point;
    # the gate runs on P_gate (== P, or the §7.5 lower bound) while the
    # counterfactual expectations stay weighted by the posterior mean P.
    # rho is traced (not static): calibration sweeps vary it per call and
    # must not retrigger XLA compilation.
    L_value = lat[None, None, :] * lams[None, :, None]
    EV = P_gate * L_value - (1.0 - P_gate) * cost[None, None, :]
    thr = (1.0 - alphas[:, None, None]) * cost[None, None, :]
    spec = EV >= thr
    # bool .mean() yields f32 regardless of jax_enable_x64 — cast first so
    # the fraction carries the working precision (f64 under x64)
    frac = spec.astype(lat.dtype).mean(axis=-1)
    exp_lat = jnp.where(spec, lat[None, None, :] * (1.0 - P), lat[None, None, :]).mean(-1)
    waste = (spec * (1.0 - P) * cost[None, None, :] * rho).sum(-1)
    exp_cost = cost.sum() + waste
    return frac, exp_lat, exp_cost, waste


def counterfactual_grid(P, latencies, costs, alphas, lambdas, rho=0.5,
                        *, P_lower=None):
    """§12.1 counterfactual EV grid as one XLA call.

    Returns dict of (len(alphas), len(lambdas)) arrays:
    speculate_fraction, expected_latency, expected_cost, expected_waste.

    ``rho`` (scalar or per-row array) is traced, so sweeping it across a
    calibration grid reuses one compiled executable.  ``P_lower`` switches
    the SPECULATE gate to the §7.5 credible bound while the latency /
    waste expectations remain weighted by the posterior mean ``P``.
    """
    P = _f(P)
    P_gate = P if P_lower is None else _f(P_lower)
    frac, exp_lat, exp_cost, waste = _grid(
        P, P_gate, _f(latencies), _f(costs), _f(alphas), _f(lambdas),
        _f(rho),
    )
    return {
        "speculate_fraction": np.asarray(frac),
        "expected_latency_s": np.asarray(exp_lat),
        "expected_cost_usd": np.asarray(exp_cost),
        "expected_waste_usd": np.asarray(waste),
    }


@functools.lru_cache(maxsize=None)
def _grid_sharded_exec(mesh, axis_name):
    """Compile (and cache per mesh) the log-axis-sharded §12.1 grid: the
    N log rows are split into C contiguous segments (masked tail padding,
    same scheme as ``fleet.chunk_episodes``), each segment reduces to raw
    per-(alpha, lambda) partial sums, and the segment axis is optionally
    ``shard_map``'d over the 1-D fleet mesh — each device sees only its
    rows, with zero cross-device traffic until the final O(C·A·L)
    combine."""

    def run(P, P_gate, lat, cost, mask, alphas, lams, rho):
        # lat / cost / mask / rho: (C, Nc) segments; returns per-segment
        # raw sums (count, lat_sum, waste_sum, cost_sum) — the combine
        # happens outside so decision *counts* stay exact integers.
        def one(lat_c, cost_c, m_c, rho_c):
            m = m_c.astype(lat_c.dtype)
            L_value = lat_c[None, None, :] * lams[None, :, None]
            EV = P_gate * L_value - (1.0 - P_gate) * cost_c[None, None, :]
            thr = (1.0 - alphas[:, None, None]) * cost_c[None, None, :]
            spec = (EV >= thr) & m_c[None, None, :]
            count = spec.astype(lat_c.dtype).sum(-1)
            lat_sum = (
                jnp.where(spec, lat_c[None, None, :] * (1.0 - P),
                          lat_c[None, None, :]) * m[None, None, :]
            ).sum(-1)
            waste = (
                spec * (1.0 - P) * cost_c[None, None, :] * rho_c
            ).sum(-1)
            return count, lat_sum, waste, (cost_c * m).sum()

        return jax.vmap(one)(lat, cost, mask, rho)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        c = PartitionSpec(axis_name)
        r = PartitionSpec()
        run = shard_map(
            run, mesh=mesh,
            in_specs=(r, r, c, c, c, r, r, c),
            out_specs=c,
            check_rep=False,
        )
    return jax.jit(run)


def counterfactual_grid_sharded(P, latencies, costs, alphas, lambdas,
                                rho=0.5, *, P_lower=None, segments=None,
                                mesh=None, axis_name="fleet"):
    """§12.1 counterfactual EV grid with the *log-row axis sharded*.

    Same contract as :func:`counterfactual_grid` (including scalar *or*
    per-row ``rho``), for logs too large to want on one device: the N
    rows split into ``segments`` contiguous chunks (default: the mesh
    extent, or the visible device count), each chunk reduces
    independently, and raw partial sums combine at the end.  The segment
    length is bucketed to a power of two (masked zero rows are exact
    no-ops), so ragged large-log sweeps reuse one executable per
    (segments, bucket).  A mesh without the fleet axis, or one whose
    extent does not divide ``segments``, falls back to the unsharded
    executable (``sharding.rules.fleet_axis_spec``).

    ``speculate_fraction`` is **bitwise-identical** to the unsharded
    grid (decision counts are exact integers; one final division); the
    latency / cost / waste expectations differ only by float summation
    order (<= ~1e-15 relative, pinned by the --smoke parity gate).
    ``calibration.offline_replay`` reroutes here when the log count
    exceeds its ``shard_threshold``.
    """
    P = _f(P)
    P_gate = P if P_lower is None else _f(P_lower)
    lat = np.atleast_1d(np.asarray(latencies, float))
    cost = np.atleast_1d(np.asarray(costs, float))
    n = lat.shape[0]
    if n == 0:
        raise ValueError("counterfactual_grid_sharded requires >= 1 log row")
    if cost.shape != lat.shape:
        raise ValueError("latencies and costs must have the same length")
    # per-row rho (same contract as counterfactual_grid) segments along
    # with the rows; a scalar broadcasts to every row first
    rho_rows = np.broadcast_to(np.asarray(rho, float), lat.shape).copy()
    if segments is None:
        if mesh is not None and axis_name in mesh.shape:
            segments = mesh.shape[axis_name]
        else:
            segments = max(1, len(jax.devices()))
    C = int(segments)
    if C < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")
    if mesh is not None:
        from ..sharding.rules import fleet_axis_spec

        if fleet_axis_spec(mesh, C, axis=axis_name) is None:
            mesh = None  # missing axis / indivisible: run unsharded
    # bucket the segment length to a power of two (masked zero rows are
    # exact no-ops in every sum) so a sweep over many ragged large logs
    # compiles one executable per (C, bucket) instead of one per
    # distinct log count — the sharded twin of offline_replay's
    # power-of-two bucketing on the unsharded path
    Nc_raw = -(-n // C)
    Nc = max(16, 1 << (Nc_raw - 1).bit_length())
    pad = C * Nc - n
    mask = np.ones(n, bool)

    def seg(x, fill):
        if pad:
            x = np.concatenate([x, np.full(pad, fill, x.dtype)])
        return x.reshape(C, Nc)

    fn = _grid_sharded_exec(mesh, axis_name)
    count, lat_sum, waste_sum, cost_sum = fn(
        P, P_gate, _f(seg(lat, 0.0)), _f(seg(cost, 0.0)),
        jnp.asarray(seg(mask, False)), _f(alphas), _f(lambdas),
        _f(seg(rho_rows, 0.0)),
    )
    count = np.asarray(count).sum(0)
    lat_sum = np.asarray(lat_sum).sum(0)
    waste = np.asarray(waste_sum).sum(0)
    cost_total = np.asarray(cost_sum).sum(0)
    # XLA lowers .mean() as sum * (1/n); mirror that here so the exact
    # integer decision counts divide to bitwise-identical fractions
    inv_n = np.asarray(_f(1.0)) / n
    return {
        "speculate_fraction": count * inv_n,
        "expected_latency_s": lat_sum * inv_n,
        "expected_cost_usd": cost_total + waste,
        "expected_waste_usd": waste,
    }


@jax.jit
def _grid_tenants(P, P_gate, lat, cost, mask, alphas, lams, rho):
    # tenant-batched §12.1 grid: P/P_gate are (T,) per-tenant seeded-prior
    # summaries, lat/cost/mask are (T, N) padded log rows.  Masked rows
    # contribute to nothing; means divide by the per-tenant real row count
    # (so a short tenant's grid equals its unpadded scalar grid).
    m = mask.astype(lat.dtype)
    n = jnp.maximum(m.sum(-1), 1.0)                       # (T,)
    lat_b = lat[:, None, None, :]
    cost_b = cost[:, None, None, :]
    m_b = m[:, None, None, :]
    P_b = P[:, None, None, None]
    L_value = lat_b * lams[None, None, :, None]
    EV = P_gate[:, None, None, None] * L_value - (1.0 - P_gate[:, None, None, None]) * cost_b
    thr = (1.0 - alphas[None, :, None, None]) * cost_b
    spec = (EV >= thr) & mask[:, None, None, :]
    frac = spec.sum(-1) / n[:, None, None]
    exp_lat = (
        jnp.where(spec, lat_b * (1.0 - P_b), lat_b) * m_b
    ).sum(-1) / n[:, None, None]
    waste = (spec * (1.0 - P_b) * cost_b * rho).sum(-1)
    exp_cost = (cost * m).sum(-1)[:, None, None] + waste
    return frac, exp_lat, exp_cost, waste


def counterfactual_grid_tenants(P, latencies, costs, mask, alphas, lambdas,
                                rho=0.5, *, P_lower=None):
    """§12.1 counterfactual EV grids for a whole fleet of tenants in one
    XLA call.

    ``P`` is the per-tenant seeded-prior mean (T,); ``latencies`` /
    ``costs`` / ``mask`` are (T, N) log rows padded to a common N with
    ``mask`` marking the real ones.  Returns dict of (T, A, L) arrays —
    ``counterfactual_grid`` stacked over tenants, with per-tenant means
    taken over each tenant's own row count.  ``P_lower`` switches the
    SPECULATE gate to the §7.5 credible bound per tenant, as in the
    single-tenant grid.
    """
    P = jnp.atleast_1d(_f(P))
    P_gate = P if P_lower is None else jnp.atleast_1d(_f(P_lower))
    lat = jnp.atleast_2d(_f(latencies))
    cost = jnp.atleast_2d(_f(costs))
    mask = jnp.atleast_2d(jnp.asarray(mask, bool))
    frac, exp_lat, exp_cost, waste = _grid_tenants(
        P, P_gate, lat, cost, mask, _f(alphas), _f(lambdas), _f(rho),
    )
    return {
        "speculate_fraction": np.asarray(frac),
        "expected_latency_s": np.asarray(exp_lat),
        "expected_cost_usd": np.asarray(exp_cost),
        "expected_waste_usd": np.asarray(waste),
    }


@jax.jit
def _post_update(alpha0, beta0, successes):
    # successes: (E, N) in {0, 1}; returns per-edge running posterior params
    s = successes.sum(-1)
    n = successes.shape[-1]
    return alpha0 + s, beta0 + (n - s)


@jax.jit
def _post_update_discounted(alpha0, beta0, successes, discount):
    # sequential over the trial axis so the exponential forgetting matches
    # BetaPosterior.update exactly (a <- a*d + x_i per observation)
    def step(ab, x):
        a, b = ab
        return (a * discount + x, b * discount + (1.0 - x)), None

    (a, b), _ = jax.lax.scan(
        step, (alpha0, beta0), jnp.moveaxis(successes, -1, 0)
    )
    return a, b


def batch_posterior_update(alpha0, beta0, outcomes, discount: float = 1.0):
    """Bulk conjugate update for E edges at once.

    ``discount=1`` is the paper's exact update, Beta(a0+s, b0+f), as one
    fused sum.  ``discount<1`` mirrors the exponential-forgetting branch of
    ``BetaPosterior.update`` (§14.3): a sequential ``lax.scan`` over the
    trial axis, vectorized across edges, bitwise-matching the scalar loop.
    """
    if discount == 1.0:
        a, b = _post_update(_f(alpha0), _f(beta0), _f(outcomes))
    else:
        a, b = _post_update_discounted(
            _f(alpha0), _f(beta0), _f(outcomes), _f(discount)
        )
    return np.asarray(a), np.asarray(b)


@functools.partial(jax.jit, static_argnames=("throttle_every",))
def _chunk_cancel(P_k, alpha, lam, latency_s, in_tok, out_tok,
                  in_price, out_price, throttle_every):
    C_spec = in_tok * in_price + out_tok * out_price
    L_value = latency_s * lam
    EV_k = P_k * L_value[..., None] - (1.0 - P_k) * C_spec[..., None]
    thr = ((1.0 - alpha) * C_spec)[..., None]
    K = P_k.shape[-1]
    valid = (jnp.arange(K) % throttle_every) == 0
    wait_k = valid & (EV_k < thr)
    cancelled = wait_k.any(-1)
    first = jnp.argmax(wait_k, axis=-1)
    return jnp.where(cancelled, first, -1), cancelled, EV_k, thr


def batch_chunk_cancel(
    P_chunks, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price,
    *, throttle_every: int = 1,
):
    """Vectorized §9.1 per-chunk re-estimation across a fleet of in-flight
    edges: re-run the D4 gate at every streamed chunk and return the first
    WAIT verdict per stream.

    ``P_chunks``: (..., K) refined success probabilities P_k; scalar inputs
    broadcast.  Returns ``(first_cancel_idx, cancelled, EV_k, threshold)``
    where ``first_cancel_idx`` is -1 for streams that never cancel —
    matching ``StreamingReestimator.run`` chunk-for-chunk (throttled chunks
    are skipped, not evaluated, exactly as the scalar loop does).
    """
    P_chunks = _f(P_chunks)
    args = [jnp.broadcast_to(_f(x), P_chunks.shape[:-1]) for x in (
        alpha, lam, latency_s, in_tok, out_tok, in_price, out_price
    )]
    first, cancelled, EV_k, thr = _chunk_cancel(
        P_chunks, *args, throttle_every=int(throttle_every)
    )
    return (np.asarray(first), np.asarray(cancelled),
            np.asarray(EV_k), np.broadcast_to(np.asarray(thr), EV_k.shape))


@jax.jit
def _frac_waste(in_tok, out_tok, frac, in_price, out_price):
    # same expression order as streaming.fractional_waste:
    # c_in(full prompt) + c_out(frac * planned output); frac > 1 bills
    # actuals, exactly like the scalar path
    return in_tok * in_price + (frac * out_tok) * out_price


def batch_fractional_waste(in_tok, out_tok, frac, in_price, out_price):
    """Vectorized §9.3 C_spec_actual for cancelled speculations: full input
    cost plus only the output tokens actually emitted."""
    return np.asarray(_frac_waste(
        _f(in_tok), _f(out_tok), _f(frac), _f(in_price), _f(out_price)
    ))


@jax.jit
def _implied(P, C, alpha_star, L_up):
    return ((1.0 - alpha_star) * C + (1.0 - P) * C) / (P * L_up)


def batch_implied_lambda(P, C_spec, alpha_star, L_upstream_s):
    """§12.3 implied-lambda over arrays of observed operating points."""
    return np.asarray(_implied(_f(P), _f(C_spec), _f(alpha_star), _f(L_upstream_s)))


@jax.jit
def _kcrit(L_value, C_spec, alphas):
    return (L_value + C_spec) / ((2.0 - alphas) * C_spec)


def critical_k_grid(L_value, C_spec, alphas):
    """k_crit(alpha) over an alpha grid (§7.6) in one call."""
    return np.asarray(_kcrit(_f(L_value), _f(C_spec), _f(alphas)))


# --------------------------------------------------- top-k beam (repro.core.beam)
def beam_gate(P_gate, conf, width, alpha, lam, latency_s, in_tok, out_tok,
              in_price, out_price, zero=None):
    """Traceable top-k D4 gate — :func:`d4_gate` generalized to a beam of
    candidates over a shared dollar budget (repro.core.beam docstring).

    ``conf`` carries per-candidate confidences on a trailing axis (sorted
    non-increasing, summing to <= 1); ``width`` caps launches per row.
    Candidate 1 is admitted unconditionally and candidates ``j >= 2``
    while the marginal EV ``p_j (L_value + C_spec) - C_spec`` stays
    non-negative (tie -> include), so at ``width == 1`` with a certain
    first candidate the EV / threshold / flag come out bitwise equal to
    :func:`d4_gate` (same ``zero`` pinning contract).

    Returns ``(EV, threshold, speculate, C_spec, L_value, w_eff, p_cum)``
    where ``w_eff`` is the admitted beam width and ``p_cum`` the beam-
    cumulative commit probability the gate ran on.
    """
    rnd = (lambda x: x) if zero is None else (lambda x: x + zero)
    C_spec = rnd(in_tok * in_price) + rnd(out_tok * out_price)
    L_value = latency_s * lam
    p = conf * P_gate[..., None]
    j = jnp.arange(conf.shape[-1])
    marginal_ok = (
        p * (L_value + C_spec)[..., None] - C_spec[..., None] >= 0.0
    )
    inc = (j == 0) | marginal_ok
    prefix = jnp.cumsum(jnp.logical_not(inc), axis=-1) == 0
    sel = prefix & (j < width[..., None])
    w_eff = sel.sum(-1)
    w_eff_f = w_eff.astype(C_spec.dtype)
    p_cum = jnp.where(sel, p, 0.0).sum(-1)
    EV = rnd(p_cum * L_value) - rnd((w_eff_f - p_cum) * C_spec)
    threshold = rnd((1.0 - alpha) * C_spec)
    return EV, threshold, EV >= threshold, C_spec, L_value, w_eff, p_cum


@jax.jit
def _beam_grid(P, conf, lat, cost, alphas, lams, widths, rho):
    # decisions[w, a, l, n]: the §12.1 grid with beam width as a third
    # axis.  Candidate admission depends on lambda (through L_value) but
    # not alpha; selection is computed once per (lambda, row, candidate)
    # and broadcast over alpha / width.
    Lv = lat[None, :] * lams[:, None]                        # (L, N)
    p = conf * P[:, None]                                    # (N, W)
    j = jnp.arange(conf.shape[-1])
    marginal_ok = (
        p[None] * (Lv + cost[None, :])[:, :, None]
        - cost[None, :, None] >= 0.0
    )                                                        # (L, N, W)
    inc = (j == 0) | marginal_ok
    prefix = jnp.cumsum(jnp.logical_not(inc), axis=-1) == 0
    sel = prefix[None] & (j < widths[:, None, None, None])   # (Wd, L, N, W)
    w_eff = sel.sum(-1).astype(lat.dtype)                    # (Wd, L, N)
    p_cum = jnp.where(sel, p[None, None], 0.0).sum(-1)       # (Wd, L, N)
    EV = p_cum * Lv[None] - (w_eff - p_cum) * cost[None, None, :]
    thr = (1.0 - alphas[:, None, None]) * cost[None, None, :]  # (A, L, N)
    spec = EV[:, None] >= thr[None]                          # (Wd, A, L, N)
    frac = spec.astype(lat.dtype).mean(axis=-1)
    # any committed candidate saves the edge's latency; all launched
    # losers are billed at rho (§9.3 expected form)
    exp_lat = jnp.where(
        spec, (lat[None, :] * (1.0 - p_cum))[:, None], lat[None, None, None, :]
    ).mean(-1)
    waste = (spec * ((w_eff - p_cum)[:, None] * cost[None, None, None, :])
             * rho).sum(-1)
    exp_cost = cost.sum() + waste
    return frac, exp_lat, exp_cost, waste


def beam_counterfactual_grid(P, conf, latencies, costs, alphas, lambdas,
                             widths, rho=0.5):
    """§12.1 counterfactual grid with beam width as a third axis.

    ``conf`` is (N, W) per-row candidate confidences (rows sorted
    non-increasing); ``widths`` the beam widths to sweep.  Returns a dict
    of (len(widths), len(alphas), len(lambdas)) arrays under the same
    keys as :func:`counterfactual_grid`; the ``width == 1`` slice of a
    single-certain-candidate ``conf`` reproduces that grid exactly
    (pinned by tests/test_beam.py).
    """
    conf = np.asarray(conf, float)
    if conf.ndim != 2:
        raise ValueError("conf must be (N, W)")
    if (conf < 0).any() or (conf > 1).any():
        raise ValueError("candidate confidences must be in [0, 1]")
    if (conf[:, 1:] > conf[:, :-1]).any():
        raise ValueError("conf rows must be sorted non-increasing")
    if (conf.sum(1) > 1.0 + 1e-9).any():
        raise ValueError("conf rows must sum to <= 1")
    widths = np.atleast_1d(np.asarray(widths))
    if not np.issubdtype(widths.dtype, np.integer) or (widths < 1).any():
        raise ValueError("widths must be integers >= 1")
    frac, exp_lat, exp_cost, waste = _beam_grid(
        _f(P), _f(conf), _f(latencies), _f(costs), _f(alphas), _f(lambdas),
        jnp.asarray(widths, jnp.int32), _f(rho),
    )
    return {
        "speculate_fraction": np.asarray(frac),
        "expected_latency_s": np.asarray(exp_lat),
        "expected_cost_usd": np.asarray(exp_cost),
        "expected_waste_usd": np.asarray(waste),
    }


@jax.jit
def _kcrit_surface(L_value, C_spec, alphas, widths):
    w = widths[:, None]
    return w * (L_value + C_spec) / ((w + 1.0 - alphas[None, :]) * C_spec)


def critical_k_surface(L_value, C_spec, alphas, widths):
    """§7.6 self-limiting closed form extended to beam width: the
    (len(widths), len(alphas)) surface

        k_crit(alpha, w) = w (L + C) / ((w + 1 - alpha) C)

    (see ``repro.core.beam.beam_critical_k``).  The ``w == 1`` row equals
    :func:`critical_k_grid`; the surface is monotone in ``w`` with
    ceiling ``(L + C) / C``.
    """
    widths = np.atleast_1d(np.asarray(widths))
    if not np.issubdtype(widths.dtype, np.integer) or (widths < 1).any():
        raise ValueError("widths must be integers >= 1")
    return np.asarray(_kcrit_surface(
        _f(L_value), _f(C_spec), _f(alphas), _f(widths)))
