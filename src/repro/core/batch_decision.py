"""Vectorized JAX decision engine — the beyond-paper fast path.

The paper's D4 rule is a handful of multiplies per decision (§6.5).  At
fleet scale the hot paths are *batched*: the §12.1 counterfactual replay
over millions of logged decisions x an (alpha, lambda) grid, per-chunk
streaming re-evaluation across thousands of in-flight edges, and bulk
posterior updates.  This module jit-compiles those as single XLA calls.

Recorded in EXPERIMENTS.md §Perf as the optimized implementation next to
the paper-faithful scalar path (repro.core.decision), with identical
numerics (tests assert bitwise-comparable float64 results).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "batch_evaluate",
    "counterfactual_grid",
    "batch_posterior_update",
    "batch_implied_lambda",
    "critical_k_grid",
]


@functools.partial(jax.jit, static_argnames=())
def _batch_evaluate(P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price):
    C_spec = in_tok * in_price + out_tok * out_price
    L_value = latency_s * lam
    EV = P * L_value - (1.0 - P) * C_spec
    threshold = (1.0 - alpha) * C_spec
    return EV, threshold, EV >= threshold, C_spec, L_value


def _f(x):
    """float array at the widest enabled precision (f64 under jax_enable_x64,
    f32 otherwise) — keeps numerics comparable to the scalar path."""
    return jnp.asarray(x, dtype=jnp.result_type(float))


def batch_evaluate(
    P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price
):
    """Vectorized D4 gate.  All inputs broadcastable arrays.  Returns
    (EV, threshold, speculate_mask, C_spec, L_value)."""
    args = [_f(x) for x in (
        P, alpha, lam, latency_s, in_tok, out_tok, in_price, out_price
    )]
    return _batch_evaluate(*args)


@functools.partial(jax.jit, static_argnames=("rho",))
def _grid(P, lat, cost, alphas, lams, rho):
    # decisions[a, l, n] for n log rows at each (alpha, lambda) grid point
    L_value = lat[None, None, :] * lams[None, :, None]
    EV = P * L_value - (1.0 - P) * cost[None, None, :]
    thr = (1.0 - alphas[:, None, None]) * cost[None, None, :]
    spec = EV >= thr
    frac = spec.mean(axis=-1)
    exp_lat = jnp.where(spec, lat[None, None, :] * (1.0 - P), lat[None, None, :]).mean(-1)
    waste = (spec * (1.0 - P) * cost[None, None, :] * rho).sum(-1)
    exp_cost = cost.sum() + waste
    return frac, exp_lat, exp_cost, waste


def counterfactual_grid(P, latencies, costs, alphas, lambdas, rho=0.5):
    """§12.1 counterfactual EV grid as one XLA call.

    Returns dict of (len(alphas), len(lambdas)) arrays:
    speculate_fraction, expected_latency, expected_cost, expected_waste.
    """
    frac, exp_lat, exp_cost, waste = _grid(
        _f(P), _f(latencies), _f(costs), _f(alphas), _f(lambdas), float(rho),
    )
    return {
        "speculate_fraction": np.asarray(frac),
        "expected_latency_s": np.asarray(exp_lat),
        "expected_cost_usd": np.asarray(exp_cost),
        "expected_waste_usd": np.asarray(waste),
    }


@jax.jit
def _post_update(alpha0, beta0, successes):
    # successes: (E, N) in {0, 1}; returns per-edge running posterior params
    s = successes.sum(-1)
    n = successes.shape[-1]
    return alpha0 + s, beta0 + (n - s)


def batch_posterior_update(alpha0, beta0, outcomes):
    """Bulk conjugate update for E edges at once: Beta(a0+s, b0+f)."""
    a, b = _post_update(_f(alpha0), _f(beta0), _f(outcomes))
    return np.asarray(a), np.asarray(b)


@jax.jit
def _implied(P, C, alpha_star, L_up):
    return ((1.0 - alpha_star) * C + (1.0 - P) * C) / (P * L_up)


def batch_implied_lambda(P, C_spec, alpha_star, L_upstream_s):
    """§12.3 implied-lambda over arrays of observed operating points."""
    return np.asarray(_implied(_f(P), _f(C_spec), _f(alpha_star), _f(L_upstream_s)))


@jax.jit
def _kcrit(L_value, C_spec, alphas):
    return (L_value + C_spec) / ((2.0 - alphas) * C_spec)


def critical_k_grid(L_value, C_spec, alphas):
    """k_crit(alpha) over an alpha grid (§7.6) in one call."""
    return np.asarray(_kcrit(_f(L_value), _f(C_spec), _f(alphas)))
