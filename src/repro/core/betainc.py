"""Jax-native inverse regularized incomplete beta function (§7.5 numerics).

``betaincinv(a, b, q)`` solves ``I_x(a, b) = q`` for ``x`` — the Beta
quantile function — as pure XLA: a Numerical-Recipes-style initial guess
(normal approximation for a, b >= 1, power-law tail inversion otherwise)
refined by a fixed number of bracketed Halley iterations on
``jax.scipy.special.betainc``.  Every step is elementwise ``jnp``, so the
function is jit-able, vmap-able, and usable inside ``lax.scan`` carries —
which is what lets the fleet replay engine (``repro.core.fleet``) gate on
the one-sided credible bound ``Beta^{-1}(gamma; alpha, beta)`` instead of
the posterior mean without leaving the compiled episode loop.

The bracket [lo, hi] is tightened from the sign of ``I_x(a,b) - q`` at
every iteration; a Halley step that leaves the bracket (or goes
non-finite, e.g. when the local pdf under- or overflows) falls back to
bisection, so the iteration cannot diverge.  At float64 the result agrees
with ``scipy.stats.beta.ppf`` to <= 1e-10 relative error across
practically relevant (a, b, q) — including a or b << 1 and tail q —
pinned by ``tests/test_betaincinv.py``.

Special values follow scipy: ``q=0 -> 0``, ``q=1 -> 1``; ``q`` outside
[0, 1] or non-positive ``a``/``b`` return NaN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import betainc, betaln

__all__ = ["betaincinv"]

# Fixed iteration count: Halley from the NR initial guess converges in a
# handful of steps; the generous budget lets pure-bisection lanes (the
# safeguard path) still reach ~1e-16 interval width at float64.
_N_ITER = 64


def _initial_guess(a, b, q):
    """Numerical Recipes 3rd ed. §6.4 ``invbetai`` starting point."""
    dt = q.dtype
    eps = jnp.finfo(dt).eps
    tiny = jnp.finfo(dt).tiny

    # a, b >= 1: invert via the normal approximation (Abramowitz & Stegun
    # 26.2.23 rational approximation for the normal quantile, then 26.5.22).
    pp = jnp.maximum(jnp.where(q < 0.5, q, 1.0 - q), tiny)
    t = jnp.sqrt(-2.0 * jnp.log(pp))
    x = (2.30753 + t * 0.27061) / (1.0 + t * (0.99229 + t * 0.04481)) - t
    x = jnp.where(q < 0.5, -x, x)
    al = (x * x - 3.0) / 6.0
    h = 2.0 / (1.0 / (2.0 * a - 1.0) + 1.0 / (2.0 * b - 1.0))
    w = (
        x * jnp.sqrt(al + h) / h
        - (1.0 / (2.0 * b - 1.0) - 1.0 / (2.0 * a - 1.0))
        * (al + 5.0 / 6.0 - 2.0 / (3.0 * h))
    )
    guess_large = a / (a + b * jnp.exp(2.0 * w))

    # a or b < 1: invert the leading power-law term of the tail series.
    lna = jnp.log(a / (a + b))
    lnb = jnp.log(b / (a + b))
    t_a = jnp.exp(a * lna) / a
    t_b = jnp.exp(b * lnb) / b
    s = t_a + t_b
    guess_small = jnp.where(
        q < t_a / s,
        (a * s * q) ** (1.0 / a),
        1.0 - (b * s * (1.0 - q)) ** (1.0 / b),
    )

    guess = jnp.where((a >= 1.0) & (b >= 1.0), guess_large, guess_small)
    return jnp.clip(guess, tiny, 1.0 - eps)


def _invert(a, b, q):
    dt = q.dtype
    tiny = jnp.finfo(dt).tiny
    a1 = a - 1.0
    b1 = b - 1.0
    lbeta = betaln(a, b)
    x0 = _initial_guess(a, b, q)
    lo0 = jnp.zeros_like(q)
    hi0 = jnp.ones_like(q)

    def body(_, state):
        x, lo, hi = state
        err = betainc(a, b, x) - q
        # I_x is increasing in x: err < 0 -> x below the root, err > 0 ->
        # above; tighten the bracket before stepping.
        lo = jnp.where(err < 0.0, jnp.maximum(lo, x), lo)
        hi = jnp.where(err > 0.0, jnp.minimum(hi, x), hi)
        logpdf = a1 * jnp.log(x) + b1 * jnp.log1p(-x) - lbeta
        u = err / jnp.maximum(jnp.exp(logpdf), tiny)
        # Halley correction (NR invbetai): second-order term from
        # d(log pdf)/dx, clipped so the denominator stays >= 1/2.
        halley = 1.0 - 0.5 * jnp.minimum(1.0, u * (a1 / x - b1 / (1.0 - x)))
        xn = x - u / halley
        # Safeguard: any step that exits the bracket or goes non-finite
        # (pdf under/overflow) degrades to bisection.
        bad = ~jnp.isfinite(xn) | (xn < lo) | (xn > hi)
        xn = jnp.where(bad, 0.5 * (lo + hi), xn)
        return xn, lo, hi

    x, _, _ = jax.lax.fori_loop(0, _N_ITER, body, (x0, lo0, hi0))
    x = jnp.where(q <= 0.0, 0.0, jnp.where(q >= 1.0, 1.0, x))
    valid = (a > 0.0) & (b > 0.0) & (q >= 0.0) & (q <= 1.0)
    return jnp.where(valid, x, jnp.nan)


def betaincinv(a, b, q, use_pallas: bool = False):
    """Inverse of ``jax.scipy.special.betainc`` in its third argument.

    Solves ``betainc(a, b, x) == q`` for ``x in [0, 1]``.  Inputs
    broadcast; computation runs at the widest enabled float (float64 under
    ``jax_enable_x64``, float32 otherwise), matching the ``_f`` convention
    of the batch decision engines.  Safe to call inside jit/vmap/scan.

    ``use_pallas=True`` dispatches to the tiled Pallas kernel
    (``repro.kernels.betaincinv_pallas``): same bracketed Halley
    iteration, but with a kernel-resident betainc evaluator — results
    agree to <= 1e-10 relative (the established tier), not bitwise.
    Interpret-vs-native lowering follows ``kernels.ops._interpret()``.
    """
    dt = jnp.result_type(float)
    a, b, q = jnp.broadcast_arrays(
        jnp.asarray(a, dt), jnp.asarray(b, dt), jnp.asarray(q, dt)
    )
    if use_pallas:
        # Lazy import: core.betainc loads very early in repro.core and
        # must not pull the kernels package in at module-import time.
        from ..kernels.betaincinv_pallas import betaincinv_kernel_call
        from ..kernels.ops import _interpret

        shape = q.shape
        out = betaincinv_kernel_call(
            a.ravel(), b.ravel(), q.ravel(), interpret=_interpret()
        )
        return out.reshape(shape)
    return _invert(a, b, q)
