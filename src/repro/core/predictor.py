"""§3.2 — where the predicted input i_hat comes from.

Three sources in preference order:
  1. context-conditioned prediction (cheap auxiliary model or template)
  2. most-likely historical input (modal output for similar inputs)
  3. streaming partial output (§9) — re-estimate as tokens arrive

The method's correctness does not depend on *how* i_hat was produced, only
that (a) a prediction exists at launch time and (b) §7.4 labels each trial.
The predictor's own cost matters for latency economics (§14.2), so every
predictor reports a ``cost_estimate_s``.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Any, Callable, Hashable, Optional, Protocol, Sequence

__all__ = [
    "InputPredictor",
    "Prediction",
    "TemplatePredictor",
    "HistoricalModalPredictor",
    "StreamingPredictor",
    "AuxiliaryModelPredictor",
]


@dataclasses.dataclass(frozen=True)
class Prediction:
    i_hat: Any
    source: str          # telemetry i_hat_source: modal|regex|historical|stream_k|auxiliary_model
    confidence: Optional[float] = None  # predictor-local P(i == i_hat), if available


class InputPredictor(Protocol):
    cost_estimate_s: float

    def predict(self, upstream_input: Any, partial_output: Any = None) -> Optional[Prediction]:
        ...


@dataclasses.dataclass
class TemplatePredictor:
    """Source 1 (template flavor): a deterministic template/regex over the
    upstream's input and partial state."""

    template: Callable[[Any, Any], Any]
    source: str = "regex"
    cost_estimate_s: float = 0.0

    def predict(self, upstream_input: Any, partial_output: Any = None) -> Optional[Prediction]:
        out = self.template(upstream_input, partial_output)
        return None if out is None else Prediction(out, self.source)


@dataclasses.dataclass
class AuxiliaryModelPredictor:
    """Source 1 (model flavor): a cheap auxiliary model call.  ``model_fn``
    may be an EngineOp decode on a small model; its latency cost is charged
    against the reclaimable latency (§14.2)."""

    model_fn: Callable[[Any, Any], Any]
    cost_estimate_s: float = 0.05

    def predict(self, upstream_input: Any, partial_output: Any = None) -> Optional[Prediction]:
        out = self.model_fn(upstream_input, partial_output)
        return None if out is None else Prediction(out, "auxiliary_model")


@dataclasses.dataclass
class HistoricalModalPredictor:
    """Source 2: from logged (upstream_input, upstream_output) pairs, the
    modal output for similar inputs.  ``bucket`` maps an input to a
    similarity bucket (default: single global bucket)."""

    bucket: Callable[[Any], Hashable] = lambda x: "__global__"
    cost_estimate_s: float = 0.0
    _history: dict = dataclasses.field(default_factory=lambda: defaultdict(Counter))

    def observe(self, upstream_input: Any, upstream_output: Any) -> None:
        self._history[self.bucket(upstream_input)][_freeze(upstream_output)] += 1

    def observe_many(self, pairs: Sequence[tuple[Any, Any]]) -> None:
        for i, o in pairs:
            self.observe(i, o)

    def predict(self, upstream_input: Any, partial_output: Any = None) -> Optional[Prediction]:
        counts = self._history.get(self.bucket(upstream_input))
        if not counts:
            return None
        (mode, n_mode), total = counts.most_common(1)[0], sum(counts.values())
        return Prediction(_thaw(mode), "historical", confidence=n_mode / total)

    def predict_topk(self, upstream_input: Any, k: int,
                     partial_output: Any = None) -> list[Prediction]:
        """Top-k modal outputs with empirical confidences ``n_i / total``,
        sorted non-increasing — the candidate beam for
        ``repro.core.beam.beam_evaluate`` (confidences are disjoint event
        probabilities over the shared posterior, so they sum to <= 1)."""
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        counts = self._history.get(self.bucket(upstream_input))
        if not counts:
            return []
        total = sum(counts.values())
        return [
            Prediction(_thaw(o), "historical", confidence=n / total)
            for o, n in counts.most_common(k)
        ]


@dataclasses.dataclass
class StreamingPredictor:
    """Source 3: re-estimate i_hat from the upstream's streamed partial
    output (§9.1).  ``refine`` maps (upstream_input, partial_output) to a
    refined prediction + confidence; throttling (every N chunks) is the
    executor's job (§9.1 'throttled ... not every token')."""

    refine: Callable[[Any, Any], tuple[Any, float]]
    cost_estimate_s: float = 0.001

    def predict(self, upstream_input: Any, partial_output: Any = None) -> Optional[Prediction]:
        if partial_output is None:
            return None
        i_hat, conf = self.refine(upstream_input, partial_output)
        if i_hat is None:
            return None
        return Prediction(i_hat, "stream_k", confidence=conf)


_FREEZE_TAGS = ("__dict__", "__list__", "__tuple__", "__set__",
                "__frozenset__", "__bytearray__")


def _freeze(o: Any) -> Hashable:
    """Canonical hashable form of a logged output.

    Containers are tagged so :func:`_thaw` can invert them; unordered
    containers (dicts, sets) and mixed-type dict keys are sorted by
    ``repr`` of the frozen element — deterministic across interpreter
    runs and total over any element mix, where natural ordering would
    raise ``TypeError`` on e.g. ``{1, "a"}`` and kill ``observe``
    mid-calibration.
    """
    if isinstance(o, dict):
        return ("__dict__", tuple(sorted(
            ((_freeze(k), _freeze(v)) for k, v in o.items()), key=repr)))
    if isinstance(o, list):
        return ("__list__", tuple(_freeze(x) for x in o))
    if isinstance(o, tuple):
        return ("__tuple__", tuple(_freeze(x) for x in o))
    if isinstance(o, (set, frozenset)):
        tag = "__set__" if isinstance(o, set) else "__frozenset__"
        return (tag, tuple(sorted((_freeze(x) for x in o), key=repr)))
    if isinstance(o, bytearray):
        return ("__bytearray__", bytes(o))
    return o


def _thaw(o: Any) -> Any:
    if isinstance(o, tuple) and len(o) == 2 and o[0] in _FREEZE_TAGS:
        tag, body = o
        if tag == "__dict__":
            return {_thaw(k): _thaw(v) for k, v in body}
        if tag == "__list__":
            return [_thaw(x) for x in body]
        if tag == "__set__":
            return {_thaw(x) for x in body}
        if tag == "__frozenset__":
            return frozenset(_thaw(x) for x in body)
        if tag == "__bytearray__":
            return bytearray(body)
        return tuple(_thaw(x) for x in body)
    return o
