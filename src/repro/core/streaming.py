"""§9 — streaming re-estimation, mid-stream cancellation, fractional waste.

If the upstream streams tokens, the runtime re-estimates i_hat (and hence
P) as chunks arrive, re-runs the D4 rule, and cancels the speculative
downstream mid-execution when P_k falls below the speculation threshold.
Cancellation matters for billing: waste is

    C_spec_actual = C_input + f * C_output,   f in [0, 1]

not the full C_spec.  The planner's pessimism is reduced accordingly:

    Expected_Speculation_Waste_v = (1 - P_v) * (C_input + rho_v * C_output)

with rho the expected cancel fraction (EMA from streaming history; default
0.5 with no history, §9.3).

This module is the scalar (per-stream) reference.  The fleet-scale
equivalents — one XLA call across thousands of in-flight streams — live
in ``repro.core.batch_decision`` (``batch_chunk_cancel``,
``batch_fractional_waste``) and inside the ``repro.core.fleet`` episode
simulator; parity tests pin them to this module chunk-for-chunk.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

from .decision import Decision, DecisionInputs, evaluate
from .pricing import CostModel

__all__ = [
    "fractional_waste",
    "expected_speculation_waste",
    "expected_beam_waste",
    "RhoEstimator",
    "StreamingReestimator",
    "ChunkVerdict",
]

DEFAULT_RHO = 0.5


def fractional_waste(
    cost_model: CostModel,
    input_tokens: int,
    output_tokens_planned: float,
    output_tokens_generated: float,
) -> float:
    """C_spec_actual for a cancelled speculation (§9.3): full input cost
    (the prompt was sent) plus only the output tokens actually emitted.

    Billing is always on the actuals: ``output_tokens_generated`` may
    exceed the plan (generation ran past it before the cancel landed) and
    is billed as-is — the plan figure only sanity-scopes the call.  The
    vectorized ``batch_decision.batch_fractional_waste`` implements the
    identical expression (``frac > 1`` there is the same ran-past case);
    parity is pinned by tests/test_fleet_parity.py.
    """
    if input_tokens < 0 or output_tokens_planned < 0 or output_tokens_generated < 0:
        raise ValueError("token counts must be non-negative")
    c_in, _ = cost_model.split(input_tokens, 0)
    _, c_out = cost_model.split(0, output_tokens_generated)
    return c_in + c_out


def expected_speculation_waste(
    P: float,
    cost_model: CostModel,
    input_tokens: int,
    output_tokens: float,
    rho: float = DEFAULT_RHO,
    *,
    streaming: bool = True,
) -> float:
    """(1-P) * (C_input + rho * C_output); rho=1 (full C_spec) when the
    provider does not stream / cannot cancel (§14.1 fallback)."""
    if not streaming:
        rho = 1.0
    if not (0.0 <= rho <= 1.0):
        raise ValueError("rho must be in [0, 1]")
    c_in, c_out = cost_model.split(input_tokens, output_tokens)
    return (1.0 - P) * (c_in + rho * c_out)


def expected_beam_waste(
    P_cum: float,
    launched: int,
    cost_model: CostModel,
    input_tokens: int,
    output_tokens: float,
    rho: float = DEFAULT_RHO,
    *,
    streaming: bool = True,
) -> float:
    """(launched - P_cum) * (C_input + rho * C_output) — the §9.3 expected
    waste generalized to a top-k beam (repro.core.beam): ``launched``
    candidates each pay the speculation cost, at most one (probability
    ``P_cum``, the beam-cumulative commit probability) is refunded by a
    commit, and every loser is cancelled on first commit at the expected
    fraction ``rho``.  At ``launched == 1`` this is bitwise
    :func:`expected_speculation_waste`.
    """
    if launched < 0:
        raise ValueError("launched must be non-negative")
    if not (0.0 <= P_cum <= 1.0) or P_cum > launched:
        raise ValueError("P_cum must be a probability in [0, min(1, launched)]")
    if not streaming:
        rho = 1.0
    if not (0.0 <= rho <= 1.0):
        raise ValueError("rho must be in [0, 1]")
    c_in, c_out = cost_model.split(input_tokens, output_tokens)
    return (launched - P_cum) * (c_in + rho * c_out)


@dataclasses.dataclass
class RhoEstimator:
    """EMA of the cancel fraction f over streaming history (§9.3)."""

    ema: float = DEFAULT_RHO
    decay: float = 0.2      # same alpha_EMA convention as §4.2 token EMA
    n: int = 0

    def observe(self, f: float) -> float:
        if not (0.0 <= f <= 1.0):
            raise ValueError("cancel fraction must be in [0, 1]")
        if self.n == 0:
            self.ema = f
        else:
            self.ema = self.decay * f + (1.0 - self.decay) * self.ema
        self.n += 1
        return self.ema

    @property
    def rho(self) -> float:
        return self.ema if self.n > 0 else DEFAULT_RHO


@dataclasses.dataclass(frozen=True)
class ChunkVerdict:
    """Outcome of re-running the D4 rule at one streamed chunk."""

    chunk_index: int
    P_k: float
    decision: Decision
    cancel: bool            # True when a running speculation should stop
    i_hat_k: Any
    EV_usd: float
    threshold_usd: float


class StreamingReestimator:
    """§9.1 per-chunk loop.  ``predict`` maps (upstream_input, partial) ->
    (i_hat_k, P_k); ``throttle_every`` implements the §9.1 throttling
    recommendation (re-estimate every N chunks, not every token)."""

    def __init__(
        self,
        predict: Callable[[Any, Any], tuple[Any, float]],
        base_inputs: DecisionInputs,
        *,
        throttle_every: int = 1,
    ) -> None:
        if throttle_every < 1:
            raise ValueError("throttle_every must be >= 1")
        self.predict = predict
        self.base = base_inputs
        self.throttle_every = throttle_every
        self.verdicts: list[ChunkVerdict] = []

    def on_chunk(
        self, chunk_index: int, upstream_input: Any, partial_output: Any
    ) -> Optional[ChunkVerdict]:
        """Process one streamed chunk; returns None on throttled chunks."""
        if chunk_index % self.throttle_every != 0:
            return None
        i_hat_k, P_k = self.predict(upstream_input, partial_output)
        res = evaluate(dataclasses.replace(self.base, P=P_k))
        verdict = ChunkVerdict(
            chunk_index=chunk_index,
            P_k=P_k,
            decision=res.decision,
            cancel=res.decision == Decision.WAIT,
            i_hat_k=i_hat_k,
            EV_usd=res.EV_usd,
            threshold_usd=res.threshold_usd,
        )
        self.verdicts.append(verdict)
        return verdict

    def run(
        self, upstream_input: Any, chunks: Iterable[Any]
    ) -> tuple[Optional[ChunkVerdict], list[ChunkVerdict]]:
        """Feed a whole stream; stop at the first cancel verdict.  Returns
        (first_cancel_or_None, all_verdicts)."""
        partial: list[Any] = []
        for idx, chunk in enumerate(chunks):
            partial.append(chunk)
            verdict = self.on_chunk(idx, upstream_input, partial)
            if verdict is not None and verdict.cancel:
                return verdict, self.verdicts
        return None, self.verdicts
