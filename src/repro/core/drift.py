"""§12.5 — drift detection and kill-switch.

Automated triggers that flip the per-edge or global enable bit without
human-in-the-loop approval.  The per-edge enable bit is the method's most
consequential operational knob: §12.1 sets it at deployment time, this
module flips it at runtime in response to evidence.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from .posterior import BetaPosterior

__all__ = ["TriggerKind", "TriggerEvent", "DriftMonitor", "EdgeState"]


class TriggerKind(str, enum.Enum):
    POSTERIOR_DROP = "posterior_drop"            # row 1 of the §12.5 table
    CREDIBLE_BOUND_FLOOR = "credible_bound_floor"  # row 2
    TIER2_FALSE_ACCEPT = "tier2_false_accept"    # row 3
    COST_SLO = "cost_slo"                        # row 4 (global)
    MODEL_VERSION_CHANGE = "model_version_change"  # row 5
    TOKEN_COV = "token_cov"                      # row 6


@dataclasses.dataclass
class TriggerEvent:
    kind: TriggerKind
    scope: str                      # "edge" | "global" | "model"
    edge: Optional[tuple[str, str]]
    action: str
    detail: str
    tenant: Optional[str] = None    # multi-tenant fleets key state per
                                    # (tenant, edge); None = single-tenant


@dataclasses.dataclass
class EdgeState:
    enabled: bool = True
    alpha_offset: float = 0.0       # POSTERIOR_DROP lowers alpha_edge by 0.2
    needs_shadow_rerun: bool = False
    page_oncall: bool = False
    posterior_means: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class DriftMonitor:
    """Stateful evaluator of the six §12.5 triggers.

    Thresholds carry the paper's defaults; every one is overridable.

    Multi-tenant fleets pass ``tenant=`` (scalar) / ``tenants=`` (batch):
    per-edge state — enable bit, alpha offset, breach runs, posterior
    history — is then keyed by ``(tenant, edge)``, so one tenant's drift
    trigger never flips the kill-switch of another tenant that happens to
    share the same edge name.  ``tenant=None`` keeps the historical
    edge-only keying (single-tenant deployments, and backward
    compatibility with persisted state).
    """

    posterior_drop_frac: float = 0.20
    recent_window: int = 100
    baseline_window: int = 500
    credible_consecutive_n: int = 5
    tier2_false_accept_tol: float = 0.05
    token_cov_threshold: float = 0.5
    monthly_budget_usd: Optional[float] = None

    edges: dict[tuple[str, str], EdgeState] = dataclasses.field(default_factory=dict)
    global_alpha_zero: bool = False
    # tenants whose own cost SLO tripped: alpha <- 0 for that tenant only
    tenant_alpha_zero: set = dataclasses.field(default_factory=set)
    tenant_budgets_usd: dict[str, float] = dataclasses.field(default_factory=dict)
    model_versions: dict[str, str] = dataclasses.field(default_factory=dict)
    _credible_breach_run: dict[tuple[str, str], int] = dataclasses.field(default_factory=dict)
    events: list[TriggerEvent] = dataclasses.field(default_factory=list)

    @staticmethod
    def _key(edge: tuple[str, str], tenant: Optional[str] = None):
        """Per-tenant kill-switch state key: the bare edge tuple when no
        tenant is given (historical layout), else ``(tenant, edge)``."""
        return edge if tenant is None else (tenant, edge)

    def state(self, edge: tuple[str, str],
              tenant: Optional[str] = None) -> EdgeState:
        return self.edges.setdefault(self._key(edge, tenant), EdgeState())

    # --------------------------------------------------- store row lifecycle
    def evict_state(self, edge: tuple[str, str],
                    tenant: Optional[str] = None) -> None:
        """Drop all host-side per-(tenant, edge) state — the
        ``PosteriorStore.on_evict`` hook.  Without it the monitor's
        ``edges`` / breach-run dicts grow unboundedly as dead tenants
        churn through a fleet-scale registry."""
        key = self._key(edge, tenant)
        self.edges.pop(key, None)
        self._credible_breach_run.pop(key, None)

    def reseed_baseline(self, edge: tuple[str, str],
                        tenant: Optional[str] = None) -> None:
        """Re-seed the trigger-1 posterior-mean baseline when a spilled
        row faults back onto the device — the ``PosteriorStore.
        on_fault_in`` hook.  A row that sat cold on the shelf may return
        into a shifted workload; comparing its fresh means against the
        pre-spill baseline would fire (or mask) trigger 1 spuriously, so
        the history restarts.  The trigger-2 breach run is *not* touched:
        it rides in the store's device/shelf flags and survives the
        round-trip authoritatively."""
        key = self._key(edge, tenant)
        st = self.edges.get(key)
        if st is not None:
            st.posterior_means.clear()

    # ------------------------------------------------------------ trigger 1
    def observe_posterior_mean(
        self, edge: tuple[str, str], mean: float,
        tenant: Optional[str] = None,
    ) -> Optional[TriggerEvent]:
        """Posterior mean drops > 20% over a 100-trial window vs the prior 500
        -> lower alpha_edge by 0.2 for the next hour."""
        st = self.state(edge, tenant)
        st.posterior_means.append(mean)
        hist = st.posterior_means
        # Only the trailing recent+baseline observations are ever read (and
        # the recent+10 warm-up gate); cap the history so long-lived edges
        # do not leak memory at fleet scale.
        cap = self.recent_window + max(self.baseline_window, 10)
        if len(hist) > cap:
            del hist[: len(hist) - cap]
        if len(hist) < self.recent_window + 10:
            return None
        recent = float(np.mean(hist[-self.recent_window:]))
        base_slice = hist[-(self.recent_window + self.baseline_window):-self.recent_window]
        baseline = float(np.mean(base_slice)) if base_slice else recent
        if baseline > 0 and (baseline - recent) / baseline > self.posterior_drop_frac:
            st.alpha_offset = -0.2
            ev = TriggerEvent(
                TriggerKind.POSTERIOR_DROP, "edge", edge,
                action="alpha_edge -= 0.2 for 1h",
                detail=f"recent={recent:.3f} baseline={baseline:.3f}",
                tenant=tenant,
            )
            self.events.append(ev)
            return ev
        return None

    # ------------------------------------------------------------ trigger 2
    def _credible_breach_step(
        self, edge: tuple[str, str], breached: bool, floor: float,
        tenant: Optional[str] = None,
    ) -> Optional[TriggerEvent]:
        """Shared run-length bookkeeping for trigger 2 (scalar and batch)."""
        key = self._key(edge, tenant)
        run = self._credible_breach_run.get(key, 0)
        run = run + 1 if breached else 0
        self._credible_breach_run[key] = run
        if run >= self.credible_consecutive_n:
            st = self.state(edge, tenant)
            st.enabled = False
            st.needs_shadow_rerun = True
            ev = TriggerEvent(
                TriggerKind.CREDIBLE_BOUND_FLOOR, "edge", edge,
                action="disable; fresh shadow-mode run required to re-enable",
                detail=f"P_lower below {floor:.4f} for {run} consecutive decisions",
                tenant=tenant,
            )
            self.events.append(ev)
            self._credible_breach_run[key] = 0
            return ev
        return None

    def check_credible_bound(
        self,
        edge: tuple[str, str],
        posterior: BetaPosterior,
        alpha: float,
        C_spec: float,
        L_value: float,
        gamma: float = 0.1,
        tenant: Optional[str] = None,
    ) -> Optional[TriggerEvent]:
        """P_lower < (1-alpha) * C / (L*lambda + C) for N consecutive decisions
        -> disable edge; require a fresh shadow run to re-enable."""
        floor = (1.0 - alpha) * C_spec / (L_value + C_spec)
        breached = posterior.lower_bound(gamma) < floor
        return self._credible_breach_step(edge, breached, floor, tenant)

    def check_credible_bound_batch(
        self,
        edges: list[tuple[str, str]],
        post_alpha,
        post_beta,
        alpha,
        C_spec,
        L_value,
        gamma: float = 0.1,
        tenants: Optional[list] = None,
        use_pallas: bool = False,
    ) -> list[Optional[TriggerEvent]]:
        """Trigger 2 across a fleet of edges in one vectorized call.

        ``post_alpha`` / ``post_beta`` are the per-edge posterior
        parameters; ``alpha`` / ``C_spec`` / ``L_value`` broadcast against
        them.  ``tenants`` (aligned with ``edges``, entries may be None)
        keys the breach runs and enable bits per (tenant, edge) — the
        multi-tenant replay engine's
        ``MultiTenantReport.final_posterior_rows`` emits exactly this row
        layout, so a whole fleet's posterior trajectories feed trigger 2
        in one call (see :meth:`check_credible_bound_fleet`).  The P_lower inversion — the expensive part at fleet scale —
        runs as a single jax ``betaincinv`` call
        (``batch_decision.batch_lower_bound``); the per-edge consecutive-
        breach bookkeeping is shared with :meth:`check_credible_bound`.
        The quantile itself comes from a different implementation than
        the scalar method's scipy ``ppf`` — agreement is <= 1e-10
        relative under ``jax_enable_x64``, but only ~1e-5 at jax's
        default float32 (the ``_f`` convention) — so a bound sitting
        within that margin of the floor can tick the breach run
        differently: do not interleave the scalar and batch checkers on
        the same monitor and expect identical counters at razor-edge
        floors, and enable x64 when the floors are tight.
        ``use_pallas=True`` routes the inversion through the tiled
        Pallas kernel (same <= 1e-10 tier; the razor-edge caveat above
        applies identically).  Returns one event-or-None per edge.
        """
        from .batch_decision import batch_lower_bound

        n = len(edges)
        if tenants is None:
            tenants = [None] * n
        if len(tenants) != n:
            raise ValueError("tenants must align with edges")
        post_alpha = np.broadcast_to(np.asarray(post_alpha, float), (n,))
        post_beta = np.broadcast_to(np.asarray(post_beta, float), (n,))
        if np.any(post_alpha <= 0) or np.any(post_beta <= 0):
            # match the scalar path (beta_lower_bound raises): a corrupted
            # posterior must surface, not silently disarm the kill-switch
            # (betaincinv would return NaN -> never-breached).
            raise ValueError("Beta parameters must be positive")
        alpha = np.broadcast_to(np.asarray(alpha, float), (n,))
        C_spec = np.broadcast_to(np.asarray(C_spec, float), (n,))
        L_value = np.broadcast_to(np.asarray(L_value, float), (n,))
        P_lower = batch_lower_bound(post_alpha, post_beta, gamma,
                                    use_pallas=use_pallas)
        floors = (1.0 - alpha) * C_spec / (L_value + C_spec)
        return [
            self._credible_breach_step(edge, bool(p < f), float(f), tenant)
            for edge, tenant, p, f in zip(edges, tenants, P_lower, floors)
        ]

    def ingest_online_triggers(
        self,
        row_keys: list,
        triggered,
        breach_runs=None,
        consecutive_n: Optional[int] = None,
    ) -> list[TriggerEvent]:
        """Fold the online decision service's in-graph trigger-2 state back
        into this monitor (the scalar event log stays the source of truth).

        ``row_keys`` is the service's ``[(tenant, edge), ...]`` row layout
        (``OnlineDecisionService.row_key``); ``triggered`` the tick's
        kill-switch mask; ``breach_runs`` (optional) the device-side
        consecutive-breach counters to mirror into the host bookkeeping.
        The service already reset a triggered row's run to 0 in-graph —
        exactly what ``_credible_breach_step`` does — so ingesting is
        idempotent with the scalar checker's semantics.  Pass
        ``consecutive_n`` when the service's trigger N differs from this
        monitor's, so the audit log records the run length that actually
        fired.
        """
        triggered = np.asarray(triggered, bool)
        if triggered.shape[0] > len(row_keys):
            # TickDecisions.drift_triggered is padded to the table size;
            # the padding rows can never trigger, so accept and drop them
            triggered = triggered[: len(row_keys)]
        if len(row_keys) != triggered.shape[0]:
            raise ValueError("row_keys must align with triggered")
        n = self.credible_consecutive_n if consecutive_n is None else int(consecutive_n)
        if breach_runs is not None:
            runs = np.asarray(breach_runs, int)
            if runs.shape[0] != len(row_keys):
                raise ValueError("breach_runs must align with row_keys")
            for (tenant, edge), run in zip(row_keys, runs):
                self._credible_breach_run[self._key(edge, tenant)] = int(run)
        events = []
        for (tenant, edge), trig in zip(row_keys, triggered):
            if not trig:
                continue
            st = self.state(edge, tenant)
            st.enabled = False
            st.needs_shadow_rerun = True
            ev = TriggerEvent(
                TriggerKind.CREDIBLE_BOUND_FLOOR, "edge", edge,
                action="disable; fresh shadow-mode run required to re-enable",
                detail=(f"P_lower below row floor for {n} consecutive "
                        f"ticks (online service)"),
                tenant=tenant,
            )
            self.events.append(ev)
            events.append(ev)
        return events

    def check_credible_bound_fleet(
        self,
        tenant_edges: list[tuple[str, tuple[str, str]]],
        post_alpha,
        post_beta,
        alpha,
        C_spec,
        L_value,
        gamma: float = 0.1,
        use_pallas: bool = False,
    ) -> list[Optional[TriggerEvent]]:
        """Trigger 2 for a sharded fleet's posterior snapshot in one call.

        ``tenant_edges`` is the ``[(tenant, edge), ...]`` row layout of
        ``MultiTenantReport.final_posterior_rows`` — each row's breach run
        and kill-switch state is keyed per (tenant, edge)."""
        return self.check_credible_bound_batch(
            [e for _, e in tenant_edges], post_alpha, post_beta,
            alpha, C_spec, L_value, gamma,
            tenants=[t for t, _ in tenant_edges],
            use_pallas=use_pallas,
        )

    # ------------------------------------------------------------ trigger 3
    def check_tier2_false_accept(
        self, edge: tuple[str, str], rate: Optional[float],
        tenant: Optional[str] = None,
    ) -> Optional[TriggerEvent]:
        """Tier-2 false-accept rate above tolerance -> disable the
        (tenant, edge) row and page on-call.  ``tenant`` scopes the
        kill-switch: tenant A's false accepts must never disable tenant
        B's same-named edge."""
        if rate is None or rate <= self.tier2_false_accept_tol:
            return None
        st = self.state(edge, tenant)
        st.enabled = False
        st.page_oncall = True
        ev = TriggerEvent(
            TriggerKind.TIER2_FALSE_ACCEPT, "edge", edge,
            action="disable speculation; page on-call",
            detail=f"false-accept rate {rate:.3f} > {self.tier2_false_accept_tol}",
            tenant=tenant,
        )
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------ trigger 4
    def check_cost_slo(self, spend_usd: float,
                       tenant: Optional[str] = None) -> Optional[TriggerEvent]:
        """Monthly cost SLO tripped -> alpha <- 0 until the next cycle.

        With ``tenant=None`` the historical global semantics apply: the
        fleet-wide budget, and a breach zeroes alpha for *every* edge.
        With a tenant, the budget is ``tenant_budgets_usd[tenant]``
        (falling back to the global ``monthly_budget_usd``) and a breach
        zeroes alpha only for that tenant's edges — one tenant
        overspending must not freeze speculation fleet-wide.
        """
        budget = (self.tenant_budgets_usd.get(tenant, self.monthly_budget_usd)
                  if tenant is not None else self.monthly_budget_usd)
        if budget is None or spend_usd <= budget:
            return None
        if tenant is None:
            self.global_alpha_zero = True
            scope, action = "global", "alpha <- 0 for all edges until next billing cycle"
        else:
            self.tenant_alpha_zero.add(tenant)
            scope = "tenant"
            action = f"alpha <- 0 for tenant {tenant!r} until next billing cycle"
        ev = TriggerEvent(
            TriggerKind.COST_SLO, scope, None,
            action=action,
            detail=f"spend ${spend_usd:.2f} > budget ${budget:.2f}",
            tenant=tenant,
        )
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------ trigger 5
    def observe_model_version(
        self, agent: str, version: str, edges_using: list[tuple[str, str]]
    ) -> Optional[TriggerEvent]:
        """New model version -> flip affected edges back to shadow for 24h and
        re-run §12.1 auto-assignment on the shadow logs."""
        old = self.model_versions.get(agent)
        self.model_versions[agent] = version
        if old is None or old == version:
            return None
        for e in edges_using:
            st = self.state(e)
            st.needs_shadow_rerun = True
        ev = TriggerEvent(
            TriggerKind.MODEL_VERSION_CHANGE, "model", None,
            action="shadow mode 24h for all edges using the model; re-run auto-assignment",
            detail=f"{agent}: {old} -> {version} ({len(edges_using)} edges)",
        )
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------ trigger 6
    def check_token_cov(
        self, edge: tuple[str, str], cov: Optional[float],
        tenant: Optional[str] = None,
    ) -> Optional[TriggerEvent]:
        """Token-count CoV above threshold -> disable the (tenant, edge)
        row; keyed per tenant like triggers 2 and 3."""
        if cov is None or cov <= self.token_cov_threshold:
            return None
        st = self.state(edge, tenant)
        st.enabled = False
        ev = TriggerEvent(
            TriggerKind.TOKEN_COV, "edge", edge,
            action="disable speculation until CoV drops below threshold",
            detail=f"CoV {cov:.3f} > {self.token_cov_threshold}",
            tenant=tenant,
        )
        self.events.append(ev)
        return ev

    # --------------------------------------------------------------- queries
    def effective_alpha(self, edge: tuple[str, str], alpha: float,
                        tenant: Optional[str] = None) -> float:
        if self.global_alpha_zero:
            return 0.0
        if tenant is not None and tenant in self.tenant_alpha_zero:
            return 0.0
        return min(1.0, max(0.0, alpha + self.state(edge, tenant).alpha_offset))

    def edge_enabled(self, edge: tuple[str, str],
                     tenant: Optional[str] = None) -> bool:
        return self.state(edge, tenant).enabled
