"""Top-k beam speculation engine — D4 generalized to multi-candidate.

The single-candidate engine speculates one predicted upstream output per
edge.  The closest published systems (B-PASTE's beam-aware pattern
speculation, SPORK's self-speculative forking — see PAPERS.md) speculate
over *k* candidates.  This module generalizes the §6 expected-value rule
to a beam of candidate predictions per edge:

* each edge carries candidate confidences ``c_1 >= c_2 >= ... >= c_W``
  (sorted descending, summing to <= 1) over a **shared** Beta posterior
  ``P`` — candidate j commits with probability ``p_j = c_j * P`` and the
  events are disjoint (at most one candidate can match the upstream's
  actual output);
* the dollar budget is shared across the beam: every launched candidate
  pays ``C_spec`` and at most one is refunded by a commit, so the
  failure-weighted cost term sums over *launched* candidates —

      EV(w)     = P_w * L_value - (w_eff - P_w) * C_spec
      threshold = (1 - alpha) * C_spec          (unchanged, §6.3)

  with ``P_w = sum_{j in beam} p_j`` and ``w_eff`` the number actually
  launched;
* candidates are admitted greedily in confidence order: candidate 1
  unconditionally (so ``w = 1`` is *exactly* the classic rule — the gate
  expression reduces bitwise to ``decision.evaluate``), candidate
  ``j >= 2`` only while its marginal EV is non-negative,

      p_j * (L_value + C_spec) - C_spec >= 0        (tie -> include),

  which with sorted confidences is a prefix rule (once one candidate
  fails the marginal, all later ones do too);
* streaming semantics cancel **all losers on first commit** — at the
  upstream finish the winner commits and every other launched candidate
  is cancelled, billed its actuals through the §9.3 fractional-waste
  rule (``streaming.expected_beam_waste`` is the planner-side expected
  form).

§7.6 closed form extended to a critical-k **surface** (pinned by
tests/test_beam.py next to tests/test_self_limiting.py): under a uniform
prior over ``k`` branches (``p_j = 1/k``), the beam rule SPECULATEs iff

    k <= k_crit(alpha, w) = w * (L_value + C_spec)
                            / ((w + 1 - alpha) * C_spec)

— monotone increasing in ``w`` with ceiling ``(L_value + C_spec) /
C_spec`` (a wider beam tolerates more branching, but never past the
point where even a certain commit cannot pay the losers), and reducing
to the classic ``k_crit(alpha) = (L+C)/((2-alpha) C)`` at ``w = 1``.

Fleet lowering: :func:`beam_replay` sweeps beam width as a **third grid
axis** next to (alpha, lambda) — ``lax.scan`` over episodes with the
Beta posterior carried per (width, grid) cell, ``vmap`` over widths x
grid points, inner ``lax.scan`` over topo-ordered ops.  The ``w = 1``
path is bitwise-f64 equal to :func:`repro.core.fleet.fleet_replay`
(asserted before any timing claim, the repo-wide discipline);
``w > 1`` is matched against the pure-numpy
:func:`reference_beam_replay` twin.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .batch_decision import _f
from .betainc import betaincinv
from .decision import (
    Decision,
    DecisionInputs,
    DecisionResult,
    _validate_alpha,
    _validate_p,
)
from .fleet import FleetLowered, _normalize_grid

__all__ = [
    "BeamDecisionResult",
    "beam_evaluate",
    "beam_critical_k",
    "validate_confidences",
    "BeamFleetReport",
    "beam_replay",
    "reference_beam_replay",
    "hit_rank_from_success",
]


# ------------------------------------------------------------- scalar rule
def validate_confidences(confidences: Sequence[float]) -> tuple[float, ...]:
    """Validate a candidate-confidence vector: each in [0, 1], sorted
    non-increasing, summing to <= 1 (disjoint candidate events over the
    shared posterior).  Returns it as a tuple."""
    conf = tuple(float(c) for c in confidences)
    if not conf:
        raise ValueError("confidences must be non-empty")
    for c in conf:
        if not (0.0 <= c <= 1.0):
            raise ValueError(f"candidate confidence must be in [0, 1], got {c}")
    if any(a < b for a, b in zip(conf, conf[1:])):
        raise ValueError("confidences must be sorted non-increasing")
    if sum(conf) > 1.0 + 1e-9:
        raise ValueError("confidences must sum to <= 1 (disjoint candidates)")
    return conf


@dataclasses.dataclass(frozen=True)
class BeamDecisionResult(DecisionResult):
    """A :class:`~repro.core.decision.DecisionResult` plus the beam
    bookkeeping.  ``P_used`` is the beam-cumulative commit probability
    ``P_w`` the gate ran on; ``launched`` is ``w_eff`` on SPECULATE and 0
    on WAIT — the per-candidate USD attribution hook."""

    width: int = 1                  # requested beam width w
    w_eff: int = 1                  # candidates admitted by the prefix rule
    launched: int = 0               # candidates actually launched
    p_candidates: tuple = ()        # per-candidate p_j = c_j * P
    included: tuple = ()            # per-candidate admission mask

    @property
    def expected_losers(self) -> float:
        """E[launched candidates that cancel] = launched - P_w."""
        return self.launched - (self.P_used if self.launched else 0.0)


def beam_evaluate(
    inputs: DecisionInputs,
    confidences: Sequence[float],
    width: int,
    *,
    use_lower_bound: bool = False,
) -> BeamDecisionResult:
    """Run the top-k D4 gate (scalar reference path).

    ``confidences`` are the per-candidate predictor confidences (sorted
    descending; see module docstring); ``width`` caps how many the beam
    may launch.  With ``width == 1`` and ``confidences[0] == 1.0`` the
    result is **bitwise-f64 identical** to ``decision.evaluate`` — same
    expression order, candidate 1 admitted unconditionally — pinned by
    tests/test_beam.py.
    """
    conf = validate_confidences(confidences)
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    cm = inputs.cost_model()
    C_spec = cm.cost(inputs.input_tokens, inputs.output_tokens)
    L_value = inputs.latency_seconds * inputs.lambda_usd_per_s
    P = inputs.P
    if use_lower_bound:
        if inputs.P_lower_bound is None:
            raise ValueError("use_lower_bound=True requires P_lower_bound")
        P = inputs.P_lower_bound
    _validate_p(P)
    _validate_alpha(inputs.alpha)

    p_candidates = tuple(c * P for c in conf)
    included = []
    prefix_ok = True
    w_eff = 0
    p_cum = 0.0
    for j, p_j in enumerate(p_candidates):
        if j > 0:
            # marginal rule (tie -> include, the §6.1 convention); with
            # sorted confidences this is a prefix property
            prefix_ok = prefix_ok and (
                p_j * (L_value + C_spec) - C_spec >= 0.0
            )
        take = (j == 0 or prefix_ok) and j < width
        included.append(take)
        if take:
            w_eff += 1
            p_cum += p_j
    w_eff_f = float(w_eff)
    # shared-budget EV; at w_eff == 1 this is bitwise the classic
    # P*L_value - (1.0 - P)*C_spec of decision.evaluate
    EV = p_cum * L_value - (w_eff_f - p_cum) * C_spec
    threshold = (1.0 - inputs.alpha) * C_spec
    decision = Decision.SPECULATE if EV >= threshold else Decision.WAIT
    return BeamDecisionResult(
        decision=decision,
        EV_usd=EV,
        threshold_usd=threshold,
        C_spec_usd=C_spec,
        L_value_usd=L_value,
        P_used=p_cum,
        width=int(width),
        w_eff=w_eff,
        launched=w_eff if decision == Decision.SPECULATE else 0,
        p_candidates=p_candidates,
        included=tuple(included),
    )


# --------------------------------------------------------- §7.6 surface
def beam_critical_k(L_value: float, C_spec: float, alpha: float,
                    width: int) -> float:
    """k_crit(alpha, w) = w * (L_value + C_spec) / ((w + 1 - alpha) * C_spec).

    Under a uniform prior over k branches speculated with beam width
    ``w <= k`` (each candidate ``p_j = 1/k``), the beam rule SPECULATEs
    iff ``k <= k_crit(alpha, w)`` — including the marginal-admission edge
    cases (for ``k > (L+C)/C`` the prefix rule trims the beam to one
    candidate and the classic ``w = 1`` bound takes over, which is always
    the tighter one).  Monotone increasing in ``w``; ceiling
    ``(L_value + C_spec) / C_spec`` as ``w -> inf``; reduces to
    ``decision.critical_k`` at ``w = 1``.
    """
    _validate_alpha(alpha)
    if C_spec <= 0:
        raise ValueError("C_spec must be positive for the critical-k form")
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    return width * (L_value + C_spec) / ((width + 1.0 - alpha) * C_spec)


# ------------------------------------------------------------ fleet report
@dataclasses.dataclass(frozen=True)
class BeamFleetReport:
    """Beam replay aggregates; shapes use E episodes, W beam widths,
    G (alpha, lambda) grid points, V ops in topo order.

    The shared-stat fields carry :class:`~repro.core.fleet.FleetReport`
    semantics per width slice; ``launched`` / ``committed`` count *edges*
    (so the ``widths == [1]`` slice is comparable to ``fleet_replay``),
    while ``launched_candidates`` / ``cancelled_candidates`` attribute
    every candidate in the beam.
    """

    alphas: np.ndarray              # (G,)
    lambdas: np.ndarray             # (G,)
    widths: np.ndarray              # (W,)
    makespan_s: np.ndarray          # (E, W, G)
    total_cost_usd: np.ndarray      # (E, W, G)
    waste_usd: np.ndarray           # (E, W, G)
    launched: np.ndarray            # (E, W, G) edges launched
    committed: np.ndarray           # (E, W, G) edges committed
    launched_candidates: np.ndarray   # (E, W, G) candidates launched
    cancelled_candidates: np.ndarray  # (E, W, G) loser candidates billed
    EV_usd: np.ndarray              # (E, W, G, V)
    threshold_usd: np.ndarray       # (E, W, G, V)
    speculate: np.ndarray           # (E, W, G, V)
    w_eff: np.ndarray               # (E, W, G, V) admitted beam width
    edge_launched: np.ndarray       # (E, W, G, V)
    edge_committed: np.ndarray      # (E, W, G, V)
    edge_waste_usd: np.ndarray      # (E, W, G, V)
    start_s: np.ndarray             # (E, W, G, V)
    finish_s: np.ndarray            # (E, W, G, V)
    post_alpha: np.ndarray          # (E, W, G, V)
    post_beta: np.ndarray           # (E, W, G, V)
    ep_mask: np.ndarray = None      # (E,)

    def width_slice(self, wi: int) -> dict:
        """The per-(E, G) stat dict at one width index — the shape the
        single-candidate parity suite compares against ``FleetReport``."""
        out = {}
        for f in dataclasses.fields(self):
            if f.name in ("alphas", "lambdas", "widths", "ep_mask"):
                continue
            out[f.name] = getattr(self, f.name)[:, wi]
        return out

    def pareto(self) -> dict:
        """Per-(width, grid) mean latency / cost / waste — the §12.3
        Pareto with beam width as the third axis."""
        rows = slice(None) if self.ep_mask is None else np.asarray(
            self.ep_mask, bool)
        return {
            "alphas": self.alphas,
            "lambdas": self.lambdas,
            "widths": self.widths,
            "latency_s": self.makespan_s[rows].mean(0),
            "cost_usd": self.total_cost_usd[rows].mean(0),
            "waste_usd": self.waste_usd[rows].mean(0),
            "launched": self.launched[rows].sum(0),
            "committed": self.committed[rows].sum(0),
            "launched_candidates": self.launched_candidates[rows].sum(0),
            "cancelled_candidates": self.cancelled_candidates[rows].sum(0),
        }


# ------------------------------------------------------------- fleet sweep
def hit_rank_from_success(success: np.ndarray) -> np.ndarray:
    """Lift a single-candidate (E, V) bool success log into beam hit
    ranks: rank 0 where the (sole) candidate committed, -1 otherwise."""
    success = np.asarray(success, bool)
    return np.where(success, 0, -1).astype(np.int32)


def _beam_conf(lowered: FleetLowered) -> np.ndarray:
    conf = getattr(lowered, "beam_conf", None)
    if conf is None:
        # single-candidate default: one certain candidate per edge, so
        # every width replays the classic engine exactly
        conf = np.zeros((lowered.n_ops, 1))
        conf[:, 0] = 1.0
    return np.asarray(conf, float)


def _pack_beam_static(lowered: FleetLowered):
    return (
        jnp.asarray(lowered.parent_mask),
        jnp.asarray(lowered.u_onehot),
        _f(lowered.dur), _f(lowered.op_cost),
        jnp.asarray(lowered.has_edge),
        _f(lowered.lat_save), _f(lowered.in_tok), _f(lowered.out_tok),
        _f(lowered.in_price), _f(lowered.out_price), _f(lowered.pred_cost),
        jnp.asarray(lowered.has_pred),
        jnp.asarray(lowered.streams),
    )


def _beam_episode(static, beam_conf, discount, use_lower_bound, gamma,
                  post_ab, alpha, lam, width, hit, pred_ok):
    """One episode at one (width, grid) cell.  Expression order mirrors
    ``fleet._episode`` exactly on the single-candidate path so the
    ``w = 1`` results stay bitwise-f64 equal to ``fleet_replay``."""
    (parent_mask, u_onehot, dur, op_cost, has_edge, lat_save, in_tok,
     out_tok, in_price, out_price, pred_cost, has_pred, streams) = static
    V = dur.shape[0]
    W = beam_conf.shape[1]
    a, b = post_ab[:, 0], post_ab[:, 1]
    if use_lower_bound:
        P = betaincinv(a, b, gamma)
    else:
        P = a / (a + b)
    neg = jnp.asarray(-jnp.inf, dur.dtype)

    # ---- top-k D4 gate over the shared dollar budget (module docstring)
    C_spec = in_tok * in_price + out_tok * out_price
    L_value = lat_save * lam
    p = beam_conf * P[:, None]                              # (V, W)
    j = jnp.arange(W)
    marginal_ok = p * (L_value + C_spec)[:, None] - C_spec[:, None] >= 0.0
    # candidate 1 unconditional (w=1 reduces to the classic gate); the
    # marginal rule is a prefix property under sorted confidences
    inc = (j == 0) | marginal_ok
    prefix = jnp.cumsum(jnp.logical_not(inc), axis=1) == 0
    sel = prefix & (j < width)
    w_eff = sel.sum(1)
    w_eff_f = w_eff.astype(dur.dtype)
    p_cum = jnp.where(sel, p, 0.0).sum(1)
    EV = p_cum * L_value - (w_eff_f - p_cum) * C_spec
    threshold = (1.0 - alpha) * C_spec
    spec_dec = EV >= threshold
    c_in = in_tok * in_price
    # a commit requires the matching candidate to be inside the launched
    # prefix (§7.4 label generalized to a rank)
    hit_ok = (hit >= 0) & (hit < w_eff)

    def step(carry, xs):
        start, finish = carry
        (pmask, umask, dur_v, spec_v, pc_v, launch_gate_v, streams_v,
         c_in_v, out_tok_v, out_price_v, w_eff_f_v, hit_ok_v, pred_ok_v,
         vmask) = xs
        t_ready = jnp.max(jnp.where(pmask, finish, neg), initial=0.0)
        start_u = jnp.sum(jnp.where(umask, start, 0.0))
        finish_u = jnp.sum(jnp.where(umask, finish, 0.0))
        other_ready = jnp.max(jnp.where(pmask & ~umask, finish, neg),
                              initial=0.0)
        launched = spec_v & launch_gate_v & pred_ok_v
        t_launch = jnp.maximum(start_u + pc_v, other_ready)

        committed = launched & hit_ok_v
        # timing mirrors fleet._episode: winner commits at max(spec
        # finish, u finish); no winner -> re-execute after u
        t1_commit = jnp.maximum(t_launch + dur_v, finish_u)
        t0 = jnp.where(committed, t_launch,
                       jnp.where(launched, finish_u, t_ready))
        t1 = jnp.where(committed, t1_commit,
                       jnp.where(launched, finish_u + dur_v,
                                 t_ready + dur_v))

        # §9.3: every loser is cancelled at the upstream finish (the
        # first-commit / verification point) and billed its actuals —
        # the same fractional-waste expression as the single path, times
        # the loser count (w_eff minus the at-most-one winner)
        elapsed_f = jnp.maximum(0.0, finish_u - t_launch)
        frac_f = jnp.where(dur_v > 0.0,
                           jnp.minimum(1.0, elapsed_f / dur_v), 1.0)
        frac_f = jnp.where(streams_v, frac_f, 1.0)
        per_loser = c_in_v + (frac_f * out_tok_v) * out_price_v
        losers = w_eff_f_v - committed.astype(dur_v.dtype)
        waste_v = jnp.where(launched, losers * per_loser, 0.0)
        losers_v = jnp.where(launched, losers, 0.0)

        start = jnp.where(vmask, t0, start)
        finish = jnp.where(vmask, t1, finish)
        outs = (launched, committed, losers_v, waste_v, t0, t1)
        return (start, finish), outs

    xs = (
        parent_mask, u_onehot, dur, spec_dec, pred_cost,
        has_edge & has_pred, streams, c_in, out_tok, out_price,
        w_eff_f, hit_ok, pred_ok, jnp.eye(V, dtype=bool),
    )
    init = (jnp.zeros(V, dur.dtype), jnp.zeros(V, dur.dtype))
    (start, finish), (launched, committed, losers, waste,
                      t0s, t1s) = jax.lax.scan(step, init, xs)

    # shared-posterior Bernoulli: the edge's trial succeeds iff any
    # launched candidate committed (same discounted recurrence as
    # fleet._episode / BetaPosterior.update)
    suc_f = committed.astype(a.dtype)
    a_new = jnp.where(launched, a * discount + suc_f, a)
    b_new = jnp.where(launched, b * discount + (1.0 - suc_f), b)
    post_new = jnp.stack([a_new, b_new], -1)

    waste_total = waste.sum()
    launched_f = launched.astype(a.dtype)
    stats = {
        "makespan_s": jnp.max(finish, initial=0.0),
        "total_cost_usd": op_cost.sum() + waste_total,
        "waste_usd": waste_total,
        "launched": launched.sum(),
        "committed": committed.sum(),
        "launched_candidates": (w_eff_f * launched_f).sum(),
        "cancelled_candidates": losers.sum(),
        "EV_usd": EV,
        "threshold_usd": threshold,
        "speculate": spec_dec,
        "w_eff": w_eff,
        "edge_launched": launched,
        "edge_committed": committed,
        "edge_waste_usd": waste,
        "start_s": t0s,
        "finish_s": t1s,
        "post_alpha": a_new,
        "post_beta": b_new,
    }
    return post_new, stats


@functools.partial(jax.jit, static_argnames=("use_lower_bound",))
def _beam_scan(static, beam_conf, a0, b0, discount, alphas, lambdas,
               widths, gamma, hit, pred_ok, ep_mask, use_lower_bound):
    G = alphas.shape[0]
    Wg = widths.shape[0]
    V = a0.shape[0]
    post0 = jnp.broadcast_to(
        jnp.stack([a0, b0], -1)[None, None], (Wg, G, V, 2))
    episode = functools.partial(
        _beam_episode, static, beam_conf, discount, use_lower_bound, gamma)

    def ep_step(post, xs):
        hit_e, pred_e, mask_e = xs

        def cell(p, al, lm, w):
            return episode(p, al, lm, w, hit_e, pred_e)

        over_grid = jax.vmap(cell, in_axes=(0, 0, 0, None))
        post_new, stats = jax.vmap(
            over_grid, in_axes=(0, None, None, 0)
        )(post, alphas, lambdas, widths)
        post_new = jnp.where(mask_e, post_new, post)
        stats = {
            k: jnp.where(mask_e, v, jnp.zeros_like(v))
            for k, v in stats.items()
        }
        stats["post_alpha"] = jnp.where(mask_e, stats["post_alpha"],
                                        post[..., 0])
        stats["post_beta"] = jnp.where(mask_e, stats["post_beta"],
                                       post[..., 1])
        return post_new, stats

    _, ys = jax.lax.scan(ep_step, post0, (hit, pred_ok, ep_mask))
    return ys


def beam_replay(
    lowered: FleetLowered,
    hit_rank: np.ndarray,
    alphas,
    lambdas,
    widths,
    *,
    pred_ok: Optional[np.ndarray] = None,
    ep_mask: Optional[np.ndarray] = None,
) -> BeamFleetReport:
    """Replay E episodes x W beam widths x G grid points in one jit'd
    XLA call — the fleet lowering of the beam engine, with beam width as
    the third grid axis.

    Args:
      lowered: output of :func:`repro.core.fleet.lower_workflow`; its
        ``beam_conf`` (populated via ``beam_confidences=``) supplies the
        per-edge sorted candidate confidences.  A lowering without one
        replays the single-candidate default (``conf = [1.0]``) at every
        width.
      hit_rank: (E, V) int — per-episode rank of the candidate matching
        the upstream's actual output (0 = top candidate), or -1 when none
        matches (tier failure).  A bool array is accepted as the
        single-candidate degenerate case (True -> rank 0).
      widths: length-W beam widths (ints >= 1) to sweep.
      pred_ok / ep_mask: as in :func:`repro.core.fleet.fleet_replay`.

    The ``width == 1`` slice is bitwise-f64 equal to ``fleet_replay`` on
    the same lowering and success log (tests/test_beam.py asserts it on
    every shared statistic before the benchmark may claim timings).
    """
    alphas, lambdas = _normalize_grid(alphas, lambdas)
    widths = np.atleast_1d(np.asarray(widths))
    if widths.ndim != 1 or widths.shape[0] == 0:
        raise ValueError("widths must be a non-empty 1-D sequence")
    if not np.issubdtype(widths.dtype, np.integer):
        raise ValueError("widths must be integers")
    if (widths < 1).any():
        raise ValueError("beam widths must be >= 1")
    hit_rank = np.asarray(hit_rank)
    if hit_rank.dtype == bool:
        hit_rank = hit_rank_from_success(hit_rank)
    hit_rank = hit_rank.astype(np.int32)
    if hit_rank.ndim != 2 or hit_rank.shape[1] != lowered.n_ops:
        raise ValueError(
            f"hit_rank must have shape (E, {lowered.n_ops})")
    E = hit_rank.shape[0]
    conf = _beam_conf(lowered)
    if conf.shape[0] != lowered.n_ops:
        raise ValueError("beam_conf rows must align with ops")
    if pred_ok is None:
        pred_ok = np.broadcast_to(lowered.has_pred, (E, lowered.n_ops)).copy()
    if ep_mask is None:
        ep_mask = np.ones(E, bool)
    else:
        ep_mask = np.asarray(ep_mask, bool)
        if ep_mask.shape != (E,):
            raise ValueError(f"ep_mask must have shape ({E},)")
    ys = _beam_scan(
        _pack_beam_static(lowered), _f(conf),
        _f(lowered.a0), _f(lowered.b0), _f(lowered.discount),
        _f(alphas), _f(lambdas), jnp.asarray(widths, jnp.int32),
        _f(lowered.gamma),
        jnp.asarray(hit_rank), jnp.asarray(pred_ok, bool),
        jnp.asarray(ep_mask), bool(lowered.use_lower_bound),
    )
    np_out = {k: np.asarray(v) for k, v in ys.items()}
    return BeamFleetReport(alphas=alphas, lambdas=lambdas, widths=widths,
                           ep_mask=ep_mask, **np_out)


# ----------------------------------------------------- scalar reference twin
def reference_beam_replay(
    lowered: FleetLowered,
    hit_rank: np.ndarray,
    alphas,
    lambdas,
    widths,
    *,
    pred_ok: Optional[np.ndarray] = None,
) -> dict:
    """Pure-numpy scalar twin of :func:`beam_replay` — one episode, one
    (width, grid) cell, one op at a time in Python floats, following the
    documented expression orders.  The parity suite pins ``beam_replay``
    against it: decisions / counts / ranks / event times bitwise, EV /
    waste to 1 ULP (the established FMA allowance).  §7.5
    ``use_lower_bound`` lowerings are not supported here (that mode's
    parity is covered by the bitwise ``w = 1`` test against
    ``fleet_replay``)."""
    if lowered.use_lower_bound:
        raise NotImplementedError(
            "reference_beam_replay gates on the posterior mean; lower-"
            "bound parity is pinned via the w=1 fleet_replay equivalence")
    alphas, lambdas = _normalize_grid(alphas, lambdas)
    widths = np.atleast_1d(np.asarray(widths, int))
    hit_rank = np.asarray(hit_rank)
    if hit_rank.dtype == bool:
        hit_rank = hit_rank_from_success(hit_rank)
    E, V = hit_rank.shape
    conf = _beam_conf(lowered)
    if pred_ok is None:
        pred_ok = np.broadcast_to(lowered.has_pred, (E, V)).copy()
    pred_ok = np.asarray(pred_ok, bool)
    G, Wg = alphas.shape[0], widths.shape[0]
    Wc = conf.shape[1]
    parents = [np.flatnonzero(lowered.parent_mask[v]) for v in range(V)]
    ups = [int(np.argmax(lowered.u_onehot[v])) if lowered.has_edge[v] else -1
           for v in range(V)]

    shape_eg = (E, Wg, G)
    out = {
        k: np.zeros(shape_eg) for k in (
            "makespan_s", "total_cost_usd", "waste_usd", "launched",
            "committed", "launched_candidates", "cancelled_candidates")
    }
    out.update({
        k: np.zeros(shape_eg + (V,)) for k in (
            "EV_usd", "threshold_usd", "edge_waste_usd", "start_s",
            "finish_s", "post_alpha", "post_beta")
    })
    out["speculate"] = np.zeros(shape_eg + (V,), bool)
    out["edge_launched"] = np.zeros(shape_eg + (V,), bool)
    out["edge_committed"] = np.zeros(shape_eg + (V,), bool)
    out["w_eff"] = np.zeros(shape_eg + (V,), int)

    base_cost = float(lowered.op_cost.sum())
    for wi, w in enumerate(widths):
        for g in range(G):
            alpha, lam = float(alphas[g]), float(lambdas[g])
            a = [float(x) for x in lowered.a0]
            b = [float(x) for x in lowered.b0]
            for e in range(E):
                start = [0.0] * V
                finish = [0.0] * V
                waste_total = 0.0
                for v in range(V):
                    dur_v = float(lowered.dur[v])
                    P = a[v] / (a[v] + b[v])
                    C_spec = (float(lowered.in_tok[v])
                              * float(lowered.in_price[v])
                              + float(lowered.out_tok[v])
                              * float(lowered.out_price[v]))
                    L_value = float(lowered.lat_save[v]) * lam
                    prefix_ok = True
                    w_eff = 0
                    p_cum = 0.0
                    for jc in range(Wc):
                        p_j = float(conf[v, jc]) * P
                        if jc > 0:
                            prefix_ok = prefix_ok and (
                                p_j * (L_value + C_spec) - C_spec >= 0.0)
                        if (jc == 0 or prefix_ok) and jc < w:
                            w_eff += 1
                            p_cum += p_j
                    w_eff_f = float(w_eff)
                    EV = p_cum * L_value - (w_eff_f - p_cum) * C_spec
                    threshold = (1.0 - alpha) * C_spec
                    spec = EV >= threshold
                    out["EV_usd"][e, wi, g, v] = EV
                    out["threshold_usd"][e, wi, g, v] = threshold
                    out["speculate"][e, wi, g, v] = spec
                    out["w_eff"][e, wi, g, v] = w_eff

                    t_ready = max((finish[p] for p in parents[v]),
                                  default=0.0)
                    t_ready = max(t_ready, 0.0)
                    launched = (spec and bool(lowered.has_edge[v])
                                and bool(lowered.has_pred[v])
                                and bool(pred_ok[e, v]))
                    if launched:
                        u = ups[v]
                        start_u, finish_u = start[u], finish[u]
                        other = max(
                            (finish[p] for p in parents[v] if p != u),
                            default=0.0)
                        other = max(other, 0.0)
                        t_launch = max(start_u + float(lowered.pred_cost[v]),
                                       other)
                        hit = int(hit_rank[e, v])
                        committed = 0 <= hit < w_eff
                        if committed:
                            t0 = t_launch
                            t1 = max(t_launch + dur_v, finish_u)
                        else:
                            t0 = finish_u
                            t1 = finish_u + dur_v
                        elapsed_f = max(0.0, finish_u - t_launch)
                        frac_f = (min(1.0, elapsed_f / dur_v)
                                  if dur_v > 0.0 else 1.0)
                        if not lowered.streams[v]:
                            frac_f = 1.0
                        per_loser = (
                            float(lowered.in_tok[v])
                            * float(lowered.in_price[v])
                            + (frac_f * float(lowered.out_tok[v]))
                            * float(lowered.out_price[v]))
                        losers = w_eff_f - float(committed)
                        waste_v = losers * per_loser
                        waste_total += waste_v
                        suc_f = float(committed)
                        d = float(lowered.discount[v])
                        a[v] = a[v] * d + suc_f
                        b[v] = b[v] * d + (1.0 - suc_f)
                        out["edge_launched"][e, wi, g, v] = True
                        out["edge_committed"][e, wi, g, v] = committed
                        out["edge_waste_usd"][e, wi, g, v] = waste_v
                        out["launched"][e, wi, g] += 1
                        out["committed"][e, wi, g] += committed
                        out["launched_candidates"][e, wi, g] += w_eff_f
                        out["cancelled_candidates"][e, wi, g] += losers
                    else:
                        t0 = t_ready
                        t1 = t_ready + dur_v
                    start[v], finish[v] = t0, t1
                    out["start_s"][e, wi, g, v] = t0
                    out["finish_s"][e, wi, g, v] = t1
                    out["post_alpha"][e, wi, g, v] = a[v]
                    out["post_beta"][e, wi, g, v] = b[v]
                out["makespan_s"][e, wi, g] = max(finish) if V else 0.0
                out["waste_usd"][e, wi, g] = waste_total
                out["total_cost_usd"][e, wi, g] = base_cost + waste_total
    return out
