"""§3.3 — the admissibility precondition.

A speculation is rolled back by *re-execution*, which refunds wasted tokens
but cannot un-send an irreversible side effect.  A downstream op is
admissible for speculation only if at least one of:

  1. side-effect-free   (pure generation / read-only tool)
  2. idempotent         (effect keyed so speculative + corrected collapse)
  3. commit-barrier     (effect staged; released only after tier-1/2 pass)

Ops failing all three are tagged NON_SPECULABLE and the EV gate never runs
on them.  This is a hard precondition, not a tuning knob.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Optional

__all__ = ["AdmissibilityTag", "CommitBarrier", "check_admissible", "NonSpeculableError"]


class AdmissibilityTag(str, enum.Enum):
    SIDE_EFFECT_FREE = "side_effect_free"
    IDEMPOTENT = "idempotent"
    COMMIT_BARRIER = "commit_barrier"
    NON_SPECULABLE = "non_speculable"


class NonSpeculableError(RuntimeError):
    """Raised when the runtime is asked to speculate a non-admissible op."""


def check_admissible(tag: AdmissibilityTag) -> bool:
    """True iff speculation is permitted on an op with this tag (§3.3)."""
    return tag != AdmissibilityTag.NON_SPECULABLE


@dataclasses.dataclass
class CommitBarrier:
    """Route 3: buffer an externally-visible effect; release only after the
    tier-1/2 check passes, drop on failure (a draft, an uncommitted txn, an
    outbound message held in a queue)."""

    release: Callable[[Any], None]
    _staged: list[Any] = dataclasses.field(default_factory=list)
    _released: bool = False
    _dropped: bool = False

    def stage(self, effect: Any) -> None:
        if self._released or self._dropped:
            raise RuntimeError("barrier already resolved")
        self._staged.append(effect)

    def commit(self) -> int:
        """Tier check passed: release everything staged.  Returns count."""
        if self._dropped:
            raise RuntimeError("cannot commit a dropped barrier")
        for effect in self._staged:
            self.release(effect)
        n = len(self._staged)
        self._staged.clear()
        self._released = True
        return n

    def drop(self) -> int:
        """Tier check failed: discard staged effects; downstream re-runs
        before anything is released.  Returns count dropped."""
        if self._released:
            raise RuntimeError("cannot drop a committed barrier")
        n = len(self._staged)
        self._staged.clear()
        self._dropped = True
        return n

    @property
    def pending(self) -> int:
        return len(self._staged)


@dataclasses.dataclass
class IdempotencyKey:
    """Route 2 helper: an upsert keyed on a deterministic id — the
    speculative write is overwritten, not duplicated."""

    key_fn: Callable[[Any], str]
    store: dict = dataclasses.field(default_factory=dict)

    def upsert(self, value: Any) -> str:
        k = self.key_fn(value)
        self.store[k] = value
        return k
