"""D5 structural priors — the dependency-type taxonomy (paper §7.2).

Each DAG edge (u, v) carries a *dependency type* describing the structural
relationship between u's output and v's usability of a predicted input.
The type selects the Beta prior mean for the success probability P.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import Counter
from typing import Iterable, Sequence

__all__ = [
    "DependencyType",
    "structural_prior",
    "prior_params",
    "auto_assign",
    "effective_k",
    "DEFAULT_N0",
]

# Prior strength n0 = alpha0 + beta0.  Appendix A.2: the smallest integer
# that retains the structural prior as a tie-breaker without overwhelming
# early observations.
DEFAULT_N0: float = 2.0


class DependencyType(str, enum.Enum):
    ALWAYS_PRODUCES_OUTPUT = "always_produces_output"
    LIST_OUTPUT_VARIABLE_LENGTH = "list_output_variable_length"
    CONDITIONAL_OUTPUT = "conditional_output"
    ROUTER_K_WAY = "router_k_way"
    RARE_EVENT_TRIGGER = "rare_event_trigger"


# §7.2 prior means.  router_k_way is derived (1/k); rare_event_trigger is a
# narrow range [0.1, 0.2] pinned per deployment (we default to its midpoint).
_FIXED_PRIORS: dict[DependencyType, float] = {
    DependencyType.ALWAYS_PRODUCES_OUTPUT: 0.9,
    DependencyType.LIST_OUTPUT_VARIABLE_LENGTH: 0.7,
    DependencyType.CONDITIONAL_OUTPUT: 0.5,
}
RARE_EVENT_RANGE: tuple[float, float] = (0.1, 0.2)


def structural_prior(
    dep_type: DependencyType,
    *,
    k: int | None = None,
    rare_event_p: float | None = None,
) -> float:
    """Prior mean p_structural for a dependency type (paper §7.2)."""
    if dep_type == DependencyType.ROUTER_K_WAY:
        if k is None or k < 1:
            raise ValueError("router_k_way requires branching factor k >= 1")
        return 1.0 / k
    if dep_type == DependencyType.RARE_EVENT_TRIGGER:
        lo, hi = RARE_EVENT_RANGE
        if rare_event_p is None:
            return (lo + hi) / 2.0
        if not (lo <= rare_event_p <= hi):
            raise ValueError(
                f"rare_event_trigger prior must be pinned within {RARE_EVENT_RANGE}"
            )
        return rare_event_p
    return _FIXED_PRIORS[dep_type]


def prior_params(
    dep_type: DependencyType,
    *,
    k: int | None = None,
    rare_event_p: float | None = None,
    n0: float = DEFAULT_N0,
) -> tuple[float, float]:
    """(alpha0, beta0) with alpha0+beta0 = n0 and mean = p_structural.

    Appendix A.3 verification table: always_produces_output -> (1.8, 0.2),
    list_output_variable_length -> (1.4, 0.6), conditional_output -> (1, 1),
    router_k_way(k=3) -> (0.667, 1.333).
    """
    p = structural_prior(dep_type, k=k, rare_event_p=rare_event_p)
    return p * n0, (1.0 - p) * n0


@dataclasses.dataclass(frozen=True)
class EffectiveK:
    """§7.6 effective branching factor under skew."""

    k_raw: int
    p_mode: float
    mode: object

    @property
    def k_eff(self) -> float:
        return 1.0 / self.p_mode if self.p_mode > 0 else float("inf")


def effective_k(outputs: Sequence[object]) -> EffectiveK:
    """Fit the empirical upstream-output distribution; k_eff = 1/p_mode (§7.6,
    §12.1 'effective branching factor')."""
    if not outputs:
        raise ValueError("need at least one observed output")
    counts = Counter(_hashable(o) for o in outputs)
    mode, n_mode = counts.most_common(1)[0]
    return EffectiveK(k_raw=len(counts), p_mode=n_mode / len(outputs), mode=mode)


def _hashable(o: object) -> object:
    if isinstance(o, dict):
        return tuple(sorted((k, _hashable(v)) for k, v in o.items()))
    if isinstance(o, (list, tuple)):
        return tuple(_hashable(x) for x in o)
    if isinstance(o, set):
        return frozenset(_hashable(x) for x in o)
    return o


def auto_assign(
    outputs: Sequence[object],
    *,
    emits_list: bool | None = None,
    flat_k_max: int = 5,
) -> DependencyType:
    """§12.1 dependency-type auto-assignment rule:

      p_mode >= 0.8                     -> always_produces_output
      upstream emits a list             -> list_output_variable_length
      k <= 5 with flat distribution     -> router_k_way
      p_mode <= 0.2                     -> rare_event_trigger
      otherwise                         -> conditional_output
    """
    ek = effective_k(outputs)
    if ek.p_mode >= 0.8:
        return DependencyType.ALWAYS_PRODUCES_OUTPUT
    if emits_list is None:
        emits_list = all(isinstance(o, (list, tuple)) for o in outputs)
    if emits_list:
        return DependencyType.LIST_OUTPUT_VARIABLE_LENGTH
    if ek.k_raw <= flat_k_max and _is_flat(outputs, ek.k_raw):
        return DependencyType.ROUTER_K_WAY
    if ek.p_mode <= 0.2:
        return DependencyType.RARE_EVENT_TRIGGER
    return DependencyType.CONDITIONAL_OUTPUT


def _is_flat(outputs: Iterable[object], k: int, tol: float = 0.5) -> bool:
    """Distribution counts within (1 +/- tol) of uniform."""
    counts = Counter(_hashable(o) for o in outputs)
    n = sum(counts.values())
    uniform = n / k
    return all(abs(c - uniform) <= tol * uniform for c in counts.values())
