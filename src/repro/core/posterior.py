"""D5 — Bayesian Beta-Binomial posterior over speculation success P.

Paper §7.3 + Appendix A.  Conjugate pair:

    Prior:       P ~ Beta(alpha0, beta0)      (mean = p_structural, n0 = 2)
    Observation: X_i ~ Bernoulli(P)           (success = "speculation useful", §7.4)
    Posterior:   P | data ~ Beta(alpha0 + s, beta0 + f)

Also implements: credible-interval gating (§7.5), data-seeded priors
(§12.1), and an optional discounted (exponential-forgetting) update noted
as the natural non-stationarity complement in §14.3.
"""
from __future__ import annotations

import dataclasses
import math

from scipy import stats as _stats

from .taxonomy import DEFAULT_N0, DependencyType, prior_params

__all__ = ["BetaPosterior", "beta_lower_bound"]


def beta_lower_bound(alpha: float, beta: float, gamma: float = 0.1) -> float:
    """One-sided (1-gamma) lower credible bound: Beta^{-1}(gamma; alpha, beta)."""
    if alpha <= 0 or beta <= 0:
        raise ValueError("Beta parameters must be positive")
    return float(_stats.beta.ppf(gamma, alpha, beta))


@dataclasses.dataclass
class BetaPosterior:
    """Mutable Beta posterior for one dependency edge (one (u, v) pair).

    Each (u, v) pair gets an independent belief (paper §14.3 notes joint /
    hierarchical estimation as open).
    """

    alpha: float
    beta: float
    successes: int = 0
    failures: int = 0
    # exponential forgetting factor in (0, 1]; 1.0 = the paper's exact
    # undiscounted conjugate update.  <1 down-weights older trials
    # (paper §14.3 "discounted Beta update" complement).
    discount: float = 1.0

    # ------------------------------------------------------------------ ctor
    @classmethod
    def from_dependency_type(
        cls,
        dep_type: DependencyType,
        *,
        k: int | None = None,
        rare_event_p: float | None = None,
        n0: float = DEFAULT_N0,
        discount: float = 1.0,
    ) -> "BetaPosterior":
        a0, b0 = prior_params(dep_type, k=k, rare_event_p=rare_event_p, n0=n0)
        return cls(alpha=a0, beta=b0, discount=discount)

    @classmethod
    def from_prior_mean(
        cls, p: float, n0: float = DEFAULT_N0, discount: float = 1.0
    ) -> "BetaPosterior":
        if not (0.0 < p < 1.0):
            raise ValueError("prior mean must be in (0, 1)")
        return cls(alpha=p * n0, beta=(1.0 - p) * n0, discount=discount)

    @classmethod
    def from_row(
        cls,
        alpha: float,
        beta: float,
        *,
        successes: int = 0,
        failures: int = 0,
        discount: float = 1.0,
    ) -> "BetaPosterior":
        """Rehydrate from a structure-of-arrays table row — the interop
        point with the online decision service's device-resident ``(N, 2)``
        posterior table (``repro.core.online``)."""
        if alpha <= 0 or beta <= 0:
            raise ValueError("Beta parameters must be positive")
        return cls(alpha=float(alpha), beta=float(beta),
                   successes=successes, failures=failures, discount=discount)

    def as_row(self) -> tuple[float, float]:
        """(alpha, beta) — the table-row projection of this belief."""
        return self.alpha, self.beta

    @classmethod
    def data_seeded(
        cls,
        dep_type: DependencyType,
        s0: int,
        f0: int,
        *,
        k: int | None = None,
        n0: float = DEFAULT_N0,
    ) -> "BetaPosterior":
        """§12.1 data-seeded prior: start the posterior from logged (s, f)
        so the edge opens production with P already close to truth."""
        post = cls.from_dependency_type(dep_type, k=k, n0=n0)
        post.alpha += s0
        post.beta += f0
        post.successes = s0
        post.failures = f0
        return post

    # --------------------------------------------------------------- updates
    def update(self, success: bool) -> "BetaPosterior":
        """One Bernoulli observation.  Streaming-cancelled failures are still
        real failures for P-estimation purposes (paper §10.3)."""
        if self.discount != 1.0:
            # discounted update: shrink pseudo-counts toward the scale of the
            # prior before adding the new observation.
            self.alpha *= self.discount
            self.beta *= self.discount
        if success:
            self.alpha += 1.0
            self.successes += 1
        else:
            self.beta += 1.0
            self.failures += 1
        return self

    def update_batch(self, s: int, f: int) -> "BetaPosterior":
        """Batch conjugate update: s successes then f failures.

        With ``discount == 1`` this is the closed form Beta(a+s, b+f).
        With ``discount < 1`` order matters, so the batch applies the same
        sequential forgetting recurrence as :meth:`update` — successes
        first, then failures — exactly matching
        ``update_many([True]*s + [False]*f)`` (pinned by a regression
        test; previously the discount was silently ignored here).
        """
        if s < 0 or f < 0:
            raise ValueError("counts must be non-negative")
        if self.discount != 1.0:
            return self.update_many([True] * s + [False] * f)
        self.alpha += s
        self.beta += f
        self.successes += s
        self.failures += f
        return self

    def update_many(self, outcomes) -> "BetaPosterior":
        """Sequential Bernoulli updates (order matters when discount < 1).

        This is the scalar reference for the vectorized
        ``repro.core.batch_decision.batch_posterior_update``, which applies
        the same per-observation recurrence across thousands of edges in
        one XLA call (tests assert they agree to 1 ULP at float64).
        """
        for x in outcomes:
            self.update(bool(x))
        return self

    # --------------------------------------------------------------- queries
    @property
    def n(self) -> int:
        return self.successes + self.failures

    @property
    def mean(self) -> float:
        return self.alpha / (self.alpha + self.beta)

    @property
    def variance(self) -> float:
        ab = self.alpha + self.beta
        return (self.alpha * self.beta) / (ab * ab * (ab + 1.0))

    @property
    def std(self) -> float:
        return math.sqrt(self.variance)

    def lower_bound(self, gamma: float = 0.1) -> float:
        """§7.5 one-sided (1-gamma) lower credible bound."""
        return beta_lower_bound(self.alpha, self.beta, gamma)

    def credible_interval(self, level: float = 0.95) -> tuple[float, float]:
        tail = (1.0 - level) / 2.0
        lo = float(_stats.beta.ppf(tail, self.alpha, self.beta))
        hi = float(_stats.beta.ppf(1.0 - tail, self.alpha, self.beta))
        return lo, hi

    def data_weight(self) -> float:
        """Fraction of the posterior mean weighted by data vs prior.

        Appendix A.4: with n0=2, after ~10 observations the posterior mean is
        ~82% data-weighted, ~18% prior-weighted.
        """
        total = self.alpha + self.beta
        return self.n / total if total > 0 else 0.0

    def snapshot(self) -> dict:
        return {
            "alpha": self.alpha,
            "beta": self.beta,
            "mean": self.mean,
            "successes": self.successes,
            "failures": self.failures,
        }

    def copy(self) -> "BetaPosterior":
        return dataclasses.replace(self)
