"""D3 + D4 — the expected-value decision rule with the alpha dial.

Paper §5 and §6:

    L_value   = L * lambda                                       (USD)
    C_spec    = in_tok * in_price + out_tok * out_price          (USD)
    EV        = P * L_value - (1 - P) * C_spec                   (USD)
    threshold = (1 - alpha) * C_spec                             (USD)
    decision  = SPECULATE iff EV >= threshold  (tie -> SPECULATE, §6.1)

alpha is a runtime-mutable dimensionless preference dial; lambda is a
deployment-level USD/s conversion.  They are deliberately separate (§5.3).

Closed forms (§7.6 / Appendix D):

    k_crit(alpha) = (L_value + C_spec) / ((2 - alpha) * C_spec)
    EV == 0           at P = C_spec / (L_value + C_spec)
    EV == threshold   at P = (2 - alpha) * C_spec / (L_value + C_spec)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from .posterior import BetaPosterior
from .pricing import CostModel, TwoRateTokenCost

__all__ = [
    "Decision",
    "DecisionInputs",
    "DecisionResult",
    "LatencyValue",
    "expected_value",
    "decision_threshold",
    "evaluate",
    "speculation_decision",
    "critical_k",
    "p_break_even",
    "p_threshold_crossing",
    "implied_lambda",
    "LambdaDerivation",
]


class Decision(str, enum.Enum):
    SPECULATE = "SPECULATE"
    WAIT = "WAIT"


# --------------------------------------------------------------------- D3: λ
@dataclasses.dataclass(frozen=True)
class LambdaDerivation:
    """§5.3 standard derivations of the latency-value ratio (USD/s)."""

    @staticmethod
    def user_value_of_time(dollars: float, seconds: float) -> float:
        """Operator sets directly, e.g. '1 minute saved = $1' -> $0.0167/s."""
        return dollars / seconds

    @staticmethod
    def labor_cost(hourly_wage: float) -> float:
        """lambda = hourly_wage / 3600."""
        return hourly_wage / 3600.0

    @staticmethod
    def workflow_value(value: float, expected_duration_s: float) -> float:
        """lambda = value / expected_duration."""
        return value / expected_duration_s

    @staticmethod
    def budget_deadline(B: float, C0: float, T0: float, T: float) -> float:
        """lambda = (B - C0) / (T0 - T): willingness to spend B to hit T."""
        if T0 <= T:
            raise ValueError("T0 must exceed the deadline T")
        return (B - C0) / (T0 - T)


def _validate_alpha(alpha: float) -> None:
    if not (0.0 <= alpha <= 1.0):
        raise ValueError(f"alpha must be in [0, 1], got {alpha}")


def _validate_p(p: float) -> None:
    if not (0.0 <= p <= 1.0):
        raise ValueError(f"P must be in [0, 1], got {p}")


# ------------------------------------------------------------------- D4 rule
def expected_value(P: float, L_value: float, C_spec: float) -> float:
    """EV = P * L_value - (1 - P) * C_spec (§6.1).

    The (1-P) failure weighting is the paper's principled form under
    pay-per-use billing: on success the op would have been paid either way;
    on failure C_spec is pure waste (§6.2).
    """
    _validate_p(P)
    return P * L_value - (1.0 - P) * C_spec


def decision_threshold(alpha: float, C_spec: float) -> float:
    """threshold = (1 - alpha) * C_spec (§6.3): scales with cost magnitude."""
    _validate_alpha(alpha)
    return (1.0 - alpha) * C_spec


@dataclasses.dataclass(frozen=True)
class DecisionInputs:
    """Everything the D4 gate consumes, at one evaluation instant."""

    P: float
    alpha: float
    lambda_usd_per_s: float
    latency_seconds: float          # estimated latency savings L on success
    input_tokens: int
    output_tokens: float
    input_price: float
    output_price: float
    P_lower_bound: Optional[float] = None  # §7.5 credible gating, if enabled

    def cost_model(self) -> CostModel:
        return TwoRateTokenCost(self.input_price, self.output_price)


@dataclasses.dataclass(frozen=True)
class DecisionResult:
    decision: Decision
    EV_usd: float
    threshold_usd: float
    C_spec_usd: float
    L_value_usd: float
    P_used: float                   # the P actually gated on (mean or lower bound)

    @property
    def margin_usd(self) -> float:
        return self.EV_usd - self.threshold_usd


def evaluate(inputs: DecisionInputs, *, use_lower_bound: bool = False) -> DecisionResult:
    """Run the D4 gate.  With ``use_lower_bound`` the §7.5 credible-bound
    variant gates on P_lower instead of the posterior mean."""
    cm = inputs.cost_model()
    C_spec = cm.cost(inputs.input_tokens, inputs.output_tokens)
    L_value = inputs.latency_seconds * inputs.lambda_usd_per_s
    P = inputs.P
    if use_lower_bound:
        if inputs.P_lower_bound is None:
            raise ValueError("use_lower_bound=True requires P_lower_bound")
        P = inputs.P_lower_bound
    EV = expected_value(P, L_value, C_spec)
    threshold = decision_threshold(inputs.alpha, C_spec)
    # Tie -> SPECULATE: speculation has potential upside, waiting has none (§6.1).
    decision = Decision.SPECULATE if EV >= threshold else Decision.WAIT
    return DecisionResult(
        decision=decision,
        EV_usd=EV,
        threshold_usd=threshold,
        C_spec_usd=C_spec,
        L_value_usd=L_value,
        P_used=P,
    )


def speculation_decision(
    P: float,
    alpha: float,
    lambda_dollars_per_sec: float,
    input_tokens: int,
    output_tokens: float,
    input_price: float,
    output_price: float,
    latency_seconds: float,
) -> str:
    """Paper §6.5 pseudocode, verbatim signature.  Returns "SPECULATE"/"WAIT"."""
    C_spec = input_tokens * input_price + output_tokens * output_price
    L_value = latency_seconds * lambda_dollars_per_sec
    EV = P * L_value - (1 - P) * C_spec
    threshold = (1 - alpha) * C_spec
    _validate_p(P)
    _validate_alpha(alpha)
    return "SPECULATE" if EV >= threshold else "WAIT"


def evaluate_posterior(
    posterior: BetaPosterior,
    alpha: float,
    lambda_usd_per_s: float,
    latency_seconds: float,
    input_tokens: int,
    output_tokens: float,
    input_price: float,
    output_price: float,
    *,
    use_lower_bound: bool = False,
    gamma: float = 0.1,
) -> DecisionResult:
    """Convenience: gate directly on a BetaPosterior (D5 -> D4)."""
    return evaluate(
        DecisionInputs(
            P=posterior.mean,
            alpha=alpha,
            lambda_usd_per_s=lambda_usd_per_s,
            latency_seconds=latency_seconds,
            input_tokens=input_tokens,
            output_tokens=output_tokens,
            input_price=input_price,
            output_price=output_price,
            P_lower_bound=posterior.lower_bound(gamma) if use_lower_bound else None,
        ),
        use_lower_bound=use_lower_bound,
    )


# ----------------------------------------------------------- §7.6 closed form
def critical_k(L_value: float, C_spec: float, alpha: float) -> float:
    """k_crit(alpha) = (L_value + C_spec) / ((2 - alpha) * C_spec).

    Under a uniform-mode prior P = 1/k, the D4 rule SPECULATEs iff
    k <= k_crit(alpha); above it the rule self-limits to WAIT (§7.6).
    """
    _validate_alpha(alpha)
    if C_spec <= 0:
        raise ValueError("C_spec must be positive for the critical-k form")
    return (L_value + C_spec) / ((2.0 - alpha) * C_spec)


def p_break_even(L_value: float, C_spec: float) -> float:
    """P at which EV == 0:  P = C_spec / (L_value + C_spec)."""
    return C_spec / (L_value + C_spec)


def p_threshold_crossing(L_value: float, C_spec: float, alpha: float) -> float:
    """P at which EV == threshold: P = (2 - alpha) * C_spec / (L_value + C_spec).

    NOTE: paper Appendix D.2 prints P* = C_spec/(L_value + alpha*C_spec),
    which matches neither EV==0 nor EV==threshold under the paper's own D4
    rule; see DESIGN.md "Paper inconsistencies".  This function is the
    decision-flip point implied by the rule as specified in §6.1.
    """
    _validate_alpha(alpha)
    return (2.0 - alpha) * C_spec / (L_value + C_spec)


def paper_d2_p_star(L_value: float, C_spec: float, alpha: float) -> float:
    """The formula as printed in Appendix D.2 (reported for comparison)."""
    return C_spec / (L_value + alpha * C_spec)


# ----------------------------------------------------------- §12.3 implied λ
def implied_lambda(
    P: float, C_spec: float, alpha_star: float, L_upstream_s: float
) -> float:
    """§12.3 / D.5 implied-λ recovery.  At the chosen operating point α*, the
    D4 rule equates P·L·λ − (1−P)·C = (1−α*)·C, giving

        λ_implied = [(1 − α*)·C_spec + (1 − P)·C_spec] / (P · L_upstream).
    """
    _validate_p(P)
    _validate_alpha(alpha_star)
    if P <= 0 or L_upstream_s <= 0:
        raise ValueError("implied lambda requires P > 0 and L > 0")
    return ((1.0 - alpha_star) * C_spec + (1.0 - P) * C_spec) / (P * L_upstream_s)
