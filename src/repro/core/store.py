"""Paged hierarchical posterior store — the single owner of
(tenant, edge) -> Beta-row state at fleet scale.

Before this module, posterior state had three ad-hoc owners: the online
service's dense ``(N, 2)`` device tables behind an O(N)-rebuild host
registry, the fleet engine's per-call carries, and the serving
front-end's host posterior mirror.  ``PosteriorStore`` unifies them and
removes the small-N assumption:

Systems half (§14.3 (b)):

* **logical rows** are stable integer ids handed out by a host registry
  with a free-list, backed by structure-of-arrays config storage that
  doubles amortized-O(1) — registering a row never touches the device
  and never loops over existing rows;
* **physical rows** live in a device-resident table of power-of-two
  capacity.  In the default *auto-grow* mode (``resident_rows=None``)
  slot == logical id and capacity doubles with the registry (the dense
  behavior the online service always had, minus the O(N) Python rebuild:
  new rows apply in one batched scatter per tick).  In *paged* mode
  (``resident_rows=R``) the physical shape is **fixed forever** — the
  jit'd ``tick`` / scatter / gather executables can never recompile from
  growth — and cold rows spill, least-recently-touched first, to a
  host-side f64 shelf with transparent fault-in on next touch;
* spill/fault-in round-trips are **bitwise-f64 exact** (the shelf stores
  f64; under ``jax_enable_x64`` the device table is f64), so a paged
  store at any occupancy answers decisions bitwise-equal to the dense
  table on the same logical rows — property-pinned in tests/test_store.py.

Statistical half (§14.3 (a)):

* one jit'd empirical-Bayes **moment-matching fit** over the
  device-resident rows, grouped by taxonomy bucket
  (``jax.ops.segment_sum`` with a static power-of-two segment count),
  produces per-bucket Beta hyperpriors;
* a brand-new (tenant, edge) row is then born from its **bucket's
  learned prior** instead of the paper's fixed taxonomy prior, with
  shrinkage fading naturally as conjugate evidence accumulates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .posterior import BetaPosterior
from .taxonomy import DEFAULT_N0, DependencyType, prior_params

__all__ = ["PosteriorStore", "BucketPrior", "_RowConfig", "ROLL_COLS"]


def _bucket(n: int, lo: int = 1) -> int:
    """Power-of-two shape bucket (compile-cache stability)."""
    if n <= 0:
        return 0
    return max(lo, 1 << (n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class _RowConfig:
    """Host-side registration record for one (tenant, edge) row.
    ``alpha0``/``beta0`` are the row's *birth* prior — the learned bucket
    hyperprior when pooling applied, else the fixed taxonomy prior."""

    tenant: Optional[str]
    edge: tuple[str, str]
    alpha0: float
    beta0: float
    gamma: float
    discount: float
    floor: float


@dataclasses.dataclass(frozen=True)
class BucketPrior:
    """A fitted per-taxonomy-bucket empirical-Bayes hyperprior."""

    bucket: str
    alpha: float
    beta: float
    n_rows: int          # rows with enough evidence that entered the fit
    mean: float          # pooled success-rate estimate mu_g
    strength: float      # pseudo-count strength s_g (alpha + beta)


# --------------------------------------------------------------------------
# jit'd kernels.  All index arrays are padded to power-of-two lengths with
# the sentinel index == table capacity: out of bounds, so scatters drop the
# padding lanes and gathers clamp them (the gathered garbage is discarded
# host-side).  Executables key on (capacity, pad bucket, dtype) only — in
# paged mode every one of those is fixed after warm-up, which is what the
# zero-recompile churn property pins.
# --------------------------------------------------------------------------
@jax.jit
def _scatter_rows(post, rowcfg, flags, roll, slots, pvals, cvals, fvals,
                  rvals):
    return (post.at[slots].set(pvals, mode="drop"),
            rowcfg.at[slots].set(cvals, mode="drop"),
            flags.at[slots].set(fvals, mode="drop"),
            roll.at[slots].set(rvals, mode="drop"))


@jax.jit
def _scatter_post(post, slots, pvals):
    return post.at[slots].set(pvals, mode="drop")


@jax.jit
def _gather_rows(post, flags, roll, slots):
    s = jnp.minimum(slots, post.shape[0] - 1)
    return post[s], flags[s], roll[s]


@jax.jit
def _gather_post(post, slots):
    s = jnp.minimum(slots, post.shape[0] - 1)
    return post[s]


@functools.partial(jax.jit, static_argnames=("G",))
def _eb_moments(post, bucket, prior_n, alive, min_evidence, G):
    """Per-bucket weighted moment sums over the resident posterior table:
    one segment-sum pass yields (count, sum m, sum m^2) of the posterior
    means of rows whose accumulated evidence (pseudo-count mass beyond
    the birth prior) clears ``min_evidence``."""
    n = post[:, 0] + post[:, 1]
    m = post[:, 0] / n
    w = (alive & (n - prior_n >= min_evidence)).astype(post.dtype)
    cnt = jax.ops.segment_sum(w, bucket, num_segments=G)
    s1 = jax.ops.segment_sum(w * m, bucket, num_segments=G)
    s2 = jax.ops.segment_sum(w * m * m, bucket, num_segments=G)
    return cnt, s1, s2


_FRESH_FLAGS = np.array([1, 0], np.int32)    # enabled, zero breach run
# staged-rollout lifecycle columns (repro.core.rollout):
# [phase, cooldown, probes, ticks_in_phase, n_obs, s_obs].  Fresh rows are
# born in SHADOW (phase 1) with empty counters; the columns spill/fault-in
# alongside the posterior so phase state survives paging bitwise.
ROLL_COLS = 6
_FRESH_ROLL = np.array([1, 0, 0, 0, 0, 0], np.int32)


class PosteriorStore:
    """Single owner of (tenant, edge) -> Beta-row state.

    ``resident_rows=None`` (default) is the dense auto-grow mode: every
    live row is device-resident, slot == logical id, and capacity grows
    by power-of-two doubling.  ``resident_rows=R`` is the paged mode: at
    most ``bucket(R)`` rows are device-resident, the physical table shape
    never changes, and cold rows live on the host shelf.

    ``on_evict(edge, tenant)`` fires when a row is *evicted* (removed
    from the registry — e.g. a departed tenant); ``on_fault_in(edge,
    tenant)`` fires when a previously *spilled* row returns to the device
    — the drift monitor uses these to drop / re-seed its per-row host
    state (satellite: unbounded DriftMonitor growth).
    """

    def __init__(
        self,
        *,
        resident_rows: Optional[int] = None,
        min_rows: int = 16,
        mesh=None,
        axis_name: str = "fleet",
        on_evict: Optional[Callable] = None,
        on_fault_in: Optional[Callable] = None,
    ) -> None:
        if resident_rows is not None and int(resident_rows) < 1:
            raise ValueError("resident_rows must be >= 1")
        self.resident_rows = None if resident_rows is None else int(resident_rows)
        self.min_rows = int(min_rows)
        self.mesh = mesh
        self.axis_name = axis_name
        self.on_evict = on_evict
        self.on_fault_in = on_fault_in

        # ---- logical registry (host, amortized-O(1) insert)
        self._keys: dict = {}            # (tenant, edge) -> logical id
        self._row_keys: list = []        # id -> (tenant, edge) | None
        self._free_ids: list[int] = []
        self._host_cap = 0
        # per-logical-id SoA (grown by doubling, never per-row Python)
        self._prior = np.zeros((0, 2))           # birth prior [a0, b0]
        self._cfg = np.zeros((0, 3))             # [gamma, discount, floor]
        self._bucket_of = np.zeros(0, np.int32)  # taxonomy-bucket id
        self._shelf_post = np.zeros((0, 2))      # spilled [alpha, beta] (f64)
        self._shelf_flags = np.zeros((0, 2), np.int32)
        self._shelf_roll = np.zeros((0, ROLL_COLS), np.int32)
        self._shelved = np.zeros(0, bool)
        self._slot_of = np.zeros(0, np.int64)    # -1 = not device-resident
        self._alive = np.zeros(0, bool)

        # ---- physical device table
        self._post = self._rowcfg = self._flags = self._roll = None
        self._dtype: Optional[str] = None
        self._np_dtype = np.dtype(np.float64)
        self._capacity = 0
        self._logical_at: Optional[np.ndarray] = None  # slot -> id, -1 free
        self._free_slots: list[int] = []
        self._last_touch: Optional[np.ndarray] = None  # LRU clock per slot
        self._clock = 1
        self._identity = self.resident_rows is None
        self._pending: list[int] = []   # identity mode: rows awaiting the
                                        # once-per-tick batched scatter
        self.row_sharding = None

        # ---- empirical-Bayes bucket registry
        self._bucket_ids: dict[str, int] = {}
        self._bucket_labels: list[str] = []
        self.hyperpriors: dict[str, BucketPrior] = {}

        self.stats = {
            "registered": 0, "evictions": 0, "rebuilds": 0,
            "fault_ins": 0, "spills": 0, "scatter_batches": 0,
            "eb_fits": 0,
        }

    # ------------------------------------------------------------- registry
    @property
    def n_rows(self) -> int:
        """Logical id high-water mark (row ids index snapshots 0..n-1)."""
        return len(self._row_keys)

    @property
    def n_alive(self) -> int:
        return len(self._keys)

    @property
    def n_resident(self) -> int:
        if self._logical_at is None:
            return 0
        return int((self._logical_at >= 0).sum())

    @property
    def n_shelved(self) -> int:
        return int(self._shelved[: self.n_rows].sum())

    @property
    def identity(self) -> bool:
        """True while slot == logical id (dense auto-grow, no evictions)."""
        return self._identity

    @property
    def capacity(self) -> int:
        return self._capacity

    def _grow_host(self, need: int) -> None:
        if need <= self._host_cap:
            return
        cap = _bucket(max(need, self.min_rows, 16))

        def grow2(a, fill=0.0):
            out = np.full((cap,) + a.shape[1:], fill, a.dtype)
            out[: a.shape[0]] = a
            return out

        self._prior = grow2(self._prior)
        self._cfg = grow2(self._cfg)
        self._bucket_of = grow2(self._bucket_of)
        self._shelf_post = grow2(self._shelf_post)
        self._shelf_flags = grow2(self._shelf_flags)
        self._shelf_roll = grow2(self._shelf_roll)
        self._shelved = grow2(self._shelved, False)
        self._slot_of = grow2(self._slot_of, -1)
        self._alive = grow2(self._alive, False)
        self._host_cap = cap

    def _bucket_id(self, label: str) -> int:
        bid = self._bucket_ids.get(label)
        if bid is None:
            bid = len(self._bucket_labels)
            self._bucket_ids[label] = bid
            self._bucket_labels.append(label)
        return bid

    @staticmethod
    def bucket_label(dep_type: Optional[DependencyType],
                     k: Optional[int] = None) -> str:
        """Default taxonomy-bucket label: the dependency type, split by
        branching factor for routers (different k => different prior)."""
        if dep_type is None:
            return "_seeded"
        label = dep_type.value
        if dep_type is DependencyType.ROUTER_K_WAY and k is not None:
            label += f":k{int(k)}"
        return label

    def register(
        self,
        edge: tuple[str, str],
        *,
        tenant: Optional[str] = None,
        dep_type: Optional[DependencyType] = None,
        k: Optional[int] = None,
        rare_event_p: Optional[float] = None,
        n0: float = DEFAULT_N0,
        posterior: Optional[BetaPosterior] = None,
        gamma: float = 0.1,
        discount: float = 1.0,
        floor_alpha: float = 0.5,
        floor_C_spec_usd: Optional[float] = None,
        floor_L_value_usd: Optional[float] = None,
        bucket: Optional[str] = None,
        pooled: bool = True,
    ) -> int:
        """Add one (tenant, edge) row; returns its stable logical id.

        Pure host work — O(1) amortized, no device transfer, no loop over
        existing rows.  The birth prior is, in order of precedence: an
        explicit ``posterior`` (§12.1 data-seeded deployment), the
        bucket's learned empirical-Bayes hyperprior (when ``pooled`` and
        :meth:`fit_hyperpriors` has produced one), else the fixed
        taxonomy prior ``prior_params(dep_type, ...)``.
        """
        key = (tenant, tuple(edge))
        if key in self._keys:
            raise ValueError(f"edge already registered: {key}")
        if bucket is None:
            bucket = self.bucket_label(dep_type, k)
        if posterior is not None:
            a0, b0 = float(posterior.alpha), float(posterior.beta)
        elif dep_type is not None:
            hp = self.hyperpriors.get(bucket) if pooled else None
            if hp is not None:
                a0, b0 = hp.alpha, hp.beta
            else:
                a0, b0 = prior_params(dep_type, k=k, rare_event_p=rare_event_p,
                                      n0=n0)
        else:
            raise ValueError("register_edge needs dep_type or posterior")
        if a0 <= 0 or b0 <= 0:
            raise ValueError("Beta parameters must be positive")
        if not (0.0 < gamma < 1.0):
            raise ValueError("gamma must be in (0, 1)")
        if floor_C_spec_usd is not None and floor_L_value_usd is not None:
            # same expression as DriftMonitor.check_credible_bound
            floor = (1.0 - floor_alpha) * floor_C_spec_usd / (
                floor_L_value_usd + floor_C_spec_usd)
        else:
            floor = -np.inf

        if self._free_ids:
            i = self._free_ids.pop()
        else:
            i = len(self._row_keys)
            self._grow_host(i + 1)
            self._row_keys.append(None)
        self._row_keys[i] = key
        self._keys[key] = i
        self._prior[i] = a0, b0
        self._cfg[i] = float(gamma), float(discount), float(floor)
        self._bucket_of[i] = self._bucket_id(bucket)
        self._shelved[i] = False
        self._slot_of[i] = -1
        self._alive[i] = True
        if self._identity:
            self._pending.append(i)
        self.stats["registered"] += 1
        return i

    def row_index(self, edge: tuple[str, str],
                  tenant: Optional[str] = None) -> int:
        return self._keys[(tenant, tuple(edge))]

    def row_key(self, row: int):
        key = self._row_keys[row]
        if key is None:
            raise KeyError(f"row {row} was evicted")
        return key

    def row_config(self, row: int) -> _RowConfig:
        tenant, edge = self.row_key(row)
        a0, b0 = self._prior[row]
        g, d, fl = self._cfg[row]
        return _RowConfig(tenant=tenant, edge=edge, alpha0=float(a0),
                          beta0=float(b0), gamma=float(g), discount=float(d),
                          floor=float(fl))

    def check_rows(self, rows: np.ndarray, what: str = "request") -> None:
        """Bounds + liveness validation (the online service's tick/observe
        contract: bad ids raise, never silently scatter onto padding)."""
        rows = np.asarray(rows)
        if rows.size == 0:
            return
        n = self.n_rows
        if rows.min() < 0 or rows.max() >= n or not self._alive[rows].all():
            raise IndexError(f"{what} row out of range")

    # ------------------------------------------------------------- eviction
    def evict_row(self, row: int) -> None:
        """Remove a logical row entirely: registry entry dropped, logical
        id recycled through the free-list, any resident slot freed, shelf
        entry cleared.  Fires ``on_evict`` so host-side per-row state
        (DriftMonitor histories) is dropped with it."""
        key = self._row_keys[row]
        if key is None:
            raise KeyError(f"row {row} already evicted")
        self._leave_identity()
        slot = self._slot_of[row]
        if slot >= 0:
            # pure host bookkeeping: the stale device values are masked by
            # the slot maps and overwritten on reuse — no device op at all
            self._logical_at[slot] = -1
            self._last_touch[slot] = 0
            self._free_slots.append(int(slot))
        self._slot_of[row] = -1
        self._shelved[row] = False
        self._alive[row] = False
        self._row_keys[row] = None
        del self._keys[key]
        self._free_ids.append(row)
        self.stats["evictions"] += 1
        if self.on_evict is not None:
            tenant, edge = key
            self.on_evict(edge, tenant)

    def evict(self, edge: tuple[str, str],
              tenant: Optional[str] = None) -> None:
        self.evict_row(self.row_index(edge, tenant))

    def evict_tenant(self, tenant: Optional[str]) -> int:
        """Evict every row of one tenant; returns the count."""
        rows = [i for i, key in enumerate(self._row_keys)
                if key is not None and key[0] == tenant]
        for i in rows:
            self.evict_row(i)
        return len(rows)

    def _leave_identity(self) -> None:
        if not self._identity:
            return
        self._identity = False
        # pending rows now fault in lazily on first touch instead
        self._pending = []

    # --------------------------------------------------------- device table
    def _target_capacity(self) -> int:
        if self.resident_rows is not None:
            return _bucket(max(self.resident_rows, self.min_rows))
        return _bucket(max(self.n_rows, self.min_rows))

    def device_tables(self, dtype: str):
        """Ensure the device-resident tables exist for ``dtype`` and that
        every pending registration has materialized (identity mode: one
        batched scatter, not one rebuild per row).  Returns
        ``(post, rowcfg, flags, roll)``."""
        cap = self._target_capacity()
        if self._post is None or self._dtype != dtype or self._capacity != cap:
            self._rebuild(dtype, cap)
        elif self._identity and self._pending:
            self._apply_pending()
        return self._post, self._rowcfg, self._flags, self._roll

    def tables(self):
        return self._post, self._rowcfg, self._flags, self._roll

    def adopt(self, post, rowcfg, flags, roll) -> None:
        """Adopt the arrays a jit'd tick returned (the store stays the
        single owner across donated double-buffer updates)."""
        self._post, self._rowcfg, self._flags = post, rowcfg, flags
        self._roll = roll

    def logical_map(self) -> Optional[np.ndarray]:
        """Copy of the slot -> logical-id map, or None in identity mode
        (slot == id).  Snapshotted per tick for drift translation."""
        if self._identity:
            return None
        return self._logical_at.copy()

    def _device_put(self, post_np, cfg_np, flags_np, roll_np):
        self.row_sharding = None
        if self.mesh is not None:
            from jax.sharding import NamedSharding

            from ..sharding.rules import fleet_axis_spec

            spec = fleet_axis_spec(self.mesh, self._capacity,
                                   axis=self.axis_name)
            if spec is not None:
                self.row_sharding = NamedSharding(self.mesh, spec)
        if self.row_sharding is not None:
            self._post = jax.device_put(post_np, self.row_sharding)
            self._rowcfg = jax.device_put(cfg_np, self.row_sharding)
            self._flags = jax.device_put(flags_np, self.row_sharding)
            self._roll = jax.device_put(roll_np, self.row_sharding)
        else:
            self._post = jnp.asarray(post_np)
            self._rowcfg = jnp.asarray(cfg_np)
            self._flags = jnp.asarray(flags_np)
            self._roll = jnp.asarray(roll_np)

    def _rebuild(self, dtype: str, cap: int) -> None:
        """(Re)build the physical table — first build, dtype switch, or an
        identity-mode capacity doubling.  Never happens in paged mode
        after the first build, which is the zero-recompile guarantee.

        Live device values survive exactly: residents spill to the f64
        shelf first, then either fault back in eagerly (identity mode, one
        vectorized transfer) or on next touch (paged mode)."""
        if self._post is not None and self._logical_at is not None:
            res = np.flatnonzero(self._logical_at >= 0)
            if res.size:
                self._spill_slots(res)
        self.stats["rebuilds"] += 1
        self._capacity = cap
        self._dtype = dtype
        self._np_dtype = np.dtype(dtype)
        self._logical_at = np.full(cap, -1, np.int64)
        self._last_touch = np.zeros(cap, np.int64)
        self._clock = 1
        self._pending = []
        n = self.n_rows
        post = np.ones((cap, 2))
        cfg = np.stack([np.full(cap, 0.5), np.ones(cap),
                        np.full(cap, -np.inf)], 1)
        flags = np.zeros((cap, 2), np.int32)
        roll = np.tile(_FRESH_ROLL, (cap, 1))
        if self._identity and n:
            # eager vectorized materialization of every live row (identity
            # mode has no evictions, so rows 0..n-1 are all alive)
            sh = self._shelved[:n, None]
            post[:n] = np.where(sh, self._shelf_post[:n], self._prior[:n])
            cfg[:n] = self._cfg[:n]
            flags[:n] = np.where(sh, self._shelf_flags[:n], _FRESH_FLAGS)
            roll[:n] = np.where(sh, self._shelf_roll[:n], _FRESH_ROLL)
            self._shelved[:n] = False
            self._slot_of[:n] = np.arange(n)
            self._logical_at[:n] = np.arange(n)
            self._free_slots = list(range(cap - 1, n - 1, -1))
        else:
            # paged (or post-eviction) mode: rows stay on the shelf / as
            # unmaterialized priors and fault in on first touch
            self._free_slots = list(range(cap - 1, -1, -1))
        self._device_put(post.astype(self._np_dtype),
                         cfg.astype(self._np_dtype), flags, roll)

    def _apply_pending(self) -> None:
        """Identity mode: materialize all registrations since the last
        tick in one batched scatter (the satellite fix for the old
        O(N)-per-new-row host rebuild)."""
        ids = np.asarray(self._pending, np.int64)
        self._pending = []
        # identity invariant: fresh ids are consecutive and the free-slot
        # list's tail is exactly those slots in pop() order
        del self._free_slots[len(self._free_slots) - ids.size:]
        self._scatter(ids, self._prior[ids], self._cfg[ids],
                      np.broadcast_to(_FRESH_FLAGS, (ids.size, 2)),
                      np.broadcast_to(_FRESH_ROLL, (ids.size, ROLL_COLS)))
        self._slot_of[ids] = ids
        self._logical_at[ids] = ids
        self.stats["fault_ins"] += int(ids.size)

    def _scatter(self, slots, pvals, cvals, fvals, rvals) -> None:
        k = int(slots.size)
        kp = _bucket(k)
        spad = np.full(kp, self._capacity, np.int64)
        spad[:k] = slots
        pp = np.zeros((kp, 2), self._np_dtype)
        pp[:k] = pvals
        cc = np.zeros((kp, 3), self._np_dtype)
        cc[:k] = cvals
        ff = np.zeros((kp, 2), np.int32)
        ff[:k] = fvals
        rr = np.zeros((kp, ROLL_COLS), np.int32)
        rr[:k] = rvals
        self._post, self._rowcfg, self._flags, self._roll = _scatter_rows(
            self._post, self._rowcfg, self._flags, self._roll, spad, pp, cc,
            ff, rr)
        self.stats["scatter_batches"] += 1

    # ------------------------------------------------------ paging / LRU
    def ensure_resident(self, ids: np.ndarray) -> np.ndarray:
        """Fault the given logical rows onto the device (spilling LRU
        victims if the free-list runs dry) and touch their LRU clocks.
        Returns the slot of each id.  No-op identity fast path."""
        ids = np.unique(np.asarray(ids, np.int64))
        if self._identity:
            if self._pending:
                self._apply_pending()
            return ids
        if ids.size == 0:
            return ids
        self.check_rows(ids)
        slots = self._slot_of[ids]
        missing = ids[slots < 0]
        if missing.size:
            k = int(missing.size)
            if k > self._capacity:
                raise RuntimeError(
                    f"one tick touches {k} distinct rows > resident "
                    f"capacity {self._capacity}")
            # pin this tick's already-resident rows before victim choice
            res = slots[slots >= 0]
            self._last_touch[res] = self._clock
            shortfall = k - len(self._free_slots)
            if shortfall > 0:
                self._spill_lru(shortfall)
            new_slots = np.array(
                [self._free_slots.pop() for _ in range(k)], np.int64)
            sh = self._shelved[missing]
            pvals = np.where(sh[:, None], self._shelf_post[missing],
                             self._prior[missing])
            fvals = np.where(sh[:, None], self._shelf_flags[missing],
                             _FRESH_FLAGS)
            rvals = np.where(sh[:, None], self._shelf_roll[missing],
                             _FRESH_ROLL)
            self._scatter(new_slots, pvals, self._cfg[missing], fvals, rvals)
            self._slot_of[missing] = new_slots
            self._logical_at[new_slots] = missing
            self._shelved[missing] = False
            self.stats["fault_ins"] += k
            if self.on_fault_in is not None:
                for i in missing[sh]:       # only rows returning from spill
                    tenant, edge = self._row_keys[i]
                    self.on_fault_in(edge, tenant)
            slots = self._slot_of[ids]
        self._last_touch[slots] = self._clock
        self._clock += 1
        return slots

    def _spill_lru(self, need: int) -> None:
        cand = np.flatnonzero(self._logical_at >= 0)
        cand = cand[self._last_touch[cand] < self._clock]   # unpinned only
        if cand.size < need:
            raise RuntimeError(
                "one tick touches more distinct rows than resident capacity")
        order = np.lexsort((cand, self._last_touch[cand]))
        self._spill_slots(cand[order[:need]])

    def _spill_slots(self, victim_slots: np.ndarray) -> None:
        """Move resident rows to the host shelf (exact f64 values; the
        breach-run / enable bits and rollout phase columns ride along)."""
        k = int(victim_slots.size)
        kp = _bucket(k)
        pad = np.full(kp, self._capacity, np.int64)
        pad[:k] = victim_slots
        p, f, r = _gather_rows(self._post, self._flags, self._roll, pad)
        ids = self._logical_at[victim_slots]
        self._shelf_post[ids] = np.asarray(p, np.float64)[:k]
        self._shelf_flags[ids] = np.asarray(f)[:k]
        self._shelf_roll[ids] = np.asarray(r)[:k]
        self._shelved[ids] = True
        self._slot_of[ids] = -1
        self._logical_at[victim_slots] = -1
        self._last_touch[victim_slots] = 0
        self._free_slots.extend(int(s) for s in victim_slots)
        self.stats["spills"] += k

    def resident_ids(self) -> np.ndarray:
        """Sorted logical ids currently device-resident."""
        if self._logical_at is None:
            return np.zeros(0, np.int64)
        ids = self._logical_at[self._logical_at >= 0]
        return np.sort(ids)

    def translate(self, rows: np.ndarray) -> np.ndarray:
        """Map logical row ids (with -1 padding sentinels) to device
        slots.  Valid ids must already be resident (``ensure_resident``
        runs first in the tick path)."""
        if self._identity:
            return rows
        out = np.full(rows.shape, -1, np.int32)
        v = rows >= 0
        out[v] = self._slot_of[rows[v]]
        return out

    # ------------------------------------------------------------ snapshots
    def snapshot(self, dtype=np.float64) -> np.ndarray:
        """(n_rows, 2) composed alpha/beta view across every storage tier:
        device-resident rows (authoritative), shelf rows (exact spilled
        values), never-touched rows (their birth prior).  Evicted ids
        read as the (1, 1) padding prior."""
        n = self.n_rows
        dt = np.dtype(dtype)
        snap = np.where(self._shelved[:n, None], self._shelf_post[:n],
                        self._prior[:n]).astype(dt)
        dead = ~self._alive[:n]
        if dead.any():
            snap[dead] = 1.0
        if self._post is not None and self._logical_at is not None:
            res = np.flatnonzero(self._logical_at >= 0)
            if res.size:
                vals = np.asarray(self._post)[res].astype(dt, copy=False)
                snap[self._logical_at[res]] = vals
        return snap

    def flags_snapshot(self) -> np.ndarray:
        """(n_rows, 2) int32 composed [enabled, breach_run] view (same
        tier precedence as :meth:`snapshot`; evicted rows read disabled)."""
        n = self.n_rows
        out = np.where(self._shelved[:n, None], self._shelf_flags[:n],
                       _FRESH_FLAGS).astype(np.int32)
        dead = ~self._alive[:n]
        if dead.any():
            out[dead] = 0
        if self._flags is not None and self._logical_at is not None:
            res = np.flatnonzero(self._logical_at >= 0)
            if res.size:
                out[self._logical_at[res]] = np.asarray(self._flags)[res]
        return out

    def roll_snapshot(self) -> np.ndarray:
        """(n_rows, ROLL_COLS) int32 composed rollout-lifecycle view
        [phase, cooldown, probes, ticks_in_phase, n_obs, s_obs] — same
        tier precedence as :meth:`snapshot`; evicted rows read phase 0
        (DISABLED) with zeroed counters."""
        n = self.n_rows
        out = np.where(self._shelved[:n, None], self._shelf_roll[:n],
                       _FRESH_ROLL).astype(np.int32)
        dead = ~self._alive[:n]
        if dead.any():
            out[dead] = 0
        if self._roll is not None and self._logical_at is not None:
            res = np.flatnonzero(self._logical_at >= 0)
            if res.size:
                out[self._logical_at[res]] = np.asarray(self._roll)[res]
        return out

    def set_roll_rows(self, ids, values) -> None:
        """Overwrite the rollout-lifecycle columns for logical rows
        (faulting them in first in paged mode) — the host override path
        RolloutController uses for tier-2 demotion and operator revives."""
        ids = np.asarray(ids, np.int64)
        values = np.asarray(values, np.int32).reshape(ids.size, ROLL_COLS)
        self.check_rows(ids)
        if self._roll is None:
            raise RuntimeError("device tables not built; call device_tables")
        self.ensure_resident(ids)
        slots = ids if self._identity else self._slot_of[ids]
        k = int(ids.size)
        kp = _bucket(k)
        spad = np.full(kp, self._capacity, np.int64)
        spad[:k] = slots
        rr = np.zeros((kp, ROLL_COLS), np.int32)
        rr[:k] = values
        self._roll = self._roll.at[jnp.asarray(spad)].set(
            jnp.asarray(rr), mode="drop")

    def rows_snapshot(self, ids, dtype=np.float64) -> np.ndarray:
        """(k, 2) composed alpha/beta values for specific logical rows —
        the lazy per-row read path (front-end mirror misses) that never
        changes residency."""
        ids = np.asarray(ids, np.int64)
        self.check_rows(ids)
        dt = np.dtype(dtype)
        out = np.where(self._shelved[ids, None], self._shelf_post[ids],
                       self._prior[ids]).astype(dt)
        if self._post is not None:
            slots = self._slot_of[ids]
            res = slots >= 0
            if res.any():
                k = int(res.sum())
                kp = _bucket(k)
                pad = np.full(kp, self._capacity, np.int64)
                pad[:k] = slots[res]
                vals = np.asarray(_gather_post(self._post, pad), np.float64)
                out[res] = vals[:k].astype(dt, copy=False)
        return out

    def set_rows(self, ids, values) -> None:
        """Overwrite alpha/beta for logical rows (faulting them in first
        in paged mode) — the ``set_posterior`` / replay-seeding path."""
        ids = np.asarray(ids, np.int64)
        values = np.asarray(values, np.float64).reshape(ids.size, 2)
        if np.any(values <= 0):
            raise ValueError("Beta parameters must be positive")
        self.check_rows(ids)
        if self._post is None:
            raise RuntimeError("device tables not built; call device_tables")
        self.ensure_resident(ids)
        uids = np.unique(ids)
        vmap = {int(i): values[j] for j, i in enumerate(ids)}
        vals = np.stack([vmap[int(i)] for i in uids]) if uids.size else values
        k = int(uids.size)
        kp = _bucket(k)
        spad = np.full(kp, self._capacity, np.int64)
        spad[:k] = self._slot_of[uids] if not self._identity else uids
        pp = np.zeros((kp, 2), self._np_dtype)
        pp[:k] = vals
        self._post = _scatter_post(self._post, spad, pp)

    # ------------------------------------------------- empirical-Bayes fit
    def fit_hyperpriors(
        self,
        *,
        min_evidence: float = 5.0,
        min_bucket_rows: int = 2,
        strength_floor: Optional[float] = None,
        strength_cap: float = 1000.0,
        var_floor: float = 1e-6,
    ) -> dict[str, BucketPrior]:
        """One jit'd empirical-Bayes fit over the device-resident rows:
        moment-matching per taxonomy bucket.

        For each bucket g the posterior means of resident rows with at
        least ``min_evidence`` pseudo-counts beyond their birth prior
        give (mu_g, var_g); the method-of-moments Beta strength is
        ``s = mu (1 - mu) / var - 1``, clipped to
        ``[strength_floor (default n0), strength_cap]``, and the
        hyperprior is ``Beta(mu s, (1 - mu) s)``.  The result is stored
        on the instance — subsequent :meth:`register` calls with the same
        bucket are born from it.  Shelved rows are deliberately excluded:
        the fit is one segment-sum pass over the live table, no host loop.
        """
        G = len(self._bucket_labels)
        if G == 0 or self._post is None:
            self.hyperpriors = {}
            return self.hyperpriors
        cap = self._capacity
        ids = self._logical_at
        alive = ids >= 0
        safe = np.maximum(ids, 0)
        bucket = np.where(alive, self._bucket_of[safe], 0).astype(np.int32)
        prior_n = np.where(alive, self._prior[safe].sum(1), 0.0)
        Gp = _bucket(G)
        cnt, s1, s2 = _eb_moments(
            self._post, bucket, prior_n.astype(self._np_dtype), alive,
            self._np_dtype.type(min_evidence), Gp)
        cnt = np.asarray(cnt, np.float64)
        s1 = np.asarray(s1, np.float64)
        s2 = np.asarray(s2, np.float64)
        lo = DEFAULT_N0 if strength_floor is None else float(strength_floor)
        out: dict[str, BucketPrior] = {}
        for g, label in enumerate(self._bucket_labels):
            c = cnt[g]
            if c < min_bucket_rows:
                continue
            mu = s1[g] / c
            var = max(s2[g] / c - mu * mu, 0.0)
            mu = min(max(mu, 1e-6), 1.0 - 1e-6)
            s = mu * (1.0 - mu) / max(var, var_floor) - 1.0
            s = min(max(s, lo), float(strength_cap))
            out[label] = BucketPrior(
                bucket=label, alpha=mu * s, beta=(1.0 - mu) * s,
                n_rows=int(round(c)), mean=float(mu), strength=float(s))
        self.hyperpriors = out
        self.stats["eb_fits"] += 1
        return out

    # ------------------------------------------------------------- plumbing
    def adopt_posteriors(self, tenant_edges, post_alpha, post_beta,
                         **register_kw) -> list[int]:
        """Bulk-load a fleet calibration result (the
        ``MultiTenantReport.final_posterior_rows`` row layout) into the
        store: unknown keys register data-seeded, known keys get their
        values overwritten in one batched scatter."""
        post_alpha = np.asarray(post_alpha, np.float64)
        post_beta = np.asarray(post_beta, np.float64)
        rows: list[int] = []
        seen_ids: list[int] = []
        seen_vals: list = []
        for (tenant, edge), a, b in zip(tenant_edges, post_alpha, post_beta):
            key = (tenant, tuple(edge))
            i = self._keys.get(key)
            if i is None:
                i = self.register(
                    edge, tenant=tenant,
                    posterior=BetaPosterior(alpha=float(a), beta=float(b)),
                    **register_kw)
            else:
                seen_ids.append(i)
                seen_vals.append((float(a), float(b)))
            rows.append(i)
        if seen_ids:
            if self._post is None:
                dtype = ("float64" if jax.config.jax_enable_x64
                         else "float32")
                self.device_tables(dtype)
            self.set_rows(np.asarray(seen_ids), np.asarray(seen_vals))
        return rows

    def memory_stats(self) -> dict:
        """Host/device byte accounting for the EXPERIMENTS.md §Store
        memory-per-row table (SoA arrays only — Python-object registry
        overhead is reported separately as an estimate)."""
        host_arrays = (self._prior, self._cfg, self._bucket_of,
                       self._shelf_post, self._shelf_flags, self._shelf_roll,
                       self._shelved, self._slot_of, self._alive)
        host = int(sum(a.nbytes for a in host_arrays))
        per_row = int(sum(a.dtype.itemsize * int(np.prod(a.shape[1:]))
                          for a in host_arrays))
        dev = 0
        if self._post is not None:
            dev = int(self._post.dtype.itemsize * self._capacity * 5
                      + 4 * self._capacity * (2 + ROLL_COLS)
                      + 8 * 2 * self._capacity)   # logical_at + last_touch
        return {
            "logical_rows": self.n_rows,
            "alive_rows": self.n_alive,
            "resident_rows": self.n_resident,
            "shelved_rows": self.n_shelved,
            "host_soa_bytes": host,
            "host_soa_bytes_per_row": per_row,
            "device_table_bytes": dev,
            "capacity": self._capacity,
        }
