"""§8.1 — Phase 1: planning.

Before execution, enumerate candidate parallelization plans over discrete
concurrency settings (sequential / maximally parallel / intermediate), make
a SPECULATE/WAIT decision per candidate edge with the §6 rule, and pick the
plan minimizing

    alpha * (Latency(plan) * lambda) + (1 - alpha) * MonetaryCost(plan)

subject to   MonetaryCost <= max_budget      (if specified)
             Latency      <= max_latency     (if specified)
             |wave|       <= max_concurrency

    MonetaryCost(plan) = sum_v cost(v) + sum_spec_v (1-P_v) * cost_actual(v)
    Latency(plan)      = sum_waves max_{v in wave} latency(v)

Concretization (the paper leaves the schedule model coarse): we compute the
expected makespan event-wise.  A speculated edge (u, v) lets v start at
u's *start* (with predicted input) instead of u's finish; on success
(prob P) v commits at max(spec finish, u finish), on failure (prob 1-P)
v re-executes after u.  Expected finish is the P-weighted mix.  Concurrency
is enforced with c machine slots, list-scheduled in topological order.
For small DAGs (5-20 ops) enumerating concurrency levels is tractable
(paper: list scheduling / ILP substitute for larger DAGs without changing
the rest of the method).

Phase 1 outputs: (plan, per-candidate decisions, expected latency, expected
cost) — the user-visible estimate (§8.3 "Visibility").
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from .decision import Decision, DecisionInputs, DecisionResult, evaluate
from .posterior import BetaPosterior
from .pricing import TwoRateTokenCost, get_pricing
from .streaming import DEFAULT_RHO, expected_beam_waste, expected_speculation_waste
from .workflow import Edge, Workflow

__all__ = ["PlannerParams", "Plan", "plan_workflow", "enumerate_plans"]


@dataclasses.dataclass
class PlannerParams:
    alpha: float = 0.5
    lambda_usd_per_s: float = 0.01
    max_budget_usd: Optional[float] = None
    max_latency_s: Optional[float] = None
    max_concurrency: Optional[int] = None
    # per-edge posteriors (D5); missing edges fall back to the edge's
    # structural prior.
    posteriors: dict[tuple[str, str], BetaPosterior] = dataclasses.field(default_factory=dict)
    # per-edge expected cancel fraction rho (§9.3)
    rho: dict[tuple[str, str], float] = dataclasses.field(default_factory=dict)
    use_lower_bound: bool = False      # §7.5 credible gating
    gamma: float = 0.1
    # per-edge latency-savings override; default = overlap = min(lat_u, lat_v)
    latency_savings_s: dict[tuple[str, str], float] = dataclasses.field(default_factory=dict)
    # top-k beam speculation (repro.core.beam): edges with an entry here
    # carry that candidate-confidence vector (sorted non-increasing,
    # summing to <= 1) and are gated with the beam rule at `beam_width`;
    # edges without one keep the classic single-candidate rule.
    beam_width: int = 1
    beam_confidences: dict[tuple[str, str], tuple] = dataclasses.field(default_factory=dict)

    def posterior_for(self, edge: Edge) -> BetaPosterior:
        post = self.posteriors.get(edge.key)
        if post is None:
            post = BetaPosterior.from_dependency_type(
                edge.dep_type, k=edge.k, rare_event_p=edge.rare_event_p
            )
            self.posteriors[edge.key] = post
        return post


@dataclasses.dataclass
class ScheduledOp:
    name: str
    start_s: float
    finish_s: float
    speculative: bool
    wave: int


@dataclasses.dataclass
class Plan:
    concurrency: int
    decisions: dict[tuple[str, str], DecisionResult]
    schedule: dict[str, ScheduledOp]
    expected_latency_s: float
    base_cost_usd: float
    expected_waste_usd: float
    feasible: bool
    infeasibility: Optional[str] = None
    # schedule-consistency record: edges whose Phase-2 SPECULATE verdict
    # the schedule could not honor, mapped to the reason (e.g. a
    # sequential plan has no slot to overlap into).  Their entries in
    # ``decisions`` are downgraded to WAIT so ``speculated_edges()`` and
    # the §8.3 user-visible estimate agree with what was actually costed.
    schedule_overrides: dict[tuple[str, str], str] = dataclasses.field(default_factory=dict)

    @property
    def expected_cost_usd(self) -> float:
        return self.base_cost_usd + self.expected_waste_usd

    def objective(self, alpha: float, lambda_usd_per_s: float) -> float:
        return alpha * self.expected_latency_s * lambda_usd_per_s + (
            1.0 - alpha
        ) * self.expected_cost_usd

    def speculated_edges(self) -> list[tuple[str, str]]:
        return [k for k, d in self.decisions.items() if d.decision == Decision.SPECULATE]


def _edge_decision(wf: Workflow, edge: Edge, params: PlannerParams) -> DecisionResult:
    op = wf.ops[edge.downstream]
    up = wf.ops[edge.upstream]
    pricing = get_pricing(op.provider, op.model)
    post = params.posterior_for(edge)
    L = params.latency_savings_s.get(
        edge.key, min(up.latency_est_s, op.latency_est_s)
    )
    inputs = DecisionInputs(
        P=post.mean,
        alpha=params.alpha,
        lambda_usd_per_s=params.lambda_usd_per_s,
        latency_seconds=L,
        input_tokens=op.input_tokens_est,
        output_tokens=op.output_tokens_est,
        input_price=pricing.input_price_per_token,
        output_price=pricing.output_price_per_token,
        P_lower_bound=post.lower_bound(params.gamma) if params.use_lower_bound else None,
    )
    confs = params.beam_confidences.get(edge.key)
    if confs is not None:
        from .beam import beam_evaluate  # deferred: beam -> fleet -> planner

        return beam_evaluate(inputs, confs, params.beam_width,
                             use_lower_bound=params.use_lower_bound)
    return evaluate(inputs, use_lower_bound=params.use_lower_bound)


def _op_cost(wf: Workflow, name: str) -> float:
    op = wf.ops[name]
    pricing = get_pricing(op.provider, op.model)
    return TwoRateTokenCost.from_entry(pricing).cost(
        op.input_tokens_est, op.output_tokens_est
    )


def _expected_schedule(
    wf: Workflow,
    speculated: set[tuple[str, str]],
    params: PlannerParams,
    concurrency: int,
    commit_P: Optional[dict[tuple[str, str], float]] = None,
) -> dict[str, ScheduledOp]:
    """Expected-time list schedule with c slots and speculative early starts.

    ``commit_P`` optionally overrides the per-edge commit probability the
    expected-finish mix uses (default: the posterior mean; the beam path
    passes the beam-cumulative probability).
    """
    topo = wf.topo_order()
    slots: list[float] = [0.0] * concurrency  # machine-ready times (min-heap)
    heapq.heapify(slots)
    start: dict[str, float] = {}
    finish: dict[str, float] = {}
    sched: dict[str, ScheduledOp] = {}
    for name in topo:
        op = wf.ops[name]
        spec_parents = {u for (u, v) in speculated if v == name}
        dep_ready = 0.0
        is_spec = bool(spec_parents)
        for p in wf.parents(name):
            if p in spec_parents:
                dep_ready = max(dep_ready, start[p])  # launch at u's start (D1)
            else:
                dep_ready = max(dep_ready, finish[p])
        slot_ready = heapq.heappop(slots)
        t0 = max(dep_ready, slot_ready)
        lat = op.latency_est_s
        if is_spec:
            # expected finish over *all* speculated parents: the early
            # commit needs every speculated prediction to hit (joint P =
            # product over edges), and both the success-verification and
            # re-execute paths wait for the latest-finishing speculated
            # parent.  Iterating in sorted order keeps the float product
            # identical across interpreter runs (set order is hash-
            # randomized); with one speculated parent this reduces
            # bitwise to the old single-parent expression.
            P = 1.0
            spec_finish = None
            for u in sorted(spec_parents):
                if commit_P is not None and (u, name) in commit_P:
                    P_u = commit_P[(u, name)]
                else:
                    P_u = params.posterior_for(wf.edges[(u, name)]).mean
                P *= P_u
                f_u = finish[u]
                spec_finish = f_u if spec_finish is None else max(spec_finish, f_u)
            commit_ok = max(t0 + lat, spec_finish)          # success path
            commit_fail = spec_finish + lat                 # re-execute with i
            t1 = P * commit_ok + (1.0 - P) * commit_fail    # expected finish
        else:
            t1 = t0 + lat
        start[name], finish[name] = t0, t1
        heapq.heappush(slots, t1)
        sched[name] = ScheduledOp(name, t0, t1, is_spec, wave=-1)
    # derive wave indices (for reporting / the paper's wave-sum view)
    order = sorted(sched.values(), key=lambda s: (s.start_s, s.name))
    wave, last_start = -1, None
    for s in order:
        if last_start is None or s.start_s > last_start + 1e-12:
            wave += 1
            last_start = s.start_s
        s.wave = wave
    return sched


def _build_plan(wf: Workflow, params: PlannerParams, concurrency: int) -> Plan:
    decisions: dict[tuple[str, str], DecisionResult] = {}
    for edge in wf.speculation_candidates():
        decisions[edge.key] = _edge_decision(wf, edge, params)
    speculated = {
        k for k, d in decisions.items() if d.decision == Decision.SPECULATE
    }
    overrides: dict[tuple[str, str], str] = {}
    if concurrency <= 1 and speculated:
        # a sequential plan cannot overlap anything: downgrade the
        # decision records too (EV numbers kept) so speculated_edges()
        # and the schedule/waste below stay consistent (§8.3)
        overrides = {k: "sequential" for k in sorted(speculated)}
        for k in speculated:
            d = decisions[k]
            if hasattr(d, "launched"):
                decisions[k] = dataclasses.replace(
                    d, decision=Decision.WAIT, launched=0)
            else:
                decisions[k] = dataclasses.replace(d, decision=Decision.WAIT)
        speculated = set()
    # commit probability per speculated edge: posterior mean for the
    # classic rule, beam-cumulative mean for beam edges (mirroring the
    # gate-on-bound / expect-on-mean convention)
    commit_P: dict[tuple[str, str], float] = {}
    beam_stats: dict[tuple[str, str], tuple[float, int]] = {}
    for k in speculated:
        post = params.posterior_for(wf.edges[k])
        d = decisions[k]
        confs = params.beam_confidences.get(k)
        if confs is not None and hasattr(d, "included"):
            conf_sum = sum(c for c, inc in zip(confs, d.included) if inc)
            commit_P[k] = conf_sum * post.mean
            beam_stats[k] = (commit_P[k], d.w_eff)
        else:
            commit_P[k] = post.mean
    sched = _expected_schedule(wf, speculated, params, max(1, concurrency),
                               commit_P)
    latency = max((s.finish_s for s in sched.values()), default=0.0)
    base_cost = sum(_op_cost(wf, n) for n in wf.ops)
    waste = 0.0
    for (u, v) in speculated:
        op = wf.ops[v]
        pricing = get_pricing(op.provider, op.model)
        post = params.posterior_for(wf.edges[(u, v)])
        if (u, v) in beam_stats:
            p_cum, launched = beam_stats[(u, v)]
            waste += expected_beam_waste(
                p_cum,
                launched,
                TwoRateTokenCost.from_entry(pricing),
                op.input_tokens_est,
                op.output_tokens_est,
                rho=params.rho.get((u, v), DEFAULT_RHO),
                streaming=op.streams,
            )
        else:
            waste += expected_speculation_waste(
                post.mean,
                TwoRateTokenCost.from_entry(pricing),
                op.input_tokens_est,
                op.output_tokens_est,
                rho=params.rho.get((u, v), DEFAULT_RHO),
                streaming=op.streams,
            )
    plan = Plan(
        concurrency=concurrency,
        decisions=decisions,
        schedule=sched,
        expected_latency_s=latency,
        base_cost_usd=base_cost,
        expected_waste_usd=waste,
        feasible=True,
        schedule_overrides=overrides,
    )
    violations = []
    if params.max_budget_usd is not None and plan.expected_cost_usd > params.max_budget_usd:
        violations.append("budget")
    if params.max_latency_s is not None and plan.expected_latency_s > params.max_latency_s:
        violations.append("latency")
    if violations:
        # record every violated constraint, not just the last one checked
        plan.feasible, plan.infeasibility = False, "+".join(violations)
    return plan


def enumerate_plans(wf: Workflow, params: PlannerParams) -> list[Plan]:
    """Candidate plans over discrete concurrency settings (§8.1):
    sequential, powers of two, and maximally parallel."""
    if not wf.frozen:
        raise ValueError("plan_workflow requires a frozen workflow")
    n = len(wf.ops)
    if params.max_concurrency is None:
        cap = n
    elif params.max_concurrency < 1:
        # `or` used to swallow 0 as "unset"; an explicit non-positive cap
        # is a configuration error, not a request for unbounded slots
        raise ValueError(
            f"max_concurrency must be >= 1, got {params.max_concurrency}")
    else:
        cap = params.max_concurrency
    levels = sorted({1, *(c for c in (2, 4, 8, 16) if c < min(n, cap)), min(n, cap)})
    return [_build_plan(wf, params, c) for c in levels]


def _violation_usd(plan: Plan, params: PlannerParams) -> float:
    """Constraint violation in USD: budget overshoot plus latency
    overshoot priced at lambda — the 'least-violating' metric."""
    v = 0.0
    if params.max_budget_usd is not None:
        v += max(0.0, plan.expected_cost_usd - params.max_budget_usd)
    if params.max_latency_s is not None:
        v += max(0.0, plan.expected_latency_s - params.max_latency_s) * params.lambda_usd_per_s
    return v


def plan_workflow(wf: Workflow, params: PlannerParams) -> tuple[Plan, list[Plan]]:
    """Phase 1 entry point.  Returns (best feasible plan, all candidates).
    If no plan is feasible the least-violating plan — smallest USD-priced
    constraint overshoot, objective as tie-break — is returned with
    feasible=False (caller decides whether to proceed)."""
    plans = enumerate_plans(wf, params)
    feasible = [p for p in plans if p.feasible]
    if feasible:
        best = min(feasible,
                   key=lambda p: p.objective(params.alpha, params.lambda_usd_per_s))
    else:
        best = min(plans, key=lambda p: (
            _violation_usd(p, params),
            p.objective(params.alpha, params.lambda_usd_per_s),
        ))
    return best, plans
