"""D2 — Two-rate per-token monetary cost (paper §4).

Every speculation decision is priced in real dollars at *separate* input
and output token rates.  Commercial APIs bill output tokens at 3-8x the
input rate (paper §4.1), so the two-rate form is the distinctive choice;
single-rate reductions (GPU-hour amortization, §4.3) are supported as
pluggable cost models that reduce to the same linear-per-token form.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

__all__ = [
    "PricingEntry",
    "PRICING_MAP",
    "CostModel",
    "TwoRateTokenCost",
    "GpuHourCost",
    "TpuChipHourCost",
    "speculation_cost",
    "register_pricing",
    "get_pricing",
]


@dataclasses.dataclass(frozen=True)
class PricingEntry:
    """Per-(provider, model) billing rates — paper §4.1 data structure."""

    provider: str                  # e.g. "anthropic", "openai"
    model: str                     # e.g. "claude-opus-4-7"
    input_price_per_token: float   # USD per input token
    output_price_per_token: float  # USD per output token

    def __post_init__(self) -> None:
        if self.input_price_per_token < 0 or self.output_price_per_token < 0:
            raise ValueError("token prices must be non-negative")

    @property
    def rate_asymmetry(self) -> float:
        """output/input rate ratio (3-8x for major APIs, paper §4.1)."""
        if self.input_price_per_token == 0:
            return float("inf")
        return self.output_price_per_token / self.input_price_per_token


# Representative 2026 frontier-API prices (USD/token).  The paper's worked
# examples use $3/M input, $15/M output ("typical frontier-API prices",
# §10.1); entries below are the canonical defaults used by examples/tests.
PRICING_MAP: dict[tuple[str, str], PricingEntry] = {}


def register_pricing(entry: PricingEntry) -> PricingEntry:
    PRICING_MAP[(entry.provider, entry.model)] = entry
    return entry


def get_pricing(provider: str, model: str) -> PricingEntry:
    try:
        return PRICING_MAP[(provider, model)]
    except KeyError:
        raise KeyError(
            f"no pricing registered for ({provider!r}, {model!r}); "
            f"known: {sorted(PRICING_MAP)}"
        ) from None


for _e in [
    # canonical worked-example tier (paper §10.1): $3/M in, $15/M out
    PricingEntry("paper", "frontier-default", 3e-6, 15e-6),
    PricingEntry("anthropic", "claude-opus-4-7", 15e-6, 75e-6),
    PricingEntry("anthropic", "claude-sonnet-4-6", 3e-6, 15e-6),
    PricingEntry("anthropic", "claude-haiku-4-5", 1e-6, 5e-6),
    PricingEntry("openai", "gpt-5.2", 10e-6, 40e-6),
    PricingEntry("openai", "gpt-5.2-mini", 1.5e-6, 6e-6),
    PricingEntry("google", "gemini-3-pro", 2.5e-6, 15e-6),
    PricingEntry("mistral", "mistral-large-3", 2e-6, 6e-6),
]:
    register_pricing(_e)


class CostModel(Protocol):
    """Pluggable C_spec model (paper §4.3): must be linear per token."""

    def cost(self, input_tokens: int, output_tokens: float) -> float:
        """USD cost of an operation with the given token counts."""
        ...

    def split(self, input_tokens: int, output_tokens: float) -> tuple[float, float]:
        """(input-side USD, output-side USD) — needed for fractional waste (§9.3)."""
        ...


@dataclasses.dataclass(frozen=True)
class TwoRateTokenCost:
    """The paper's distinctive D2 form: input and output billed separately."""

    input_price: float   # USD / input token
    output_price: float  # USD / output token

    @classmethod
    def from_entry(cls, entry: PricingEntry) -> "TwoRateTokenCost":
        return cls(entry.input_price_per_token, entry.output_price_per_token)

    def cost(self, input_tokens: int, output_tokens: float) -> float:
        c_in, c_out = self.split(input_tokens, output_tokens)
        return c_in + c_out

    def split(self, input_tokens: int, output_tokens: float) -> tuple[float, float]:
        if input_tokens < 0 or output_tokens < 0:
            raise ValueError("token counts must be non-negative")
        return input_tokens * self.input_price, output_tokens * self.output_price


@dataclasses.dataclass(frozen=True)
class GpuHourCost:
    """Paper §4.3 self-hosted form:

        C_spec = (unit_price * num_gpus * output_tokens) / (throughput * utilization)

    Reduces to linear-per-token with a single blended rate, so the decision
    rule is unchanged.  Input tokens are priced at the prefill throughput.
    """

    unit_price_per_hour: float       # USD per GPU-hour
    num_gpus: int
    decode_tokens_per_hour: float    # aggregate decode throughput
    prefill_tokens_per_hour: float   # aggregate prefill throughput
    utilization: float = 1.0

    def __post_init__(self) -> None:
        if not (0 < self.utilization <= 1):
            raise ValueError("utilization must be in (0, 1]")

    @property
    def _out_rate(self) -> float:
        return (self.unit_price_per_hour * self.num_gpus) / (
            self.decode_tokens_per_hour * self.utilization
        )

    @property
    def _in_rate(self) -> float:
        return (self.unit_price_per_hour * self.num_gpus) / (
            self.prefill_tokens_per_hour * self.utilization
        )

    def cost(self, input_tokens: int, output_tokens: float) -> float:
        c_in, c_out = self.split(input_tokens, output_tokens)
        return c_in + c_out

    def split(self, input_tokens: int, output_tokens: float) -> tuple[float, float]:
        return input_tokens * self._in_rate, output_tokens * self._out_rate


@dataclasses.dataclass(frozen=True)
class TpuChipHourCost:
    """TPU-native adaptation of §4.3: chip-hour amortization at per-chip
    $/hr.  Same linear-per-token reduction as GpuHourCost (DESIGN.md §3)."""

    chip_price_per_hour: float
    num_chips: int
    decode_tokens_per_hour: float
    prefill_tokens_per_hour: float
    utilization: float = 1.0

    def _rates(self) -> tuple[float, float]:
        denom_in = self.prefill_tokens_per_hour * self.utilization
        denom_out = self.decode_tokens_per_hour * self.utilization
        scale = self.chip_price_per_hour * self.num_chips
        return scale / denom_in, scale / denom_out

    def cost(self, input_tokens: int, output_tokens: float) -> float:
        c_in, c_out = self.split(input_tokens, output_tokens)
        return c_in + c_out

    def split(self, input_tokens: int, output_tokens: float) -> tuple[float, float]:
        r_in, r_out = self._rates()
        return input_tokens * r_in, output_tokens * r_out


def speculation_cost(
    input_tokens: int,
    output_tokens: float,
    input_price: float,
    output_price: float,
) -> float:
    """C_spec = input_tokens*input_price + output_tokens*output_price (§4.1)."""
    return TwoRateTokenCost(input_price, output_price).cost(input_tokens, output_tokens)
