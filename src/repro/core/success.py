"""§7.4 — the three-tier "speculation useful" success criterion.

    Tier 1: exact match                  i == i_hat
    Tier 2: semantic equivalence         equiv(i, i_hat) per domain predicate
            - text:        normalized-embedding cosine similarity >= 0.95
            - code:        AST equality modulo formatting
            - structured:  semantic_json equality
    Tier 3: downstream-output validation (opt-in, offline)

Default policy is Tier 1 + Tier 2.  The tier-2 embedding must be *cheap*
because it runs on the critical path at commit time (§9.1 / §14.2): here a
deterministic hashed character-n-gram embedding (no model call) serves as
the small-embedder stand-in; deployments plug their own via
``TierPolicy(embed=...)``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import zlib
from typing import Callable, Optional

import numpy as np

__all__ = [
    "SuccessTier",
    "TierPolicy",
    "check_success",
    "hashed_ngram_embedding",
    "cosine_similarity",
    "text_equivalent",
    "code_equivalent",
    "json_equivalent",
]

DEFAULT_SIMILARITY_THRESHOLD = 0.95
_EMBED_DIM = 256


def _normalize_text(s: str) -> str:
    return re.sub(r"\s+", " ", s.strip().lower())


def hashed_ngram_embedding(text: str, dim: int = _EMBED_DIM, n: int = 3) -> np.ndarray:
    """Deterministic, model-free text embedding: hashed character n-grams,
    L2-normalized.  O(len(text)) — cheap enough for the commit-time critical
    path (§14.2 'recommend small tier-2 models')."""
    s = _normalize_text(text)
    vec = np.zeros(dim, dtype=np.float64)
    if not s:
        return vec
    padded = f"^{s}$"
    for i in range(max(1, len(padded) - n + 1)):
        gram = padded[i : i + n].encode("utf-8")
        h = zlib.crc32(gram)  # stable across processes (unlike builtin hash)
        sign = 1.0 if (h >> 16) & 1 else -1.0  # signed hashing kernel
        vec[h % dim] += sign
    norm = np.linalg.norm(vec)
    return vec / norm if norm > 0 else vec


def cosine_similarity(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if na == 0 or nb == 0:
        return 1.0 if na == nb else 0.0
    return float(np.dot(a, b) / (na * nb))


def text_equivalent(
    i: str,
    i_hat: str,
    threshold: float = DEFAULT_SIMILARITY_THRESHOLD,
    embed: Callable[[str], np.ndarray] = hashed_ngram_embedding,
) -> bool:
    """Tier-2 text predicate: normalized-embedding cosine >= threshold."""
    if _normalize_text(i) == _normalize_text(i_hat):
        return True
    return cosine_similarity(embed(i), embed(i_hat)) >= threshold


def code_equivalent(i: str, i_hat: str) -> bool:
    """Tier-2 code predicate: AST equality modulo formatting."""
    try:
        return ast.dump(ast.parse(i)) == ast.dump(ast.parse(i_hat))
    except SyntaxError:
        return False


def _canonical_json(obj: object) -> object:
    if isinstance(obj, dict):
        return {k: _canonical_json(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_canonical_json(v) for v in obj]
    if isinstance(obj, float) and obj.is_integer():
        return int(obj)
    return obj


def json_equivalent(i: object, i_hat: object) -> bool:
    """Tier-2 structured predicate: semantic_json equality (key order,
    int/float coercion, tuple/list coercion are immaterial)."""
    try:
        a = _canonical_json(i if not isinstance(i, str) else json.loads(i))
        b = _canonical_json(i_hat if not isinstance(i_hat, str) else json.loads(i_hat))
    except (json.JSONDecodeError, TypeError):
        return False
    return a == b


class SuccessTier:
    NONE = 0
    TIER1_EXACT = 1
    TIER2_SEMANTIC = 2
    TIER3_DOWNSTREAM = 3


@dataclasses.dataclass
class TierPolicy:
    """Per-dependency success policy.  Default: Tier 1 + Tier 2 (§7.4).

    ``domain`` selects the tier-2 predicate; ``tier3`` is opt-in because it
    requires running the real downstream and comparing post-hoc (fine
    offline, defeats latency online).
    """

    domain: str = "text"  # "text" | "code" | "json" | "custom"
    similarity_threshold: float = DEFAULT_SIMILARITY_THRESHOLD
    embed: Callable[[str], np.ndarray] = hashed_ngram_embedding
    custom_equiv: Optional[Callable[[object, object], bool]] = None
    tier3_validator: Optional[Callable[[object, object], bool]] = None
    enable_tier2: bool = True
    enable_tier3: bool = False

    def tier2(self, i: object, i_hat: object) -> bool:
        if self.custom_equiv is not None:
            return bool(self.custom_equiv(i, i_hat))
        if self.domain == "code":
            return code_equivalent(str(i), str(i_hat))
        if self.domain == "json":
            return json_equivalent(i, i_hat)
        return text_equivalent(
            str(i), str(i_hat), self.similarity_threshold, self.embed
        )


@dataclasses.dataclass(frozen=True)
class SuccessResult:
    success: bool
    tier: int                      # SuccessTier.* of the first tier that passed
    tier1_match: bool
    tier2_match: Optional[bool]    # None when tier-2 disabled or short-circuited
    tier3_accept: Optional[bool]   # None unless tier-3 opted in


def check_success(
    i: object,
    i_hat: object,
    policy: TierPolicy | None = None,
    *,
    downstream_output_from_i_hat: object = None,
) -> SuccessResult:
    """Label one speculation trial per §7.4.  ``success`` feeds the D5
    posterior as one Bernoulli observation."""
    policy = policy or TierPolicy()
    tier1 = i == i_hat
    if tier1:
        return SuccessResult(True, SuccessTier.TIER1_EXACT, True, None, None)
    tier2: Optional[bool] = None
    if policy.enable_tier2:
        tier2 = policy.tier2(i, i_hat)
        if tier2:
            return SuccessResult(True, SuccessTier.TIER2_SEMANTIC, False, True, None)
    tier3: Optional[bool] = None
    if policy.enable_tier3 and policy.tier3_validator is not None:
        tier3 = bool(policy.tier3_validator(i, downstream_output_from_i_hat))
        if tier3:
            return SuccessResult(True, SuccessTier.TIER3_DOWNSTREAM, False, tier2, True)
    return SuccessResult(False, SuccessTier.NONE, False, tier2, tier3)
