"""§12 — the five-stage calibration and evaluation pipeline.

Staged in order of increasing exposure:

  1. offline replay   — touches no production traffic
  2. shadow mode      — serves a decision but discards it
  3. canary           — live fraction + alpha sweep + implied-lambda recovery
  4. online           — steady-state continuous checks
  5. drift kill-switch — repro.core.drift (flips the enable bit)

Every §12 knob (dependency-type tag, p_structural, n0, alpha, lambda,
tier-2 threshold, token estimators, per-edge enable bit, credible gamma) is
set or kept honest by one of these stages (§12.6 knob-to-stage map).

Stages 2–4 also exist as table-batched twins over the online decision
service's posterior snapshot (``repro.core.online.shadow_mode_batch`` /
``canary_batch`` / ``online_calibration_batch``): one calibration round
for a whole fleet of edges as array ops, matching these scalar stages
bitwise at f64 (posteriors, implied lambdas, rates) and exactly
(promotion / trigger flags).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Callable, Optional, Sequence

import numpy as np
from jax.experimental import enable_x64

from .decision import Decision, decision_threshold, expected_value, implied_lambda
from .posterior import BetaPosterior
from .predictor import InputPredictor
from .success import TierPolicy, check_success
from .taxonomy import DependencyType, auto_assign, effective_k
from .telemetry import SpeculationDecision, TelemetryLog

__all__ = [
    "SequentialLogRecord",
    "OfflineReplayReport",
    "offline_replay",
    "offline_replay_multi_tenant",
    "ShadowReport",
    "shadow_mode",
    "CanaryReport",
    "canary",
    "OnlineReport",
    "online_calibration",
    "TokenEstimator",
    "seed_store_from_replay",
]


# ---------------------------------------------------------------------------
# Stage 1: offline replay on sequential logs (§12.1)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SequentialLogRecord:
    """One logged tuple from a strictly-sequential deployment (§12.1)."""

    upstream_input: Any
    upstream_output: Any
    downstream_input: Any
    downstream_output: Any
    latency_s: float          # downstream latency (the reclaimable wait)
    cost_usd: float           # realized downstream cost
    tenant: str = "default"
    input_tokens: int = 500
    output_tokens: int = 1000


@dataclasses.dataclass
class GridPoint:
    alpha: float
    lambda_usd_per_s: float
    speculate_fraction: float
    expected_latency_s: float
    expected_cost_usd: float
    expected_waste_usd: float


@dataclasses.dataclass
class OfflineReplayReport:
    edge: tuple[str, str]
    k_raw: int
    p_mode: float
    k_eff: float
    dep_type: DependencyType
    seeded_prior: BetaPosterior
    predictor_match_rates: dict[str, float]
    grid: list[GridPoint]
    go: bool                  # per-edge go/no-go before any dollar of waste
    default_alpha: float


def _memoized_predictions(
    pred: InputPredictor, logs: Sequence[SequentialLogRecord]
) -> list:
    """One prediction per *distinct* upstream input.

    Production logs repeat inputs heavily (the AutoReply corpus is one
    prompt template over and over), and predictors can be arbitrarily
    expensive Python — so the replay memoizes ``pred.predict`` per input
    value instead of re-calling it per record.  Unhashable inputs fall
    back to a direct call.
    """
    cache: dict = {}
    out = []
    for r in logs:
        key = r.upstream_input
        try:
            hit = key in cache
        except TypeError:            # unhashable input: no memoization
            out.append(pred.predict(key))
            continue
        if not hit:
            cache[key] = pred.predict(key)
        out.append(cache[key])
    return out


def _seed_from_logs(
    logs: Sequence[SequentialLogRecord],
    predictors: dict[str, InputPredictor],
    tier_policy: TierPolicy,
):
    """§12.1 bootstrap: effective k, dependency type, per-predictor match
    rates and the data-seeded prior from the best predictor's (s, f)."""
    outputs = [r.upstream_output for r in logs]
    ek = effective_k(outputs)
    dep_type = auto_assign(outputs)

    match_rates: dict[str, float] = {}
    best_sf: tuple[int, int] = (0, len(logs))
    best_rate = -1.0
    for pname, pred in predictors.items():
        s = f = 0
        for r, p in zip(logs, _memoized_predictions(pred, logs)):
            if p is None:
                f += 1
                continue
            ok = check_success(r.upstream_output, p.i_hat, tier_policy).success
            s, f = s + int(ok), f + int(not ok)
        rate = s / max(1, s + f)
        match_rates[pname] = rate
        if rate > best_rate:
            best_rate, best_sf = rate, (s, f)
    seeded = BetaPosterior.data_seeded(dep_type, *best_sf, k=max(2, ek.k_raw))
    return ek, dep_type, match_rates, seeded


def _grid_points(g: dict, t: Optional[int], alphas, lambdas) -> list[GridPoint]:
    """Unpack a ``counterfactual_grid``(-``_tenants``) result dict into the
    row-major (alpha, lambda) GridPoint list the report carries."""
    sel = (lambda arr, i, j: arr[i, j]) if t is None else (
        lambda arr, i, j: arr[t, i, j])
    return [
        GridPoint(
            a, lam,
            float(sel(g["speculate_fraction"], i, j)),
            float(sel(g["expected_latency_s"], i, j)),
            float(sel(g["expected_cost_usd"], i, j)),
            float(sel(g["expected_waste_usd"], i, j)),
        )
        for i, a in enumerate(alphas)
        for j, lam in enumerate(lambdas)
    ]


def _go_and_default(
    grid: list[GridPoint], go_min_speculate_fraction: float
) -> tuple[bool, float]:
    # go/no-go: does any balanced-or-lower grid point speculate usefully?
    balanced = [g for g in grid if g.alpha <= 0.5]
    go = any(g.speculate_fraction >= go_min_speculate_fraction for g in balanced)
    # deployment default alpha: smallest alpha whose grid point speculates on
    # a majority of rows (cost-conservative default)
    default_alpha = next(
        (g.alpha for g in sorted(grid, key=lambda g: g.alpha)
         if g.speculate_fraction >= go_min_speculate_fraction),
        0.0,
    )
    return go, default_alpha


def offline_replay(
    edge: tuple[str, str],
    logs: Sequence[SequentialLogRecord],
    predictors: dict[str, InputPredictor],
    *,
    tier_policy: TierPolicy | None = None,
    alphas: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0),
    lambdas: Sequence[float] = (0.005, 0.01, 0.05, 0.1),
    rho: float = 0.5,
    go_min_speculate_fraction: float = 0.5,
    shard_threshold: int = 1 << 17,
    mesh=None,
) -> OfflineReplayReport:
    """§12.1: everything bootstrappable from sequential logs before any
    speculation is enabled.

    The counterfactual EV grid runs through the jit'd batch engine
    (``batch_decision.counterfactual_grid_tenants``, one XLA call for the
    whole (alpha, lambda) cross product) under float64, matching the
    historical per-cell Python loop to f64 rounding; predictor match
    rates memoize ``pred.predict`` per distinct upstream input.

    Million-row logs — the scale the episode-sharded fleet engine
    targets — reroute through the *log-axis-sharded* grid when
    ``len(logs)`` exceeds ``shard_threshold``:
    ``batch_decision.counterfactual_grid_sharded`` splits the rows into
    contiguous segments (``shard_map``'d over ``mesh`` when given, e.g.
    ``repro.launch.mesh.make_fleet_mesh()``), so one tenant's replay no
    longer funnels every row through a single device.  Decision
    fractions stay bitwise-identical to the unsharded
    ``counterfactual_grid`` (exact integer counts, one division);
    latency / waste expectations move only by float summation order
    (<= ~1e-15 relative).
    """
    if not logs:
        raise ValueError("offline replay requires at least one log record")
    tier_policy = tier_policy or TierPolicy()
    ek, dep_type, match_rates, seeded = _seed_from_logs(
        logs, predictors, tier_policy)

    n = len(logs)
    if n > shard_threshold:
        # episode-scale logs: segment the row axis across the fleet mesh
        from .batch_decision import counterfactual_grid_sharded

        lat = np.array([r.latency_s for r in logs])
        cost = np.array([r.cost_usd for r in logs])
        with enable_x64():
            g = counterfactual_grid_sharded(
                seeded.mean, lat, cost,
                np.asarray(alphas, float), np.asarray(lambdas, float),
                rho=rho, mesh=mesh,
            )
        grid = _grid_points(g, None, alphas, lambdas)
        go, default_alpha = _go_and_default(grid, go_min_speculate_fraction)
        return OfflineReplayReport(
            edge=edge, k_raw=ek.k_raw, p_mode=ek.p_mode, k_eff=ek.k_eff,
            dep_type=dep_type, seeded_prior=seeded,
            predictor_match_rates=match_rates, grid=grid, go=go,
            default_alpha=default_alpha,
        )

    # counterfactual EV grid (§12.1): replay D4 at each (alpha, lambda).
    # The log axis is padded to a power-of-two bucket under the masked
    # tenant kernel — padded rows contribute an exact 0.0 to every sum,
    # so results are bitwise-identical to the unpadded call, and a sweep
    # over hundreds of ragged per-edge log lists compiles one executable
    # per bucket instead of one per distinct log count.
    from .batch_decision import counterfactual_grid_tenants

    n_pad = max(16, 1 << (n - 1).bit_length())
    lat = np.zeros(n_pad)
    cost = np.zeros(n_pad)
    mask = np.zeros(n_pad, bool)
    lat[:n] = [r.latency_s for r in logs]
    cost[:n] = [r.cost_usd for r in logs]
    mask[:n] = True
    with enable_x64():
        g = counterfactual_grid_tenants(
            seeded.mean, lat[None], cost[None], mask[None],
            np.asarray(alphas, float), np.asarray(lambdas, float), rho=rho,
        )
    grid = _grid_points(g, 0, alphas, lambdas)
    go, default_alpha = _go_and_default(grid, go_min_speculate_fraction)
    return OfflineReplayReport(
        edge=edge,
        k_raw=ek.k_raw,
        p_mode=ek.p_mode,
        k_eff=ek.k_eff,
        dep_type=dep_type,
        seeded_prior=seeded,
        predictor_match_rates=match_rates,
        grid=grid,
        go=go,
        default_alpha=default_alpha,
    )


def offline_replay_multi_tenant(
    edge: tuple[str, str],
    logs: Sequence[SequentialLogRecord],
    predictors: dict[str, InputPredictor],
    *,
    tier_policy: TierPolicy | None = None,
    alphas: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0),
    lambdas: Sequence[float] = (0.005, 0.01, 0.05, 0.1),
    rho: float = 0.5,
    go_min_speculate_fraction: float = 0.5,
) -> dict[str, OfflineReplayReport]:
    """Fleet-backed §12.1: one report per tenant, one XLA grid call total.

    Records are grouped by ``SequentialLogRecord.tenant``; each tenant
    gets its own effective-k / dependency-type / data-seeded prior
    bootstrap (cheap, scalar-side), then every tenant's counterfactual EV
    grid is computed in a single jit'd
    ``batch_decision.counterfactual_grid_tenants`` call over the padded
    ``tenants x logs`` batch — the same move the multi-tenant replay
    engine makes for Phase-2 sweeps.  Per-tenant reports agree with
    running :func:`offline_replay` on each tenant's slice to f64 rounding.
    """
    if not logs:
        raise ValueError("offline replay requires at least one log record")
    tier_policy = tier_policy or TierPolicy()
    groups: dict[str, list[SequentialLogRecord]] = {}
    for r in logs:
        groups.setdefault(r.tenant, []).append(r)
    tenants = sorted(groups)

    seeds = {t: _seed_from_logs(groups[t], predictors, tier_policy)
             for t in tenants}

    from .batch_decision import counterfactual_grid_tenants

    n_max = max(len(groups[t]) for t in tenants)
    n_max = max(16, 1 << (n_max - 1).bit_length())  # bucket, as above
    T = len(tenants)
    P = np.array([seeds[t][3].mean for t in tenants])
    lat = np.zeros((T, n_max))
    cost = np.zeros((T, n_max))
    mask = np.zeros((T, n_max), bool)
    for i, t in enumerate(tenants):
        rows = groups[t]
        lat[i, : len(rows)] = [r.latency_s for r in rows]
        cost[i, : len(rows)] = [r.cost_usd for r in rows]
        mask[i, : len(rows)] = True
    with enable_x64():
        g = counterfactual_grid_tenants(
            P, lat, cost, mask,
            np.asarray(alphas, float), np.asarray(lambdas, float), rho=rho,
        )

    reports = {}
    for i, t in enumerate(tenants):
        ek, dep_type, match_rates, seeded = seeds[t]
        grid = _grid_points(g, i, alphas, lambdas)
        go, default_alpha = _go_and_default(grid, go_min_speculate_fraction)
        reports[t] = OfflineReplayReport(
            edge=edge,
            k_raw=ek.k_raw,
            p_mode=ek.p_mode,
            k_eff=ek.k_eff,
            dep_type=dep_type,
            seeded_prior=seeded,
            predictor_match_rates=match_rates,
            grid=grid,
            go=go,
            default_alpha=default_alpha,
        )
    return reports


# ---------------------------------------------------------------------------
# Stage 2: shadow mode (§12.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TokenEstimator:
    """§4.2 EMA over historical output lengths, alpha_EMA = 0.2 default,
    plus the CoV-based uncertain_cost flag (§12.2/§12.4)."""

    ema: float = 0.0
    decay: float = 0.2
    n: int = 0
    history: list = dataclasses.field(default_factory=list)
    cov_threshold: float = 0.5

    def observe(self, output_tokens: float) -> float:
        self.history.append(output_tokens)
        self.ema = output_tokens if self.n == 0 else (
            self.decay * output_tokens + (1.0 - self.decay) * self.ema
        )
        self.n += 1
        return self.ema

    @property
    def cov(self) -> Optional[float]:
        if self.n < 2:
            return None
        m = statistics.fmean(self.history)
        return statistics.stdev(self.history) / m if m > 0 else None

    @property
    def uncertain_cost(self) -> bool:
        c = self.cov
        return c is not None and c > self.cov_threshold

    def estimate(self, sigma_ceiling: bool = False) -> float:
        """Point estimate; with sigma_ceiling, the §4.2 fixed-ceiling policy
        (estimated + 2*sigma)."""
        if sigma_ceiling and self.n >= 2:
            return self.ema + 2.0 * statistics.stdev(self.history)
        return self.ema


@dataclasses.dataclass
class ShadowReport:
    edge: tuple[str, str]
    trials: int
    posterior: BetaPosterior
    converged: bool
    best_tier2_threshold: float
    tier2_f1: float
    token_estimator: TokenEstimator
    rho_mean: float


def _stability_converged(means: Sequence[float], window: int, tol: float) -> bool:
    """§12.2 posterior-stability check: the trailing ``window`` means move
    by at most ``tol``.  Shared by the scalar stage and the table-batched
    twin (``repro.core.online.shadow_mode_batch``) so the two can never
    drift apart."""
    return (
        len(means) >= window
        and max(means[-window:]) - min(means[-window:]) <= tol
    )


def _tier2_threshold_sweep(
    graded_subset: Sequence[tuple[Any, Any, bool]],
    thresholds: Sequence[float],
) -> tuple[float, float]:
    """Tier-2 threshold grid sweep: maximize F1 against the human-graded
    subset (first strict improvement wins, matching the historical loop).
    Shared with ``repro.core.online.shadow_mode_batch``."""
    best_thr, best_f1 = 0.95, -1.0
    for thr in thresholds:
        tp = fp = fn = 0
        for i, i_hat, label in graded_subset:
            pred = check_success(i, i_hat, TierPolicy(similarity_threshold=thr)).success
            tp += int(pred and label)
            fp += int(pred and not label)
            fn += int((not pred) and label)
        denom = 2 * tp + fp + fn
        f1 = (2 * tp / denom) if denom else 0.0
        if f1 > best_f1:
            best_f1, best_thr = f1, thr
    return best_thr, best_f1


def shadow_mode(
    edge: tuple[str, str],
    posterior: BetaPosterior,
    trials: Sequence[tuple[Any, Any]],          # (i_actual, i_hat) per shadow trial
    *,
    graded_subset: Sequence[tuple[Any, Any, bool]] = (),  # (i, i_hat, human_label)
    thresholds: Sequence[float] = (0.80, 0.85, 0.90, 0.95, 0.99),
    output_token_counts: Sequence[float] = (),
    cancel_fractions: Sequence[float] = (),
    n_shadow: int = 100,
    stability_window: int = 50,
    stability_tol: float = 0.05,
) -> ShadowReport:
    """§12.2: speculative decisions served and discarded; posterior, tier-2
    threshold, token estimators, and rho tuned with zero user exposure.

    Zero exposure includes the *live* posterior: the caller's object is
    never mutated — shadow trials accumulate on an internal copy
    (returned as ``ShadowReport.posterior``), and the live belief only
    moves when the operator promotes the shadow result at stage
    boundaries (§12.6).  Previously the passed-in posterior was updated
    in place, which let a shadow run bleed into production gating.
    """
    posterior = posterior.copy()
    means: list[float] = []
    policy = TierPolicy()
    for i_actual, i_hat in trials:
        ok = check_success(i_actual, i_hat, policy).success
        posterior.update(ok)
        means.append(posterior.mean)

    converged = len(trials) >= n_shadow and _stability_converged(
        means, stability_window, stability_tol)

    # tier-2 threshold grid sweep: maximize F1 against the human-graded subset
    best_thr, best_f1 = _tier2_threshold_sweep(graded_subset, thresholds)

    est = TokenEstimator()
    for t in output_token_counts:
        est.observe(t)
    rho_mean = statistics.fmean(cancel_fractions) if cancel_fractions else 0.5
    return ShadowReport(
        edge=edge,
        trials=len(trials),
        posterior=posterior,
        converged=converged,
        best_tier2_threshold=best_thr,
        tier2_f1=max(best_f1, 0.0),
        token_estimator=est,
        rho_mean=rho_mean,
    )


# ---------------------------------------------------------------------------
# Stage 3: canary with alpha sweep + implied-lambda recovery (§12.3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CanaryArm:
    name: str
    alpha: Optional[float]
    latency_s: float
    cost_usd: float
    waste_usd_per_hr: float = 0.0


@dataclasses.dataclass
class CanaryReport:
    arms: list[CanaryArm]
    pareto_alphas: list[float]
    lambda_implied: float
    lambda_declared: float
    audit: str                 # "refresh_lambda" | "consistent" | "inspect_declared"
    promote: bool              # go/no-go to full rollout


def _canary_sweep_eval(
    sweep: dict[float, tuple[float, float]],
    chosen_alpha: float,
    control_latency_s: float,
    control_cost_usd: float,
    budget_guardrail_usd: Optional[float],
) -> tuple[list[CanaryArm], list[float], bool]:
    """Arms list, Pareto frontier and promote verdict for one edge's
    canary sweep — shared by the scalar stage and the table-batched
    ``repro.core.online.canary_batch`` so the two can never drift."""
    arms = [CanaryArm("control", None, control_latency_s, control_cost_usd)]
    for a, (lat, cost) in sorted(sweep.items()):
        arms.append(CanaryArm(f"alpha={a}", a, lat, cost))

    # Pareto frontier over the sweep arms
    pts = sorted((lat, cost, a) for a, (lat, cost) in sweep.items())
    pareto: list[float] = []
    best_cost = float("inf")
    for lat, cost, a in pts:
        if cost < best_cost - 1e-12:
            pareto.append(a)
            best_cost = cost

    chosen = sweep.get(chosen_alpha)
    promote = False
    if chosen is not None:
        lat_ok = chosen[0] <= control_latency_s
        budget_ok = budget_guardrail_usd is None or chosen[1] <= budget_guardrail_usd
        # Pareto-dominates sequential: no worse on both, better on one
        dominates = (
            chosen[0] <= control_latency_s and chosen[1] <= control_cost_usd
            and (chosen[0] < control_latency_s or chosen[1] < control_cost_usd)
        ) or (lat_ok and budget_ok)
        promote = bool(lat_ok and budget_ok and dominates)
    return arms, pareto, promote


def canary(
    control_latency_s: float,
    control_cost_usd: float,
    sweep: dict[float, tuple[float, float]],     # alpha -> (latency, cost)
    chosen_alpha: float,
    P: float,
    C_spec: float,
    L_upstream_s: float,
    lambda_declared: float,
    *,
    budget_guardrail_usd: Optional[float] = None,
    consistency_band: float = 0.5,
) -> CanaryReport:
    """§12.3: percentage rollout with a held-out sequential control, the
    alpha sweep tracing the (latency, cost) Pareto frontier, and the
    implied-lambda audit at the chosen operating point."""
    arms, pareto, promote = _canary_sweep_eval(
        sweep, chosen_alpha, control_latency_s, control_cost_usd,
        budget_guardrail_usd)

    lam_imp = implied_lambda(P, C_spec, chosen_alpha, L_upstream_s)
    ratio = lam_imp / lambda_declared if lambda_declared > 0 else float("inf")
    if ratio > 1.0 + consistency_band:
        audit = "refresh_lambda"          # operators value latency MORE than priced
    elif ratio < 1.0 - consistency_band:
        audit = "inspect_declared"        # declared lambda over-values latency
    else:
        audit = "consistent"

    return CanaryReport(
        arms=arms,
        pareto_alphas=pareto,
        lambda_implied=lam_imp,
        lambda_declared=lambda_declared,
        audit=audit,
        promote=promote,
    )


# ---------------------------------------------------------------------------
# Stage 4: online calibration (§12.4)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CalibrationBucket:
    midpoint: float
    empirical_rate: float
    n: int
    within_ci: bool


@dataclasses.dataclass
class OnlineReport:
    buckets: list[CalibrationBucket]
    monotonic_overprediction: bool
    tier2_false_accept_rate: Optional[float]
    tier2_needs_tightening: bool
    token_cov: Optional[float]
    uncertain_cost: bool
    lambda_refresh_due: bool


def _calibration_bucket(
    mid: float, rate: float, n: int, bucket_width: float
) -> tuple[CalibrationBucket, bool]:
    """One §12.4 calibration bucket with its binomial-CI verdicts —
    shared by the scalar stage and the table-batched
    ``repro.core.online.online_calibration_batch``.  Returns
    (bucket, overpredicted)."""
    # binomial 95% CI half-width
    half = 1.96 * np.sqrt(max(rate * (1 - rate), 1e-9) / n) if n else 1.0
    within = abs(rate - mid) <= max(half, bucket_width / 2)
    return CalibrationBucket(mid, rate, n, within), rate < mid - half


def online_calibration(
    log: TelemetryLog,
    *,
    bucket_width: float = 0.1,
    tier2_tolerance: float = 0.05,
    cov_threshold: float = 0.5,
    quarters_since_lambda_refresh: int = 0,
) -> OnlineReport:
    """§12.4 four continuous checks, all derived from telemetry rows alone."""
    raw = log.calibration_buckets(bucket_width)
    buckets = []
    overpredicted = []
    for mid, (rate, n) in raw.items():
        bucket, over = _calibration_bucket(mid, rate, n, bucket_width)
        buckets.append(bucket)
        overpredicted.append(over)
    monotonic_over = len(overpredicted) >= 2 and all(overpredicted)

    far = log.tier2_false_accept_rate()
    cov = log.token_estimate_cov()
    return OnlineReport(
        buckets=buckets,
        monotonic_overprediction=monotonic_over,
        tier2_false_accept_rate=far,
        tier2_needs_tightening=far is not None and far > tier2_tolerance,
        token_cov=cov,
        uncertain_cost=cov is not None and cov > cov_threshold,
        lambda_refresh_due=quarters_since_lambda_refresh >= 1,
    )


# ---------------------------------------------------------------------------
# Fleet-replay -> posterior-store bridge (§12.1 deployment seeding at scale)
# ---------------------------------------------------------------------------
def seed_store_from_replay(
    store,
    report,
    grid_index: int = 0,
    **register_kwargs,
) -> list[int]:
    """Load one grid cell of a ``MultiTenantReport`` (the fleet replay
    engine's output) into a ``repro.core.store.PosteriorStore`` — the
    §12.1 "deploy with data-seeded priors" step, fleet-wide.

    Every (tenant, edge) row the replay produced is upserted: unknown
    keys register data-seeded from their final replay posterior (so the
    store's free-list / paging machinery owns them from birth), known
    keys get their alpha/beta overwritten in one batched scatter.
    Returns the logical row id per replay row, aligned with
    ``report.final_posterior_rows(grid_index)``.  Extra keyword
    arguments (``gamma=``, ``discount=``, ``floor_*=``...) pass through
    to ``PosteriorStore.register`` for the newly-created rows.
    """
    tenant_edges, alpha, beta = report.final_posterior_rows(grid_index)
    return store.adopt_posteriors(tenant_edges, alpha, beta,
                                  **register_kwargs)
