"""Appendix C — the per-decision telemetry schema.

Every calibration/evaluation stage of §12 consumes the same per-decision
row; without it, none of the stages run.  §C.2 requires that *every*
calibration signal be derivable from rows alone — the derivations live
here and are exercised by tests.

Field count note: the Appendix C listing has 32 named fields;
``committed_speculative`` is referenced by the §C.2 derivations (tier-2
false-accept rate, waste-per-failure) and counted by D.4's "33 fields", so
it is included explicitly.
"""
from __future__ import annotations

import dataclasses
import json
import uuid
from collections import defaultdict
from typing import Literal, Optional

from .decision import implied_lambda

__all__ = [
    "SpeculationDecision",
    "TelemetryLog",
    "bucket_key",
    "new_decision_id",
    "RESILIENCE_KINDS",
    "ResilienceEvent",
    "ResilienceLog",
]


def new_decision_id() -> str:
    return str(uuid.uuid4())


def bucket_key(p_mean: float, width: float) -> float:
    """§12.4 calibration-bucket key for one predicted P: fp-robust floor
    to a bucket index, rounded midpoint, capped at the last bucket.
    Shared by :meth:`TelemetryLog.calibration_buckets` and the batched
    ``repro.core.online.online_calibration_batch`` so the two bucketings
    can never diverge."""
    mid = (int(p_mean / width + 1e-9) + 0.5) * width
    return round(min(mid, 1.0 - width / 2), 6)


@dataclasses.dataclass
class SpeculationDecision:
    """One per-decision row (Appendix C.1), emitted at decision time and
    filled in when the upstream completes."""

    # identity
    decision_id: str                       # UUID, unique per candidate edge event
    trace_id: str                          # workflow execution id
    edge: tuple[str, str]                  # (upstream agent, downstream agent)
    dep_type: Literal[
        "always_produces_output",
        "list_output_variable_length",
        "conditional_output",
        "router_k_way",
        "rare_event_trigger",
    ]
    tenant: str                            # per-tenant posteriors require this key
    model_version: tuple[str, str]         # (agent, version) for drift re-tag

    # decision inputs (at evaluation time)
    alpha: float                           # in [0, 1]
    lambda_usd_per_s: float
    P_mean: float                          # Beta posterior mean
    P_lower_bound: Optional[float]         # gamma-credible lower bound, if gating
    C_spec_est_usd: float
    L_est_s: float                         # estimated latency savings on success
    input_tokens_est: int
    output_tokens_est: int
    input_price: float                     # USD/token
    output_price: float                    # USD/token

    # decision outputs
    EV_usd: float
    threshold_usd: float
    decision: Literal["SPECULATE", "WAIT"]
    phase: Literal["plan", "runtime"]      # §8 two-phase model
    overrode: Literal["none", "upgrade", "downgrade"]
    i_hat_source: Literal["modal", "regex", "historical", "stream_k", "auxiliary_model"]

    # guardrails / audit (set at decision time)
    uncertain_cost_flag: bool              # set by §12.4 EMA monitor
    enabled: bool                          # §12.5 kill-switch state at decision time
    budget_remaining_usd: Optional[float]  # for cost SLO triggers

    # realized outcomes (filled after upstream completes; default None)
    i_actual: Optional[object] = None      # full upstream output for replay
    tier1_match: Optional[bool] = None
    tier2_match: Optional[bool] = None
    tier3_accept: Optional[bool] = None    # filled offline, sampled (§12.4)
    C_spec_actual_usd: Optional[float] = None   # §9.3 fractional waste
    tokens_generated_before_cancel: Optional[int] = None
    latency_actual_s: Optional[float] = None
    committed_speculative: Optional[bool] = None  # §C.2 derivations key off this

    # --------------------------------------------------------------- helpers
    @property
    def success(self) -> Optional[bool]:
        """tier1 v tier2 — the Bernoulli label for the D5 posterior (§C.2)."""
        if self.tier1_match is None and self.tier2_match is None:
            return None
        return bool(self.tier1_match) or bool(self.tier2_match)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["edge"] = list(self.edge)
        d["model_version"] = list(self.model_version)
        if not _json_safe(d.get("i_actual")):
            d["i_actual"] = repr(d["i_actual"])
        return json.dumps(d)

    @classmethod
    def from_json(cls, s: str) -> "SpeculationDecision":
        d = json.loads(s)
        d["edge"] = tuple(d["edge"])
        d["model_version"] = tuple(d["model_version"])
        return cls(**d)


def _json_safe(o: object) -> bool:
    try:
        json.dumps(o)
        return True
    except (TypeError, ValueError):
        return False


class TelemetryLog:
    """An append-only in-memory/file-backed log of SpeculationDecision rows
    plus the §C.2 signal derivations.  Rows are < 1 KB serialized (§C.3)."""

    def __init__(self) -> None:
        self.rows: list[SpeculationDecision] = []

    def emit(self, row: SpeculationDecision) -> SpeculationDecision:
        self.rows.append(row)
        return row

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    # ------------------------------------------------------------ persistence
    def save_jsonl(self, path: str) -> int:
        with open(path, "w") as fh:
            for r in self.rows:
                fh.write(r.to_json() + "\n")
        return len(self.rows)

    @classmethod
    def load_jsonl(cls, path: str) -> "TelemetryLog":
        log = cls()
        with open(path) as fh:
            for line in fh:
                if line.strip():
                    log.rows.append(SpeculationDecision.from_json(line))
        return log

    # ------------------------------------------------- §C.2 signal derivations
    def posterior_counts(self) -> dict[tuple[str, str], tuple[int, int]]:
        """(s, f) per edge: (s,f) += (tier1 v tier2, not(tier1 v tier2))."""
        out: dict[tuple[str, str], list[int]] = defaultdict(lambda: [0, 0])
        for r in self.rows:
            ok = r.success
            if ok is None:
                continue
            out[r.edge][0 if ok else 1] += 1
        return {k: (v[0], v[1]) for k, v in out.items()}

    def effective_k(self) -> dict[tuple[tuple[str, str], str], float]:
        """k_eff per (edge, tenant) from the empirical i_actual distribution."""
        from .taxonomy import effective_k as _ek

        buckets: dict[tuple[tuple[str, str], str], list[object]] = defaultdict(list)
        for r in self.rows:
            if r.i_actual is not None:
                buckets[(r.edge, r.tenant)].append(r.i_actual)
        return {k: _ek(v).k_eff for k, v in buckets.items()}

    def tier2_false_accept_rate(self) -> Optional[float]:
        """fraction of committed_speculative ∧ ¬tier3_accept over sampled rows."""
        sampled = [
            r for r in self.rows
            if r.committed_speculative and r.tier3_accept is not None
        ]
        if not sampled:
            return None
        return sum(1 for r in sampled if not r.tier3_accept) / len(sampled)

    def token_estimate_cov(self) -> Optional[float]:
        """std(actual/est) over rows with realized token counts (§12.4).

        On full-completion rows tokens_generated_before_cancel equals the
        actual output count.
        """
        import numpy as np

        ratios = [
            r.tokens_generated_before_cancel / r.output_tokens_est
            for r in self.rows
            if r.tokens_generated_before_cancel is not None and r.output_tokens_est > 0
        ]
        if len(ratios) < 2:
            return None
        return float(np.std(ratios, ddof=1))

    def implied_lambdas(self) -> list[float]:
        """§12.3 implied-λ per SPECULATE row at its observed alpha*."""
        out = []
        for r in self.rows:
            if r.decision != "SPECULATE" or r.P_mean <= 0 or r.L_est_s <= 0:
                continue
            out.append(implied_lambda(r.P_mean, r.C_spec_est_usd, r.alpha, r.L_est_s))
        return out

    def waste_per_failed_speculation(self) -> list[float]:
        """C_spec_actual_usd when not committed (§9.3 realized waste)."""
        return [
            r.C_spec_actual_usd
            for r in self.rows
            if r.committed_speculative is False and r.C_spec_actual_usd is not None
        ]

    def cost_slo_burn(self) -> float:
        """Σ C_spec_actual_usd over the log window."""
        return sum(r.C_spec_actual_usd or 0.0 for r in self.rows)

    def posterior_mean_series(self, edge: tuple[str, str]) -> list[float]:
        """per-edge P_mean over time, for §12.5 drift triggers."""
        return [r.P_mean for r in self.rows if r.edge == edge]

    def calibration_buckets(self, width: float = 0.1) -> dict[float, tuple[float, int]]:
        """§12.4 posterior calibration curve: bucket by predicted P, return
        {bucket_midpoint: (empirical success rate, n)}."""
        buckets: dict[float, list[bool]] = defaultdict(list)
        for r in self.rows:
            ok = r.success
            if ok is None:
                continue
            buckets[bucket_key(r.P_mean, width)].append(ok)
        return {
            mid: (sum(v) / len(v), len(v)) for mid, v in sorted(buckets.items())
        }


# ---------------------------------------------------------------------------
# Resilience events — the serving front-end's degradation trail.
#
# The paper's §12 safety story (staged rollout, drift kill-switch) stops at
# *whether* to speculate; production also needs *how the system degraded*:
# every bulkhead shed, circuit-breaker transition, fallback-chain hop and
# provider timeout is one event here, USD-attributed so the cost of running
# degraded is a first-class, exportable number next to the per-decision
# rows above.  The device-side twin is the online service's telemetry ring
# (``repro.core.online`` appends the same kinds as encoded ring rows).
# ---------------------------------------------------------------------------
RESILIENCE_KINDS = (
    "shed",                   # bulkhead/admission rejected, answered WAIT
    "breaker_open",           # circuit opened (consecutive faults / trip)
    "breaker_half_open",      # cooldown elapsed, probe admitted
    "breaker_close",          # probe succeeded, circuit closed
    "fallback_scalar",        # answered by host-side decision.evaluate
    "fallback_conservative",  # answered by the terminal no-speculate stage
    "timeout",                # service tick / provider call timed out
    "exception",              # service tick / provider call raised
    "drift_trip",             # in-graph kill-switch breach folded into breaker
    # staged-rollout lifecycle transitions (repro.core.rollout) — appended,
    # never reordered: the device event ring encodes kinds positionally
    "rollout_promote",        # SHADOW→CANARY→ONLINE_CAL→FULL advance
    "rollout_demote",         # breach/tier-2 demotion (→ SHADOW or DISABLED)
    "rollout_reenter",        # cooldown expired, bounded probe window opened
    "rollout_probe_fail",     # probe window exhausted without promotion
)


@dataclasses.dataclass(frozen=True)
class ResilienceEvent:
    """One degradation event, attributed in dollars.

    ``usd`` is the money *at stake* for the event, not necessarily money
    spent: for sheds it is the latency value foregone (L·λ), for fallback
    and breaker events the speculative cost C_spec the degraded path was
    protecting.  Summing per (tenant, kind) prices the degraded modes.
    """

    kind: str
    tenant: Optional[str] = None
    edge: Optional[tuple[str, str]] = None
    row: Optional[int] = None
    usd: float = 0.0
    detail: str = ""

    def __post_init__(self) -> None:
        if self.kind not in RESILIENCE_KINDS:
            raise ValueError(f"unknown resilience kind: {self.kind!r}")

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        if self.edge is not None:
            d["edge"] = list(self.edge)
        return json.dumps(d)


class ResilienceLog:
    """Append-only host-side log of ResilienceEvent rows plus the USD
    cost-attribution export the serving front-end publishes."""

    def __init__(self) -> None:
        self.events: list[ResilienceEvent] = []

    def emit(self, event: ResilienceEvent) -> ResilienceEvent:
        self.events.append(event)
        return event

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def by_kind(self) -> dict[str, int]:
        out: dict[str, int] = defaultdict(int)
        for e in self.events:
            out[e.kind] += 1
        return dict(out)

    def usd_attribution(self) -> dict[tuple[Optional[str], str], float]:
        """{(tenant, kind): summed USD at stake} — the export the cost
        dashboards consume (§C.2 style: derivable from rows alone)."""
        out: dict[tuple[Optional[str], str], float] = defaultdict(float)
        for e in self.events:
            out[(e.tenant, e.kind)] += e.usd
        return dict(out)

    def save_jsonl(self, path: str) -> int:
        with open(path, "w") as fh:
            for e in self.events:
                fh.write(e.to_json() + "\n")
        return len(self.events)
