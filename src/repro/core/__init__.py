"""repro.core — the paper's contribution: cost-aware speculative execution
for LLM-agent workflows (five dimensions D1-D5 + auxiliary mechanisms)."""

from .admissibility import AdmissibilityTag, CommitBarrier, NonSpeculableError
from .betainc import betaincinv
from .decision import (
    Decision,
    DecisionInputs,
    DecisionResult,
    LambdaDerivation,
    critical_k,
    decision_threshold,
    evaluate,
    expected_value,
    implied_lambda,
    p_break_even,
    p_threshold_crossing,
    speculation_decision,
)
from .posterior import BetaPosterior
from .pricing import (
    PRICING_MAP,
    GpuHourCost,
    PricingEntry,
    TpuChipHourCost,
    TwoRateTokenCost,
    get_pricing,
    register_pricing,
    speculation_cost,
)
from .success import TierPolicy, check_success
from .taxonomy import DependencyType, auto_assign, effective_k, structural_prior
from .telemetry import SpeculationDecision, TelemetryLog
from .workflow import Edge, Operation, Workflow
from .planner import Plan, PlannerParams, enumerate_plans, plan_workflow
from .beam import (
    BeamDecisionResult,
    BeamFleetReport,
    beam_critical_k,
    beam_evaluate,
    beam_replay,
    hit_rank_from_success,
    reference_beam_replay,
)
from .executor import ExecutionReport, ExecutorConfig, execute
from .fleet import (
    EpisodeChunks,
    FleetLowered,
    FleetReport,
    FleetStack,
    MultiTenantReport,
    chunk_episodes,
    compose_segment_posteriors,
    episode_sharded_replay,
    fleet_replay,
    lower_workflow,
    multi_tenant_replay,
    stack_tenants,
)
from .online import (
    OnlineDecisionService,
    ServiceState,
    TickDecisions,
    TelemetryBatch,
    canary_batch,
    online_calibration_batch,
    shadow_mode_batch,
)
from .rollout import (
    PHASE_NAMES,
    ReferenceLifecycle,
    RolloutConfig,
    RolloutController,
    decode_transition,
)
from .store import BucketPrior, PosteriorStore
from .streaming import (
    RhoEstimator,
    StreamingReestimator,
    expected_beam_waste,
    expected_speculation_waste,
    fractional_waste,
)

__all__ = [
    # D1 / DAG
    "Workflow", "Operation", "Edge",
    # D2
    "PricingEntry", "PRICING_MAP", "TwoRateTokenCost", "GpuHourCost",
    "TpuChipHourCost", "speculation_cost", "get_pricing", "register_pricing",
    # D3/D4
    "Decision", "DecisionInputs", "DecisionResult", "evaluate",
    "speculation_decision", "expected_value", "decision_threshold",
    "critical_k", "p_break_even", "p_threshold_crossing", "implied_lambda",
    "LambdaDerivation",
    # D5 (+ §7.5 jax-native credible-bound numerics)
    "BetaPosterior", "DependencyType", "structural_prior", "auto_assign",
    "effective_k", "betaincinv",
    # §7.4 / §3.3
    "TierPolicy", "check_success", "AdmissibilityTag", "CommitBarrier",
    "NonSpeculableError",
    # §8
    "Plan", "PlannerParams", "plan_workflow", "enumerate_plans",
    "ExecutorConfig", "ExecutionReport", "execute",
    # top-k beam speculation (D4 generalized; repro.core.beam)
    "BeamDecisionResult", "beam_evaluate", "beam_critical_k",
    "BeamFleetReport", "beam_replay", "reference_beam_replay",
    "hit_rank_from_success", "expected_beam_waste",
    # §12 fleet-scale replay (beyond-paper fast path)
    "FleetLowered", "FleetReport", "lower_workflow", "fleet_replay",
    "FleetStack", "MultiTenantReport", "stack_tenants",
    "multi_tenant_replay",
    "EpisodeChunks", "chunk_episodes", "compose_segment_posteriors",
    "episode_sharded_replay",
    # online decision service (beyond-paper jit'd request path) + the
    # §12.2-12.4 stages folded onto its posterior table
    "OnlineDecisionService", "ServiceState", "TickDecisions",
    "TelemetryBatch", "shadow_mode_batch", "canary_batch",
    "online_calibration_batch",
    # §14.3 paged hierarchical posterior store (empirical-Bayes pooling)
    "PosteriorStore", "BucketPrior",
    # §12.5 staged-rollout lifecycle over the store's roll columns
    "RolloutConfig", "RolloutController", "ReferenceLifecycle",
    "PHASE_NAMES", "decode_transition",
    # §9
    "StreamingReestimator", "RhoEstimator", "fractional_waste",
    "expected_speculation_waste",
    # App. C
    "SpeculationDecision", "TelemetryLog",
]
