"""Pallas TPU Mamba-2 SSD chunk-scan kernel.

Per (batch, head), chunks of the sequence are processed sequentially:
the carried SSD state (P x N, f32) lives in VMEM scratch.  Within a chunk
(the MXU part):

    L    = exp(segsum(A))                 (chunk x chunk, lower-tri decay)
    Yd   = ((C B^T) * L) x                intra-chunk
    Yo   = (C h_prev^T) * exp(A_cum)      inter-chunk (carried state)
    h   <- h * exp(A_sum) + (B * decay)^T x

Grid = (B, H, n_chunks), chunk innermost/sequential.  VMEM working set is
O(chunk^2 + chunk*(P+N) + P*N) — chunk=128..256, P=64, N=128 fits easily.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel_call"]


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scratch, *, chunk: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0, 0].astype(jnp.float32)       # (chunk, P)
    A = a_ref[0, 0].astype(jnp.float32)       # (chunk,)
    Bm = b_ref[0].astype(jnp.float32)         # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)         # (chunk, N)

    A_cum = jnp.cumsum(A)                     # (chunk,)
    # lower-triangular decay L[i, j] = exp(sum_{j<k<=i} A_k), i >= j
    diff = A_cum[:, None] - A_cum[None, :] + jnp.diag(A) * 0.0
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    # intra-chunk: ((C B^T) ⊙ L) x
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (chunk, chunk)
    y_diag = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: C h_prev^T scaled by decay-in
    h_prev = h_scratch[...]                   # (P, N)
    y_off = jax.lax.dot_general(Cm, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (chunk, P)
    y_off = y_off * jnp.exp(A_cum)[:, None]

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h = h * exp(A_sum) + sum_t decay_out_t * x_t B_t^T
    A_sum = A_cum[-1]
    decay_out = jnp.exp(A_sum - A_cum)        # (chunk,)
    xb = jax.lax.dot_general(
        x * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                         # (P, N)
    h_scratch[...] = h_prev * jnp.exp(A_sum) + xb


def ssd_scan_kernel_call(
    x: jax.Array,    # (B, S, H, P)  pre-multiplied by dt
    A: jax.Array,    # (B, S, H)     A*dt (negative)
    Bm: jax.Array,   # (B, S, N)     ngroups = 1
    Cm: jax.Array,   # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns y (B, S, H, P).  Final state is recomputable from y; the
    serving path uses the single-step decode update instead."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    ncf = -(-S // chunk)
    if ncf * chunk != S:
        pad = ncf * chunk - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = ncf * chunk

    xt = x.transpose(0, 2, 1, 3)             # (B, H, Sp, P)
    At = A.transpose(0, 2, 1)                # (B, H, Sp)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, ncf),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, At, Bm, Cm)
    return y.transpose(0, 2, 1, 3)[:, :S]
