"""Pallas TPU flash-attention (forward) with explicit BlockSpec VMEM tiling.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv dimension is the
innermost sequential axis; the online-softmax running max / normalizer /
accumulator live in VMEM scratch that persists across kv iterations.
GQA is handled in the k/v index maps (head h reads kv head h // G).

TARGET is TPU (MXU-aligned block shapes, f32 accumulation in VMEM);
in this CPU container the kernel is validated under interpret=True against
``ref.reference_attention``.  The backward pass is a rematerialized
reference VJP (custom_vjp) — standard for inference-first deployments;
a fused bwd kernel is future work recorded in DESIGN.md.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K"]

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, block_q: int, block_k: int, seq_len: int,
    causal: bool, window: Optional[int], num_kv_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0]                                  # (block_q, d)
    k = k_ref[0, 0]                                  # (block_k, d)
    v = v_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                        # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scratch[...]                          # (block_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scratch[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / safe).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,            # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Forward flash attention.  Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    Sp_q, Sp_k = nq * block_q, nk * block_k
    if Sp_q != S:
        q = jnp.pad(q, ((0, 0), (0, Sp_q - S), (0, 0), (0, 0)))
    if Sp_k != S:
        k = jnp.pad(k, ((0, 0), (0, Sp_k - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp_k - S), (0, 0), (0, 0)))

    # layout: (B, H, S, D) blocks of (1, 1, block, D)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, causal=causal, window=window, num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp_q, D), q.dtype),
        scratch_shapes=[
            # VMEM accumulators persisting across the kv grid dimension
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :S]
