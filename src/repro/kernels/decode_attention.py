"""Pallas TPU decode attention: one query token against a (ring-buffered)
KV cache, blocked over the cache dimension.

Grid: (batch, q_heads, kv_blocks) — kv innermost/sequential; online-softmax
running stats in VMEM scratch.  Masking is positional: the cache carries an
absolute position per slot (-1 = empty), so ring buffers and sliding
windows fall out of the same mask.  GQA via index-map head folding.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel_call", "DEFAULT_BLOCK_KV"]

DEFAULT_BLOCK_KV = 512
_NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, window: Optional[int], num_kv_blocks: int,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0]                    # (1, D) one token, one head
    k = k_ref[0, 0]                    # (block_kv, D)
    v = v_ref[0, 0]
    pos = pos_ref[0]                   # (block_kv,)
    cur = cur_ref[0]                   # scalar

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                          # (1, block_kv)
    mask = (pos >= 0) & (pos <= cur)
    if window is not None:
        mask &= (cur - pos) < window
    s = jnp.where(mask[None, :], s, _NEG_INF)

    m_prev = m_scratch[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, :], jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scratch[...] = alpha * l_scratch[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scratch[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / safe).astype(o_ref.dtype)


def decode_attention_kernel_call(
    q: jax.Array,              # (B, H, D)
    k_cache: jax.Array,        # (B, C, Hkv, D)
    v_cache: jax.Array,
    cache_positions: jax.Array,  # (B, C) int32
    current_pos: jax.Array,      # (B,) int32
    *,
    window: Optional[int] = None,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
) -> jax.Array:
    B, H, D = q.shape
    C = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    block_kv = min(block_kv, C)
    nk = -(-C // block_kv)
    if nk * block_kv != C:
        pad = nk * block_kv - C
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_positions = jnp.pad(cache_positions, ((0, 0), (0, pad)),
                                  constant_values=-1)

    kt = k_cache.transpose(0, 2, 1, 3)   # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    q3 = q[:, :, None, :]                # (B, H, 1, D)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, block_kv), lambda b, h, j: (b, j)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, kt, vt, cache_positions, current_pos)
    return out[:, :, 0, :]
