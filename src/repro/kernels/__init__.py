"""repro.kernels — Pallas TPU kernels (validated under interpret=True on
CPU against the pure-jnp oracles in ref.py)."""
from .ops import (
    betaincinv_op,
    decode_attention_op,
    flash_attention,
    on_tpu,
    online_tick_op,
    replay_grid_op,
    rglru_scan_op,
    ssd_scan_op,
)

__all__ = [
    "flash_attention", "decode_attention_op", "rglru_scan_op",
    "ssd_scan_op", "replay_grid_op", "betaincinv_op", "online_tick_op",
    "on_tpu",
]
