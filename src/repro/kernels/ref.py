"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "reference_attention",
    "reference_decode_attention",
    "reference_rglru_scan",
    "reference_ssd_scan",
    "reference_replay_grid",
]


def reference_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """Naive softmax attention with GQA repeat.  q: (B,S,H,D), k/v (B,S,Hkv,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None].any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)


def reference_decode_attention(
    q: jax.Array,                # (B, H, D) one token per sequence
    k_cache: jax.Array,          # (B, C, Hkv, D)
    v_cache: jax.Array,
    cache_positions: jax.Array,  # (B, C) absolute positions, -1 = empty
    current_pos: jax.Array,      # (B,)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    B, H, D = q.shape
    Hkv = k_cache.shape[2]
    if Hkv != H:
        k_cache = jnp.repeat(k_cache, H // Hkv, axis=2)
        v_cache = jnp.repeat(v_cache, H // Hkv, axis=2)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhd,bchd->bhc", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = (cache_positions >= 0) & (cache_positions <= current_pos[:, None])
    if window is not None:
        mask &= (current_pos[:, None] - cache_positions) < window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, :].any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhc,bchd->bhd", p.astype(v_cache.dtype), v_cache)


def reference_rglru_scan(
    a: jax.Array,   # (B, T, C) decay in (0, 1)
    b: jax.Array,   # (B, T, C) gated input
    h0: Optional[jax.Array] = None,
) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, returned for every t.  (B, T, C)."""
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    if h0 is not None:
        b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)


def reference_ssd_scan(
    x: jax.Array,    # (B, S, H, P) pre-multiplied by dt
    A: jax.Array,    # (B, S, H) A*dt (negative)
    Bm: jax.Array,   # (B, S, N)  (ngroups = 1)
    Cm: jax.Array,   # (B, S, N)
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the literal definition, O(S) steps):

        h_t = exp(A_t) * h_{t-1} + x_t B_t^T ;  y_t = h_t C_t
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, t):
        decay = jnp.exp(A[:, t].astype(jnp.float32))             # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t].astype(jnp.float32),
                         Bm[:, t].astype(jnp.float32))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                   # (B,S,H,P)
    return y, h


def reference_replay_grid(
    P: jax.Array,        # (n,) per-row success probability
    lat: jax.Array,      # (n,) latency savings per row (s)
    cost: jax.Array,     # (n,) C_spec per row (USD)
    alphas: jax.Array,   # (A,)
    lambdas: jax.Array,  # (L,)
    rho: float = 0.5,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Naive §12.1 counterfactual grid: per-cell (A, L) sums of speculate
    count, expected latency, expected waste over the n log rows."""
    gain = (P * lat)[None, None, :] * lambdas[None, :, None]
    lose = ((1.0 - P) * cost)[None, None, :]
    ev = gain - lose
    thr = (1.0 - alphas)[:, None, None] * cost[None, None, :]
    spec = ev >= thr
    count = spec.sum(-1).astype(P.dtype)
    exp_lat = jnp.where(spec, (lat * (1.0 - P))[None, None, :],
                        lat[None, None, :]).sum(-1)
    waste = (spec * lose).sum(-1) * rho
    return count, exp_lat, waste
