"""Pallas TPU RG-LRU linear-recurrence kernel.

Computes h_t = a_t * h_{t-1} + b_t over time, blocked (batch, channel,
time): grid = (B/bb, C/bc, T/bt) with time innermost/sequential; the
carried state h lives in VMEM scratch and persists across time blocks.
Inside a block the recurrence steps with a fori_loop over VMEM rows —
the op is memory-bound, so the win is streaming (bb, bt, bc) tiles
through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_kernel_call"]


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scratch, *, block_t: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)     # (bb, bt, bc)
    b = b_ref[...].astype(jnp.float32)

    def step(t, h):
        h = a[:, t, :] * h + b[:, t, :]
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scratch[...])
    h_scratch[...] = h


def rglru_scan_kernel_call(
    a: jax.Array,               # (B, T, C) decay
    b: jax.Array,               # (B, T, C) gated input
    h0: jax.Array | None = None,  # (B, C)
    *,
    block_b: int = 8,
    block_t: int = 128,
    block_c: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns h for every t: (B, T, C)."""
    B, T, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    block_b = min(block_b, B)
    block_t = min(block_t, T)
    block_c = min(block_c, C)
    nb, nt, nc = -(-B // block_b), -(-T // block_t), -(-C // block_c)
    padded = (nb * block_b != B) or (nt * block_t != T) or (nc * block_c != C)
    if padded:
        a = jnp.pad(a, ((0, nb * block_b - B), (0, nt * block_t - T),
                        (0, nc * block_c - C)), constant_values=1.0)
        b = jnp.pad(b, ((0, nb * block_b - B), (0, nt * block_t - T),
                        (0, nc * block_c - C)))
        h0 = jnp.pad(h0, ((0, nb * block_b - B), (0, nc * block_c - C)))

    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=(nb, nc, nt),   # time innermost: h carries across t blocks
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_c), lambda i, c, t: (i, t, c)),
            pl.BlockSpec((block_b, block_t, block_c), lambda i, c, t: (i, t, c)),
            pl.BlockSpec((block_b, block_c), lambda i, c, t: (i, c)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t, block_c), lambda i, c, t: (i, t, c)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[:B, :T, :C]
