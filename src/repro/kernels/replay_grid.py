"""Pallas TPU kernel for the §12.1 counterfactual (alpha, lambda) grid.

The offline-replay calibration stage re-runs the D4 gate for every logged
decision row at every (alpha, lambda) grid point and aggregates per-cell
statistics (speculate fraction, expected latency, expected waste).  The
reduction axis is the log (millions of rows); the grid is small.  This
kernel fuses the whole sweep into one launch:

    grid = (num_row_blocks,)  — sequential on TPU, so each program
    accumulates its block's partial sums into the same (A, L) output
    block (the standard revisited-output accumulation pattern).

Per row i and cell (a, l):

    EV[a,l,i]  = P_i * lat_i * lam_l - (1 - P_i) * cost_i
    thr[a,i]   = (1 - alpha_a) * cost_i
    spec       = EV >= thr
    count     += spec
    lat_sum   += spec ? lat_i * (1 - P_i) : lat_i     (expected latency)
    waste_sum += spec * (1 - P_i) * cost_i * rho      (§9.3 expected waste)

Padded rows are encoded as (P=0, lat=0, cost=1) so they never speculate
and contribute zero to every sum; padded alpha cells use alpha=1 and
padded lambda cells lam=0, and are sliced off by the wrapper.

Validated under interpret=True on CPU against ``ref.reference_replay_grid``
(and transitively against ``batch_decision.counterfactual_grid``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["replay_grid_kernel_call", "replay_grid_summary"]


def _replay_grid_kernel(alpha_ref, lam_ref, p_ref, lat_ref, cost_ref,
                        count_ref, lat_o_ref, waste_o_ref, *, rho: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        lat_o_ref[...] = jnp.zeros_like(lat_o_ref)
        waste_o_ref[...] = jnp.zeros_like(waste_o_ref)

    P = p_ref[...]        # (bn,)
    lat = lat_ref[...]    # (bn,)
    cost = cost_ref[...]  # (bn,)
    alphas = alpha_ref[...]  # (A,)
    lams = lam_ref[...]      # (L,)

    gain = (P * lat)[None, :] * lams[:, None]          # (L, bn)
    lose = (1.0 - P) * cost                            # (bn,)
    ev = gain[None, :, :] - lose[None, None, :]        # (1, L, bn)
    thr = (1.0 - alphas)[:, None, None] * cost[None, None, :]  # (A, 1, bn)
    spec = ev >= thr                                   # (A, L, bn)

    count_ref[...] += spec.sum(-1).astype(count_ref.dtype)
    exp_lat = jnp.where(spec, (lat * (1.0 - P))[None, None, :],
                        lat[None, None, :])
    lat_o_ref[...] += exp_lat.sum(-1)
    waste_o_ref[...] += (spec * lose[None, None, :]).sum(-1) * rho


def replay_grid_kernel_call(
    P: jax.Array,         # (n,) per-row success probability
    lat: jax.Array,       # (n,) latency savings per row (s)
    cost: jax.Array,      # (n,) C_spec per row (USD)
    alphas: jax.Array,    # (A,)
    lambdas: jax.Array,   # (L,)
    *,
    rho: float = 0.5,
    block_n: int = 4096,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused §12.1 grid sweep.  Returns per-cell (A, L) arrays:
    (speculate_count, expected_latency_sum, expected_waste_sum)."""
    n = P.shape[0]
    A = alphas.shape[0]
    L = lambdas.shape[0]
    dtype = jnp.result_type(P.dtype, jnp.float32)
    if n == 0:
        zeros = jnp.zeros((A, L), dtype)
        return zeros, zeros, zeros
    P = P.astype(dtype)
    lat = lat.astype(dtype)
    cost = cost.astype(dtype)

    block_n = min(block_n, max(n, 1))
    nb = -(-n // block_n)
    pad_n = nb * block_n - n
    if pad_n:
        # inert rows: never speculate, zero latency/waste contribution
        P = jnp.pad(P, (0, pad_n))
        lat = jnp.pad(lat, (0, pad_n))
        cost = jnp.pad(cost, (0, pad_n), constant_values=1.0)

    # pad the grid axes toward TPU tile shape (harmless under interpret)
    Ap = -(-A // 8) * 8
    Lp = -(-L // 128) * 128
    alphas_p = jnp.pad(alphas.astype(dtype), (0, Ap - A),
                       constant_values=1.0)
    lambdas_p = jnp.pad(lambdas.astype(dtype), (0, Lp - L))

    kernel = functools.partial(_replay_grid_kernel, rho=float(rho))
    count, lat_sum, waste_sum = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Ap,), lambda i: (0,)),
            pl.BlockSpec((Lp,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((Ap, Lp), lambda i: (0, 0)),
            pl.BlockSpec((Ap, Lp), lambda i: (0, 0)),
            pl.BlockSpec((Ap, Lp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ap, Lp), dtype),
            jax.ShapeDtypeStruct((Ap, Lp), dtype),
            jax.ShapeDtypeStruct((Ap, Lp), dtype),
        ],
        interpret=interpret,
    )(alphas_p, lambdas_p, P, lat, cost)
    return count[:A, :L], lat_sum[:A, :L], waste_sum[:A, :L]


def replay_grid_summary(
    P: np.ndarray, lat: np.ndarray, cost: np.ndarray,
    alphas: np.ndarray, lambdas: np.ndarray,
    *, rho: float = 0.5, interpret: bool = True,
) -> dict:
    """Convenience wrapper matching ``batch_decision.counterfactual_grid``'s
    output dict, computed via the fused kernel."""
    n = np.shape(lat)[0]
    P = jnp.broadcast_to(jnp.asarray(P, jnp.float32), (n,))
    count, lat_sum, waste = replay_grid_kernel_call(
        P, jnp.asarray(lat, jnp.float32), jnp.asarray(cost, jnp.float32),
        jnp.asarray(alphas, jnp.float32), jnp.asarray(lambdas, jnp.float32),
        rho=rho, interpret=interpret,
    )
    total_cost = float(np.sum(cost))
    waste = np.asarray(waste)
    return {
        "speculate_fraction": np.asarray(count) / n,
        "expected_latency_s": np.asarray(lat_sum) / n,
        "expected_cost_usd": total_cost + waste,
        "expected_waste_usd": waste,
    }
