"""Pallas TPU kernel for the batched Beta quantile (§7.5 numerics).

``core.betainc.betaincinv`` is a bracketed Halley iteration on
``jax.scipy.special.betainc`` — purely elementwise over the row axis, so
it is a natural Pallas fit: tile the (N,) axis into ``block_n`` lanes and
run the fixed-count iteration entirely inside the kernel.  BENCH_fleet
shows the §7.5 credible-bound path at ~8x vs the no-bound path's ~66x —
this inversion is the dominant remaining cost of every gated path.

The kernel mirrors ``core.betainc._invert`` step for step: the same
Numerical-Recipes initial guess, the same 64 bracketed Halley iterations
with bisection fallback, the same special-value handling.  The one
difference is the ``I_x(a, b)`` evaluation itself: ``jax.scipy``'s
``betainc`` is an XLA custom call that Mosaic cannot lower, so the kernel
carries its own evaluator — the Lentz continued fraction (NR §6.4
``betacf``, fixed iteration count, FPMIN clamps, the symmetry switch at
``x >= (a + 1)/(a + b + 2)``) with a Lanczos ``lgamma`` for the log-Beta
front factor.  Consequence for parity: results agree with the
``jax.scipy``-based path (and scipy's ``beta.ppf``) to <= 1e-10 relative
— the established betaincinv tier — but not bitwise; the fused online
tick keeps its bitwise contract on the mean path, where no inversion
runs.

Inert padding lanes use (a=1, b=1, q=0.5): ``I_x(1,1) = x``, so every
step is benign and the pad result (0.5) is sliced off by the wrapper.

Validated under interpret=True on CPU against ``core.betainc.betaincinv``
and ``scipy.stats.beta.ppf`` (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["betaincinv_kernel_call", "betainc_in_kernel", "lbeta_in_kernel"]

# Same fixed Halley budget as core.betainc (the bisection-fallback lanes
# need the headroom to reach ~1e-16 interval width at float64).
_N_ITER = 64
# Lentz continued-fraction budget: NR quotes <~50 double-steps for
# convergence at double precision over the symmetry-reduced domain; the
# fixed 100 keeps deep-tail a, b ~ 150 lanes converged without a
# data-dependent exit (which would shear the SIMD lanes apart).
_CF_ITER = 100

# Lanczos g=7, n=9 coefficients (Godfrey/Boost; standard double-precision
# set, ~1e-13 relative on lgamma over the positive axis).
_LANCZOS_G = 7.0
_LANCZOS = (
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
)
_HALF_LOG_2PI = 0.9189385332046727417803297364056176


def _lgamma(z):
    """Lanczos log-gamma for z > 0 (elementwise, Mosaic-lowerable).

    Evaluated at z + 1 (the approximation's sweet spot) and stepped down
    via ``lgamma(z) = lgamma(z + 1) - log(z)``, so a, b << 1 lanes stay
    accurate without a reflection branch.
    """
    w = z + 1.0
    x = _LANCZOS[0]
    for i, c in enumerate(_LANCZOS[1:]):
        x = x + c / (w + i)
    t = w + (_LANCZOS_G - 0.5)
    return (_HALF_LOG_2PI + (w - 0.5) * jnp.log(t) - t + jnp.log(x)
            - jnp.log(z))


def lbeta_in_kernel(a, b):
    """log B(a, b) from the in-kernel Lanczos ``lgamma``."""
    return _lgamma(a) + _lgamma(b) - _lgamma(a + b)


def _betacf(a, b, x, dt):
    """Lentz continued fraction for ``I_x`` (NR §6.4 betacf): fixed
    iteration count, FPMIN clamps on near-zero denominators."""
    fpmin = jnp.finfo(dt).tiny / jnp.finfo(dt).eps
    qab = a + b
    qap = a + 1.0
    qam = a - 1.0
    c = jnp.ones_like(x)
    d = 1.0 - qab * x / qap
    d = jnp.where(jnp.abs(d) < fpmin, fpmin, d)
    d = 1.0 / d
    h = d

    def body(m, cdh):
        c, d, h = cdh
        mf = m.astype(dt) if hasattr(m, "astype") else jnp.asarray(m, dt)
        m2 = 2.0 * mf
        # even step
        aa = mf * (b - mf) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        d = jnp.where(jnp.abs(d) < fpmin, fpmin, d)
        c = 1.0 + aa / c
        c = jnp.where(jnp.abs(c) < fpmin, fpmin, c)
        d = 1.0 / d
        h = h * d * c
        # odd step
        aa = -(a + mf) * (qab + mf) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        d = jnp.where(jnp.abs(d) < fpmin, fpmin, d)
        c = 1.0 + aa / c
        c = jnp.where(jnp.abs(c) < fpmin, fpmin, c)
        d = 1.0 / d
        h = h * d * c
        return c, d, h

    def step(m, cdh):
        return body(jnp.asarray(m + 1, dt), cdh)

    _, _, h = jax.lax.fori_loop(0, _CF_ITER, step, (c, d, h))
    return h


def betainc_in_kernel(a, b, x):
    """Regularized incomplete beta ``I_x(a, b)`` for x in (0, 1), a, b > 0
    — the kernel-resident replacement for ``jax.scipy.special.betainc``
    (agreement ~1e-13 relative; see module docstring)."""
    dt = x.dtype
    # front factor; symmetric under (a, b, x) -> (b, a, 1 - x)
    lnfront = (a * jnp.log(x) + b * jnp.log1p(-x)
               - lbeta_in_kernel(a, b))
    bt = jnp.exp(lnfront)
    # symmetry switch keeps the continued fraction in its fast region
    swap = x >= (a + 1.0) / (a + b + 2.0)
    aa = jnp.where(swap, b, a)
    bb = jnp.where(swap, a, b)
    xx = jnp.where(swap, 1.0 - x, x)
    res = bt * _betacf(aa, bb, xx, dt) / aa
    return jnp.where(swap, 1.0 - res, res)


def _initial_guess(a, b, q):
    """NR 3rd ed. §6.4 ``invbetai`` starting point — identical to
    ``core.betainc._initial_guess`` (all ops Mosaic-lowerable already)."""
    dt = q.dtype
    eps = jnp.finfo(dt).eps
    tiny = jnp.finfo(dt).tiny
    pp = jnp.maximum(jnp.where(q < 0.5, q, 1.0 - q), tiny)
    t = jnp.sqrt(-2.0 * jnp.log(pp))
    x = (2.30753 + t * 0.27061) / (1.0 + t * (0.99229 + t * 0.04481)) - t
    x = jnp.where(q < 0.5, -x, x)
    al = (x * x - 3.0) / 6.0
    h = 2.0 / (1.0 / (2.0 * a - 1.0) + 1.0 / (2.0 * b - 1.0))
    w = (
        x * jnp.sqrt(al + h) / h
        - (1.0 / (2.0 * b - 1.0) - 1.0 / (2.0 * a - 1.0))
        * (al + 5.0 / 6.0 - 2.0 / (3.0 * h))
    )
    guess_large = a / (a + b * jnp.exp(2.0 * w))
    lna = jnp.log(a / (a + b))
    lnb = jnp.log(b / (a + b))
    t_a = jnp.exp(a * lna) / a
    t_b = jnp.exp(b * lnb) / b
    s = t_a + t_b
    guess_small = jnp.where(
        q < t_a / s,
        (a * s * q) ** (1.0 / a),
        1.0 - (b * s * (1.0 - q)) ** (1.0 / b),
    )
    guess = jnp.where((a >= 1.0) & (b >= 1.0), guess_large, guess_small)
    return jnp.clip(guess, tiny, 1.0 - eps)


def betaincinv_in_kernel(a, b, q):
    """The bracketed Halley inversion, kernel-resident: mirrors
    ``core.betainc._invert`` line for line with ``betainc_in_kernel``
    as the evaluator.  Shared by the betaincinv kernel and the fused
    online-tick kernel's lower-bound / drift paths."""
    dt = q.dtype
    tiny = jnp.finfo(dt).tiny
    a1 = a - 1.0
    b1 = b - 1.0
    lbeta = lbeta_in_kernel(a, b)
    x0 = _initial_guess(a, b, q)
    lo0 = jnp.zeros_like(q)
    hi0 = jnp.ones_like(q)

    def body(_, state):
        x, lo, hi = state
        err = betainc_in_kernel(a, b, x) - q
        lo = jnp.where(err < 0.0, jnp.maximum(lo, x), lo)
        hi = jnp.where(err > 0.0, jnp.minimum(hi, x), hi)
        logpdf = a1 * jnp.log(x) + b1 * jnp.log1p(-x) - lbeta
        u = err / jnp.maximum(jnp.exp(logpdf), tiny)
        halley = 1.0 - 0.5 * jnp.minimum(1.0, u * (a1 / x - b1 / (1.0 - x)))
        xn = x - u / halley
        bad = ~jnp.isfinite(xn) | (xn < lo) | (xn > hi)
        xn = jnp.where(bad, 0.5 * (lo + hi), xn)
        return xn, lo, hi

    x, _, _ = jax.lax.fori_loop(0, _N_ITER, body, (x0, lo0, hi0))
    x = jnp.where(q <= 0.0, 0.0, jnp.where(q >= 1.0, 1.0, x))
    valid = (a > 0.0) & (b > 0.0) & (q >= 0.0) & (q <= 1.0)
    return jnp.where(valid, x, jnp.nan)


def _betaincinv_kernel(a_ref, b_ref, q_ref, out_ref):
    out_ref[...] = betaincinv_in_kernel(a_ref[...], b_ref[...], q_ref[...])


def betaincinv_kernel_call(
    a: jax.Array,       # (n,) Beta alpha
    b: jax.Array,       # (n,) Beta beta
    q: jax.Array,       # (n,) quantile levels
    *,
    block_n: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Batched Beta quantile ``I_x^{-1}(a, b) = q`` as one Pallas launch
    over ``block_n``-lane row tiles.  Returns (n,) in ``q``'s float dtype.

    ``block_n`` is the tunable row-tile width (sweep hook:
    ``benchmarks/kernels_bench.py``); padding lanes are inert
    (a=b=1, q=0.5) and sliced off.
    """
    n = q.shape[0]
    dtype = jnp.result_type(q.dtype, jnp.float32)
    if n == 0:
        return jnp.zeros((0,), dtype)
    a = a.astype(dtype)
    b = b.astype(dtype)
    q = q.astype(dtype)

    block_n = min(block_n, max(n, 1))
    nb = -(-n // block_n)
    pad_n = nb * block_n - n
    if pad_n:
        a = jnp.pad(a, (0, pad_n), constant_values=1.0)
        b = jnp.pad(b, (0, pad_n), constant_values=1.0)
        q = jnp.pad(q, (0, pad_n), constant_values=0.5)

    out = pl.pallas_call(
        _betaincinv_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_n,), dtype),
        interpret=interpret,
    )(a, b, q)
    return out[:n]
