"""jit'd public wrappers for the Pallas kernels.

Backend dispatch: on TPU the kernels compile natively; elsewhere they run
under interpret=True (Python evaluation of the kernel body — correctness
validation on CPU).  ``flash_attention`` exposes a custom_vjp whose
backward is the rematerialized reference (fused bwd kernel is future
work); the scan kernels are forward-only ops used by serving paths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from .decode_attention import decode_attention_kernel_call
from .flash_attention import flash_attention_fwd
from .replay_grid import replay_grid_kernel_call
from .rglru_scan import rglru_scan_kernel_call
from .ssd_scan import ssd_scan_kernel_call

__all__ = [
    "flash_attention",
    "decode_attention_op",
    "rglru_scan_op",
    "ssd_scan_op",
    "replay_grid_op",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    """Flash attention with Pallas fwd + reference-recompute bwd."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


def _fa_fwd(q, k, v, causal, window):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              interpret=_interpret())
    return out, (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.reference_attention(
            q_, k_, v_, causal=causal, window=window),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


@jax.jit
def decode_attention_op(q, k_cache, v_cache, cache_positions, current_pos):
    """(B,H,D) x cache -> (B,H,D)."""
    return decode_attention_kernel_call(
        q, k_cache, v_cache, cache_positions, current_pos,
        interpret=_interpret(),
    )


@jax.jit
def rglru_scan_op(a, b, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t, all t.  (B,T,C)."""
    return rglru_scan_kernel_call(a, b, h0, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(x, A, Bm, Cm, chunk: int = 128):
    """Mamba-2 SSD chunk scan.  Returns y (B,S,H,P)."""
    return ssd_scan_kernel_call(x, A, Bm, Cm, chunk=chunk,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("rho",))
def replay_grid_op(P, lat, cost, alphas, lambdas, rho: float = 0.5):
    """§12.1 fused counterfactual (alpha, lambda) grid sweep: one kernel
    launch over all log rows x grid cells.  Returns (A, L) arrays
    (speculate_count, expected_latency_sum, expected_waste_sum)."""
    return replay_grid_kernel_call(P, lat, cost, alphas, lambdas,
                                   rho=rho, interpret=_interpret())
