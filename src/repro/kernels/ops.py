"""jit'd public wrappers for the Pallas kernels.

Backend dispatch: on TPU the kernels compile natively; elsewhere they run
under interpret=True (Python evaluation of the kernel body — correctness
validation on CPU).  ``flash_attention`` exposes a custom_vjp whose
backward is the rematerialized reference (fused bwd kernel is future
work); the scan kernels are forward-only ops used by serving paths.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from .betaincinv_pallas import betaincinv_kernel_call
from .decode_attention import decode_attention_kernel_call
from .flash_attention import flash_attention_fwd
from .online_tick import online_tick_kernel_call
from .replay_grid import replay_grid_kernel_call
from .rglru_scan import rglru_scan_kernel_call
from .ssd_scan import ssd_scan_kernel_call

__all__ = [
    "flash_attention",
    "decode_attention_op",
    "rglru_scan_op",
    "ssd_scan_op",
    "replay_grid_op",
    "betaincinv_op",
    "online_tick_op",
    "on_tpu",
]

# Explicit override for the interpret/native switch.  Unset (the
# default) -> backend autodetection: native lowering on TPU, interpret
# elsewhere.  "1"/"true"/"yes"/"interpret" -> force interpret; any other
# non-empty value -> force native.
_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    """Resolve the Pallas interpret flag: env override first, then
    backend autodetection (native iff the default backend is TPU).

    Resolved OUTSIDE jit by the ops below and passed as a static arg, so
    flipping the env var between calls is honored rather than baked into
    the first trace.
    """
    env = os.environ.get(_INTERPRET_ENV, "").strip().lower()
    if env:
        return env in ("1", "true", "yes", "interpret")
    return not on_tpu()


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    """Flash attention with Pallas fwd + reference-recompute bwd."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


def _fa_fwd(q, k, v, causal, window):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              interpret=_interpret())
    return out, (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.reference_attention(
            q_, k_, v_, causal=causal, window=window),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


@jax.jit
def decode_attention_op(q, k_cache, v_cache, cache_positions, current_pos):
    """(B,H,D) x cache -> (B,H,D)."""
    return decode_attention_kernel_call(
        q, k_cache, v_cache, cache_positions, current_pos,
        interpret=_interpret(),
    )


@jax.jit
def rglru_scan_op(a, b, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t, all t.  (B,T,C)."""
    return rglru_scan_kernel_call(a, b, h0, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(x, A, Bm, Cm, chunk: int = 128):
    """Mamba-2 SSD chunk scan.  Returns y (B,S,H,P)."""
    return ssd_scan_kernel_call(x, A, Bm, Cm, chunk=chunk,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("rho", "interpret"))
def _replay_grid_jit(P, lat, cost, alphas, lambdas, rho, interpret):
    return replay_grid_kernel_call(P, lat, cost, alphas, lambdas,
                                   rho=rho, interpret=interpret)


def replay_grid_op(P, lat, cost, alphas, lambdas, rho: float = 0.5):
    """§12.1 fused counterfactual (alpha, lambda) grid sweep: one kernel
    launch over all log rows x grid cells.  Returns (A, L) arrays
    (speculate_count, expected_latency_sum, expected_waste_sum)."""
    return _replay_grid_jit(P, lat, cost, alphas, lambdas, rho,
                            _interpret())


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _betaincinv_jit(a, b, q, block_n, interpret):
    return betaincinv_kernel_call(a, b, q, block_n=block_n,
                                  interpret=interpret)


def betaincinv_op(a, b, q, block_n: int = 1024):
    """Batched Beta quantile via the Pallas kernel: (n,) -> (n,).
    <=1e-10 relative vs the `jax.scipy`-based `core.betainc.betaincinv`
    (not bitwise — the kernel carries its own betainc evaluator)."""
    return _betaincinv_jit(a, b, q, block_n, _interpret())


@functools.partial(
    jax.jit,
    static_argnames=("use_lower_bound", "check_drift", "block_n",
                     "interpret"),
)
def _online_tick_jit(post, rowcfg, flags, zero, row, reqs, out_row, out_x,
                     consecutive_n, use_lower_bound, check_drift, block_n,
                     interpret):
    return online_tick_kernel_call(
        post, rowcfg, flags, zero, row, reqs, out_row, out_x,
        consecutive_n, use_lower_bound=use_lower_bound,
        check_drift=check_drift, block_n=block_n, interpret=interpret)


def online_tick_op(post, rowcfg, flags, zero, row, reqs, out_row, out_x,
                   consecutive_n, use_lower_bound: bool = False,
                   check_drift: bool = False, block_n: int = 1024):
    """Fused online-service tick (settle + D4 gate + drift) in one Pallas
    launch over the SoA row axis.  Mean-path outputs are bitwise-f64
    equal to `OnlineDecisionService._tick_impl`; the lower-bound / drift
    quantile paths sit at the <=1e-10 betaincinv tier."""
    return _online_tick_jit(
        post, rowcfg, flags, zero, row, reqs, out_row, out_x,
        consecutive_n, use_lower_bound, check_drift, block_n,
        _interpret())
