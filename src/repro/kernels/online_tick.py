"""Pallas TPU kernel for the online decision service's fused tick.

``OnlineDecisionService._tick_impl`` is three XLA loops over the SoA row
table — the settle scan, the batched D4 gate, the drift breach step —
each reading and writing the same posterior rows.  This kernel fuses all
three into one launch over ``block_n``-row tiles:

    grid = (num_row_blocks,) — sequential on TPU.  Each program settles
    its rows (a masked elementwise replay of the settle scan, preserving
    per-row arrival order), gates the requests whose (clamped) row lives
    in its tile (writing them into revisited (Bp,) output blocks via
    select — no arithmetic touches another block's values), and runs the
    trigger-2 breach step on its rows.

Parity tiers (tests/test_kernels.py):

* mean-path ticks (``use_lower_bound=False``) are **bitwise-f64 equal**
  to ``_tick_impl`` — settled posteriors, decisions, drift runs and
  telemetry rows.  The traced-runtime-zero FMA pin survives: ``zero``
  arrives as a (1,) operand block, so ``x * d + zero`` inside the kernel
  contracts (or not) exactly as in the XLA lowering;
* ``use_lower_bound=True`` gates on the kernel-resident ``betaincinv``
  (see ``betaincinv_pallas`` — ``jax.scipy``'s betainc is a custom call
  Mosaic cannot lower), so P_used agrees with ``_tick_impl`` to the
  established <= 1e-10 betaincinv tier rather than bitwise; decision
  flags can differ only when EV - threshold sits inside that margin;
* ``check_drift`` breach *booleans* compare the same kernel-resident
  bound against the row floor: run counters and trigger bits are bitwise
  vs ``_tick_impl`` except when a bound sits within ~1e-12 of its floor
  (the same razor-edge caveat ``DriftMonitor.check_credible_bound_batch``
  documents for its scalar-vs-batch pairing).

The rollout lifecycle (3b) and beam gate are not fused — the service
falls back to the XLA tick for those statics (they are cold paths next
to the gate + settle + drift hot loop this kernel owns).

Padding: request and settle slots carry the -1 row sentinel (same
convention as the service's shape buckets); padded *table* rows (row-axis
tile alignment) are inert (a=b=1, enabled=0, floor=0) and, since no
request or settle row can index them, emerge unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.batch_decision import d4_gate
from .betaincinv_pallas import betaincinv_in_kernel

__all__ = ["online_tick_kernel_call"]


def _online_tick_kernel(
    # replicated operands
    zero_ref, cn_ref, srow_ref, alpha_ref, lam_ref, lat_ref, itok_ref,
    otok_ref, iprice_ref, oprice_ref, orow_ref, ox_ref,
    # row-tiled operands
    a_ref, b_ref, gam_ref, disc_ref, floor_ref, en_ref, run_ref,
    # row-tiled outputs
    a_out, b_out, en_out, run_out, trig_out,
    # revisited (Bp,) request outputs
    pused_out, pmean_out, ev_out, thr_out, cspec_out, lval_out,
    flag_out, enreq_out,
    *, use_lower_bound: bool, check_drift: bool,
):
    i = pl.program_id(0)
    block_n = a_ref.shape[0]
    zero = zero_ref[0]
    base = i * block_n
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)[:, 0]

    @pl.when(i == 0)
    def _init():
        for ref in (pused_out, pmean_out, ev_out, thr_out, cspec_out,
                    lval_out, flag_out, enreq_out):
            ref[...] = jnp.zeros_like(ref)

    a = a_ref[...]
    b = b_ref[...]
    disc = disc_ref[...]
    gam = gam_ref[...]

    # ---- 1. settle: sequential masked replay of the settle scan.  Each
    # entry updates exactly one lane with the same ``(a*d + zero) + x``
    # recurrence as ``_tick_impl``; entries hitting other tiles (or the
    # -1 sentinel) are full-width no-ops, so per-row arrival order is
    # preserved and cross-tile order is irrelevant.
    S = orow_ref.shape[0]
    if S:
        orow = orow_ref[...]
        ox = ox_ref[...]

        def settle_step(s, ab):
            a, b = ab
            r = jax.lax.dynamic_index_in_dim(orow, s, keepdims=False)
            x = jax.lax.dynamic_index_in_dim(ox, s, keepdims=False)
            rl = r - base
            m = (r >= 0) & (lane == rl)
            a2 = (a * disc + zero) + x
            b2 = (b * disc + zero) + (1.0 - x)
            return jnp.where(m, a2, a), jnp.where(m, b2, b)

        a, b = jax.lax.fori_loop(0, S, settle_step, (a, b))

    # ---- 2. D4 gate for the requests this tile owns (clamped row in
    # [base, base + block_n)).  The posterior gather is a one-hot select
    # + sum — every addend but the target lane is an exact 0.0, so the
    # gathered (a, b) are bitwise the table rows.
    srow = srow_ref[...]
    ri = jnp.maximum(srow, 0)
    rl = ri - base
    own = (rl >= 0) & (rl < block_n)
    sel = (lane[:, None] == rl[None, :]) & own[None, :]
    ga = jnp.where(sel, a[:, None], 0.0).sum(0)
    gb = jnp.where(sel, b[:, None], 0.0).sum(0)
    gen = jnp.where(sel, en_ref[...][:, None], 0).sum(0)
    P_mean = ga / (ga + gb)
    if use_lower_bound:
        ggam = jnp.where(sel, gam[:, None], 0.0).sum(0)
        P_used = betaincinv_in_kernel(ga, gb, ggam)
    else:
        P_used = P_mean
    EV, thr, flag, C_spec, L_value = d4_gate(
        P_used, alpha_ref[...], lam_ref[...], lat_ref[...], itok_ref[...],
        otok_ref[...], iprice_ref[...], oprice_ref[...], zero)

    def wr(ref, val):
        ref[...] = jnp.where(own, val.astype(ref.dtype), ref[...])

    wr(pused_out, P_used)
    wr(pmean_out, P_mean)
    wr(ev_out, EV)
    wr(thr_out, thr)
    wr(cspec_out, C_spec)
    wr(lval_out, L_value)
    wr(flag_out, flag.astype(jnp.int32))
    wr(enreq_out, (gen > 0).astype(jnp.int32))

    # ---- 3. trigger-2 drift over this tile's rows (post-settle table,
    # touched = any valid request landed on the row — the same mask
    # ``_tick_impl`` scatters).
    en = en_ref[...]
    run = run_ref[...]
    if check_drift:
        valid = srow >= 0
        touched = (sel & valid[None, :]).any(1)
        P_low = betaincinv_in_kernel(a, b, gam)
        breached = touched & (P_low < floor_ref[...])
        run = jnp.where(touched, jnp.where(breached, run + 1, 0), run)
        triggered = touched & (run >= cn_ref[0])
        en = ((en > 0) & ~triggered).astype(jnp.int32)
        run = jnp.where(triggered, 0, run)
        trig_out[...] = triggered.astype(jnp.int32)
    else:
        trig_out[...] = jnp.zeros_like(trig_out)

    a_out[...] = a
    b_out[...] = b
    en_out[...] = en
    run_out[...] = run


def online_tick_kernel_call(
    post: jax.Array,     # (N, 2) posterior alpha/beta rows
    rowcfg: jax.Array,   # (N, 3) [gamma, discount, trigger-2 floor]
    flags: jax.Array,    # (N, 2) int32 [enabled, breach_run]
    zero: jax.Array,     # () traced runtime 0.0 (the FMA pin)
    row: jax.Array,      # (Bp,) int32 request rows, -1 padding
    reqs: jax.Array,     # (Bp, 7) [alpha, lam, lat, itok, otok, ipr, opr]
    out_row: jax.Array,  # (S,) int32 settled rows, -1 padding
    out_x: jax.Array,    # (S,) settled outcomes as 0/1 floats
    consecutive_n,       # () int32 trigger-2 N
    *,
    use_lower_bound: bool = False,
    check_drift: bool = False,
    block_n: int = 1024,
    interpret: bool = True,
):
    """Fused gate + settle + drift tick as one Pallas launch.

    Returns ``(post', flags', P_used, P_mean, EV, thr, C_spec, L_value,
    flag, enabled_req, triggered)`` with the request vectors shaped
    (Bp,), ``flag``/``enabled_req`` as int32 0/1 and ``triggered`` an
    (N,) int32 mask — the raw parts ``online.py``'s fused-tick wrapper
    reassembles into the ``_tick_impl`` output contract.  ``block_n`` is
    the row-tile tunable (sweep hook: ``benchmarks/kernels_bench.py``).
    """
    N = post.shape[0]
    Bp = row.shape[0]
    dt = post.dtype
    block_n = min(block_n, max(N, 1))
    nb = -(-N // block_n)
    pad_n = nb * block_n - N

    a, b = post[:, 0], post[:, 1]
    gam, disc, floor = rowcfg[:, 0], rowcfg[:, 1], rowcfg[:, 2]
    en, run = flags[:, 0], flags[:, 1]
    if pad_n:
        # inert table rows: valid Beta params (the drift inversion stays
        # finite), never enabled, floor 0 -> never breached; requests and
        # settles cannot index them (row ids < N)
        a = jnp.pad(a, (0, pad_n), constant_values=1.0)
        b = jnp.pad(b, (0, pad_n), constant_values=1.0)
        gam = jnp.pad(gam, (0, pad_n), constant_values=0.5)
        disc = jnp.pad(disc, (0, pad_n), constant_values=1.0)
        floor = jnp.pad(floor, (0, pad_n))
        en = jnp.pad(en, (0, pad_n))
        run = jnp.pad(run, (0, pad_n))

    # Bp = 0 ticks (settle-only / drift-only): pad one sentinel request
    # slot so the revisited output blocks stay non-empty; sliced off.
    Bk = max(Bp, 1)
    if Bp == 0:
        row = jnp.full((1,), -1, jnp.int32)
        reqs = jnp.zeros((1, 7), dt)

    zero1 = jnp.reshape(zero, (1,)).astype(dt)
    cn1 = jnp.reshape(jnp.asarray(consecutive_n, jnp.int32), (1,))

    kernel = functools.partial(
        _online_tick_kernel,
        use_lower_bound=bool(use_lower_bound),
        check_drift=bool(check_drift),
    )
    rep = pl.BlockSpec((Bk,), lambda i: (0,))
    tile = pl.BlockSpec((block_n,), lambda i: (i,))
    Np = nb * block_n
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),          # zero
            pl.BlockSpec((1,), lambda i: (0,)),          # consecutive_n
            rep, rep, rep, rep, rep, rep, rep, rep,      # row + req cols
            pl.BlockSpec((max(out_row.shape[0], 1),), lambda i: (0,)),
            pl.BlockSpec((max(out_row.shape[0], 1),), lambda i: (0,)),
            tile, tile, tile, tile, tile, tile, tile,    # table columns
        ],
        out_specs=(
            [tile] * 5
            + [pl.BlockSpec((Bk,), lambda i: (0,))] * 8
        ),
        out_shape=(
            [jax.ShapeDtypeStruct((Np,), dt)] * 2
            + [jax.ShapeDtypeStruct((Np,), jnp.int32)] * 3
            + [jax.ShapeDtypeStruct((Bk,), dt)] * 6
            + [jax.ShapeDtypeStruct((Bk,), jnp.int32)] * 2
        ),
        interpret=interpret,
    )(
        zero1, cn1, row, reqs[:, 0], reqs[:, 1], reqs[:, 2], reqs[:, 3],
        reqs[:, 4], reqs[:, 5], reqs[:, 6],
        (out_row if out_row.shape[0] else jnp.full((1,), -1, jnp.int32)),
        (out_x if out_row.shape[0] else jnp.zeros((1,), dt)),
        a, b, gam, disc, floor, en, run,
    )
    (a2, b2, en2, run2, trig,
     pused, pmean, ev, thr, cspec, lval, flagv, enreq) = outs
    post2 = jnp.stack([a2[:N], b2[:N]], axis=1)
    flags2 = jnp.stack([en2[:N], run2[:N]], axis=1)
    return (post2, flags2, pused[:Bp], pmean[:Bp], ev[:Bp], thr[:Bp],
            cspec[:Bp], lval[:Bp], flagv[:Bp], enreq[:Bp], trig[:N])
