"""Training loop with fault tolerance.

Features exercised by the integration tests:
  * checkpoint/restart — atomic async checkpoints every N steps; on (re)start
    the trainer resumes from the latest complete checkpoint, including the
    data-pipeline cursor, bitwise-identically;
  * straggler monitor — per-step wall-time EMA; a step slower than
    ``straggler_factor`` x EMA is flagged (on real fleets this feeds the
    workload manager; here it is surfaced in metrics and counted);
  * optional gradient compression (int8 + error feedback) on the DP reduce;
  * gradient accumulation (microbatching) for memory-constrained configs.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..checkpoint.io import AsyncSaver, latest_step, load_pytree
from ..configs.base import ModelConfig
from ..models import build_model
from .data import DataConfig, SyntheticLMDataset
from .grad_compress import GradCompressor
from .optimizer import OptimizerConfig, make_optimizer

__all__ = ["TrainerConfig", "Trainer", "TrainReport"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    grad_accum: int = 1
    compress_grads: bool = False
    straggler_factor: float = 3.0
    seed: int = 0
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    data: Optional[DataConfig] = None
    moe_impl: str = "einsum"
    ce_chunk: int = 0


@dataclasses.dataclass
class TrainReport:
    final_step: int
    losses: list[float]
    straggler_steps: list[int]
    resumed_from: Optional[int]
    checkpoints: list[int]


class Trainer:
    def __init__(self, model_cfg: ModelConfig, cfg: TrainerConfig) -> None:
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.model = build_model(model_cfg)
        self.opt = make_optimizer(cfg.optimizer)
        data_cfg = cfg.data or DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=256, global_batch=8,
            num_codebooks=model_cfg.num_codebooks,
        )
        self.data = SyntheticLMDataset(data_cfg)
        self.saver = AsyncSaver(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self) -> None:
        model, opt, cfg = self.model, self.opt, self.cfg
        accum = cfg.grad_accum

        def loss_fn(p, batch):
            loss, metrics = model.loss(
                p, batch, moe_impl=cfg.moe_impl, ce_chunk=cfg.ce_chunk
            )
            return loss, metrics

        def train_step(params, opt_state, batch):
            if accum <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                tokens = batch["tokens"]
                micro = tokens.reshape(accum, tokens.shape[0] // accum,
                                       *tokens.shape[1:])

                def body(carry, mb):
                    gsum, lsum = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, {"tokens": mb})
                    gsum = jax.tree.map(jnp.add, gsum, g)
                    return (gsum, lsum + l), None

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), micro)
                grads = jax.tree.map(lambda g: g / accum, gsum)
                loss = lsum / accum
                metrics = {"ce": loss}
            params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
            return params, opt_state, grads, {**metrics, **opt_metrics, "loss": loss}

        self._step = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def run(self, *, resume: bool = True,
            stop_after: Optional[int] = None,
            on_step: Optional[Callable[[int, dict], None]] = None) -> TrainReport:
        cfg = self.cfg
        params = self.model.init(jax.random.key(cfg.seed))
        opt_state = self.opt.init(params)
        start = 0
        resumed_from = None
        if resume:
            last = latest_step(cfg.checkpoint_dir)
            if last is not None:
                state, extra = load_pytree(
                    cfg.checkpoint_dir, last, {"params": params, "opt": opt_state}
                )
                params, opt_state = state["params"], state["opt"]
                start = extra.get("next_step", last)
                resumed_from = last

        comp = GradCompressor.init(params) if cfg.compress_grads else None
        losses: list[float] = []
        stragglers: list[int] = []
        saved: list[int] = []
        ema: Optional[float] = None

        step = start
        for step in range(start, cfg.steps):
            if stop_after is not None and step >= stop_after:
                break
            batch = {
                k: jnp.asarray(v) for k, v in self.data.batch_at(step).items()
                if k != "step"
            }
            t0 = time.perf_counter()
            params, opt_state, grads, metrics = self._step(params, opt_state, batch)
            if comp is not None:
                # compression demo path: quantize the gradient stream the DP
                # reduce would carry; applied pre-update in the sharded step
                _, comp = comp.roundtrip(grads)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            if step == start:
                pass  # first step includes jit compile; exclude from EMA
            elif ema is None:
                ema = dt
            elif dt > cfg.straggler_factor * ema and step > start + 2:
                stragglers.append(step)
            else:
                ema = 0.2 * dt + 0.8 * ema
            if on_step is not None:
                on_step(step, metrics)
            if (step + 1) % cfg.checkpoint_every == 0:
                self.saver.save(
                    step + 1, {"params": params, "opt": opt_state},
                    extra={"next_step": step + 1, "loss": loss},
                )
                saved.append(step + 1)
        self.saver.wait()
        return TrainReport(
            final_step=step + 1 if losses or start else start,
            losses=losses,
            straggler_steps=stragglers,
            resumed_from=resumed_from,
            checkpoints=saved,
        )
