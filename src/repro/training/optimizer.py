"""Optimizers: AdamW and Adafactor (factored second moment), plus gradient
clipping and LR schedules.  No external deps (optax is not installed
offline) — the state layout is explicit so the checkpoint/reshard machinery
can shard it.

Dense architectures default to AdamW.  The giant MoEs (DeepSeek-V3 671B,
Arctic 480B) default to Adafactor: full f32 Adam moments for 671B params
are 5.4 TB — over the 16 GB/chip HBM budget of a 256-chip v5e pod even
fully sharded — while Adafactor's factored row/col statistics are O(d+ff)
per matrix (the T5/PaLM production choice, recorded in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "OptimizerConfig",
    "make_optimizer",
    "Optimizer",
    "global_norm",
    "clip_by_global_norm",
    "warmup_cosine",
]


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def warmup_cosine(
    step: jax.Array, peak_lr: float, warmup_steps: int, total_steps: int,
    min_ratio: float = 0.1,
) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, warmup_steps)
    frac = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"              # adamw | adafactor | sgd
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    # adafactor
    factored_min_dim: int = 128      # factor 2nd moment when both dims >= this
    decay_rate: float = 0.8


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any, dict]]
    config: OptimizerConfig


# ----------------------------------------------------------------- AdamW
def _adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, _unused_lr=None):
        step = state["step"] + 1
        lr = warmup_cosine(step, cfg.peak_lr, cfg.warmup_steps, cfg.total_steps)
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, {
            "lr": lr, "grad_norm": gnorm,
        }

    return Optimizer(init, update, cfg)


# -------------------------------------------------------------- Adafactor
def _factored(shape: tuple[int, ...], cfg: OptimizerConfig) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.factored_min_dim and \
        shape[-2] >= cfg.factored_min_dim


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def stat(p):
            if _factored(p.shape, cfg):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),         # row
                    "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),  # col
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"stats": jax.tree.map(stat, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _unused_lr=None):
        step = state["step"] + 1
        lr = warmup_cosine(step, cfg.peak_lr, cfg.warmup_steps, cfg.total_steps)
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)

        def upd(g, st, p):
            g2 = jnp.square(g) + 1e-30
            if "vr" in st:
                vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(axis=-2)
                rcp = vr / jnp.clip(vr.mean(axis=-1, keepdims=True), 1e-30)
                precond = jnp.sqrt(rcp)[..., None] * jnp.sqrt(vc)[..., None, :]
                delta = g / jnp.clip(precond, 1e-30)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                delta = g / (jnp.sqrt(v) + cfg.eps)
                new_st = {"v": v}
            # update clipping (Adafactor RMS rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_st

        # stats leaves are dicts ({"v"} or {"vr","vc"}): flatten explicitly so
        # the structures line up with the grads/params trees.
        is_stat = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        g_leaves, treedef = jax.tree.flatten(grads)
        s_leaves = jax.tree.flatten(state["stats"], is_leaf=is_stat)[0]
        p_leaves = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_stats = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"stats": new_stats, "step": step}, {
            "lr": lr, "grad_norm": gnorm,
        }

    return Optimizer(init, update, cfg)


def _sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _unused_lr=None):
        step = state["step"] + 1
        lr = warmup_cosine(step, cfg.peak_lr, cfg.warmup_steps, cfg.total_steps)
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params, grads,
        )
        return new_params, {"step": step}, {"lr": lr, "grad_norm": gnorm}

    return Optimizer(init, update, cfg)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.kind == "adamw":
        return _adamw(cfg)
    if cfg.kind == "adafactor":
        return _adafactor(cfg)
    if cfg.kind == "sgd":
        return _sgd(cfg)
    raise ValueError(f"unknown optimizer {cfg.kind!r}")
