"""Gradient compression for the DP all-reduce: stochastic int8 quantization
with error feedback.

At multi-pod scale the gradient all-reduce crosses the slow pod axis;
quantizing to int8 cuts that traffic 4x (vs f32 grads).  Error feedback
(residual accumulation) keeps SGD convergence (Seide et al., Karimireddy
et al.): the quantization error of step t is added back into step t+1's
gradient before quantizing.

Usage: wrap the gradient tree between value_and_grad and optimizer.update:

    comp = GradCompressor.init(params)
    grads, comp = comp.roundtrip(grads)   # quantize -> (all-reduce) -> dequantize
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "GradCompressor"]


class QuantizedTensor(NamedTuple):
    values: jax.Array      # int8
    scale: jax.Array       # f32 per-tensor scale


def quantize_int8(x: jax.Array, key: jax.Array) -> QuantizedTensor:
    """Stochastic rounding to int8 with a per-tensor scale."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scaled = x32 / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale)


def dequantize_int8(q: QuantizedTensor) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale


@dataclasses.dataclass
class GradCompressor:
    """Error-feedback state: one residual per gradient leaf."""

    residuals: Any
    seed: int = 0
    step: int = 0

    @classmethod
    def init(cls, params: Any, seed: int = 0) -> "GradCompressor":
        res = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return cls(residuals=res, seed=seed)

    def roundtrip(self, grads: Any) -> tuple[Any, "GradCompressor"]:
        """Quantize (+residual), dequantize, and record the new residual.

        In the sharded train step the dequantized values feed the all-reduce
        (XLA reduces int8->f32 post-dequant); the compression happens before
        the cross-pod reduce when the grads tree is per-pod.
        """
        key = jax.random.key((self.seed, self.step)[1] * 2654435761 % (2**31) + self.seed)
        leaves, treedef = jax.tree.flatten(grads)
        res_leaves = jax.tree.leaves(self.residuals)
        keys = jax.random.split(key, len(leaves))
        new_grads, new_res = [], []
        for g, r, k in zip(leaves, res_leaves, keys):
            g32 = g.astype(jnp.float32) + r
            q = quantize_int8(g32, k)
            deq = dequantize_int8(q)
            new_grads.append(deq.astype(g.dtype))
            new_res.append(g32 - deq)
        return (
            jax.tree.unflatten(treedef, new_grads),
            dataclasses.replace(
                self,
                residuals=jax.tree.unflatten(treedef, new_res),
                step=self.step + 1,
            ),
        )
