"""Deterministic, resumable synthetic data pipeline.

Production shape: a seeded token stream with document structure (Zipfian
unigrams + short-range Markov correlations, BOS/EOS framing, packing into
fixed-length rows).  The iterator state is one integer (the step) — it
checkpoints alongside the model and resumes bitwise-identically, which the
fault-tolerance tests rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLMDataset"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_codebooks: int = 1       # musicgen: tokens (B, S, K)
    zipf_alpha: float = 1.2
    markov_strength: float = 0.3  # P(next token = f(prev)) for correlation
    bos_id: int = 1
    eos_id: int = 2
    mean_doc_len: int = 512


class SyntheticLMDataset:
    """Deterministic batches: ``batch_at(step)`` is a pure function of
    (config, step), so any worker can resume anywhere."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        # fixed Zipf unigram distribution + a fixed Markov permutation
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        shape = (cfg.global_batch, cfg.seq_len)
        if cfg.num_codebooks > 1:
            shape = (*shape, cfg.num_codebooks)
        base = rng.choice(cfg.vocab_size, size=shape, p=self._probs)
        # short-range correlation: with prob markov_strength, token t is a
        # deterministic function of token t-1 (tests perplexity learnability)
        markov = self._perm[base[:, :-1]] if cfg.num_codebooks == 1 else None
        if markov is not None:
            use = rng.random((cfg.global_batch, cfg.seq_len - 1)) < cfg.markov_strength
            tokens = base.copy()
            tokens[:, 1:] = np.where(use, markov, base[:, 1:])
        else:
            tokens = base
        # document framing: BOS at doc starts (geometric doc lengths)
        doc_starts = rng.random((cfg.global_batch, cfg.seq_len)) < (1.0 / cfg.mean_doc_len)
        doc_starts[:, 0] = True
        if cfg.num_codebooks == 1:
            tokens = np.where(doc_starts, cfg.bos_id, tokens)
        return {"tokens": tokens.astype(np.int32), "step": step}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
