"""repro.training — optimizers, data pipeline, trainer."""
from .optimizer import Optimizer, OptimizerConfig, make_optimizer

__all__ = ["Optimizer", "OptimizerConfig", "make_optimizer"]
