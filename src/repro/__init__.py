"""repro — cost-aware speculative execution for LLM-agent workflows on a
multi-pod JAX substrate (paper: Fareed, CS.DC 2026)."""

__version__ = "1.0.0"
