"""Checkpoint I/O: flat-key npz shards, atomic rename, async save.

Layout: <dir>/step_<N>/
    manifest.json        — step, flat keys, shapes/dtypes, extra metadata
    arrays.npz           — one entry per flattened pytree leaf
Writes go to ``step_<N>.tmp`` and are renamed atomically; a crashed save
never shadows the previous checkpoint (fault-tolerance tests kill a
trainer mid-save and restart).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "AsyncSaver", "latest_step", "available_steps"]

_SEP = "/"


def _flatten_with_keys(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save_pytree(directory: str | Path, step: int, tree: Any,
                extra: Optional[dict] = None) -> Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten_with_keys(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_pytree(directory: str | Path, step: int, like: Any,
                *, shardings: Any = None) -> tuple[Any, dict]:
    """Load into the structure of ``like``.  With ``shardings`` (a matching
    pytree of NamedSharding) each leaf is placed sharded — this is the
    elastic-reshard path: the checkpoint layout is mesh-agnostic, so a
    checkpoint written on one mesh loads onto any other."""
    directory = Path(directory)
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as npz:
        flat = {k: npz[k] for k in npz.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for p, leaf in leaves_like:
        key = _SEP.join(_key_str(k) for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key].astype(np.asarray(leaf).dtype if hasattr(leaf, "dtype") else flat[key].dtype)
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out_leaves
    )
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["extra"]


def available_steps(directory: str | Path) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str | Path) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


class AsyncSaver:
    """One background thread; at most one save in flight (the training loop
    never blocks on I/O unless a save is already pending)."""

    def __init__(self, directory: str | Path, keep: int = 3) -> None:
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async write

        def run():
            try:
                save_pytree(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
