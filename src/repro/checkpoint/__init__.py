"""repro.checkpoint — sharded atomic checkpoints with async save + reshard."""
from .io import AsyncSaver, available_steps, latest_step, load_pytree, save_pytree

__all__ = ["save_pytree", "load_pytree", "AsyncSaver", "latest_step", "available_steps"]
