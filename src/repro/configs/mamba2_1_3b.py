"""--arch config module (one file per assigned architecture)."""
from .archs import MAMBA2_1_3B as CONFIG

__all__ = ["CONFIG"]
