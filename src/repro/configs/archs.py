"""The 10 assigned architectures, exact configs from the assignment table.

Each entry records its source tag.  ``REGISTRY`` maps --arch ids to
ModelConfig; per-arch modules (qwen2_vl_72b.py etc.) re-export for the
one-file-per-arch convention.
"""
from __future__ import annotations

from .base import MLAConfig, MoEConfig, ModelConfig, SSMConfig

__all__ = ["REGISTRY", "get_config"]


# [vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE,
# dynamic resolution [arXiv:2409.12191; hf]
QWEN2_VL_72B = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,                      # qwen2 family QKV bias
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),        # sums to head_dim/2
    vision_tokens=256,                  # stub frontend supplies patch embeds
)

# [dense] 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256
# [hf:meta-llama/Llama-3.2-1B; unverified]
LLAMA3_2_1B = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

# [dense] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
# [arXiv:2403.04652; hf]
YI_34B = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)

# [dense] 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064 — QKV bias
# [hf:Qwen/Qwen2.5-0.5B; hf]
QWEN2_5_32B = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

# [dense] 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152 — code
# [arXiv:2405.04324; hf]
GRANITE_34B = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,                     # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
)

# [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
# + dense residual [hf:Snowflake/snowflake-arctic-base; hf]
ARCTIC_480B = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                          # dense-residual FFN width
    vocab_size=32000,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,            # Arctic's dense-MoE hybrid
    ),
)

# [moe] 61L d_model=7168 128H d_ff=2048 vocab=129280, MoE 256e top-8 — MLA,
# 1 shared + 256 routed top-8, MTP [arXiv:2412.19437; hf]
DEEPSEEK_V3_671B = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,                   # MLA: per-head latent, no GQA grouping
    head_dim=128,
    d_ff=2048,                          # routed-expert width
    vocab_size=129280,
    attn_type="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_dense_layers=3,           # DSv3: first 3 layers dense
        dense_d_ff=18432,
    ),
    mtp_depth=1,                        # multi-token prediction aux head
)

# [hybrid] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 —
# RG-LRU + local attn, 1:2 [arXiv:2402.19427; unverified]
RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,                     # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "attn"),   # 1 attn : 2 recurrent
    local_window=2048,
    lru_width=4096,
    tie_embeddings=True,
    sub_quadratic=True,                 # runs long_500k
)

# [audio] 48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048 —
# decoder-only over EnCodec tokens [arXiv:2306.05284; hf]
MUSICGEN_MEDIUM = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,                    # EnCodec RVQ codebooks, delay pattern
)

# [ssm] 48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128 —
# SSD (state-space duality) [arXiv:2405.21060; unverified]
MAMBA2_1_3B = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    sub_quadratic=True,                 # runs long_500k
)


REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        QWEN2_VL_72B,
        LLAMA3_2_1B,
        YI_34B,
        QWEN2_5_32B,
        GRANITE_34B,
        ARCTIC_480B,
        DEEPSEEK_V3_671B,
        RECURRENTGEMMA_9B,
        MUSICGEN_MEDIUM,
        MAMBA2_1_3B,
    ]
}


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}") from None
