"""--arch config module (one file per assigned architecture)."""
from .archs import RECURRENTGEMMA_9B as CONFIG

__all__ = ["CONFIG"]
