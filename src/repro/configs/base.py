"""Model/shape configuration system.

One ``ModelConfig`` per assigned architecture (exact numbers from the
assignment table), plus reduced smoke variants and the four assigned input
shapes.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation); smoke tests use ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "shape_for",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0          # deepseek: 1 shared
    dense_residual: bool = False         # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    first_dense_layers: int = 0          # deepseek: first 3 layers are dense FFN
    dense_d_ff: Optional[int] = None     # d_ff of those dense layers
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_type: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    local_window: Optional[int] = None                     # sliding-window attn
    # hybrid pattern: per-layer kinds, cycled; e.g. ("rglru","rglru","attn")
    layer_pattern: Optional[tuple[str, ...]] = None
    lru_width: Optional[int] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # audio (musicgen): decoder over K EnCodec codebooks
    num_codebooks: int = 1
    # vlm (qwen2-vl): stub frontend supplies this many patch embeddings
    vision_tokens: int = 0

    mtp_depth: int = 0               # deepseek multi-token prediction heads
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # True when the architecture has a sub-quadratic sequence mixer and can
    # serve the long_500k shape (DESIGN.md §5 skip table)
    sub_quadratic: bool = False
    scan_layers: bool = True
    remat: bool = True
    attn_impl: str = "xla"           # xla | pallas (TPU only)

    # ------------------------------------------------------------- derived
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers + head)."""
        d, V, L = self.d_model, self.vocab_size, self.num_layers
        emb = V * d * self.num_codebooks
        head = 0 if self.tie_embeddings else V * d * max(1, self.num_codebooks)
        per_layer = 0
        if self.attn_type == "gqa":
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        elif self.attn_type == "mla":
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (
                m.qk_nope_head_dim + m.v_head_dim
            )
            per_layer += self.num_heads * m.v_head_dim * d
        if self.moe is not None:
            moe_layers = L - self.moe.first_dense_layers
            dense_layers = self.moe.first_dense_layers
            e = self.moe.num_experts + self.moe.num_shared_experts
            moe_ffn = 3 * d * self.moe.d_ff_expert * e
            if self.moe.dense_residual:
                moe_ffn += 3 * d * self.d_ff
            dense_ffn = 3 * d * (self.moe.dense_d_ff or self.d_ff)
            total_ffn = moe_layers * moe_ffn + dense_layers * dense_ffn
            return emb + head + L * per_layer + total_ffn
        if self.ssm is not None:
            s = self.ssm
            din = s.d_inner(d)
            nh = s.num_heads(d)
            per_layer += d * (2 * din + 2 * s.ngroups * s.d_state + nh)
            per_layer += din * d + 2 * nh  # out proj + A, D
        elif self.family == "hybrid":
            lru = self.lru_width or d
            # mix of recurrent + attention layers; count the cycled pattern
            pat = self.layer_pattern or ("attn",)
            n_attn = sum(1 for i in range(L) if pat[i % len(pat)] == "attn")
            n_rec = L - n_attn
            rec = 2 * d * lru + lru * d + 3 * lru  # gates + convs approx
            att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            ffn = 3 * d * self.d_ff
            return emb + head + n_rec * (rec + ffn) + n_attn * (att + ffn)
        if self.d_ff:
            per_layer += 3 * d * self.d_ff
        return emb + head + L * per_layer

    # ------------------------------------------------------------ reductions
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests: few layers, narrow
        width, few experts, tiny vocab."""
        kw: dict = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if self.layer_pattern is None
                           else len(self.layer_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            lru_width=128 if self.lru_width else None,
            vision_tokens=min(self.vision_tokens, 8),
            dtype="float32",
            scan_layers=self.scan_layers,
            remat=False,
            sub_quadratic=self.sub_quadratic,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                dense_d_ff=256 if self.moe.dense_d_ff else None,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                # dropless at smoke scale: capacity dropping is batch-
                # composition-dependent, which would make decode-vs-forward
                # comparisons flaky (GShard drops differ between the full
                # batch and the decode path)
                capacity_factor=8.0,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=32, head_dim=32,
                                            chunk_size=32)
        if self.local_window:
            kw["local_window"] = 64
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None
