"""--arch config module (one file per assigned architecture)."""
from .archs import QWEN2_5_32B as CONFIG

__all__ = ["CONFIG"]
