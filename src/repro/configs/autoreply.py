"""The paper's canonical scenario parameters (DESIGN.md 'Canonical
parameters'): the §10 worked example and the Appendix D AutoReply setup.

Everything in the benchmarks/tests that reproduces a paper number reads
from here, so the two parameter sets exist in exactly one place.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ScenarioParams", "WORKED_EXAMPLE", "AUTOREPLY", "SEED"]

SEED = 20260531  # Appendix D fixed seed


@dataclasses.dataclass(frozen=True)
class ScenarioParams:
    input_tokens: int
    output_tokens: int
    input_price: float          # USD/token
    output_price: float
    latency_savings_s: float    # reclaimable upstream wait L
    lambda_usd_per_s: float

    @property
    def C_spec(self) -> float:
        return (self.input_tokens * self.input_price
                + self.output_tokens * self.output_price)

    @property
    def L_value(self) -> float:
        return self.latency_savings_s * self.lambda_usd_per_s


# §10.1 worked example: C_spec = $0.0165, L_value = $0.05
WORKED_EXAMPLE = ScenarioParams(
    input_tokens=500, output_tokens=1000,
    input_price=3e-6, output_price=15e-6,
    latency_savings_s=5.0, lambda_usd_per_s=0.01,
)

# Appendix D AutoReply: C_spec = $0.0135, L_value = $0.064
AUTOREPLY = ScenarioParams(
    input_tokens=500, output_tokens=800,
    input_price=3e-6, output_price=15e-6,
    latency_savings_s=0.8, lambda_usd_per_s=0.08,
)

assert abs(WORKED_EXAMPLE.C_spec - 0.0165) < 1e-12
assert abs(AUTOREPLY.C_spec - 0.0135) < 1e-12
assert abs(AUTOREPLY.L_value - 0.064) < 1e-12
