"""--arch config module (one file per assigned architecture)."""
from .archs import DEEPSEEK_V3_671B as CONFIG

__all__ = ["CONFIG"]
