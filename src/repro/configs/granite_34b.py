"""--arch config module (one file per assigned architecture)."""
from .archs import GRANITE_34B as CONFIG

__all__ = ["CONFIG"]
