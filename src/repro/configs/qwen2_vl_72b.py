"""--arch config module (one file per assigned architecture)."""
from .archs import QWEN2_VL_72B as CONFIG

__all__ = ["CONFIG"]
