"""--arch config module (one file per assigned architecture)."""
from .archs import LLAMA3_2_1B as CONFIG

__all__ = ["CONFIG"]
