"""--arch config module (one file per assigned architecture)."""
from .archs import YI_34B as CONFIG

__all__ = ["CONFIG"]
