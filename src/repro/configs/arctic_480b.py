"""--arch config module (one file per assigned architecture)."""
from .archs import ARCTIC_480B as CONFIG

__all__ = ["CONFIG"]
