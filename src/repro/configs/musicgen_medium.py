"""--arch config module (one file per assigned architecture)."""
from .archs import MUSICGEN_MEDIUM as CONFIG

__all__ = ["CONFIG"]
