"""repro.configs — assigned architectures, shapes, and scenario configs."""
from .archs import REGISTRY, get_config
from .base import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    shape_for,
)

__all__ = [
    "REGISTRY", "get_config", "ModelConfig", "MoEConfig", "MLAConfig",
    "SSMConfig", "ShapeConfig", "SHAPES", "shape_for",
]
