"""Regression + property tests for predictor._freeze / _thaw: the
canonical hashable form must be total over every container the §3.2
predictors log (sets, frozensets, dicts with mixed-type keys, bytearrays,
arbitrary nesting), invert exactly through _thaw, and be deterministic
regardless of container iteration order.  The original implementation
raised TypeError on any set/frozenset output (unhashable Counter key),
killing observe() mid-calibration."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import HistoricalModalPredictor, _freeze, _thaw

ATOMS = [None, True, False, 0, 1, -3, 2.5, float("inf"), "", "a", "topic",
         b"bytes", (1, "t"), frozenset({1, 2})]


def build_value(rng: random.Random, depth: int = 0):
    """A random nested container over mixed-type atoms — the shapes a
    logged upstream output can take."""
    if depth >= 3 or rng.random() < 0.4:
        return rng.choice(ATOMS)
    kind = rng.randrange(5)
    n = rng.randrange(4)
    if kind == 0:
        return [build_value(rng, depth + 1) for _ in range(n)]
    if kind == 1:
        return tuple(build_value(rng, depth + 1) for _ in range(n))
    if kind == 2:
        # dict keys: any frozen-able hashable atom mix
        return {rng.choice(ATOMS): build_value(rng, depth + 1)
                for _ in range(n)}
    if kind == 3:
        return {rng.choice(ATOMS) for _ in range(n)}
    return bytearray(rng.randrange(8))


class TestFreezeThaw:
    @settings(max_examples=60)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_roundtrip_and_hashability(self, seed):
        """hash(_freeze(x)) never raises and _thaw inverts exactly, over
        randomized nested containers with mixed-type elements."""
        rng = random.Random(seed)
        for _ in range(5):
            value = build_value(rng)
            frozen = _freeze(value)
            hash(frozen)                      # Counter-key contract
            assert _thaw(frozen) == value
            assert type(_thaw(frozen)) is type(value)

    @settings(max_examples=40)
    @given(st.lists(st.integers(min_value=-50, max_value=50), max_size=8),
           st.text(max_size=6))
    def test_order_independence(self, ints, text):
        """Sets / dicts freeze identically whatever order their elements
        were inserted in (the determinism the modal Counter requires)."""
        mixed = list(dict.fromkeys(ints + list(text)))  # dedup, keep mix
        fwd, rev = set(mixed), set(reversed(mixed))
        assert _freeze(fwd) == _freeze(rev)
        d_fwd = {k: i for i, k in enumerate(mixed)}
        d_rev = dict(reversed(list(d_fwd.items())))
        assert _freeze(d_fwd) == _freeze(d_rev)

    def test_mixed_type_set_does_not_raise(self):
        """{1, "a"} has no natural sort order — sorting by repr of the
        frozen element must keep _freeze total."""
        frozen = _freeze({1, "a", (2, "b"), None})
        hash(frozen)
        assert _thaw(frozen) == {1, "a", (2, "b"), None}

    def test_container_tags_distinguish_types(self):
        """list vs tuple vs set vs frozenset of the same elements freeze
        to distinct keys (distinct outputs must not alias in the modal
        Counter)."""
        variants = [[1, 2], (1, 2), {1, 2}, frozenset({1, 2}),
                    bytearray(b"\x01\x02")]
        frozen = [_freeze(v) for v in variants]
        assert len(set(frozen)) == len(frozen)
        for v, f in zip(variants, frozen):
            got = _thaw(f)
            assert got == v and type(got) is type(v)

    def test_nested_dict_with_container_keys(self):
        value = {("k", frozenset({1})): {"inner": [{1, "x"}, bytearray(b"z")]}}
        assert _thaw(_freeze(value)) == value


class TestPredictorWithSetOutputs:
    def test_observe_set_output_regression(self):
        """The original _freeze left sets unhashable — observe() raised
        TypeError on the first set-valued upstream output."""
        p = HistoricalModalPredictor()
        p.observe("q", {"entity-1", "entity-2"})
        p.observe("q", {"entity-2", "entity-1"})    # same set, other order
        p.observe("q", {"entity-3"})
        pred = p.predict("q")
        assert pred.i_hat == {"entity-1", "entity-2"}
        assert pred.confidence == pytest.approx(2 / 3)

    def test_predict_topk_confidences(self):
        p = HistoricalModalPredictor()
        for out, n in ((frozenset({"a"}), 5), ({"b": 1}, 3), (["c"], 2)):
            for _ in range(n):
                p.observe("q", out)
        top = p.predict_topk("q", 3)
        assert [t.i_hat for t in top] == [frozenset({"a"}), {"b": 1}, ["c"]]
        confs = [t.confidence for t in top]
        assert confs == sorted(confs, reverse=True)
        assert confs == pytest.approx([0.5, 0.3, 0.2])
        assert sum(confs) <= 1.0 + 1e-12
        # the top-1 of the beam is predict()
        assert top[0].i_hat == p.predict("q").i_hat
        assert p.predict_topk("q", 2) == top[:2]
        # no history at all -> empty beam
        assert HistoricalModalPredictor().predict_topk("q", 3) == []
        with pytest.raises(ValueError):
            p.predict_topk("q", 0)
