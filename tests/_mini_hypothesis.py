"""Minimal, dependency-free stand-in for the `hypothesis` API surface the
test suite uses (given / settings / strategies.{floats,integers,booleans,
lists,text}).

The container image does not ship hypothesis and the repo policy forbids
installing packages, so ``conftest.py`` installs this module under the
``hypothesis`` name *only when the real library is absent*.  Draws are
deterministic (seeded per test name) and always include the strategy
boundary values first, so the invariant tests keep their edge-case
coverage.  This is intentionally NOT a property-testing engine — no
shrinking, no database — just enough to execute the suite's @given tests.
"""
from __future__ import annotations

import itertools
import random
import string
import zlib

__all__ = ["given", "settings", "strategies", "HealthCheck", "assume"]

_DEFAULT_MAX_EXAMPLES = 50


class HealthCheck:  # placeholder namespace, matching hypothesis.HealthCheck
    all = staticmethod(lambda: [])


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


class _Strategy:
    """A draw(rng) callable plus the boundary examples to try first."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def draw(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw) -> _Strategy:
        lo, hi = float(min_value), float(max_value)
        mid = lo + (hi - lo) * 0.5
        return _Strategy(
            lambda rng: rng.uniform(lo, hi), boundaries=(lo, hi, mid)
        )

    @staticmethod
    def integers(min_value=0, max_value=100, **_kw) -> _Strategy:
        lo, hi = int(min_value), int(max_value)
        return _Strategy(
            lambda rng: rng.randint(lo, hi), boundaries=(lo, hi)
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, boundaries=(False, True))

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10, **_kw) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]

        smallest = [
            (elements.boundaries[0] if elements.boundaries else elements.draw(random.Random(0)))
        ] * max(1, min_size)
        return _Strategy(draw, boundaries=([] if min_size == 0 else smallest,))

    @staticmethod
    def text(min_size=0, max_size=20, alphabet=None, **_kw) -> _Strategy:
        chars = alphabet or (string.ascii_letters + string.digits + " _-.\n")

        def draw(rng):
            n = rng.randint(min_size, max_size)
            return "".join(rng.choice(chars) for _ in range(n))

        return _Strategy(draw, boundaries=("" if min_size == 0 else "a" * min_size,))


strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._mini_hyp_max_examples = max_examples
        return fn

    return deco


def _boundary_combos(strats):
    """First examples: cartesian boundary combos (capped), like hypothesis's
    preference for edge values."""
    per = [s.boundaries or (s.draw(random.Random(0)),) for s in strats]
    return list(itertools.islice(itertools.product(*per), 32))


def given(*arg_strats, **kw_strats):
    def deco(fn):
        max_examples = getattr(fn, "_mini_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
        names = list(kw_strats)
        strats = list(arg_strats) + [kw_strats[k] for k in names]

        def wrapper(*outer_args, **outer_kw):
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            combos = _boundary_combos(strats)
            ran = 0
            trial = 0
            while ran < max_examples:
                trial += 1
                if trial > max_examples * 10 + len(combos):
                    break  # too many assume() rejections
                if combos:
                    values = list(combos.pop(0))
                else:
                    values = [s.draw(rng) for s in strats]
                pos = values[: len(arg_strats)]
                kw = dict(zip(names, values[len(arg_strats):]))
                try:
                    fn(*outer_args, *pos, **outer_kw, **kw)
                except _Unsatisfied:
                    continue
                # Exception only: KeyboardInterrupt/SystemExit and pytest's
                # Skipped/Failed (BaseException subclasses) must propagate
                except Exception as exc:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"mini-hypothesis falsifying example for "
                        f"{fn.__qualname__}: args={pos} kwargs={kw}"
                    ) from exc
                ran += 1
            if ran == 0:
                # mirror hypothesis's FailedHealthCheck: a test whose every
                # draw was rejected must not silently pass
                raise AssertionError(
                    f"mini-hypothesis: assume() rejected every example for "
                    f"{fn.__qualname__}; the test executed zero examples"
                )

        # keep identity for test reports, but NOT the signature (pytest
        # would otherwise treat the strategy parameters as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        # pytest plugins (anyio) introspect fn.hypothesis.inner_test
        wrapper.hypothesis = type("_Hyp", (), {"inner_test": staticmethod(fn)})()
        return wrapper

    return deco
