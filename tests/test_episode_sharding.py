"""Episode-sharded replay engine: ``episode_sharded_replay`` must be
bitwise-f64 equal to the unsharded ``fleet_replay`` on the same log —
decisions, flags, times and posterior trajectories exactly, EV/waste to
the established 1-ULP FMA allowance — across segment counts (including a
ragged last chunk), discounted posteriors, §7.5 credible-bound gating and
streaming cancels.  Plus the ``chunk_episodes`` input contract and the
``lax.associative_scan`` closed-form composition of segment posteriors.
(The 8-forced-device shard_map row lives in tests/test_multidevice.py.)
"""
import dataclasses

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    Edge,
    Operation,
    PlannerParams,
    Workflow,
    chunk_episodes,
    compose_segment_posteriors,
    episode_sharded_replay,
    fleet_replay,
    lower_workflow,
)
from repro.core.posterior import BetaPosterior
from repro.core.predictor import TemplatePredictor

from test_fleet_parity import ULP, make_random_dag
from test_fleet_multitenant import _lower_dag

GRID_ALPHAS = np.array([0.0, 0.5, 0.9])
GRID_LAMS = np.array([0.01, 0.08, 0.08])
SEGMENTS = (1, 2, 3, 7)   # 7 does not divide the 10-episode logs: ragged


def _assert_sharded_parity(base, sharded, *, ev_ulp=False):
    """Everything bitwise; ``ev_ulp`` gives the EV column the 1-ULP
    allowance (the segment-vmapped betaincinv can fuse one multiply
    differently than the unvmapped scan — same convention as the
    tenant-vmapped §7.5 rows in tests/test_fleet_multitenant.py)."""
    for f in dataclasses.fields(base):
        if ev_ulp and f.name == "EV_usd":
            np.testing.assert_allclose(
                base.EV_usd, sharded.EV_usd, **ULP, err_msg="EV_usd")
            continue
        np.testing.assert_array_equal(
            getattr(base, f.name), getattr(sharded, f.name),
            err_msg=f.name)


@pytest.mark.parametrize("n_segments", SEGMENTS)
@pytest.mark.parametrize("seed", range(4))
def test_random_dag_sharded_bitwise_parity(seed, n_segments):
    """Randomized DAGs, C ∈ {1, 2, 3, 7} over 10-episode logs (7 leaves a
    ragged last chunk): the two-pass sharded replay is bitwise-f64 equal
    to the single sequential scan."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(seed, episodes=10))
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=n_segments)
        _assert_sharded_parity(base, sharded)


@pytest.mark.parametrize("n_segments", SEGMENTS)
@pytest.mark.parametrize("seed", [100, 101])
def test_sharded_discounted_posterior_parity(seed, n_segments):
    """discount<1: the exponential-forgetting carry hands off exactly at
    segment boundaries (the sequential-handoff regime — there is no
    associative closed form to fall back on)."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(seed, episodes=10, discount=0.9))
        assert np.any(lowered.discount[lowered.has_edge] < 1.0)
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=n_segments)
        _assert_sharded_parity(base, sharded)


@pytest.mark.parametrize("n_segments", SEGMENTS)
@pytest.mark.parametrize("seed", range(4))
def test_sharded_lower_bound_parity(seed, n_segments):
    """§7.5 credible-bound gating: the betaincinv inversion runs on each
    segment's carried-in posterior and must track the unsharded scan —
    decisions, flags and posteriors bitwise, EV to 1 ULP."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(seed, episodes=10, use_lower_bound=True))
        assert lowered.use_lower_bound
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=n_segments)
        _assert_sharded_parity(base, sharded, ev_ulp=True)


def _make_stream_case(E=10, K=4):
    """The §9.1 streaming-cancel test vector: a chunked u -> v edge whose
    replay mixes cancelled and surviving streams.  Returns (lowered,
    success, cP)."""
    rng = np.random.default_rng(7)
    chunk_P = rng.uniform(0.05, 0.95, (E, K))

    wf = Workflow("stream")
    wf.add_op(Operation(
        "u", run=lambda x: "chunked-output-string-for-u",
        latency_est_s=2.0, input_tokens_est=100, output_tokens_est=50,
        metadata={"input": "doc", "chunks": K},
    ))
    wf.add_op(Operation(
        "v", run=lambda i: f"v({i})", latency_est_s=1.5,
        input_tokens_est=400, output_tokens_est=900,
    ))
    wf.add_edge(Edge("u", "v"))
    wf = wf.freeze()
    key = ("u", "v")
    params = PlannerParams(
        alpha=0.4, lambda_usd_per_s=0.08,
        posteriors={key: BetaPosterior.from_prior_mean(0.9)},
    )
    pred = {key: TemplatePredictor(
        template=lambda i, p=None: "chunked-output-string-for-u")}
    lowered = lower_workflow(
        wf, params, predictors=pred,
        stream_refiners={key: lambda i, p: (None, 0.0)},
    )
    vi = lowered.names.index("v")
    success = np.ones((E, lowered.n_ops), bool)
    cP = np.ones((E, lowered.n_ops, K))
    cP[:, vi, :] = chunk_P
    return lowered, success, cP


@pytest.mark.parametrize("n_segments", SEGMENTS)
def test_sharded_streaming_cancel_parity(n_segments):
    """§9.1 mid-stream cancellation (chunk_P + stream refiner): chunk
    verdicts, fractional waste and makespans survive episode sharding
    bitwise — including when a cancel lands in a ragged last chunk."""
    with enable_x64():
        lowered, success, cP = _make_stream_case()
        base = fleet_replay(lowered, success, [0.4], [0.08], chunk_P=cP)
        assert base.cancelled.any() and not base.cancelled.all(), \
            "test vector should mix cancelled and surviving streams"
        sharded = episode_sharded_replay(
            lowered, success, [0.4], [0.08], chunk_P=cP,
            n_segments=n_segments)
        _assert_sharded_parity(base, sharded)


def test_sharded_respects_caller_ep_mask():
    """A caller-masked (identity) episode in the middle of the log stays
    an identity step in whichever segment it lands in."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(2, episodes=9))
        mask = np.ones(9, bool)
        mask[[2, 5, 6]] = False
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok, ep_mask=mask)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            ep_mask=mask, n_segments=3)
        _assert_sharded_parity(base, sharded)


def test_more_segments_than_episodes():
    """C > E leaves trailing all-masked segments — pure identity scans
    that must not perturb the stats or the final carry."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(4, episodes=3))
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=7)
        _assert_sharded_parity(base, sharded)


def test_associative_composition_matches_sequential_handoff():
    """discount=1 closed form: one ``lax.associative_scan`` over the
    per-segment (Δs, Δf) sufficient statistics rebuilds every
    segment-boundary posterior the sequential handoff produced (1-ULP:
    ``prior + Σcounts`` rounds once where the in-scan carry rounds per
    episode)."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(0, episodes=12))
        C = 4
        report, starts = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=C, return_boundaries=True)
        chunks = chunk_episodes(lowered, success, C, pred_ok=pred_ok)
        S, E = chunks.seg_len, chunks.n_episodes
        pad = C * S - E

        def segs(x):
            if pad:
                x = np.concatenate(
                    [x, np.zeros((pad,) + x.shape[1:], x.dtype)])
            return x.reshape((C, S) + x.shape[1:])

        launched = report.edge_launched.astype(bool)
        committed = report.edge_committed.astype(bool)
        ds = segs((launched & committed).astype(float)).sum(1)
        df = segs((launched & ~committed).astype(float)).sum(1)
        composed = compose_segment_posteriors(lowered.a0, lowered.b0, ds, df)
        assert composed.shape == starts.shape == (
            C, len(GRID_ALPHAS), lowered.n_ops, 2)
        np.testing.assert_allclose(composed, starts, **ULP)


def test_chunk_episodes_rejects_empty_log_and_bad_segments():
    """Regression (satellite): an E=0 log used to be representable as an
    all-identity segment that replays to zero stats; it is now rejected
    loudly, as are non-positive segment counts."""
    lowered, success, pred_ok = _lower_dag(make_random_dag(0, episodes=4))
    with pytest.raises(ValueError, match="at least one episode"):
        chunk_episodes(lowered, success[:0], 2, pred_ok=pred_ok[:0])
    with pytest.raises(ValueError, match="n_segments"):
        chunk_episodes(lowered, success, 0, pred_ok=pred_ok)
    with pytest.raises(ValueError, match="success"):
        chunk_episodes(lowered, success[:, :1], 2)
    # ragged split: ceil sizing, padded tail masked off
    ch = chunk_episodes(lowered, success, 3, pred_ok=pred_ok)
    assert (ch.n_segments, ch.seg_len, ch.n_episodes) == (3, 2, 4)
    assert ch.ep_mask.sum() == 4 and not ch.ep_mask[-1, -1]


class TestPipelinedReplay:
    """``pipelined=True``: the host-loop overlap (segment c's stats
    dispatched the moment its boundary carry exists, the carry advanced
    immediately after) must not change a single bit relative to the
    two-pass engine or the unsharded scan — same per-segment scan
    bodies, same sequential handoff semantics, only the dispatch order
    differs."""

    @pytest.mark.parametrize("n_segments", SEGMENTS)
    @pytest.mark.parametrize("seed", range(2))
    def test_random_dag_bitwise(self, seed, n_segments):
        with enable_x64():
            lowered, success, pred_ok = _lower_dag(
                make_random_dag(seed, episodes=10))
            base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                                pred_ok=pred_ok)
            piped = episode_sharded_replay(
                lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
                n_segments=n_segments, pipelined=True)
            _assert_sharded_parity(base, piped)

    @pytest.mark.parametrize("n_segments", SEGMENTS)
    def test_discounted(self, n_segments):
        """discount<1: the forgetting carry hands off exactly — the
        regime with no associative fallback, so the pipelined handoff
        must be the same sequential recurrence."""
        with enable_x64():
            lowered, success, pred_ok = _lower_dag(
                make_random_dag(100, episodes=10, discount=0.9))
            assert np.any(lowered.discount[lowered.has_edge] < 1.0)
            base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                                pred_ok=pred_ok)
            piped = episode_sharded_replay(
                lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
                n_segments=n_segments, pipelined=True)
            _assert_sharded_parity(base, piped)

    @pytest.mark.parametrize("n_segments", SEGMENTS)
    def test_lower_bound(self, n_segments):
        """§7.5 credible-bound gating through the pipelined path: same
        EV convention as the two-pass engine (1 ULP for the betaincinv
        fusion), everything else bitwise."""
        with enable_x64():
            lowered, success, pred_ok = _lower_dag(
                make_random_dag(1, episodes=10, use_lower_bound=True))
            assert lowered.use_lower_bound
            base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                                pred_ok=pred_ok)
            piped = episode_sharded_replay(
                lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
                n_segments=n_segments, pipelined=True)
            _assert_sharded_parity(base, piped, ev_ulp=True)

    @pytest.mark.parametrize("n_segments", SEGMENTS)
    def test_streaming_cancel(self, n_segments):
        with enable_x64():
            lowered, success, cP = _make_stream_case()
            base = fleet_replay(lowered, success, [0.4], [0.08],
                                chunk_P=cP)
            piped = episode_sharded_replay(
                lowered, success, [0.4], [0.08], chunk_P=cP,
                n_segments=n_segments, pipelined=True)
            _assert_sharded_parity(base, piped)

    def test_boundaries_and_stats_match_two_pass(self):
        """Direct pipelined-vs-two-pass check: identical segment-start
        carries (return_boundaries) and identical stat blocks."""
        with enable_x64():
            lowered, success, pred_ok = _lower_dag(
                make_random_dag(5, episodes=12))
            two_pass, b2 = episode_sharded_replay(
                lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
                n_segments=4, return_boundaries=True)
            piped, bp = episode_sharded_replay(
                lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
                n_segments=4, pipelined=True, return_boundaries=True)
            np.testing.assert_array_equal(b2, bp)
            _assert_sharded_parity(two_pass, piped)


def test_sharded_pareto_matches_unsharded():
    """The §12.3 Pareto consumer contract survives sharding (means over
    real episodes only)."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(3, episodes=10))
        base = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                            pred_ok=pred_ok)
        sharded = episode_sharded_replay(
            lowered, success, GRID_ALPHAS, GRID_LAMS, pred_ok=pred_ok,
            n_segments=3)
        pb, ps = base.pareto(), sharded.pareto()
        for k in ("latency_s", "cost_usd", "waste_usd", "launched",
                  "committed"):
            np.testing.assert_array_equal(pb[k], ps[k], err_msg=f"pareto {k}")
