"""Multi-tenant sharded fleet engine: T stacked tenants — each with its
own padded DAG, taxonomy-keyed prior, gamma and (ragged) episode log —
must replay bitwise-identically (float64) to T independent single-tenant
``fleet_replay`` calls, masked episodes must be identity scan steps, and
the donatable posterior carry must chain across calibration rounds.
(The 8-forced-device shard_map case lives in tests/test_multidevice.py.)
"""
import dataclasses

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    fleet_replay,
    lower_workflow,
    multi_tenant_replay,
    stack_tenants,
)
from repro.core.drift import DriftMonitor, TriggerKind

from test_fleet_parity import make_random_dag

GRID_ALPHAS = np.array([0.0, 0.5, 0.9])
GRID_LAMS = np.array([0.01, 0.08, 0.08])


def _lower_dag(dag):
    """Lower a RandomDag and reorder its episode arrays to topo order."""
    params = dag.fresh_params(0.5, 0.01)
    wf = dag.build_workflow(0)
    lowered = lower_workflow(wf, params, predictors=dag.predictors(0))
    order = np.array([int(n[1:]) for n in lowered.names])
    return lowered, dag.success[:, order], dag.pred_ok[:, order]


def _stack_for(seeds, episodes=None, **dag_kw):
    lowereds, succs, preds = [], [], []
    for i, seed in enumerate(seeds):
        e = episodes[i] if episodes is not None else 6
        lowered, success, pred_ok = _lower_dag(
            make_random_dag(seed, episodes=e, **dag_kw))
        lowereds.append(lowered)
        succs.append(success)
        preds.append(pred_ok)
    return (stack_tenants(lowereds, succs, pred_oks=preds),
            lowereds, succs, preds)


def _assert_tenant_parity(report, lowereds, succs, preds, *, ev_ulp=False):
    """Every field bitwise; with ``ev_ulp`` the EV column gets a 1-ULP
    allowance — the batched betaincinv can fuse one multiply differently
    under the tenant vmap than the single-tenant executable (same
    convention as the §7.5 rows in tests/test_fleet_parity.py); decisions,
    flags, timing, waste and posteriors stay bitwise either way."""
    for t, (lowered, success, pred_ok) in enumerate(
            zip(lowereds, succs, preds)):
        single = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                              pred_ok=pred_ok)
        tr = report.tenant_report(t)
        for f in dataclasses.fields(single):
            if ev_ulp and f.name == "EV_usd":
                np.testing.assert_allclose(
                    single.EV_usd, tr.EV_usd, rtol=1e-13, atol=1e-16,
                    err_msg=f"tenant {t} field EV_usd")
                continue
            np.testing.assert_array_equal(
                getattr(single, f.name), getattr(tr, f.name),
                err_msg=f"tenant {t} field {f.name}")


@pytest.mark.parametrize("seeds", [(0, 1, 2, 3), (4, 5, 6)])
def test_multi_tenant_bitwise_parity(seeds):
    """Randomized per-tenant DAGs + priors, ragged op counts: the stacked
    one-call replay slices back to each tenant's independent
    ``fleet_replay`` bitwise at float64."""
    with enable_x64():
        episodes = [5 + i for i in range(len(seeds))]   # ragged on purpose
        stack, lowereds, succs, preds = _stack_for(seeds, episodes)
        report = multi_tenant_replay(stack, GRID_ALPHAS, GRID_LAMS)
        _assert_tenant_parity(report, lowereds, succs, preds)


def test_multi_tenant_lower_bound_and_per_tenant_gamma():
    """§7.5 credible-bound gating with a *different* gamma per tenant:
    each tenant's slice must equal its own single-tenant run (which
    carries that tenant's gamma into betaincinv)."""
    with enable_x64():
        gammas = (0.05, 0.25)
        lowereds, succs, preds = [], [], []
        for seed, gamma in zip((0, 3), gammas):
            dag = make_random_dag(seed, episodes=5, use_lower_bound=True)
            dag.gamma = gamma
            lowered, success, pred_ok = _lower_dag(dag)
            assert lowered.use_lower_bound and lowered.gamma == gamma
            lowereds.append(lowered)
            succs.append(success)
            preds.append(pred_ok)
        stack = stack_tenants(lowereds, succs, pred_oks=preds)
        assert stack.use_lower_bound
        np.testing.assert_array_equal(stack.gammas, gammas)
        report = multi_tenant_replay(stack, GRID_ALPHAS, GRID_LAMS)
        _assert_tenant_parity(report, lowereds, succs, preds, ev_ulp=True)


def test_ragged_episodes_do_not_perturb_other_tenants():
    """Regression (satellite): a tenant with fewer logs must not change
    the posterior trajectory (or any stats) of tenants that have more —
    masked scan steps are identity updates."""
    with enable_x64():
        long_low, long_suc, long_pred = _lower_dag(
            make_random_dag(1, episodes=8))
        short_low, short_suc, short_pred = _lower_dag(
            make_random_dag(2, episodes=3))

        solo = multi_tenant_replay(
            stack_tenants([long_low], [long_suc], pred_oks=[long_pred]),
            GRID_ALPHAS, GRID_LAMS)
        both = multi_tenant_replay(
            stack_tenants([long_low, short_low], [long_suc, short_suc],
                          pred_oks=[long_pred, short_pred]),
            GRID_ALPHAS, GRID_LAMS)

        a = solo.tenant_report(0)
        b = both.tenant_report(0)
        for f in dataclasses.fields(a):
            np.testing.assert_array_equal(
                getattr(a, f.name), getattr(b, f.name), err_msg=f.name)

        # the short tenant's padded episodes: zero stats, carried posterior
        E_s = both.n_episodes[1]
        assert E_s == 3
        np.testing.assert_array_equal(both.launched[1, E_s:], 0)
        np.testing.assert_array_equal(both.makespan_s[1, E_s:], 0.0)
        np.testing.assert_array_equal(both.waste_usd[1, E_s:], 0.0)
        V_s = both.n_ops[1]
        carried_a = both.post_alpha[1, E_s - 1, :, :V_s]
        carried_b = both.post_beta[1, E_s - 1, :, :V_s]
        for e in range(E_s, both.post_alpha.shape[1]):
            np.testing.assert_array_equal(
                both.post_alpha[1, e, :, :V_s], carried_a)
            np.testing.assert_array_equal(
                both.post_beta[1, e, :, :V_s], carried_b)
        # and the final carry equals the last real episode's posterior
        np.testing.assert_array_equal(
            np.asarray(both.post_final)[1, :, :V_s, 0], carried_a)
        np.testing.assert_array_equal(
            np.asarray(both.post_final)[1, :, :V_s, 1], carried_b)


def test_posterior_carry_chains_across_rounds():
    """Two replay rounds chained through ``post0=report.post_final`` (the
    donation path) equal one run over the concatenated episode log —
    repeated calibration rounds continue the same trajectories."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(make_random_dag(7, episodes=8))
        stack_all = stack_tenants([lowered], [success], pred_oks=[pred_ok])
        full = multi_tenant_replay(stack_all, GRID_ALPHAS, GRID_LAMS,
                                   donate=False)

        s1 = stack_tenants([lowered], [success[:5]], pred_oks=[pred_ok[:5]])
        s2 = stack_tenants([lowered], [success[5:]], pred_oks=[pred_ok[5:]])
        r1 = multi_tenant_replay(s1, GRID_ALPHAS, GRID_LAMS, donate=False)
        r2 = multi_tenant_replay(s2, GRID_ALPHAS, GRID_LAMS,
                                 post0=r1.post_final, donate=True)

        np.testing.assert_array_equal(
            full.post_alpha[:, 5:], r2.post_alpha)
        np.testing.assert_array_equal(
            full.post_beta[:, 5:], r2.post_beta)
        np.testing.assert_array_equal(full.makespan_s[:, 5:], r2.makespan_s)
        np.testing.assert_array_equal(
            full.edge_committed[:, 5:], r2.edge_committed)
        np.testing.assert_array_equal(
            np.asarray(full.post_final), np.asarray(r2.post_final))


def test_final_posterior_rows_bounds_checks_grid_index():
    """Regression (satellite): ``final_posterior_rows`` trusted the
    caller's ``grid_index`` — an out-of-range or negative index either
    crashed deep in numpy or silently wrapped to a *different operating
    point's* posteriors before feeding the kill-switch.  It now raises a
    clear IndexError at the boundary and still serves every valid
    index."""
    with enable_x64():
        stack, lowereds, succs, preds = _stack_for((0, 2), [4, 4])
        report = multi_tenant_replay(stack, GRID_ALPHAS, GRID_LAMS)
        G = len(GRID_ALPHAS)
        for g in range(G):
            rows, a, b = report.final_posterior_rows(g)
            assert len(rows) == len(a) == len(b) > 0
        for bad in (G, G + 5, -1, -G):
            with pytest.raises(IndexError, match="grid_index"):
                report.final_posterior_rows(bad)


def test_stack_rejects_mixed_lower_bound_and_bad_shapes():
    lowered, success, pred_ok = _lower_dag(make_random_dag(0, episodes=4))
    lb_low, lb_suc, lb_pred = _lower_dag(
        make_random_dag(3, episodes=4, use_lower_bound=True))
    with pytest.raises(ValueError, match="use_lower_bound"):
        stack_tenants([lowered, lb_low], [success, lb_suc])
    with pytest.raises(ValueError, match="success"):
        stack_tenants([lowered], [success[:, :1]])
    with pytest.raises(ValueError, match="unique"):
        stack_tenants([lowered, lowered], [success, success],
                      tenants=["a", "a"])


def test_fleet_replay_ep_mask_identity_steps():
    """Single-workflow ragged support: a masked suffix replays identically
    to truncating the episode log, and ``pareto()`` means are taken over
    the real episodes only (padded zero rows must not dilute the §12.3
    statistics)."""
    with enable_x64():
        lowered, success, pred_ok = _lower_dag(make_random_dag(4, episodes=6))
        mask = np.array([True] * 4 + [False] * 2)
        masked = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS,
                              pred_ok=pred_ok, ep_mask=mask)
        short = fleet_replay(lowered, success[:4], GRID_ALPHAS, GRID_LAMS,
                             pred_ok=pred_ok[:4])
        for f in dataclasses.fields(short):
            if f.name in ("alphas", "lambdas", "ep_mask"):
                continue
            np.testing.assert_array_equal(
                getattr(short, f.name), getattr(masked, f.name)[:4],
                err_msg=f.name)
        assert masked.launched[4:].sum() == 0
        np.testing.assert_array_equal(masked.waste_usd[4:], 0.0)
        p_short, p_masked = short.pareto(), masked.pareto()
        for k in ("latency_s", "cost_usd", "waste_usd", "launched",
                  "committed"):
            np.testing.assert_array_equal(p_short[k], p_masked[k],
                                          err_msg=f"pareto {k}")


def test_fleet_posteriors_feed_drift_monitor_in_one_call():
    """The sharded engine's posterior snapshot drives §12.5 trigger 2
    per (tenant, edge) in a single vectorized call: a drifting tenant's
    kill-switch flips without touching a healthy tenant sharing the same
    edge names."""
    with enable_x64():
        stack, lowereds, succs, preds = _stack_for((0, 2), [6, 6])
        report = multi_tenant_replay(stack, GRID_ALPHAS, GRID_LAMS)
        tenant_edges, post_a, post_b = report.final_posterior_rows(0)
        # both tenants must contribute rows for the isolation check to bite
        assert {t for t, _ in tenant_edges} == set(stack.tenants)
        assert np.all(post_a > 0) and np.all(post_b > 0)

        mon = DriftMonitor(credible_consecutive_n=2)
        # drive one tenant's rows into certain breach, keep the others safe
        rigged_a = np.where([t == "tenant0" for t, _ in tenant_edges],
                            0.5, 50.0)
        rigged_b = np.where([t == "tenant0" for t, _ in tenant_edges],
                            9.5, 1.0)
        for _ in range(2):
            evs = mon.check_credible_bound_fleet(
                tenant_edges, rigged_a, rigged_b,
                alpha=0.5, C_spec=0.0135, L_value=0.064)
        fired = [e for e in evs if e is not None]
        assert fired and all(
            e.kind == TriggerKind.CREDIBLE_BOUND_FLOOR and e.tenant == "tenant0"
            for e in fired)
        for tenant, edge in tenant_edges:
            assert mon.edge_enabled(edge, tenant=tenant) == (
                tenant != "tenant0")
