"""Top-k beam speculation engine (repro.core.beam): the D4 generalization
must collapse bitwise-f64 onto every existing single-candidate path at
``width == 1`` — scalar ``decision.evaluate``, the fused ``d4_gate``, the
fleet replay and the online tick — before any wider-beam claim counts.
``width > 1`` is pinned against the pure-numpy ``reference_beam_replay``
twin, and the §7.6 self-limiting closed form extends to the critical-k
*surface* k_crit(alpha, w) = w (L + C) / ((w + 1 - alpha) C) exactly, in
the style of tests/test_self_limiting.py."""
import dataclasses

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    DependencyType,
    Edge,
    Operation,
    PlannerParams,
    Workflow,
    beam_critical_k,
    beam_evaluate,
    beam_replay,
    expected_beam_waste,
    expected_speculation_waste,
    fleet_replay,
    hit_rank_from_success,
    lower_workflow,
    reference_beam_replay,
)
from repro.core.batch_decision import (
    beam_counterfactual_grid,
    beam_gate,
    counterfactual_grid,
    critical_k_grid,
    critical_k_surface,
    d4_gate,
)
from repro.core.beam import BeamDecisionResult, validate_confidences
from repro.core.decision import Decision, DecisionInputs, critical_k, evaluate
from repro.core.online import OnlineDecisionService
from repro.core.posterior import BetaPosterior
from repro.core.predictor import TemplatePredictor
from repro.core.pricing import TwoRateTokenCost

# the established fleet-parity allowance: everything contraction-free is
# compared bitwise; EV / threshold / waste (products feeding adds that
# XLA may fuse into FMAs) to 1 ULP
ULP = dict(rtol=1e-13, atol=1e-16)

GRID_ALPHAS = np.array([0.0, 0.5, 0.9])
GRID_LAMS = np.array([0.01, 0.08, 0.05])


def _inputs(P=0.7, alpha=0.5, lam=0.01, lat=5.0, in_tok=500, out_tok=1000,
            in_p=3e-6, out_p=15e-6, P_lb=None):
    return DecisionInputs(
        P=P, alpha=alpha, lambda_usd_per_s=lam, latency_seconds=lat,
        input_tokens=in_tok, output_tokens=out_tok, input_price=in_p,
        output_price=out_p, P_lower_bound=P_lb)


# ------------------------------------------------------------ scalar rule
class TestScalarRule:
    def test_w1_bitwise_equals_classic_evaluate(self):
        """width=1 with one certain candidate IS the classic rule —
        bitwise-f64 on every float field, across a parameter sweep."""
        rng = np.random.default_rng(0)
        for _ in range(200):
            inp = _inputs(
                P=float(rng.uniform(0, 1)), alpha=float(rng.uniform(0, 1)),
                lam=float(rng.uniform(1e-4, 0.5)),
                lat=float(rng.uniform(0.01, 5.0)),
                in_tok=int(rng.integers(1, 2000)),
                out_tok=float(rng.uniform(1, 2000)),
                in_p=float(rng.uniform(1e-8, 1e-4)),
                out_p=float(rng.uniform(1e-8, 1e-4)))
            ref = evaluate(inp)
            got = beam_evaluate(inp, (1.0,), 1)
            assert got.decision == ref.decision
            for f in ("EV_usd", "threshold_usd", "C_spec_usd",
                      "L_value_usd", "P_used"):
                assert getattr(got, f) == getattr(ref, f), f
            assert got.w_eff == 1
            assert got.launched == (1 if ref.decision == Decision.SPECULATE
                                    else 0)

    def test_w1_lower_bound_bitwise(self):
        inp = _inputs(P=0.9, P_lb=0.42)
        ref = evaluate(inp, use_lower_bound=True)
        got = beam_evaluate(inp, (1.0,), 1, use_lower_bound=True)
        assert (got.EV_usd, got.P_used) == (ref.EV_usd, ref.P_used)
        with pytest.raises(ValueError):
            beam_evaluate(_inputs(P_lb=None), (1.0,), 1,
                          use_lower_bound=True)

    def test_marginal_rule_trims_uneconomic_tail(self):
        """Candidates are admitted while p_j (L + C) - C >= 0; a weak
        tail candidate is excluded even when the width allows it."""
        # C = 0.0165, L_value = 0.05: candidate needs p_j >= C/(L+C) ~ 0.2481
        inp = _inputs(P=1.0, alpha=0.0)
        res = beam_evaluate(inp, (0.5, 0.3, 0.1), 3)
        assert res.included == (True, True, False)
        assert res.w_eff == 2
        assert res.P_used == pytest.approx(0.8)
        # the admitted beam is a prefix
        assert list(res.included) == sorted(res.included, reverse=True)

    def test_first_candidate_unconditional(self):
        """Candidate 1 is admitted even when its own marginal is
        negative (that case is the classic rule's WAIT territory)."""
        res = beam_evaluate(_inputs(P=0.05), (0.9, 0.1), 2)
        assert res.w_eff == 1
        assert res.included == (True, False)
        assert res.decision == Decision.WAIT
        assert res.launched == 0 and res.expected_losers == 0.0

    def test_width_caps_admission(self):
        inp = _inputs(P=1.0, alpha=0.0)
        r1 = beam_evaluate(inp, (0.5, 0.3, 0.1), 1)
        r2 = beam_evaluate(inp, (0.5, 0.3, 0.1), 2)
        assert (r1.w_eff, r2.w_eff) == (1, 2)
        assert r2.P_used > r1.P_used

    def test_shared_budget_ev(self):
        """EV = P_w L - (w_eff - P_w) C with P_w the beam-cumulative
        commit probability."""
        inp = _inputs(P=1.0, alpha=0.0)
        res = beam_evaluate(inp, (0.5, 0.3), 2)
        C, L = res.C_spec_usd, res.L_value_usd
        assert res.EV_usd == pytest.approx(0.8 * L - (2 - 0.8) * C)
        assert res.expected_losers == pytest.approx(2 - 0.8)

    def test_confidence_validation(self):
        inp = _inputs()
        with pytest.raises(ValueError):
            beam_evaluate(inp, (), 1)                    # empty
        with pytest.raises(ValueError):
            beam_evaluate(inp, (0.3, 0.5), 2)            # not sorted
        with pytest.raises(ValueError):
            beam_evaluate(inp, (0.8, 0.7), 2)            # sums past 1
        with pytest.raises(ValueError):
            beam_evaluate(inp, (1.2,), 1)                # out of [0, 1]
        with pytest.raises(ValueError):
            beam_evaluate(inp, (0.5,), 0)                # width < 1
        assert validate_confidences([0.5, 0.5]) == (0.5, 0.5)


# ----------------------------------------------------- §7.6 critical surface
# same synthetic edges as tests/test_self_limiting.py: k_crit lands at
# different, non-integer places per edge
EDGES = [
    (0.8, 0.08, 500, 800, 3e-6, 15e-6),
    (2.5, 0.08, 200, 400, 3e-6, 15e-6),
    (0.5, 0.01, 1500, 2000, 3e-6, 15e-6),
    (1.0, 0.02, 100, 150, 1e-6, 5e-6),
    (1.2, 0.02, 800, 1200, 2e-6, 10e-6),
]
KS = np.arange(1, 33)
ALPHAS = (0.0, 0.3, 0.5, 0.9, 1.0)
WIDTHS = (1, 2, 3, 4, 8)


def _edge_terms(edge):
    L, lam, in_tok, out_tok, in_p, out_p = edge
    return lam * L, in_tok * in_p + out_tok * out_p


class TestCriticalSurface:
    @pytest.mark.parametrize("alpha", ALPHAS)
    @pytest.mark.parametrize("width", WIDTHS)
    def test_beam_verdict_matches_surface_indicator(self, alpha, width):
        """Under the uniform prior (k branches, conf_j = 1/k, w <= k) the
        beam SPECULATE verdict is exactly the closed-form
        k <= k_crit(alpha, w) indicator — the §7.6 self-limiting law in
        both axes."""
        for edge in EDGES:
            L, lam, in_tok, out_tok, in_p, out_p = edge
            Lv, C = _edge_terms(edge)
            kc = beam_critical_k(Lv, C, alpha, width)
            assert abs(kc - round(kc)) > 1e-6, \
                "test edge parks k_crit on an integer; pick another edge"
            for k in KS[KS >= width]:
                res = beam_evaluate(
                    _inputs(P=1.0, alpha=alpha, lam=lam, lat=L,
                            in_tok=in_tok, out_tok=out_tok, in_p=in_p,
                            out_p=out_p),
                    (1.0 / k,) * int(k), width)
                spec = res.decision == Decision.SPECULATE
                if k <= (Lv + C) / C:
                    # marginal rule admits the full beam
                    assert res.w_eff == width
                    assert spec == (k <= kc)
                else:
                    # prefix rule trims to one candidate; the classic
                    # (always tighter) w=1 bound takes over
                    assert res.w_eff == 1
                    assert spec == (k <= critical_k(Lv, C, alpha))
                    assert not spec and k > kc

    @pytest.mark.parametrize("alpha", ALPHAS)
    def test_speculation_rate_self_limits_in_both_axes(self, alpha):
        """Population speculation rate is non-increasing in branching k
        at every width, non-decreasing in width at every k, and reaches
        zero inside the sweep at every width (the ceiling (L+C)/C is
        finite)."""
        rates = np.zeros((len(WIDTHS), len(KS)))
        for wi, w in enumerate(WIDTHS):
            for ki, k in enumerate(KS):
                decs = []
                for edge in EDGES:
                    if k < w:
                        continue
                    L, lam, in_tok, out_tok, in_p, out_p = edge
                    res = beam_evaluate(
                        _inputs(P=1.0, alpha=alpha, lam=lam, lat=L,
                                in_tok=in_tok, out_tok=out_tok,
                                in_p=in_p, out_p=out_p),
                        (1.0 / k,) * int(k), int(w))
                    decs.append(res.decision == Decision.SPECULATE)
                rates[wi, ki] = np.mean(decs) if decs else np.nan
        for wi in range(len(WIDTHS)):
            row = rates[wi][~np.isnan(rates[wi])]
            assert all(a >= b for a, b in zip(row, row[1:]))
            assert row[-1] == 0.0                    # k=32 self-limits
        # wider beams keep speculating at higher k (monotone in w)
        valid = ~np.isnan(rates).any(0)
        assert (np.diff(rates[:, valid], axis=0) >= 0.0).all()
        assert rates[-1, valid].max() >= rates[0, valid].max()

    def test_closed_form_properties(self):
        for edge in EDGES:
            Lv, C = _edge_terms(edge)
            for alpha in ALPHAS:
                # w=1 reduces to the classic critical_k
                assert beam_critical_k(Lv, C, alpha, 1) == pytest.approx(
                    critical_k(Lv, C, alpha), rel=1e-12)
                kcs = [beam_critical_k(Lv, C, alpha, w)
                       for w in range(1, 200)]
                # non-decreasing up to float wobble (exactly constant in
                # exact arithmetic at alpha = 1)
                assert all(a <= b + 1e-12 * abs(b)
                           for a, b in zip(kcs, kcs[1:]))
                assert all(kc <= (Lv + C) / C + 1e-12 for kc in kcs)
        with pytest.raises(ValueError):
            beam_critical_k(1.0, 0.0, 0.5, 2)
        with pytest.raises(ValueError):
            beam_critical_k(1.0, 0.1, 0.5, 0)

    def test_surface_grid_matches_scalar_closed_form(self):
        """critical_k_surface == scalar beam_critical_k over the full
        (width, alpha) cross; the w=1 row is critical_k_grid (f64)."""
        with enable_x64():
            alphas = np.asarray(ALPHAS)
            widths = np.asarray(WIDTHS)
            for edge in EDGES:
                Lv, C = _edge_terms(edge)
                surf = critical_k_surface(Lv, C, alphas, widths)
                assert surf.shape == (len(WIDTHS), len(ALPHAS))
                ref = np.array([[beam_critical_k(Lv, C, a, int(w))
                                 for a in alphas] for w in widths])
                np.testing.assert_allclose(surf, ref, rtol=1e-9, atol=0.0)
                np.testing.assert_allclose(
                    surf[0], critical_k_grid(Lv, C, alphas),
                    rtol=1e-12, atol=0.0)
        with pytest.raises(ValueError):
            critical_k_surface(0.05, 0.0165, alphas, [0])


# ------------------------------------------------------------- batch gate
class TestBatchGate:
    def test_beam_gate_w1_bitwise_equals_d4_gate(self):
        with enable_x64():
            rng = np.random.default_rng(1)
            B = 64
            P = rng.uniform(0, 1, B)
            args = (rng.uniform(0, 1, B), rng.uniform(1e-4, 0.5, B),
                    rng.uniform(0.01, 5.0, B),
                    rng.integers(1, 2000, B).astype(float),
                    rng.uniform(1, 2000, B), rng.uniform(1e-8, 1e-4, B),
                    rng.uniform(1e-8, 1e-4, B))
            ref = d4_gate(P, *args)
            got = beam_gate(P, np.ones((B, 1)), np.ones(B, np.int32),
                            *args)
            for r, g in zip(ref, got[:5]):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
            np.testing.assert_array_equal(np.asarray(got[5]), np.ones(B))
            np.testing.assert_array_equal(np.asarray(got[6]), P)

    def test_beam_counterfactual_grid_w1_matches_classic(self):
        with enable_x64():
            rng = np.random.default_rng(2)
            N = 40
            P = rng.uniform(0, 1, N)
            lat = rng.uniform(0.1, 4.0, N)
            cost = rng.uniform(1e-4, 5e-2, N)
            ref = counterfactual_grid(P, lat, cost, GRID_ALPHAS, GRID_LAMS)
            got = beam_counterfactual_grid(
                P, np.ones((N, 1)), lat, cost, GRID_ALPHAS, GRID_LAMS, [1])
            assert set(got) == set(ref)
            for k in ref:
                np.testing.assert_allclose(got[k][0], ref[k], **ULP)

    def test_beam_counterfactual_grid_width_axis(self):
        """A wider beam never lowers the speculate fraction and never
        lowers the expected waste (more launched candidates)."""
        with enable_x64():
            rng = np.random.default_rng(3)
            N = 30
            conf = np.sort(rng.dirichlet(np.ones(3), N), 1)[:, ::-1] * 0.9
            out = beam_counterfactual_grid(
                rng.uniform(0, 1, N), conf, rng.uniform(0.1, 4.0, N),
                rng.uniform(1e-4, 5e-2, N), GRID_ALPHAS, GRID_LAMS,
                [1, 2, 3])
            assert out["speculate_fraction"].shape == (3, 3, 3)
            assert (np.diff(out["speculate_fraction"], axis=0)
                    >= -1e-15).all()
            assert (np.diff(out["expected_waste_usd"], axis=0)
                    >= -1e-15).all()
        with pytest.raises(ValueError):
            beam_counterfactual_grid(
                [0.5], [[0.3, 0.5]], [1.0], [0.01], GRID_ALPHAS,
                GRID_LAMS, [1])


# ------------------------------------------------------------ fleet replay
def build_lowered(beam_confidences=None, use_lower_bound=False):
    """4-op DAG with two speculation edges (one non-streaming downstream,
    one with predictor cost) — the shape the parity suite sweeps."""
    wf = Workflow("beam-dag")
    spec = dict(lat=(2.0, 3.0, 1.5, 2.5), in_tok=(100, 400, 800, 600),
                out_tok=(200, 900, 500, 1200),
                streams=(True, True, True, False))
    for i in range(4):
        wf.add_op(Operation(
            f"n{i}", run=lambda *a: "o", latency_est_s=spec["lat"][i],
            input_tokens_est=spec["in_tok"][i],
            output_tokens_est=spec["out_tok"][i],
            streams=spec["streams"][i], metadata={"input": f"in{i}"}))
    wf.add_edge(Edge("n0", "n1", dep_type=DependencyType.CONDITIONAL_OUTPUT))
    wf.add_edge(Edge("n0", "n2", enabled=False))
    wf.add_edge(Edge("n2", "n3",
                     dep_type=DependencyType.LIST_OUTPUT_VARIABLE_LENGTH))
    wf.freeze()
    params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01,
                           use_lower_bound=use_lower_bound)
    preds = {
        ("n0", "n1"): TemplatePredictor(template=lambda i, p=None: "x",
                                        cost_estimate_s=0.05),
        ("n2", "n3"): TemplatePredictor(template=lambda i, p=None: "x"),
    }
    return lower_workflow(wf, params, predictors=preds,
                          beam_confidences=beam_confidences)


SHARED_STATS = [
    "makespan_s", "total_cost_usd", "waste_usd", "launched", "committed",
    "EV_usd", "threshold_usd", "speculate", "edge_launched",
    "edge_committed", "edge_waste_usd", "start_s", "finish_s",
    "post_alpha", "post_beta",
]


def _hit_ranks(E, V, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(-1, 3, (E, V)).astype(np.int32)


class TestFleetParity:
    @pytest.mark.parametrize("use_lower_bound", [False, True])
    def test_w1_bitwise_equals_fleet_replay(self, use_lower_bound):
        """The width=1 slice of the beam replay is bitwise-f64 identical
        to fleet_replay on every shared statistic, in both the posterior-
        mean and §7.5 lower-bound gating modes — asserted before the
        benchmark may claim any beam timing."""
        with enable_x64():
            lowered = build_lowered(use_lower_bound=use_lower_bound)
            E, V = 6, lowered.n_ops
            hit = _hit_ranks(E, V)
            success = hit == 0
            ref = fleet_replay(lowered, success, GRID_ALPHAS, GRID_LAMS)
            rep = beam_replay(lowered, hit, GRID_ALPHAS, GRID_LAMS, [1])
            sl = rep.width_slice(0)
            for k in SHARED_STATS:
                np.testing.assert_array_equal(
                    sl[k], getattr(ref, k), err_msg=k)
            # candidate attribution degenerates to the edge counts
            np.testing.assert_array_equal(sl["launched_candidates"],
                                          sl["launched"].astype(float))
            np.testing.assert_array_equal(sl["w_eff"][sl["speculate"]], 1)

    def test_default_conf_every_width_replays_classic(self):
        """Without beam_confidences the lowering carries one certain
        candidate, so every width slice equals the classic engine."""
        with enable_x64():
            lowered = build_lowered()
            hit = _hit_ranks(5, lowered.n_ops, seed=11)
            ref = fleet_replay(lowered, hit == 0, GRID_ALPHAS, GRID_LAMS)
            rep = beam_replay(lowered, hit, GRID_ALPHAS, GRID_LAMS,
                              [1, 2, 4])
            for wi in range(3):
                sl = rep.width_slice(wi)
                for k in SHARED_STATS:
                    np.testing.assert_array_equal(
                        sl[k], getattr(ref, k), err_msg=f"{k}@w{wi}")

    def test_wider_beam_matches_reference_twin(self):
        """width > 1 against the pure-numpy reference: decisions, counts,
        ranks and event times bitwise; EV / waste to 1 ULP."""
        with enable_x64():
            confs = {("n0", "n1"): (0.55, 0.25, 0.1),
                     ("n2", "n3"): (0.5, 0.3)}
            lowered = build_lowered(beam_confidences=confs)
            E, V = 6, lowered.n_ops
            hit = _hit_ranks(E, V, seed=13)
            widths = [1, 2, 3]
            rep = beam_replay(lowered, hit, GRID_ALPHAS, GRID_LAMS, widths)
            ref = reference_beam_replay(lowered, hit, GRID_ALPHAS,
                                        GRID_LAMS, widths)
            exact = ("speculate", "w_eff", "edge_launched",
                     "edge_committed", "launched", "committed",
                     "launched_candidates", "cancelled_candidates",
                     "start_s", "finish_s", "makespan_s", "post_alpha",
                     "post_beta")
            for k in exact:
                np.testing.assert_array_equal(
                    getattr(rep, k), ref[k], err_msg=k)
            for k in ("EV_usd", "threshold_usd", "edge_waste_usd",
                      "waste_usd", "total_cost_usd"):
                np.testing.assert_allclose(
                    getattr(rep, k), ref[k], err_msg=k, **ULP)

    def test_hit_rank_gates_commit_on_admitted_prefix(self):
        """A rank-1 hit commits only when the beam actually launched at
        least two candidates — widening the beam converts a tier failure
        into a commit on exactly those episodes."""
        with enable_x64():
            confs = {("n0", "n1"): (0.5, 0.4),
                     ("n2", "n3"): (0.5, 0.4)}
            lowered = build_lowered(beam_confidences=confs)
            V = lowered.n_ops
            hit = np.ones((4, V), np.int32)     # the runner-up always hits
            rep = beam_replay(lowered, hit, [0.5], [0.08], [1, 2])
            edge = np.asarray(lowered.has_edge) & np.asarray(
                lowered.has_pred)
            launched = rep.edge_launched[..., edge]
            committed = rep.edge_committed[..., edge]
            assert launched.any()
            # width 1 never commits a rank-1 hit; width 2 commits wherever
            # the marginal rule admitted the runner-up
            assert not committed[:, 0].any()
            w2 = rep.w_eff[..., edge][:, 1]
            assert committed[:, 1].sum() == (launched[:, 1] & (w2 >= 2)).sum()
            assert committed[:, 1].any()
            # every launched loser is billed: cancelled = launched - won
            np.testing.assert_array_equal(
                rep.cancelled_candidates,
                rep.launched_candidates
                - rep.committed)

    def test_hit_rank_from_success_and_validation(self):
        np.testing.assert_array_equal(
            hit_rank_from_success(np.array([[True, False]])),
            np.array([[0, -1]], np.int32))
        lowered = build_lowered()
        E, V = 3, lowered.n_ops
        ok = np.zeros((E, V), bool)
        # bool success arrays are accepted as the degenerate case
        rep = beam_replay(lowered, ok, [0.5], [0.01], [1])
        assert not rep.committed.any()
        with pytest.raises(ValueError):
            beam_replay(lowered, np.zeros((E, V + 1), np.int32),
                        [0.5], [0.01], [1])
        with pytest.raises(ValueError):
            beam_replay(lowered, ok, [0.5], [0.01], [])
        with pytest.raises(ValueError):
            beam_replay(lowered, ok, [0.5], [0.01], [0])
        with pytest.raises(ValueError):
            beam_replay(lowered, ok, [0.5], [0.01], [1.5])

    def test_ep_mask_freezes_masked_episodes(self):
        with enable_x64():
            lowered = build_lowered()
            hit = _hit_ranks(6, lowered.n_ops, seed=17)
            mask = np.array([True, False, True, True, False, True])
            full = beam_replay(lowered, hit, [0.5], [0.08], [1, 2])
            part = beam_replay(lowered, hit, [0.5], [0.08], [1, 2],
                               ep_mask=mask)
            assert not part.edge_launched[~mask].any()
            # masked episodes carry the prior forward unchanged
            np.testing.assert_array_equal(part.post_alpha[1],
                                          part.post_alpha[0])
            # pareto aggregation skips masked rows
            np.testing.assert_array_equal(
                part.pareto()["launched"],
                part.launched[mask].sum(0))
            assert full.pareto()["launched"].sum() >= \
                part.pareto()["launched"].sum()


# ------------------------------------------------------------ online tick
class TestOnlineBeam:
    def _service(self):
        svc = OnlineDecisionService()
        for i, p in enumerate((0.7, 0.35, 0.9)):
            svc.register_edge(("u", f"v{i}"),
                              posterior=BetaPosterior.from_prior_mean(p))
        return svc

    REQ = dict(alpha=0.4, lambda_usd_per_s=0.08, latency_s=2.0,
               input_tokens=500, output_tokens=1000, input_price=3e-6,
               output_price=15e-6)

    def test_decide_beam_bitwise_equals_beam_evaluate(self):
        with enable_x64():
            svc = self._service()
            conf = (0.6, 0.25, 0.1)
            for row, p in enumerate((0.7, 0.35, 0.9)):
                for width in (1, 2, 3):
                    got = svc.decide_beam(row=row, confidences=conf,
                                          width=width, **self.REQ)
                    ref = beam_evaluate(
                        _inputs(P=p, alpha=0.4, lam=0.08, lat=2.0),
                        conf, width)
                    assert isinstance(got, BeamDecisionResult)
                    assert got.decision == ref.decision
                    for f in ("EV_usd", "threshold_usd", "C_spec_usd",
                              "L_value_usd", "P_used"):
                        assert getattr(got, f) == getattr(ref, f), (f, width)
                    assert got.launched == ref.launched

    def test_tick_mixed_widths_and_telemetry_launched(self):
        with enable_x64():
            svc = self._service()
            bc = np.array([[0.6, 0.3, 0.1], [0.9, 0.05, 0.0]])
            d = svc.tick([0, 2], beam_confidences=bc, beam_width=[3, 2],
                         **self.REQ)
            assert d.launched.shape == (2,)
            # per-row reference through the scalar rule
            for i, (row_p, conf, w) in enumerate(
                    [(0.7, (0.6, 0.3, 0.1), 3),
                     (0.9, (0.9, 0.05, 0.0), 2)]):
                ref = beam_evaluate(
                    _inputs(P=row_p, alpha=0.4, lam=0.08, lat=2.0),
                    conf, w)
                assert bool(d.speculate[i]) == (
                    ref.decision == Decision.SPECULATE)
                assert (int(d.launched[i]) == ref.launched
                        or not d.speculate[i])
                assert float(d.P_used[i]) == ref.P_used
            tb = svc.drain_telemetry()
            launched = tb.fields["launched"]
            spec = tb.fields["speculate"].astype(bool)
            assert (launched[spec] >= 1).all()
            np.testing.assert_array_equal(
                launched, np.asarray(d.launched, float))

    def test_single_candidate_tick_unchanged(self):
        """A beam tick with one certain candidate answers exactly like
        the classic tick (same posterior, same request)."""
        with enable_x64():
            svc = self._service()
            ref = svc.tick([0, 1, 2], **self.REQ)
            svc2 = self._service()
            got = svc2.tick([0, 1, 2],
                            beam_confidences=np.ones((3, 1)), **self.REQ)
            for f in ("EV_usd", "threshold_usd", "P_used", "speculate"):
                np.testing.assert_array_equal(getattr(got, f),
                                              getattr(ref, f), err_msg=f)
            # classic ticks attribute one launched candidate per served row
            np.testing.assert_array_equal(np.asarray(ref.launched),
                                          np.asarray(got.launched))

    def test_beam_request_validation(self):
        svc = self._service()
        with pytest.raises(ValueError):
            svc.tick([0], beam_width=2, **self.REQ)
        with pytest.raises(ValueError):
            svc.tick([0], beam_confidences=np.array([[0.3, 0.5]]),
                     **self.REQ)
        with pytest.raises(ValueError):
            svc.tick([0], beam_confidences=np.array([[0.8, 0.7]]),
                     **self.REQ)
        with pytest.raises(ValueError):
            svc.tick([0, 1], beam_confidences=np.ones((1, 1)), **self.REQ)
        with pytest.raises(ValueError):
            svc.tick([0], beam_confidences=np.ones((1, 1)), beam_width=0,
                     **self.REQ)


# ------------------------------------------------------------ §9.3 waste
class TestExpectedBeamWaste:
    CM = TwoRateTokenCost(3e-6, 15e-6)

    def test_launched_one_is_classic_waste(self):
        for P in (0.0, 0.31, 1.0):
            assert expected_beam_waste(P, 1, self.CM, 500, 1000) == \
                expected_speculation_waste(P, self.CM, 500, 1000)

    def test_scales_with_losers_and_rho(self):
        w = expected_beam_waste(0.8, 3, self.CM, 500, 1000, rho=0.5)
        assert w == pytest.approx((3 - 0.8) * (500 * 3e-6 + 0.5 * 1000 * 15e-6))
        full = expected_beam_waste(0.8, 3, self.CM, 500, 1000,
                                   streaming=False)
        assert full > w                      # no cancel -> full C_out
        assert expected_beam_waste(0.0, 0, self.CM, 500, 1000) == 0.0

    def test_validation(self):
        with pytest.raises(ValueError):
            expected_beam_waste(0.5, -1, self.CM, 500, 1000)
        with pytest.raises(ValueError):
            expected_beam_waste(0.5, 0, self.CM, 500, 1000)   # P > launched
        with pytest.raises(ValueError):
            expected_beam_waste(1.2, 2, self.CM, 500, 1000)
        with pytest.raises(ValueError):
            expected_beam_waste(0.5, 2, self.CM, 500, 1000, rho=1.5)
