"""Test bootstrap: install the mini-hypothesis shim when the real library
is unavailable (the CI image does not ship it and installing packages is
out of policy)."""
from __future__ import annotations

import importlib.util
import pathlib
import sys


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401  (real library wins when present)
        return
    except ImportError:
        pass
    path = pathlib.Path(__file__).with_name("_mini_hypothesis.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    sys.modules["hypothesis"] = module
    sys.modules["hypothesis.strategies"] = module.strategies


_install_hypothesis_shim()
