"""Scalar <-> fleet parity: the vectorized replay engine (repro.core.fleet)
must reproduce the paper-faithful discrete-event executor bitwise at
float64 — decisions, EV, timing, waste, and posterior trajectories — on
randomized small DAGs, plus the batched streaming / posterior primitives
against their scalar counterparts."""
import dataclasses

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    DependencyType,
    Edge,
    ExecutorConfig,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    fleet_replay,
    lower_workflow,
    plan_workflow,
)
from repro.core.batch_decision import (
    batch_chunk_cancel,
    batch_fractional_waste,
    batch_posterior_update,
    counterfactual_grid,
)
from repro.core.decision import DecisionInputs
from repro.core.posterior import BetaPosterior
from repro.core.predictor import TemplatePredictor
from repro.core.pricing import TwoRateTokenCost
from repro.core.streaming import StreamingReestimator, fractional_waste

PRED = "predicted-topic-alpha"
MISS = "zzz-unrelated-output-999"


# ------------------------------------------------------------- DAG generator
@dataclasses.dataclass
class RandomDag:
    """A randomized DAG spec: topology + per-episode upstream outcomes.

    Ops are n0..n{V-1}; candidate (speculation) edges carry a predictor
    that predicts PRED; the upstream emits PRED on success episodes and
    MISS otherwise, so §7.4 tier-1 labels are fully controlled."""

    n_ops: int
    plain_parents: list[tuple[int, int]]      # enabled=False edges (u, v)
    spec_edges: list[tuple[int, int]]         # candidate edges (u, v)
    latency: np.ndarray                       # (V,)
    in_tok: np.ndarray
    out_tok: np.ndarray
    streams: np.ndarray                       # (V,) bool, downstream streaming
    pred_cost: np.ndarray                     # (V,) predictor cost (s)
    success: np.ndarray                       # (E, V) bool
    pred_ok: np.ndarray                       # (E, V) bool
    discount: float = 1.0
    use_lower_bound: bool = False             # §7.5 credible-bound gating
    gamma: float = 0.1

    def name(self, i: int) -> str:
        return f"n{i}"

    def build_workflow(self, episode: int) -> Workflow:
        wf = Workflow(f"rand-{self.n_ops}")
        # the UPSTREAM of a spec edge emits PRED/MISS; success is keyed by
        # the downstream op (each upstream serves at most one spec edge)
        spec_up = {u: v for (u, v) in self.spec_edges}
        for i in range(self.n_ops):
            v = spec_up.get(i)
            if v is None:
                out = f"out-{self.name(i)}"
            else:
                out = PRED if self.success[episode, v] else MISS
            wf.add_op(Operation(
                self.name(i),
                run=lambda *a, _o=out: _o,
                latency_est_s=float(self.latency[i]),
                input_tokens_est=int(self.in_tok[i]),
                output_tokens_est=int(self.out_tok[i]),
                streams=bool(self.streams[i]),
                metadata={"input": f"in-{self.name(i)}"},
            ))
        for (u, v) in self.plain_parents:
            wf.add_edge(Edge(self.name(u), self.name(v), enabled=False))
        for (u, v) in self.spec_edges:
            wf.add_edge(Edge(self.name(u), self.name(v),
                             dep_type=DependencyType.CONDITIONAL_OUTPUT))
        return wf.freeze()

    def predictors(self, episode: int) -> dict:
        preds = {}
        for (u, v) in self.spec_edges:
            ok = bool(self.pred_ok[episode, v])
            preds[(self.name(u), self.name(v))] = TemplatePredictor(
                template=lambda i, p=None, _ok=ok: (PRED if _ok else None),
                cost_estimate_s=float(self.pred_cost[v]),
            )
        return preds

    def fresh_params(self, alpha: float, lam: float) -> PlannerParams:
        posts = {}
        for (u, v) in self.spec_edges:
            posts[(self.name(u), self.name(v))] = (
                BetaPosterior.from_dependency_type(
                    DependencyType.CONDITIONAL_OUTPUT, discount=self.discount
                )
            )
        return PlannerParams(alpha=alpha, lambda_usd_per_s=lam,
                             posteriors=posts,
                             use_lower_bound=self.use_lower_bound,
                             gamma=self.gamma)


def make_random_dag(seed: int, episodes: int = 6,
                    discount: float = 1.0,
                    use_lower_bound: bool = False) -> RandomDag:
    rng = np.random.default_rng(seed)
    V = int(rng.integers(2, 6))
    plain, spec = [], []
    spec_ups = set()
    for v in range(1, V):
        parents = [u for u in range(v) if rng.random() < 0.6]
        if not parents and rng.random() < 0.7:
            parents = [int(rng.integers(0, v))]
        free = [u for u in parents if u not in spec_ups]
        if free and rng.random() < 0.8:
            u = int(rng.choice(free))
            spec.append((u, v))
            spec_ups.add(u)
            parents.remove(u)
        plain.extend((u, v) for u in parents)
    return RandomDag(
        n_ops=V,
        plain_parents=plain,
        spec_edges=spec,
        latency=rng.uniform(0.2, 3.0, V).round(3),
        in_tok=rng.integers(50, 2000, V),
        out_tok=rng.integers(50, 2000, V),
        streams=rng.random(V) < 0.7,
        pred_cost=np.where(rng.random(V) < 0.5, 0.0, 0.05),
        success=rng.random((episodes, V)) < 0.55,
        pred_ok=rng.random((episodes, V)) < 0.85,
        discount=discount,
        use_lower_bound=use_lower_bound,
    )


def run_scalar(dag: RandomDag, alphas, lams):
    """Episode loop through plan_workflow + execute, one posterior set per
    grid point (the §12.3 sweep exactly as workflow_sim does it)."""
    E = dag.success.shape[0]
    V = dag.n_ops
    G = len(alphas)
    shape = (E, G, V)
    out = {
        "EV": np.zeros(shape), "thr": np.zeros(shape),
        "spec": np.zeros(shape, bool), "launched": np.zeros(shape, bool),
        "committed": np.zeros(shape, bool), "waste": np.zeros(shape),
        "finish": np.zeros(shape), "post_a": np.zeros(shape),
        "post_b": np.zeros(shape), "makespan": np.zeros((E, G)),
        "waste_total": np.zeros((E, G)),
    }
    for g, (alpha, lam) in enumerate(zip(alphas, lams)):
        params = dag.fresh_params(alpha, lam)
        for e in range(E):
            wf = dag.build_workflow(e)
            plan, _ = plan_workflow(wf, params)
            cfg = ExecutorConfig(params=params, predictors=dag.predictors(e),
                                 use_lower_bound=dag.use_lower_bound,
                                 gamma=dag.gamma)
            rep = execute(wf, plan, cfg)
            by_edge = {r.edge: r for r in cfg.telemetry.rows
                       if r.phase == "runtime"}
            launched_edges = {o.edge: o for o in rep.outcomes}
            for (u, v) in dag.spec_edges:
                key = (dag.name(u), dag.name(v))
                row = by_edge[key]
                out["EV"][e, g, v] = row.EV_usd
                out["thr"][e, g, v] = row.threshold_usd
                out["spec"][e, g, v] = row.decision == "SPECULATE"
                o = launched_edges.get(key)
                out["launched"][e, g, v] = o is not None and o.launched
                out["committed"][e, g, v] = o is not None and o.committed
                out["waste"][e, g, v] = o.waste_usd if o is not None else 0.0
                post = params.posteriors[key]
                out["post_a"][e, g, v] = post.alpha
                out["post_b"][e, g, v] = post.beta
            for i in range(V):
                out["finish"][e, g, i] = rep.finish_times_s[dag.name(i)]
            out["makespan"][e, g] = rep.makespan_s
            out["waste_total"][e, g] = rep.waste_usd
    return out


def run_fleet(dag: RandomDag, alphas, lams):
    """Lower + replay, then re-index (E, G, V) outputs from the lowering's
    topological order back to the dag's op numbering."""
    params = dag.fresh_params(0.5, 0.01)  # priors only; grid comes from args
    wf = dag.build_workflow(0)
    preds = dag.predictors(0)
    lowered = lower_workflow(wf, params, predictors=preds)
    order = np.array([int(n[1:]) for n in lowered.names])  # lowered -> dag
    report = fleet_replay(
        lowered, dag.success[:, order], np.asarray(alphas),
        np.asarray(lams), pred_ok=dag.pred_ok[:, order],
    )
    inv = np.empty_like(order)
    inv[order] = np.arange(order.size)
    reindexed = {
        f.name: getattr(report, f.name)[:, :, inv]
        for f in dataclasses.fields(report)
        if getattr(report, f.name).ndim == 3
    }
    report = dataclasses.replace(report, **reindexed)
    edge_ops = sorted(order[j] for j in lowered.edge_ops())
    return edge_ops, report


GRID_ALPHAS = [0.0, 0.5, 0.9]
GRID_LAMS = [0.01, 0.08, 0.08]


# XLA CPU contracts a*b + c into a single FMA (one rounding) while CPython
# rounds twice, so products compared against the pure-Python scalar path can
# differ by 1 ULP.  Decisions, counts, posterior trajectories (discount=1)
# and event times (add/max chains) are contraction-free and compared
# bitwise; EV/threshold/waste use an ULP-level tolerance.
ULP = dict(rtol=1e-13, atol=1e-16)


@pytest.mark.parametrize("seed", range(8))
def test_random_dag_bitwise_parity(seed):
    """Decisions, timing and posterior trajectories match the scalar
    executor bitwise at float64 (EV/waste to 1 ULP, see note above)."""
    with enable_x64():
        dag = make_random_dag(seed)
        scalar = run_scalar(dag, GRID_ALPHAS, GRID_LAMS)
        edge_ops, fleet = run_fleet(dag, GRID_ALPHAS, GRID_LAMS)
        assert sorted(v for (_, v) in dag.spec_edges) == edge_ops
        sel = np.array(edge_ops, int)
        np.testing.assert_allclose(
            fleet.EV_usd[:, :, sel], scalar["EV"][:, :, sel], **ULP)
        np.testing.assert_allclose(
            fleet.threshold_usd[:, :, sel], scalar["thr"][:, :, sel], **ULP)
        np.testing.assert_array_equal(
            fleet.speculate[:, :, sel], scalar["spec"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.edge_launched[:, :, sel], scalar["launched"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.edge_committed[:, :, sel], scalar["committed"][:, :, sel])
        np.testing.assert_allclose(
            fleet.edge_waste_usd[:, :, sel], scalar["waste"][:, :, sel],
            **ULP)
        np.testing.assert_array_equal(fleet.finish_s, scalar["finish"])
        np.testing.assert_array_equal(fleet.makespan_s, scalar["makespan"])
        np.testing.assert_array_equal(
            fleet.post_alpha[:, :, sel], scalar["post_a"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.post_beta[:, :, sel], scalar["post_b"][:, :, sel])
        np.testing.assert_allclose(
            fleet.waste_usd, scalar["waste_total"], rtol=1e-12, atol=1e-16)


@pytest.mark.parametrize("seed", [100, 101])
def test_random_dag_discounted_posterior_parity(seed):
    """Exponential-forgetting posteriors (§14.3) carry through the fleet
    scan identically to BetaPosterior.update."""
    with enable_x64():
        dag = make_random_dag(seed, discount=0.9)
        scalar = run_scalar(dag, GRID_ALPHAS, GRID_LAMS)
        edge_ops, fleet = run_fleet(dag, GRID_ALPHAS, GRID_LAMS)
        sel = np.array(edge_ops, int)
        if sel.size == 0:
            pytest.skip("degenerate draw: no candidate edges")
        # a*0.9 + x contracts to an FMA under XLA -> 1-ULP tolerance
        np.testing.assert_allclose(
            fleet.post_alpha[:, :, sel], scalar["post_a"][:, :, sel], **ULP)
        np.testing.assert_allclose(
            fleet.post_beta[:, :, sel], scalar["post_b"][:, :, sel], **ULP)


# The §7.5 EV is fed by two independent Beta-quantile implementations:
# the scalar path inverts through scipy.stats.beta.ppf, the fleet path
# through the jax-native betaincinv (tests/test_betaincinv.py pins their
# agreement at ~1e-13 relative over the posterior range).  EV inherits
# that spread on top of the FMA ULP, so it gets a little extra headroom;
# everything downstream of the *decisions* — launch/commit flags, event
# times, posterior trajectories — still matches bitwise, and waste /
# threshold stay at the plain ULP tolerance (they do not depend on P).
LB_ULP = dict(rtol=1e-11, atol=1e-14)


@pytest.mark.parametrize("seed", range(8))
def test_random_dag_lower_bound_parity(seed):
    """§7.5 credible-bound gating (use_lower_bound=True, gamma=0.1):
    fleet replay matches the scalar executor on randomized DAGs at
    float64 — decisions, flags, timing and posterior trajectories
    bitwise; EV to the cross-quantile tolerance; waste to 1 ULP."""
    with enable_x64():
        dag = make_random_dag(seed, use_lower_bound=True)
        scalar = run_scalar(dag, GRID_ALPHAS, GRID_LAMS)
        edge_ops, fleet = run_fleet(dag, GRID_ALPHAS, GRID_LAMS)
        assert sorted(v for (_, v) in dag.spec_edges) == edge_ops
        sel = np.array(edge_ops, int)
        np.testing.assert_allclose(
            fleet.EV_usd[:, :, sel], scalar["EV"][:, :, sel], **LB_ULP)
        np.testing.assert_allclose(
            fleet.threshold_usd[:, :, sel], scalar["thr"][:, :, sel], **ULP)
        np.testing.assert_array_equal(
            fleet.speculate[:, :, sel], scalar["spec"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.edge_launched[:, :, sel], scalar["launched"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.edge_committed[:, :, sel], scalar["committed"][:, :, sel])
        np.testing.assert_allclose(
            fleet.edge_waste_usd[:, :, sel], scalar["waste"][:, :, sel],
            **ULP)
        np.testing.assert_array_equal(fleet.finish_s, scalar["finish"])
        np.testing.assert_array_equal(fleet.makespan_s, scalar["makespan"])
        np.testing.assert_array_equal(
            fleet.post_alpha[:, :, sel], scalar["post_a"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.post_beta[:, :, sel], scalar["post_b"][:, :, sel])
        np.testing.assert_allclose(
            fleet.waste_usd, scalar["waste_total"], rtol=1e-12, atol=1e-16)


@pytest.mark.parametrize("seed", [100, 101])
def test_random_dag_lower_bound_discounted_parity(seed):
    """Credible-bound gating composed with exponential-forgetting
    posteriors: the betaincinv inversion runs on the discounted
    (fractional) carry and must track scipy on the same trajectory."""
    with enable_x64():
        dag = make_random_dag(seed, discount=0.9, use_lower_bound=True)
        scalar = run_scalar(dag, GRID_ALPHAS, GRID_LAMS)
        edge_ops, fleet = run_fleet(dag, GRID_ALPHAS, GRID_LAMS)
        sel = np.array(edge_ops, int)
        if sel.size == 0:
            pytest.skip("degenerate draw: no candidate edges")
        np.testing.assert_array_equal(
            fleet.speculate[:, :, sel], scalar["spec"][:, :, sel])
        np.testing.assert_array_equal(
            fleet.edge_committed[:, :, sel], scalar["committed"][:, :, sel])
        np.testing.assert_allclose(
            fleet.EV_usd[:, :, sel], scalar["EV"][:, :, sel], **LB_ULP)
        # a*0.9 + x contracts to an FMA under XLA -> 1-ULP tolerance
        np.testing.assert_allclose(
            fleet.post_alpha[:, :, sel], scalar["post_a"][:, :, sel], **ULP)
        np.testing.assert_allclose(
            fleet.post_beta[:, :, sel], scalar["post_b"][:, :, sel], **ULP)


@pytest.mark.parametrize("use_lb", [False, True])
def test_streaming_cancel_parity(use_lb):
    """§9.1 mid-stream cancellation: fleet chunk path vs the scalar
    executor with a stream refiner, including fractional waste — under
    both posterior-mean and §7.5 credible-bound launch gating (chunk
    re-checks gate on the refined P_k either way, exactly like the
    scalar executor's evaluate(inputs_k))."""
    with enable_x64():
        E, K = 8, 4
        rng = np.random.default_rng(7)
        # chunk confidences: some episodes dip below the threshold mid-stream
        chunk_P = rng.uniform(0.05, 0.95, (E, K))
        alphas = [0.4]
        lams = [0.08]

        def build(episode):
            wf = Workflow("stream")
            wf.add_op(Operation(
                "u", run=lambda x: "chunked-output-string-for-u",
                latency_est_s=2.0, input_tokens_est=100, output_tokens_est=50,
                metadata={"input": "doc", "chunks": K},
            ))
            wf.add_op(Operation(
                "v", run=lambda i: f"v({i})", latency_est_s=1.5,
                input_tokens_est=400, output_tokens_est=900,
            ))
            wf.add_edge(Edge("u", "v"))
            return wf.freeze()

        key = ("u", "v")
        post_scalar = BetaPosterior.from_prior_mean(0.9)
        params = PlannerParams(alpha=alphas[0], lambda_usd_per_s=lams[0],
                               posteriors={key: post_scalar},
                               use_lower_bound=use_lb)
        scalar_waste = np.zeros(E)
        scalar_cancel = np.zeros(E, bool)
        scalar_finish = np.zeros(E)
        for e in range(E):
            wf = build(e)
            plan, _ = plan_workflow(wf, params)

            def refine(upstream_input, partial, _e=e):
                return None, float(chunk_P[_e, len(partial) - 1])

            cfg = ExecutorConfig(
                params=params,
                predictors={key: TemplatePredictor(
                    template=lambda i, p=None: "chunked-output-string-for-u")},
                stream_refiners={key: refine},
                use_lower_bound=use_lb,
            )
            rep = execute(wf, plan, cfg)
            scalar_waste[e] = rep.waste_usd
            scalar_cancel[e] = any(o.cancelled_mid_stream for o in rep.outcomes)
            scalar_finish[e] = rep.makespan_s

        params_f = PlannerParams(
            alpha=0.5, lambda_usd_per_s=0.01,
            posteriors={key: BetaPosterior.from_prior_mean(0.9)},
            use_lower_bound=use_lb,
        )
        wf = build(0)
        pred = {key: TemplatePredictor(
            template=lambda i, p=None: "chunked-output-string-for-u")}
        lowered = lower_workflow(
            wf, params_f, predictors=pred,
            stream_refiners={key: lambda i, p: (None, 0.0)},
        )
        vi = lowered.names.index("v")
        success = np.ones((E, lowered.n_ops), bool)  # prediction is exact
        cP = np.ones((E, lowered.n_ops, K))
        cP[:, vi, :] = chunk_P
        fleet = fleet_replay(lowered, success, alphas, lams, chunk_P=cP)
        assert scalar_cancel.any() and not scalar_cancel.all(), \
            "test vector should mix cancelled and surviving streams"
        np.testing.assert_array_equal(
            fleet.cancelled[:, 0].astype(bool), scalar_cancel)
        np.testing.assert_allclose(fleet.waste_usd[:, 0], scalar_waste, **ULP)
        np.testing.assert_allclose(
            fleet.makespan_s[:, 0], scalar_finish, **ULP)


def test_batch_chunk_cancel_matches_reestimator():
    """batch_chunk_cancel == StreamingReestimator.run chunk-for-chunk,
    including throttling."""
    with enable_x64():
        rng = np.random.default_rng(11)
        N, K = 32, 6
        P_chunks = rng.uniform(0.0, 1.0, (N, K))
        base = DecisionInputs(
            P=0.5, alpha=0.3, lambda_usd_per_s=0.08, latency_seconds=1.2,
            input_tokens=400, output_tokens=900,
            input_price=3e-6, output_price=15e-6,
        )
        for throttle in (1, 2, 3):
            first, cancelled, EV_k, thr = batch_chunk_cancel(
                P_chunks, base.alpha, base.lambda_usd_per_s,
                base.latency_seconds, base.input_tokens, base.output_tokens,
                base.input_price, base.output_price,
                throttle_every=throttle,
            )
            for i in range(N):
                table = {k: (None, float(P_chunks[i, k])) for k in range(K)}
                re = StreamingReestimator(
                    lambda inp, partial, _t=table: _t[len(partial) - 1],
                    base, throttle_every=throttle,
                )
                verdict, all_verdicts = re.run(None, ["c"] * K)
                assert cancelled[i] == (verdict is not None)
                if verdict is not None:
                    assert first[i] == verdict.chunk_index
                    np.testing.assert_allclose(
                        EV_k[i, verdict.chunk_index], verdict.EV_usd, **ULP)
                    np.testing.assert_allclose(
                        thr[i, verdict.chunk_index], verdict.threshold_usd,
                        **ULP)
                else:
                    assert first[i] == -1


def test_batch_posterior_discounted_matches_scalar():
    """batch_posterior_update(discount<1) == the discounted-update branch
    of BetaPosterior.update, bitwise at float64."""
    with enable_x64():
        rng = np.random.default_rng(5)
        E, N = 16, 64
        outcomes = rng.random((E, N)) < 0.6
        a0 = rng.uniform(0.5, 3.0, E)
        b0 = rng.uniform(0.5, 3.0, E)
        for d in (1.0, 0.95, 0.5):
            a, b = batch_posterior_update(a0, b0, outcomes.astype(float),
                                          discount=d)
            for i in range(E):
                post = BetaPosterior(alpha=float(a0[i]), beta=float(b0[i]),
                                     discount=d)
                post.update_many(outcomes[i])
                # d=1 uses the conjugate closed form a0+s (one rounding);
                # the scalar loop rounds per +1.0 step at fractional priors
                np.testing.assert_allclose(a[i], post.alpha, **ULP)
                np.testing.assert_allclose(b[i], post.beta, **ULP)


def test_batch_fractional_waste_matches_scalar():
    with enable_x64():
        rng = np.random.default_rng(9)
        n = 64
        in_tok = rng.integers(10, 2000, n)
        out_tok = rng.integers(10, 2000, n)
        frac = rng.uniform(0.0, 1.2, n)   # >1 bills actuals
        w = batch_fractional_waste(in_tok, out_tok, frac, 3e-6, 15e-6)
        cm = TwoRateTokenCost(3e-6, 15e-6)
        for i in range(n):
            np.testing.assert_allclose(
                w[i],
                fractional_waste(cm, int(in_tok[i]), float(out_tok[i]),
                                 frac[i] * float(out_tok[i])),
                **ULP)


def test_replay_grid_kernel_matches_oracle_and_batch():
    """The fused Pallas §12.1 grid kernel == pure-jnp oracle ==
    batch_decision.counterfactual_grid."""
    import jax.numpy as jnp

    from repro.kernels import replay_grid_op
    from repro.kernels.ref import reference_replay_grid

    rng = np.random.default_rng(3)
    n = 3000
    P = rng.uniform(0.1, 0.95, n).astype(np.float32)
    lat = rng.uniform(0.5, 3.0, n).astype(np.float32)
    cost = rng.uniform(0.005, 0.03, n).astype(np.float32)
    alphas = np.array([0.0, 0.25, 0.5, 0.75, 1.0], np.float32)
    lams = np.array([0.005, 0.01, 0.05, 0.1], np.float32)

    cnt, lsum, wsum = replay_grid_op(
        jnp.asarray(P), jnp.asarray(lat), jnp.asarray(cost),
        jnp.asarray(alphas), jnp.asarray(lams))
    rcnt, rlsum, rwsum = reference_replay_grid(
        jnp.asarray(P), jnp.asarray(lat), jnp.asarray(cost),
        jnp.asarray(alphas), jnp.asarray(lams))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))
    np.testing.assert_allclose(lsum, rlsum, rtol=1e-5)
    np.testing.assert_allclose(wsum, rwsum, rtol=1e-5)

    g = counterfactual_grid(P, lat, cost, alphas, lams)
    np.testing.assert_allclose(np.asarray(cnt) / n,
                               g["speculate_fraction"], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(lsum) / n,
                               g["expected_latency_s"], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(wsum),
                               g["expected_waste_usd"], rtol=1e-4)


def test_counterfactual_grid_single_compile_across_rho():
    """Regression: rho sat in _grid's static_argnames, so every distinct
    float recompiled the XLA executable during §12.3 calibration sweeps.
    It is now a traced argument — one compile serves the whole rho sweep —
    and the lower-bound gate variant reuses the same executable."""
    from repro.core import batch_decision as bd

    rng = np.random.default_rng(21)
    n = 64
    P = rng.uniform(0.05, 0.95, n)
    lat = rng.uniform(0.2, 3.0, n)
    cost = rng.uniform(0.001, 0.03, n)
    alphas = np.array([0.0, 0.5, 1.0])
    lams = np.array([0.01, 0.08])
    bd._grid.clear_cache()
    base = None
    for rho in (0.0, 0.1, 0.25, 0.5, 0.77, 1.0):
        g = counterfactual_grid(P, lat, cost, alphas, lams, rho=rho)
        if base is None:
            base = g
        assert bd._grid._cache_size() == 1, \
            f"rho={rho} triggered a recompile"
    # the §7.5 gate variant shares the executable (same shapes/dtypes)
    P_low = bd.batch_lower_bound(2.0 * P, 2.0 * (1.0 - P), 0.1)
    counterfactual_grid(P, lat, cost, alphas, lams, rho=0.3, P_lower=P_low)
    assert bd._grid._cache_size() == 1
    # rho=0 zeroes expected waste but not the gate
    g0 = counterfactual_grid(P, lat, cost, alphas, lams, rho=0.0)
    np.testing.assert_array_equal(g0["expected_waste_usd"], 0.0)
    np.testing.assert_array_equal(
        g0["speculate_fraction"], base["speculate_fraction"])


def test_counterfactual_grid_lower_bound_gate_is_conservative():
    """With P_lower the SPECULATE gate runs on the credible bound (fewer
    or equal speculations than the mean gate) while latency / waste
    expectations stay weighted by the posterior mean."""
    with enable_x64():
        rng = np.random.default_rng(33)
        n = 200
        a = rng.uniform(0.5, 6.0, n)
        b = rng.uniform(0.5, 6.0, n)
        P = a / (a + b)
        from repro.core.batch_decision import batch_lower_bound
        P_low = batch_lower_bound(a, b, 0.1)
        assert np.all(P_low <= P)
        lat = rng.uniform(0.2, 3.0, n)
        cost = rng.uniform(0.001, 0.03, n)
        alphas = np.array([0.0, 0.3, 0.6, 0.9])
        lams = np.array([0.01, 0.08])
        g_mean = counterfactual_grid(P, lat, cost, alphas, lams)
        g_lb = counterfactual_grid(P, lat, cost, alphas, lams, P_lower=P_low)
        assert np.all(
            g_lb["speculate_fraction"] <= g_mean["speculate_fraction"])
        # and gating on P_lower directly == passing it as the gate
        g_direct = counterfactual_grid(P_low, lat, cost, alphas, lams)
        np.testing.assert_array_equal(
            g_lb["speculate_fraction"], g_direct["speculate_fraction"])


def test_batch_evaluate_lower_bound_matches_scalar_evaluate():
    """batch_evaluate(P_lower=...) == decision.evaluate(use_lower_bound=
    True) row-for-row: EV and the gate run on the bound (P_used)."""
    from repro.core.decision import evaluate

    with enable_x64():
        rng = np.random.default_rng(17)
        n = 48
        a = rng.uniform(0.5, 8.0, n)
        b = rng.uniform(0.5, 8.0, n)
        P = a / (a + b)
        from repro.core.batch_decision import batch_evaluate, batch_lower_bound
        P_low = batch_lower_bound(a, b, 0.1)
        lat = rng.uniform(0.2, 3.0, n)
        EV, thr, spec, C, L = batch_evaluate(
            P, 0.4, 0.08, lat, 400, 900, 3e-6, 15e-6, P_lower=P_low)
        # scalar alpha/token inputs broadcast the threshold down to 0-d
        thr = np.broadcast_to(np.asarray(thr), np.asarray(EV).shape)
        for i in range(n):
            res = evaluate(
                DecisionInputs(
                    P=float(P[i]), alpha=0.4, lambda_usd_per_s=0.08,
                    latency_seconds=float(lat[i]), input_tokens=400,
                    output_tokens=900, input_price=3e-6, output_price=15e-6,
                    P_lower_bound=float(P_low[i]),
                ),
                use_lower_bound=True,
            )
            np.testing.assert_allclose(EV[i], res.EV_usd, **ULP)
            np.testing.assert_allclose(thr[i], res.threshold_usd, **ULP)
            assert bool(spec[i]) == (res.decision.value == "SPECULATE")


def test_fleet_autoreply_pareto_matches_scalar_sweep():
    """End-to-end: the benchmark's AutoReply alpha sweep, scalar vs fleet,
    matching Pareto statistics (the §12.3 canary consumer contract)."""
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parents[1]))
    from benchmarks.workflow_sim import (
        DEFAULT_ALPHAS,
        assert_pareto_parity,
        fleet_sweep,
        sweep,
    )

    scalar = sweep(episodes=60)
    fleet = fleet_sweep(episodes=60)
    parity = assert_pareto_parity(scalar, fleet, DEFAULT_ALPHAS, rtol=1e-4)
    assert parity["max_rel_error"] < 1e-4
