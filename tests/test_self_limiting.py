"""Paper §7.6 closed-form self-limiting behavior: under the uniform-mode
prior P = 1/k, the scalar D4 ``decision`` rule SPECULATEs iff
k <= k_crit(alpha) = (L_value + C_spec) / ((2 - alpha) * C_spec) — so the
speculation rate over a population of synthetic edges *falls* with the
upstream branching factor k, exactly where the analytic bound says it
does.  Sweeps k = 1..32 and compares EV / margin against the closed form
to 1e-9."""
import numpy as np
import pytest

from repro.core.batch_decision import critical_k_grid
from repro.core.decision import (
    Decision,
    DecisionInputs,
    critical_k,
    evaluate,
    p_threshold_crossing,
)

KS = np.arange(1, 33)
ALPHAS = (0.0, 0.3, 0.5, 0.9, 1.0)

# synthetic edges: (latency savings L [s], lambda [USD/s], in_tok,
# out_tok, in_price, out_price) — spread so k_crit lands at different,
# non-integer places per edge
EDGES = [
    (0.8, 0.08, 500, 800, 3e-6, 15e-6),
    (2.5, 0.08, 200, 400, 3e-6, 15e-6),
    (0.5, 0.01, 1500, 2000, 3e-6, 15e-6),
    (1.0, 0.02, 100, 150, 1e-6, 5e-6),
    (1.2, 0.02, 800, 1200, 2e-6, 10e-6),
]
# every edge must self-limit inside the k = 1..32 sweep even at the most
# latency-hungry dial, or the rate cannot reach zero (checked in-test)


def _edge_terms(edge):
    L, lam, in_tok, out_tok, in_p, out_p = edge
    C = in_tok * in_p + out_tok * out_p
    return lam * L, C


def _decide(edge, k, alpha) -> "tuple[bool, float, float]":
    L, lam, in_tok, out_tok, in_p, out_p = edge
    res = evaluate(DecisionInputs(
        P=1.0 / k, alpha=alpha, lambda_usd_per_s=lam, latency_seconds=L,
        input_tokens=in_tok, output_tokens=out_tok, input_price=in_p,
        output_price=out_p))
    return (res.decision == Decision.SPECULATE, res.EV_usd,
            res.threshold_usd)


@pytest.mark.parametrize("alpha", ALPHAS)
def test_decision_matches_critical_k_closed_form(alpha):
    """Per edge and per k: EV(1/k) equals the analytic
    (L_value + C)/k - C to 1e-9, and the SPECULATE verdict is exactly
    the closed-form k <= k_crit(alpha) indicator."""
    for edge in EDGES:
        Lv, C = _edge_terms(edge)
        kc = critical_k(Lv, C, alpha)
        assert abs(kc - round(kc)) > 1e-6, \
            "test edge parks k_crit on an integer; pick another edge"
        for k in KS:
            spec, EV, thr = _decide(edge, int(k), alpha)
            assert abs(EV - ((Lv + C) / k - C)) <= 1e-9
            assert abs(thr - (1.0 - alpha) * C) <= 1e-9
            assert spec == (k <= kc)
            # equivalent threshold-crossing form: P = 1/k vs P*(alpha)
            assert spec == (1.0 / k >= p_threshold_crossing(Lv, C, alpha)
                            - 1e-15)


@pytest.mark.parametrize("alpha", ALPHAS)
def test_speculation_rate_falls_with_branching_factor(alpha):
    """The population speculation rate at branching k equals the
    analytic fraction of edges with k <= k_crit, is non-increasing in k,
    and self-limits to zero once k clears every edge's k_crit."""
    kcs = np.array([critical_k(*_edge_terms(e), alpha) for e in EDGES])
    assert np.all(kcs < KS[-1]), \
        "every edge must self-limit inside the sweep"
    rates = []
    for k in KS:
        decisions = [_decide(e, int(k), alpha)[0] for e in EDGES]
        rate = float(np.mean(decisions))
        analytic = float(np.mean(k <= kcs))
        assert abs(rate - analytic) <= 1e-9
        rates.append(rate)
    assert all(a >= b for a, b in zip(rates, rates[1:]))   # monotone fall
    assert rates[0] > 0.0                                  # k=1 speculates
    assert rates[-1] == 0.0                                # k=32 self-limits
    # the fall is strict somewhere inside the sweep for every alpha
    assert rates[0] > rates[-1]


def test_critical_k_grid_matches_scalar_closed_form():
    """The vectorized k_crit grid (batch_decision) agrees with the
    scalar closed form to 1e-9 over the full (edge, alpha) cross (f64 —
    the analytic-curve contract runs at double precision)."""
    from jax.experimental import enable_x64

    alphas = np.asarray(ALPHAS)
    with enable_x64():
        for edge in EDGES:
            Lv, C = _edge_terms(edge)
            grid = critical_k_grid(Lv, C, alphas)
            ref = np.array([critical_k(Lv, C, a) for a in alphas])
            np.testing.assert_allclose(grid, ref, rtol=1e-9, atol=0.0)


def test_alpha_raises_the_self_limiting_point():
    """k_crit is monotone in alpha: a more latency-hungry dial keeps
    speculating at higher branching factors, but never past
    (L_value + C)/C (the alpha=1 ceiling)."""
    for edge in EDGES:
        Lv, C = _edge_terms(edge)
        kcs = [critical_k(Lv, C, a) for a in np.linspace(0.0, 1.0, 21)]
        assert all(a <= b + 1e-15 for a, b in zip(kcs, kcs[1:]))
        assert kcs[-1] == pytest.approx((Lv + C) / C, rel=1e-12)
