"""benchmarks/run.py --smoke wired into tier-1: tiny-episode parity
(scalar<->fleet Pareto, bitwise multi-tenant) plus schema validation of
both the freshly-built record and every checked-in BENCH_*.json — so
benchmark or record-format drift breaks fast tests instead of rotting
until the next manual benchmark run."""
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import run as bench_run


def test_smoke_mode_parity_and_schema():
    rec = bench_run.smoke()
    # the smoke record is the full BENCH_fleet.json shape at tiny sizes
    assert rec["multi_tenant"]["parity"][
        "bitwise_f64_vs_independent_fleet_replay"] is True
    assert rec["parity"]["launched_match"] and rec["parity"]["committed_match"]
    assert rec["credible_bound"]["parity"]["launched_match"]
    # tiny sizes: the smoke path must never masquerade as the real record
    assert rec["episodes"] < 100


def test_checked_in_bench_files_carry_required_schema():
    checked = bench_run.validate_bench_files()
    assert "BENCH_fleet.json" in checked
    fleet = json.loads((bench_run.ROOT / "BENCH_fleet.json").read_text())
    mt = fleet["multi_tenant"]
    # acceptance shape: >= 8 tenants in one sharded call, with the
    # 1/2/4/8 forced-host-device scaling rows recorded
    assert mt["tenants"] >= 8
    assert mt["parity"]["bitwise_f64_vs_independent_fleet_replay"] is True
    assert [r["devices"] for r in mt["scaling"]] == [1, 2, 4, 8]
    assert all(r["shards"] == r["devices"] for r in mt["scaling"])


def test_smoke_rejects_malformed_record():
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_fleet_record({"benchmark": "x"})
