"""benchmarks/run.py --smoke wired into tier-1: tiny-episode parity
(scalar<->fleet Pareto, bitwise multi-tenant) plus the serving
front-end gate (bitwise parity, fault matrix on a virtual clock) and
schema validation of both the freshly-built records and every
checked-in BENCH_*.json — so benchmark or record-format drift breaks
fast tests instead of rotting until the next manual benchmark run."""
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import run as bench_run


def test_smoke_mode_parity_and_schema():
    rec = bench_run.smoke()
    # the smoke record is the full BENCH_fleet.json shape at tiny sizes
    assert rec["multi_tenant"]["parity"][
        "bitwise_f64_vs_independent_fleet_replay"] is True
    assert rec["parity"]["launched_match"] and rec["parity"]["committed_match"]
    assert rec["credible_bound"]["parity"]["launched_match"]
    # episode-sharded gate: the two-pass engine replayed the tiny log
    # bitwise-equal to the sequential scan, and the log-axis-sharded
    # §12.1 grid (the offline_replay reroute) kept decision fractions
    # bitwise with float sums inside reorder tolerance
    es = rec["episode_sharded"]
    assert es["parity"]["bitwise_f64_vs_fleet_replay"] is True
    assert es["parity"]["grid_reroute_fraction_bitwise"] is True
    assert es["parity"]["grid_reroute_max_rel_error"] <= 1e-12
    assert es["segments"] > 1
    # the pipelined replay (stats/handoff overlap) ran the same tiny log
    # through its host-loop dispatch and matched fleet_replay bitwise too
    assert es["pipelined"]["parity"]["bitwise_f64_vs_fleet_replay"] is True
    # online decision service gate: the batched tick must have passed the
    # bitwise-f64 decide parity (and §7.5 flag parity) before timing, and
    # the published pareto rows must carry the f64 dtype label matching
    # the parity tier
    osvc = rec["online_service"]
    assert osvc["parity"]["bitwise_f64_vs_scalar_evaluate"] is True
    assert osvc["parity"]["lower_bound_flags_match"] is True
    assert rec["pareto_dtype"] == "float64"
    assert rec["credible_bound"]["pareto_dtype"] == "float64"
    # beam gate: the top-k engine's width=1 slice replayed bitwise-f64
    # against fleet_replay, and the wide-beam sweep matched its pure-numpy
    # reference twin (decisions bitwise, USD stats inside 1-ULP FMA
    # tolerance), both before any timing was recorded
    beam = rec["beam"]
    assert beam["parity"]["w1_bitwise_f64_vs_fleet_replay"] is True
    assert beam["parity"]["reference_decisions_bitwise"] is True
    assert beam["parity"]["reference_max_rel_error"] <= 1e-12
    assert beam["widths"][0] == 1 and len(beam["widths"]) >= 2
    assert beam["pareto_dtype"] == "float64"
    # the width axis is live: some grid cell launches more candidates
    # (and bills more §9.3 waste) at the widest beam than at width 1
    w_lo, w_hi = str(beam["widths"][0]), str(beam["widths"][-1])
    assert any(
        beam["pareto"][w_hi][a]["launched_candidates"]
        > beam["pareto"][w_lo][a]["launched_candidates"]
        for a in beam["pareto"][w_lo])
    # tiny sizes: the smoke path must never masquerade as the real record
    assert rec["episodes"] < 100
    assert es["episodes"] < 100
    assert max(b["B"] for b in osvc["batches"]) < 64
    assert beam["episodes"] < 100


def test_frontend_smoke_gate_parity_and_fault_matrix():
    from benchmarks import frontend_load

    rec = frontend_load.smoke()
    bench_run.validate_frontend_record(rec, "frontend smoke record")
    # both parity stages ran: healthy batched tick bitwise-f64 vs the
    # scalar decision.evaluate path, and the breaker-open scalar
    # fallback answers bitwise vs the same reference
    assert rec["parity"]["service_vs_scalar_bitwise_f64"] is True
    assert rec["parity"]["fallback_vs_scalar_bitwise_f64"] is True
    # every fault-matrix scenario executed and recorded resilience events
    for name in sorted(bench_run._FRONTEND_FAULTS):
        events = rec["fault_matrix"][name]["events"]
        assert events, f"fault scenario {name} recorded no events"
    # drift_flip must have reached the §12.5 kill-switch on-device and
    # tenant_flood must have shed with USD attributed to the noisy tenant
    assert rec["fault_matrix"]["drift_flip"]["events"].get("drift_trip", 0) >= 1
    assert rec["fault_matrix"]["tenant_flood"]["events"].get("shed", 0) > 0
    # smoke never makes timing claims and never writes BENCH files
    assert rec["decisions_per_s"] == 0.0
    # the virtual-clock drive replayed deadline ticks deterministically
    assert rec["deadline_ticks"] >= 1
    assert rec["requests"] > 0 and rec["shed_rate"] == 0.0


def test_store_smoke_gate_parity_and_zero_recompile():
    from benchmarks import store_scale

    rec = store_scale.smoke()
    bench_run.validate_store_record(rec, "store smoke record")
    # both parity stages ran: the paged store answered churn ticks
    # bitwise-f64 equal to the dense identity-mode service, and its
    # batched decisions matched scalar decision.evaluate over the
    # composed (device + shelf + unborn) snapshot
    assert rec["parity"]["paged_vs_dense_bitwise_f64"] is True
    assert rec["parity"]["paged_vs_scalar_bitwise_f64"] is True
    assert rec["parity"]["rows_checked"] > 0
    assert rec["parity"]["dense_paged"]["spills"] > 0
    # capacity-doubling insert/evict churn left every jit cache where
    # warm-up put it and never rebuilt the physical table
    zr = rec["zero_recompile"]
    assert zr["asserted"] is True and zr["rebuilds"] == 1
    assert zr["host_capacity_doublings"] >= 1
    # empirical-Bayes pooling: the cold row born from the fitted bucket
    # hyperprior starts strictly tighter than the fixed taxonomy prior
    curve = rec["cold_start"]["curve"]
    assert rec["cold_start"]["pooled_tighter_at_birth"] is True
    assert curve[0]["pooled_abs_err"] < curve[0]["fixed_abs_err"]
    # smoke never makes timing claims and never writes BENCH files
    assert rec["decisions_per_s"] == 0.0
    assert rec["register"]["us_per_row"] == 0.0
    assert rec["logical_rows"] < 10_000


def test_checked_in_store_record_shape():
    checked = bench_run.validate_bench_files()
    assert "BENCH_store.json" in checked
    rec = json.loads((bench_run.ROOT / "BENCH_store.json").read_text())
    # acceptance shape: >= 1M logical rows served from a fixed physical
    # table a fraction of that size — every touched row beyond capacity
    # LRU-spilled to the host shelf (untouched rows stay unborn priors)
    # — with the bitwise scalar parity gate asserted before any timing
    assert rec["logical_rows"] >= 1_000_000
    assert rec["memory"]["capacity"] < rec["logical_rows"]
    assert rec["memory"]["resident_rows"] <= rec["memory"]["capacity"]
    assert rec["memory"]["shelved_rows"] > 0
    assert rec["decide"]["spills"] > 0 and rec["decide"]["fault_ins"] > 0
    assert rec["parity"]["paged_vs_scalar_bitwise_f64"] is True
    assert rec["decide"]["us_per_decision"] > 0.0
    assert rec["decisions_per_s"] > 0.0
    # zero recompiles across >= 3 host-capacity doublings
    assert rec["zero_recompile"]["host_capacity_doublings"] >= 3
    assert rec["zero_recompile"]["rebuilds"] == 1
    # cold-start recovery: pooled strictly tighter at birth
    curve = rec["cold_start"]["curve"]
    assert curve[0]["pooled_abs_err"] < curve[0]["fixed_abs_err"]


def test_checked_in_bench_files_carry_required_schema():
    checked = bench_run.validate_bench_files()
    assert "BENCH_fleet.json" in checked
    fleet = json.loads((bench_run.ROOT / "BENCH_fleet.json").read_text())
    mt = fleet["multi_tenant"]
    # acceptance shape: >= 8 tenants in one sharded call, with the
    # 1/2/4/8 forced-host-device scaling rows recorded
    assert mt["tenants"] >= 8
    assert mt["parity"]["bitwise_f64_vs_independent_fleet_replay"] is True
    assert [r["devices"] for r in mt["scaling"]] == [1, 2, 4, 8]
    assert all(r["shards"] == r["devices"] for r in mt["scaling"])
    # acceptance shape: the single-tenant 1M-episode sharded replay row,
    # bitwise parity asserted before timing, 1/2/4/8 device rows with the
    # segment axis really partitioned (shards == devices)
    es = fleet["episode_sharded"]
    assert es["episodes"] >= 1_000_000
    assert es["parity"]["bitwise_f64_vs_fleet_replay"] is True
    assert [r["devices"] for r in es["scaling"]] == [1, 2, 4, 8]
    assert all(r["shards"] == r["devices"] for r in es["scaling"])
    # the pipelined row is a timed measurement whose parity gate passed
    # at the full episode count before its clock started
    assert es["pipelined"]["pipelined_s"] > 0.0
    assert es["pipelined"]["parity"]["bitwise_f64_vs_fleet_replay"] is True
    # acceptance shape: the online decision service row — B up to 1024,
    # bitwise decide parity asserted pre-timing, and the warm B=1024 tick
    # >= 20x faster per decision than the scalar decide loop
    osvc = fleet["online_service"]
    assert osvc["parity"]["bitwise_f64_vs_scalar_evaluate"] is True
    assert [b["B"] for b in osvc["batches"]] == [1, 64, 1024]
    assert osvc["batches"][-1]["speedup"] >= 20.0
    # the published pareto rows carry the dtype of the parity tier
    assert fleet["pareto_dtype"] == "float64"
    assert fleet["credible_bound"]["pareto_dtype"] == "float64"
    # acceptance shape: the beam-width sweep — width 1 first (the
    # parity-gated slice), the w=1 bitwise gate and the reference twin
    # both asserted pre-timing, and the published per-width Pareto
    # attributing every launched/cancelled candidate in USD
    beam = fleet["beam"]
    assert beam["widths"] == [1, 2, 4]
    assert beam["parity"]["w1_bitwise_f64_vs_fleet_replay"] is True
    assert beam["parity"]["reference_decisions_bitwise"] is True
    assert beam["parity"]["reference_max_rel_error"] <= 1e-12
    assert beam["pareto_dtype"] == "float64"
    assert beam["one_call_s"] > 0.0 and beam["per_width_calls_s"] > 0.0
    # the checked-in record must show the width axis doing real work:
    # strictly more candidates launched (and more USD waste billed) at
    # the widest beam on at least one grid cell
    w_lo, w_hi = str(beam["widths"][0]), str(beam["widths"][-1])
    assert any(
        beam["pareto"][w_hi][a]["launched_candidates"]
        > beam["pareto"][w_lo][a]["launched_candidates"]
        and beam["pareto"][w_hi][a]["waste_usd"]
        > beam["pareto"][w_lo][a]["waste_usd"]
        for a in beam["pareto"][w_lo])


def test_checked_in_frontend_record_shape():
    checked = bench_run.validate_bench_files()
    assert "BENCH_frontend.json" in checked
    fe = json.loads((bench_run.ROOT / "BENCH_frontend.json").read_text())
    # acceptance shape: a timed open-loop run (not a smoke record) whose
    # parity gates passed before timing and whose fault matrix covers
    # all four injected-failure scenarios
    assert fe["decisions_per_s"] > 0.0
    assert fe["requests"] >= 1000
    assert 0.0 <= fe["shed_rate"] <= 1.0
    assert fe["latency_ms"]["p50"] <= fe["latency_ms"]["p99"] <= \
        fe["latency_ms"]["max"]
    assert set(fe["fault_matrix"]) >= bench_run._FRONTEND_FAULTS


def test_kernels_smoke_gate_parity_before_timing():
    from benchmarks import kernels_bench

    rec = kernels_bench.smoke()
    bench_run.validate_kernels_record(rec, "kernels smoke record")
    # the betaincinv kernel sat inside the same 1e-10 envelope tier-1
    # pins for the XLA inversion, against both references
    bii = rec["betaincinv"]
    assert bii["parity"]["max_rel_vs_core"] <= bii["parity"]["asserted_rtol"]
    assert bii["parity"]["max_rel_vs_scipy"] <= bii["parity"]["asserted_rtol"]
    assert [r["block_n"] for r in bii["sweep"]] == sorted(
        {r["block_n"] for r in bii["sweep"]})
    # the fused tick matched the default XLA tick bitwise-f64 on the
    # mean path through the real service dispatch, and the §7.5 tier
    # flag-matched with only betainc-implementation-level EV drift
    tick = rec["online_tick"]
    assert tick["parity"]["mean_path_bitwise_f64"] is True
    assert tick["parity"]["lower_bound_max_rel"] <= 1e-9
    # absent an explicit env override the kernels run in interpret mode
    # off-TPU (Mosaic only lowers on TPU) and the record must say so
    import os
    if not os.environ.get("REPRO_PALLAS_INTERPRET"):
        assert rec["interpret"] == (rec["backend"] != "tpu")
    # tiny shapes: the smoke record never masquerades as the real one
    assert bii["n"] < 1024 and tick["rows"] < 64


def test_checked_in_kernels_record_shape():
    checked = bench_run.validate_bench_files()
    assert "BENCH_kernels.json" in checked
    rec = json.loads((bench_run.ROOT / "BENCH_kernels.json").read_text())
    # acceptance shape: a timed record (full batch, real sweeps) whose
    # parity gates passed before its clock started
    assert rec["betaincinv"]["n"] >= 1024
    assert all(r["us_per_call"] > 0.0 for r in rec["betaincinv"]["sweep"])
    assert rec["betaincinv"]["reference_us_per_call"] > 0.0
    assert rec["online_tick"]["parity"]["mean_path_bitwise_f64"] is True
    assert all(r["us_per_tick"] > 0.0 for r in rec["online_tick"]["sweep"])
    assert rec["online_tick"]["reference_us_per_tick"] > 0.0


def test_smoke_rejects_malformed_record():
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_fleet_record({"benchmark": "x"})
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_frontend_record({"benchmark": "x"})
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_store_record({"benchmark": "x"})
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_kernels_record({"benchmark": "x"})
    # a hand-edited kernels record can't smuggle timing past a failed
    # parity gate: the validator re-checks the recorded outcome
    bad = {
        "benchmark": "pallas_hot_path_kernels", "backend": "cpu",
        "interpret": True,
        "betaincinv": {
            "n": 8,
            "parity": {"max_rel_vs_core": 1e-3, "max_rel_vs_scipy": 0.0,
                       "asserted_rtol": 1e-10},
            "sweep": [{"block_n": 8, "us_per_call": 1.0}],
            "reference_us_per_call": 1.0,
        },
        "online_tick": {
            "rows": 8, "batch": 8, "settles": 8,
            "parity": {"mean_path_bitwise_f64": True,
                       "lower_bound_max_rel": 0.0},
            "sweep": [{"block_n": 8, "us_per_tick": 1.0}],
            "reference_us_per_tick": 1.0,
        },
    }
    with pytest.raises(AssertionError, match="exceeds asserted rtol"):
        bench_run.validate_kernels_record(bad)


def test_rollout_smoke_gate_determinism_parity_zero_recompile():
    from benchmarks import rollout_fleet

    rec = rollout_fleet.smoke()
    bench_run.validate_rollout_record(rec, "rollout smoke record")
    # seeded scenario fleet replays bit-identically (transition
    # signatures AND resilience event streams)
    det = rec["determinism"]
    assert det["deterministic"] is True and det["scenarios_checked"] >= 3
    # the in-graph phase machine matched the scalar ReferenceLifecycle
    # transition-for-transition and state-bitwise across the flip trace
    par = rec["parity"]
    assert par["in_graph_vs_scalar_lifecycle"] is True
    assert par["transitions"] >= 6 and par["roll_state_bitwise"] is True
    # promote/demote churn across paged spill/fault-in left every tick
    # executable where warm-up put it
    zr = rec["zero_recompile"]
    assert zr["asserted"] is True
    assert {"rollout_promote", "rollout_demote"} <= set(
        zr["transition_kinds"])
    # the acceptance flip demoted inside the trigger window, billed the
    # demotion in USD, and re-promoted to FULL through cooldown + probes
    acc = rec["acceptance"]
    assert acc["flip_at"] <= acc["first_demote_tick"] <= \
        acc["flip_at"] + acc["trigger_window_ticks"]
    assert acc["demote_usd"] > 0.0 and acc["final_phase"] == "FULL"
    assert all(t > acc["revert_at"] for t in acc["re_promote_ticks"])
    assert acc["events"].get("rollout_reenter", 0) >= 1
    assert acc["events"].get("drift_trip", 0) >= 1
    # the per-archetype pareto separates: confident archetypes reach
    # FULL with no demotes, flat ones never leave SHADOW
    top, bottom = rec["pareto"][0], rec["pareto"][-1]
    assert top["final_phases"].get("FULL", 0) >= 1 and top["demotes"] == 0
    assert bottom["final_phases"].get("FULL", 0) == 0
    assert bottom["promotes"] == 0
    # smoke never makes timing claims and never writes BENCH files
    assert rec["decisions_per_s"] == 0.0


def test_checked_in_rollout_record_shape():
    checked = bench_run.validate_bench_files()
    assert "BENCH_rollout.json" in checked
    rec = json.loads((bench_run.ROOT / "BENCH_rollout.json").read_text())
    # acceptance shape: a timed record with all four gates asserted and
    # the eight-archetype pareto table
    assert rec["decisions_per_s"] > 0.0
    assert rec["determinism"]["deterministic"] is True
    assert rec["parity"]["in_graph_vs_scalar_lifecycle"] is True
    assert rec["zero_recompile"]["asserted"] is True
    assert rec["acceptance"]["final_phase"] == "FULL"
    assert len(rec["pareto"]) >= 8
    archetypes = {r["archetype"] for r in rec["pareto"]}
    assert len(archetypes) >= 8


def test_rollout_smoke_rejects_malformed_record():
    with pytest.raises(AssertionError, match="missing keys"):
        bench_run.validate_rollout_record({"benchmark": "x"})
