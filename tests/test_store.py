"""Paged hierarchical posterior store (repro.core.store): a paged store
at any occupancy must answer ticks bitwise-f64 equal to the dense
identity-mode service on the same logical rows (spill/fault-in is an
exact f64 round-trip), capacity-doubling insert/evict churn must never
recompile the jit'd tick/scatter/gather executables, the free-list must
recycle evicted ids, and the empirical-Bayes bucket hyperpriors must
make planted-p* cold starts strictly tighter than the fixed taxonomy
prior while converging to the same posterior as evidence accumulates."""
import numpy as np
import pytest
from hypothesis import assume, given, settings
from hypothesis import strategies as st
from jax.experimental import enable_x64

from repro.core import online as online_mod
from repro.core.calibration import seed_store_from_replay
from repro.core.decision import DecisionInputs, evaluate
from repro.core.drift import DriftMonitor
from repro.core.online import (
    OnlineDecisionService,
    online_calibration_batch,
    shadow_mode_batch,
)
from repro.core.posterior import BetaPosterior
from repro.core.store import PosteriorStore, _gather_rows, _scatter_rows
from repro.core.taxonomy import DependencyType, prior_params


def _register_rows(svc, n, tenant_every=None):
    for i in range(n):
        svc.register_edge(
            ("u", f"v{i}"),
            tenant=(f"t{i % tenant_every}" if tenant_every else None),
            dep_type=DependencyType.ROUTER_K_WAY,
            k=2 + i % 5,
            discount=(0.95 if i % 3 == 0 else 1.0),
            floor_C_spec_usd=0.01,
            floor_L_value_usd=0.05,
        )


def _requests(rng, B, rows):
    return dict(
        rows=rng.choice(rows, B),
        alpha=rng.uniform(0, 1, B),
        lam=rng.uniform(1e-4, 0.5, B),
        lat=rng.uniform(0.01, 5.0, B),
        in_tok=rng.integers(1, 2000, B).astype(float),
        out_tok=rng.uniform(1, 2000, B),
        in_price=rng.uniform(1e-8, 1e-4, B),
        out_price=rng.uniform(1e-8, 1e-4, B),
    )


def _tick(svc, req, **kw):
    return svc.tick(
        req["rows"], alpha=req["alpha"], lambda_usd_per_s=req["lam"],
        latency_s=req["lat"], input_tokens=req["in_tok"],
        output_tokens=req["out_tok"], input_price=req["in_price"],
        output_price=req["out_price"], **kw)


# ---------------------------------------------------------------------------
# paged vs dense bitwise parity (the tentpole contract)
# ---------------------------------------------------------------------------
def test_paged_store_bitwise_matches_dense_service_under_churn():
    """A paged store holding only 8 of 40 rows on device — ticks cycling
    through every row force constant LRU spill / fault-in — answers every
    decision, settles every outcome, and runs every drift step bitwise
    -f64 identical to the dense identity-mode service."""
    with enable_x64():
        n = 40
        dense = OnlineDecisionService(use_lower_bound=True)
        paged = OnlineDecisionService(use_lower_bound=True, resident_rows=8,
                                      min_rows=8)
        _register_rows(dense, n)
        _register_rows(paged, n)
        rng_seq = np.random.default_rng(7)
        for t in range(12):
            rows = np.arange((t * 7) % n, (t * 7) % n + 6) % n
            req = _requests(np.random.default_rng(100 + t), 6, rows)
            outcomes = [(int(r), bool(rng_seq.integers(2)))
                        for r in rng_seq.choice(rows, 4)]
            dd = _tick(dense, req, outcomes=outcomes, check_drift=True)
            dp = _tick(paged, req, outcomes=outcomes, check_drift=True)
            assert np.array_equal(dd.speculate, dp.speculate)
            assert np.array_equal(dd.EV_usd, dp.EV_usd)
            assert np.array_equal(dd.threshold_usd, dp.threshold_usd)
            assert np.array_equal(dd.margin_usd, dp.margin_usd)
            assert np.array_equal(dd.P_used, dp.P_used)
            assert np.array_equal(dd.drift_triggered[:n],
                                  dp.drift_triggered[:n])
        assert paged.store.stats["spills"] > 0
        assert paged.store.stats["fault_ins"] > paged.store.capacity
        assert paged.store.n_resident <= paged.store.capacity == 8
        # the composed snapshots (device + shelf + unborn tiers) agree
        # bitwise, as do the kill-switch flags riding through the shelf
        assert np.array_equal(dense.posterior_snapshot(),
                              paged.posterior_snapshot())
        assert np.array_equal(dense.breach_runs(), paged.breach_runs())
        assert np.array_equal(dense.enabled_snapshot(),
                              paged.enabled_snapshot())


def test_paged_decisions_bitwise_equal_scalar_evaluate():
    """Spilled-then-faulted rows answer bitwise-f64 equal to the scalar
    decision.evaluate mean path (the acceptance contract, small-scale —
    benchmarks/store_scale.py asserts it at 1M logical rows)."""
    with enable_x64():
        svc = OnlineDecisionService(resident_rows=4, min_rows=4)
        _register_rows(svc, 16)
        rng = np.random.default_rng(3)
        # touch all rows so everything spills at least once
        for start in range(0, 16, 4):
            _tick(svc, _requests(rng, 4, np.arange(start, start + 4)),
                  outcomes=[(start, True), (start + 1, False)])
        snap = svc.posterior_snapshot()
        for start in range(0, 16, 4):
            rows = np.arange(start, start + 4)
            req = _requests(np.random.default_rng(40 + start), 4, rows)
            req["rows"] = rows
            d = _tick(svc, req)
            for j, i in enumerate(rows):
                a, b = snap[i]
                ref = evaluate(DecisionInputs(
                    P=BetaPosterior(alpha=float(a), beta=float(b)).mean,
                    alpha=float(req["alpha"][j]),
                    lambda_usd_per_s=float(req["lam"][j]),
                    latency_seconds=float(req["lat"][j]),
                    input_tokens=int(req["in_tok"][j]),
                    output_tokens=float(req["out_tok"][j]),
                    input_price=float(req["in_price"][j]),
                    output_price=float(req["out_price"][j]),
                ))
                assert d.EV_usd[j] == ref.EV_usd
                assert d.threshold_usd[j] == ref.threshold_usd
                assert d.P_used[j] == ref.P_used


# ---------------------------------------------------------------------------
# zero recompiles across capacity-doubling churn
# ---------------------------------------------------------------------------
def test_paged_churn_never_recompiles():
    """Insert/evict churn that doubles the logical registry capacity
    multiple times leaves every jit cache exactly where warm-up put it:
    the physical table shape is fixed, so growth is host-only."""
    with enable_x64():
        svc = OnlineDecisionService(resident_rows=8, min_rows=8)
        _register_rows(svc, 16)
        rng = np.random.default_rng(11)
        _tick(svc, _requests(rng, 4, np.arange(4)),
              outcomes=[(0, True)], check_drift=True)   # tick executables
        # warm every power-of-two scatter/gather pad bucket the churn can
        # reach (the store's shape-bucketing contract: a bounded, finite
        # executable set, all compiled during warm-up)
        svc.store.ensure_resident(np.arange(8, 16))     # 8-row fault+spill
        svc.store.ensure_resident(np.arange(0, 4))      # 4-row
        svc.store.ensure_resident(np.arange(4, 6))      # 2-row
        svc.store.ensure_resident(np.arange(6, 7))      # 1-row
        caches = lambda: (
            online_mod._tick._cache_size(),
            _scatter_rows._cache_size(),
            _gather_rows._cache_size(),
        )
        warm = caches()
        live = list(range(16))
        next_edge = 16
        for step in range(40):              # 16 logical rows -> 130+
            for _ in range(3):
                live.append(svc.register_edge(
                    ("u", f"v{next_edge}"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT))
                next_edge += 1
            if step % 4 == 0:
                svc.store.evict_row(live.pop(int(rng.integers(len(live)))))
            rows = rng.choice(np.asarray(live), 4, replace=False)
            _tick(svc, _requests(rng, 4, rows),
                  outcomes=[(int(rows[0]), True)], check_drift=True)
        assert svc.store.n_rows > 120          # logical capacity doubled 3x
        assert caches() == warm                # zero recompiles
        assert svc.store.stats["rebuilds"] == 1
        assert svc.store.capacity == 8         # physical shape never moved


# ---------------------------------------------------------------------------
# free-list, eviction semantics, LRU order
# ---------------------------------------------------------------------------
def test_free_list_reuses_evicted_ids_and_dead_rows_raise():
    with enable_x64():
        svc = OnlineDecisionService(resident_rows=4, min_rows=4)
        _register_rows(svc, 6, tenant_every=3)
        _tick(svc, _requests(np.random.default_rng(0), 4, np.arange(4)))
        svc.evict_edge(("u", "v4"), tenant="t1")
        with pytest.raises(KeyError):
            svc.row_key(4)
        with pytest.raises(IndexError, match="outcome row out of range"):
            svc.observe(4, True)
        with pytest.raises(IndexError, match="request row out of range"):
            _tick(svc, _requests(np.random.default_rng(1), 2,
                                 np.asarray([4])))
        # the freed id is recycled by the next registration
        new = svc.register_edge(("u", "v9"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
        assert new == 4
        assert svc.row_key(4) == (None, ("u", "v9"))
        # the recycled row starts from its own prior, not the dead row's
        a0, b0 = prior_params(DependencyType.ALWAYS_PRODUCES_OUTPUT)
        assert tuple(svc.posterior_snapshot()[4]) == (a0, b0)
        # tenant-level eviction drops both of t2's rows in one call
        assert svc.store.evict_tenant("t2") == 2
        assert svc.store.n_alive == 4


def test_lru_spills_least_recently_touched():
    with enable_x64():
        store = PosteriorStore(resident_rows=4, min_rows=4)
        for i in range(8):
            store.register(("u", f"v{i}"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
        store.device_tables("float64")
        store.ensure_resident(np.asarray([0, 1, 2, 3]))
        store.ensure_resident(np.asarray([1]))       # 0 now the coldest
        store.ensure_resident(np.asarray([4]))       # needs one victim
        assert set(store.resident_ids()) == {1, 2, 3, 4}
        store.ensure_resident(np.asarray([5, 6]))    # 2, 3 next coldest
        assert set(store.resident_ids()) == {1, 4, 5, 6}
        # a tick touching more distinct rows than capacity must refuse
        with pytest.raises(RuntimeError, match="resident capacity"):
            store.ensure_resident(np.arange(8))


def test_dtype_switch_and_set_posterior_reach_spilled_rows():
    """A spilled row keeps exact f64 state across an f32 <-> f64 switch,
    and set_posterior faults the row in transparently."""
    svc = OnlineDecisionService(resident_rows=4, min_rows=4)
    _register_rows(svc, 8)
    _tick(svc, _requests(np.random.default_rng(0), 4, np.arange(4)),
          outcomes=[(0, True), (0, True)])            # f32 tables
    svc.store.ensure_resident(np.arange(4, 8))        # spill rows 0-3
    snap32 = svc.posterior_snapshot().astype(np.float64)
    with enable_x64():
        assert np.array_equal(svc.posterior_snapshot(), snap32)
        svc.set_posterior(1, 7.5, 2.5)                # row 1 is spilled
        assert tuple(svc.posterior_snapshot()[1]) == (7.5, 2.5)
        assert 1 in set(svc.store.resident_ids())     # faulted in to write


# ---------------------------------------------------------------------------
# drift-monitor lifecycle wiring (satellite)
# ---------------------------------------------------------------------------
def test_drift_monitor_evicts_and_reseeds_with_store():
    with enable_x64():
        svc = OnlineDecisionService(resident_rows=4, min_rows=4)
        mon = DriftMonitor()
        svc.attach_drift_monitor(mon)
        _register_rows(svc, 8, tenant_every=4)
        for i in range(8):
            tenant, edge = svc.row_key(i)
            for _ in range(120):
                mon.observe_posterior_mean(edge, 0.9, tenant=tenant)
        assert len(mon.edges) == 8
        # eviction drops the monitor's host state for exactly that row
        svc.evict_edge(("u", "v7"), tenant="t3")
        assert mon._key(("u", "v7"), "t3") not in mon.edges
        assert len(mon.edges) == 7
        # birth is not a fault-in: first residency keeps the histories
        svc.state                                     # build device tables
        svc.store.ensure_resident(np.arange(4))
        assert all(len(st.posterior_means) == 120
                   for st in mon.edges.values())
        # 4-6 faulting in evicts 0-2 to the shelf; pulling 0-2 back is a
        # genuine shelf fault-in and re-seeds their trigger-1 baselines
        svc.store.ensure_resident(np.asarray([4, 5, 6]))
        svc.store.ensure_resident(np.asarray([0, 1, 2]))
        for i in (0, 1, 2):
            tenant, edge = svc.row_key(i)
            assert mon.state(edge, tenant).posterior_means == []
        # row 3 spilled but has not returned yet: history intact (reseed
        # happens on fault-in, not on spill)
        tenant, edge = svc.row_key(3)
        assert len(mon.state(edge, tenant).posterior_means) == 120


# ---------------------------------------------------------------------------
# empirical-Bayes pooled cold start (satellite: planted-p* property test)
# ---------------------------------------------------------------------------
def _planted_store(p_star, n_warm, trials, seed):
    """A store whose warm LLM_CALL rows each saw `trials` Bernoulli(p*)
    outcomes, then an EB fit over the resident table."""
    store = PosteriorStore(resident_rows=256)
    rng = np.random.default_rng(seed)
    for i in range(n_warm):
        store.register(("u", f"w{i}"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
    store.device_tables("float64")
    store.ensure_resident(np.arange(n_warm))
    a0, b0 = prior_params(DependencyType.ALWAYS_PRODUCES_OUTPUT)
    succ = rng.binomial(trials, p_star, n_warm)
    vals = np.stack([a0 + succ, b0 + (trials - succ)], 1).astype(float)
    store.set_rows(np.arange(n_warm), vals)
    store.fit_hyperpriors(min_evidence=5.0, strength_cap=200.0)
    return store


@settings(max_examples=15)
@given(
    p_star=st.floats(min_value=0.15, max_value=0.92),
    n_warm=st.integers(min_value=12, max_value=40),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_pooled_prior_recovers_planted_p_faster_than_fixed(
        p_star, n_warm, seed):
    """Cold-start acceptance: a brand-new row born from its bucket's
    fitted hyperprior starts strictly closer to the planted p* than the
    fixed taxonomy prior, and both posteriors converge to the same belief
    as conjugate evidence accumulates."""
    a_fix, b_fix = prior_params(DependencyType.ALWAYS_PRODUCES_OUTPUT)
    # "strictly tighter" is only a meaningful claim when the planted rate
    # actually differs from the fixed prior's guess by more than the
    # pooled estimate's own sampling noise
    assume(abs(a_fix / (a_fix + b_fix) - p_star) > 0.1)
    store = _planted_store(p_star, n_warm, trials=80, seed=seed)
    hp = store.hyperpriors[PosteriorStore.bucket_label(
        DependencyType.ALWAYS_PRODUCES_OUTPUT)]
    cold = store.register(("u", "cold"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
    a0, b0 = prior_params(DependencyType.ALWAYS_PRODUCES_OUTPUT)
    fixed_mean = a0 / (a0 + b0)
    pooled = BetaPosterior(alpha=hp.alpha, beta=hp.beta)
    assert tuple(store.rows_snapshot([cold])[0]) == (hp.alpha, hp.beta)
    # strictly tighter cold start (the taxonomy prior knows nothing of
    # this bucket's planted rate; the pooled one estimated it)
    assert abs(pooled.mean - p_star) < abs(fixed_mean - p_star)
    # shrinkage fades: after enough shared evidence the pooled and fixed
    # rows hold (a) nearly identical beliefs that are (b) near p*
    fixed = BetaPosterior(alpha=a0, beta=b0)
    rng = np.random.default_rng(seed + 1)
    outcomes = rng.random(4000) < p_star
    for x in outcomes:
        pooled.update(bool(x))
        fixed.update(bool(x))
    assert abs(pooled.mean - fixed.mean) < 0.02
    assert abs(pooled.mean - p_star) < 0.05


def test_eb_fit_is_per_bucket_and_ignores_thin_buckets():
    with enable_x64():
        store = PosteriorStore(resident_rows=64)
        for i in range(10):
            store.register(("u", f"a{i}"), dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
        for i in range(10):
            store.register(("u", f"b{i}"),
                           dep_type=DependencyType.ROUTER_K_WAY, k=3)
        store.register(("u", "solo"), dep_type=DependencyType.CONDITIONAL_OUTPUT)
        store.device_tables("float64")
        store.ensure_resident(np.arange(21))
        ids = np.arange(20)
        vals = np.zeros((20, 2))
        vals[:10] = (90.0, 10.0)    # bucket a: ~0.9
        vals[10:] = (20.0, 80.0)    # bucket b: ~0.2
        store.set_rows(ids, vals)
        hps = store.fit_hyperpriors(min_evidence=5.0, min_bucket_rows=2)
        lab_a = PosteriorStore.bucket_label(DependencyType.ALWAYS_PRODUCES_OUTPUT)
        lab_b = PosteriorStore.bucket_label(DependencyType.ROUTER_K_WAY, 3)
        assert hps[lab_a].mean == pytest.approx(0.9, abs=1e-9)
        assert hps[lab_b].mean == pytest.approx(0.2, abs=1e-9)
        assert hps[lab_a].n_rows == hps[lab_b].n_rows == 10
        # the single RETRIEVAL row never clears min_bucket_rows: its
        # registrations keep the fixed taxonomy prior
        assert PosteriorStore.bucket_label(DependencyType.CONDITIONAL_OUTPUT) not in hps
        new = store.register(("u", "solo2"), dep_type=DependencyType.CONDITIONAL_OUTPUT)
        assert tuple(store.rows_snapshot([new])[0]) == \
            prior_params(DependencyType.CONDITIONAL_OUTPUT)


# ---------------------------------------------------------------------------
# calibration stages through the store snapshot API
# ---------------------------------------------------------------------------
def test_shadow_and_online_calibration_reroute_through_store():
    with enable_x64():
        svc = OnlineDecisionService(resident_rows=4, min_rows=4)
        _register_rows(svc, 8)
        rng = np.random.default_rng(5)
        for start in (0, 4):
            _tick(svc, _requests(rng, 4, np.arange(start, start + 4)),
                  outcomes=[(start, True), (start + 1, False)])
        edges = [svc.row_key(i)[1] for i in range(8)]
        trials = [[(f"x{t}", f"x{t}" if (i + t) % 3 else f"y{t}")
                   for t in range(6)] for i in range(8)]
        # the store route must match handing the composed snapshot + the
        # per-row discounts explicitly (rows 0-3 are spilled right now)
        via_store = shadow_mode_batch(edges, svc, trials)
        snap = svc.posterior_snapshot()
        discounts = [svc._rows[i].discount for i in range(8)]
        via_snap = shadow_mode_batch(edges, snap, trials,
                                     discounts=discounts)
        for rs, rr in zip(via_store, via_snap):
            assert rs.posterior.alpha == rr.posterior.alpha
            assert rs.posterior.beta == rr.posterior.beta
            assert rs.posterior.discount == rr.posterior.discount
        # §12.4 accepts the service/store in place of the row count
        rep_a = online_calibration_batch(
            svc, [0, 1, 1, 5], [0.9, 0.8, 0.8, 0.7],
            [True] * 4, [True, False, True, True])
        rep_b = online_calibration_batch(
            8, [0, 1, 1, 5], [0.9, 0.8, 0.8, 0.7],
            [True] * 4, [True, False, True, True])
        assert len(rep_a) == len(rep_b) == 8
        assert [r.buckets for r in rep_a] == [r.buckets for r in rep_b]


def test_seed_store_from_replay_upserts_fleet_rows():
    class _FakeReport:
        def final_posterior_rows(self, grid_index=0):
            keys = [("t0", ("u", "v0")), ("t1", ("u", "v1")),
                    (None, ("u", "v2"))]
            return keys, np.asarray([3.0, 5.0, 7.0]), \
                np.asarray([1.5, 2.5, 3.5])

    with enable_x64():
        store = PosteriorStore(resident_rows=4)
        # v1/t1 pre-exists: seeding must overwrite, not re-register
        store.register(("u", "v1"), tenant="t1",
                       dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
        rows = seed_store_from_replay(store, _FakeReport(), gamma=0.05)
        assert store.n_rows == 3 and rows == [1, 0, 2]
        got = store.rows_snapshot(rows)
        assert np.array_equal(got, [[3.0, 1.5], [5.0, 2.5], [7.0, 3.5]])
        # the freshly-registered rows carried the passthrough kwargs
        assert store.row_config(rows[0]).gamma == 0.05
