"""§12 calibration pipeline + §12.5 drift kill-switch + App. C telemetry."""
import numpy as np
import pytest

import itertools

from repro.core.calibration import (
    SequentialLogRecord,
    TokenEstimator,
    canary,
    offline_replay,
    offline_replay_multi_tenant,
    online_calibration,
    shadow_mode,
)
from repro.core.decision import decision_threshold, expected_value, implied_lambda
from repro.core.drift import DriftMonitor, TriggerKind
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor
from repro.core.taxonomy import DependencyType
from repro.core.telemetry import SpeculationDecision, TelemetryLog


def make_row(i: int, *, P=0.7, alpha=0.5, decision="SPECULATE", committed=True,
             tokens_gen=800, tier3=None, i_actual="billing") -> SpeculationDecision:
    C = 0.0135
    return SpeculationDecision(
        decision_id=f"d{i}", trace_id=f"t{i}", edge=("clf", "drafter"),
        dep_type="router_k_way", tenant="acme", model_version=("m", "v1"),
        alpha=alpha, lambda_usd_per_s=0.08, P_mean=P, P_lower_bound=None,
        C_spec_est_usd=C, L_est_s=0.8, input_tokens_est=500,
        output_tokens_est=800, input_price=3e-6, output_price=15e-6,
        EV_usd=expected_value(P, 0.064, C),
        threshold_usd=decision_threshold(alpha, C),
        decision=decision, phase="runtime", overrode="none",
        i_hat_source="modal", uncertain_cost_flag=False, enabled=True,
        budget_remaining_usd=None, i_actual=i_actual,
        tier1_match=committed, tier2_match=False if not committed else None,
        tier3_accept=tier3, C_spec_actual_usd=C if committed else C * 0.5,
        tokens_generated_before_cancel=tokens_gen, latency_actual_s=0.8,
        committed_speculative=committed,
    )


class TestOfflineReplay:
    def test_full_stage(self):
        rng = np.random.default_rng(0)
        intents = rng.choice(["billing", "support", "sales"], p=[0.7, 0.2, 0.1],
                             size=200)
        logs = [SequentialLogRecord("email", i, "x", "y", 2.0, 0.0135)
                for i in intents]
        pred = HistoricalModalPredictor()
        pred.observe_many([("email", i) for i in intents])
        rep = offline_replay(("clf", "drafter"), logs, {"modal": pred})
        assert rep.k_raw == 3
        assert rep.p_mode == pytest.approx(0.7, abs=0.1)
        assert rep.dep_type in (DependencyType.ROUTER_K_WAY,
                                DependencyType.CONDITIONAL_OUTPUT)
        assert rep.predictor_match_rates["modal"] == pytest.approx(rep.p_mode, abs=0.05)
        # data-seeded prior opens near truth (§12.1)
        assert rep.seeded_prior.mean == pytest.approx(rep.p_mode, abs=0.1)
        assert rep.go  # strong mode -> speculation worth enabling
        assert len(rep.grid) == 20

    def test_no_go_for_flat_distribution(self):
        """§13.3 high-k flat: grid dominated by WAIT -> no-go."""
        rng = np.random.default_rng(1)
        outs = rng.choice([f"o{i}" for i in range(20)], size=200)
        logs = [SequentialLogRecord("in", o, "x", "y", 0.3, 0.0135) for o in outs]
        pred = HistoricalModalPredictor()
        pred.observe_many([("in", o) for o in outs])
        rep = offline_replay(("a", "b"), logs, {"modal": pred},
                             lambdas=(0.005, 0.01))
        assert not rep.go

    def test_grid_matches_pre_batch_scalar_loop(self):
        """The jit'd counterfactual grid reproduces the historical
        per-cell Python loop (itertools.product over the grid, numpy per
        log row) to f64 rounding on the AutoReply config — the §12.1
        replay semantics did not move when the grid moved into XLA."""
        rng = np.random.default_rng(0)
        intents = rng.choice(["billing", "support", "sales"],
                             p=[0.7, 0.2, 0.1], size=200)
        lats = rng.uniform(0.5, 3.0, size=200)
        costs = rng.uniform(0.005, 0.03, size=200)
        logs = [SequentialLogRecord("email", i, "x", "y", float(l), float(c))
                for i, l, c in zip(intents, lats, costs)]
        pred = HistoricalModalPredictor()
        pred.observe_many([("email", i) for i in intents])
        alphas = (0.0, 0.25, 0.5, 0.75, 1.0)
        lambdas = (0.005, 0.01, 0.05, 0.1)
        rho = 0.37
        rep = offline_replay(("clf", "drafter"), logs, {"modal": pred},
                             alphas=alphas, lambdas=lambdas, rho=rho)

        # the pre-batch reference loop, verbatim
        P = rep.seeded_prior.mean
        lat = np.array([r.latency_s for r in logs])
        cost = np.array([r.cost_usd for r in logs])
        ref = []
        for a, lam in itertools.product(alphas, lambdas):
            ev = P * (lat * lam) - (1.0 - P) * cost
            spec = ev >= (1.0 - a) * cost
            frac = float(spec.mean())
            exp_lat = float(np.where(spec, lat * (1.0 - P), lat).mean())
            waste = float((spec * (1.0 - P) * cost * rho).mean() * len(logs))
            ref.append((frac, exp_lat, float(cost.sum() + waste), waste))
        assert len(rep.grid) == len(ref)
        for g, (frac, exp_lat, exp_cost, waste) in zip(rep.grid, ref):
            assert g.speculate_fraction == pytest.approx(frac, rel=1e-12)
            assert g.expected_latency_s == pytest.approx(exp_lat, rel=1e-12)
            assert g.expected_cost_usd == pytest.approx(exp_cost, rel=1e-12)
            assert g.expected_waste_usd == pytest.approx(
                waste, rel=1e-12, abs=1e-15)

    def test_ragged_log_counts_share_bucketed_executable(self):
        """Review regression: the jit'd grid must not recompile per
        distinct log count — a sweep over ragged per-edge logs pads the
        log axis to power-of-two buckets (bitwise-exact: padded rows are
        masked zeros), so many lengths share one XLA executable."""
        from repro.core import batch_decision as bd

        pred = HistoricalModalPredictor()
        pred.observe("e", "x")
        bd._grid_tenants.clear_cache()
        base = None
        for n in (33, 40, 51, 64):      # all in the 64-bucket
            logs = [SequentialLogRecord("e", "x", "a", "b", 1.0, 0.01)
                    for _ in range(n)]
            rep = offline_replay(("u", "v"), logs, {"m": pred})
            base = base or rep
            assert bd._grid_tenants._cache_size() == 1, \
                f"n={n} triggered a recompile"
        # and the bucket padding is invisible in the results: fractions
        # are exact row counts over n, not over the padded length
        assert {g.speculate_fraction for g in base.grid} <= {0.0, 1.0}

    def test_predictions_memoized_per_distinct_input(self):
        """Satellite regression: the replay used to call pred.predict once
        per (predictor, record) — O(predictors x logs) Python-side model
        calls.  Repeated upstream inputs now hit a per-input memo."""

        class CountingPredictor:
            def __init__(self):
                self.calls = 0
                self.inner = HistoricalModalPredictor()

            def predict(self, upstream_input):
                self.calls += 1
                return self.inner.predict(upstream_input)

        logs = [SequentialLogRecord("email", "billing", "x", "y", 2.0, 0.0135)
                for _ in range(100)]
        logs += [SequentialLogRecord("ticket", "support", "x", "y", 2.0, 0.0135)
                 for _ in range(100)]
        preds = {"a": CountingPredictor(), "b": CountingPredictor()}
        for p in preds.values():
            p.inner.observe_many(
                [("email", "billing"), ("ticket", "support")])
        rep = offline_replay(("clf", "drafter"), logs, preds)
        # 2 distinct inputs x 2 predictors, not 200 x 2
        assert preds["a"].calls == 2 and preds["b"].calls == 2
        assert set(rep.predictor_match_rates) == {"a", "b"}

    def test_multi_tenant_matches_per_tenant_reports(self):
        """offline_replay_multi_tenant (one padded XLA grid call for the
        whole fleet) == offline_replay per tenant slice: same seeded
        priors, go verdicts and grids to f64 rounding, despite ragged
        per-tenant log counts."""
        rng = np.random.default_rng(7)
        logs = []
        for t, (n, p_mode) in enumerate([(150, 0.75), (90, 0.4), (40, 0.9)]):
            rest = (1.0 - p_mode) / 2.0
            intents = rng.choice(["billing", "support", "sales"],
                                 p=[p_mode, rest, rest], size=n)
            for i in intents:
                logs.append(SequentialLogRecord(
                    f"in{t}", i, "x", "y",
                    float(rng.uniform(0.5, 3.0)),
                    float(rng.uniform(0.005, 0.03)),
                    tenant=f"t{t}"))
        rng.shuffle(logs)
        pred = HistoricalModalPredictor()
        pred.observe_many([(r.upstream_input, r.upstream_output)
                           for r in logs])
        fleet = offline_replay_multi_tenant(
            ("clf", "drafter"), logs, {"modal": pred})
        assert set(fleet) == {"t0", "t1", "t2"}
        for t in fleet:
            subset = [r for r in logs if r.tenant == t]
            solo = offline_replay(("clf", "drafter"), subset,
                                  {"modal": pred})
            ft = fleet[t]
            assert ft.seeded_prior.alpha == solo.seeded_prior.alpha
            assert ft.seeded_prior.beta == solo.seeded_prior.beta
            assert ft.dep_type == solo.dep_type
            assert ft.go == solo.go
            assert ft.default_alpha == solo.default_alpha
            for a, b in zip(ft.grid, solo.grid):
                assert a.speculate_fraction == pytest.approx(
                    b.speculate_fraction, rel=1e-12)
                assert a.expected_latency_s == pytest.approx(
                    b.expected_latency_s, rel=1e-12)
                assert a.expected_cost_usd == pytest.approx(
                    b.expected_cost_usd, rel=1e-12)
                assert a.expected_waste_usd == pytest.approx(
                    b.expected_waste_usd, rel=1e-12, abs=1e-15)


class TestShadowMode:
    def test_convergence_and_threshold_sweep(self):
        rng = np.random.default_rng(2)
        trials = [("billing", "billing") if rng.random() < 0.8
                  else ("support-very-different", "billing")
                  for _ in range(150)]
        graded = [("refund order", "refund order", True),
                  ("refund order", "totally unrelated text", False)] * 10
        post = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=5)
        rep = shadow_mode(("clf", "drafter"), post, trials,
                          graded_subset=graded,
                          output_token_counts=[800, 820, 790, 810],
                          cancel_fractions=[0.3, 0.4, 0.5])
        assert rep.converged
        assert rep.posterior.mean == pytest.approx(0.8, abs=0.08)
        assert 0.5 <= rep.best_tier2_threshold <= 1.0
        assert rep.tier2_f1 == 1.0
        assert not rep.token_estimator.uncertain_cost
        assert rep.rho_mean == pytest.approx(0.4)

    def test_token_estimator_flags_high_variance(self):
        est = TokenEstimator()
        for t in [100, 2000, 50, 3000, 80, 2500]:
            est.observe(t)
        assert est.uncertain_cost            # §4.2 uncertain_cost tag
        assert est.estimate(sigma_ceiling=True) > est.estimate()


class TestCanary:
    def test_implied_lambda_audit_flags(self):
        """§12.3: operators at alpha*=0.9 reveal lambda far below declared."""
        rep = canary(
            control_latency_s=1.6, control_cost_usd=0.015,
            sweep={0.1: (1.5, 0.0151), 0.5: (1.3, 0.0155), 0.9: (1.1, 0.016)},
            chosen_alpha=0.9, P=0.62, C_spec=0.0135, L_upstream_s=0.8,
            lambda_declared=0.08,
        )
        assert rep.lambda_implied == pytest.approx(0.013, abs=1e-3)
        assert rep.audit == "inspect_declared"
        assert rep.promote            # latency beats control within budget
        assert rep.pareto_alphas      # frontier non-empty

    def test_consistent_operating_point(self):
        lam = implied_lambda(0.62, 0.0135, 0.5, 0.8)   # ~0.024
        rep = canary(1.6, 0.015, {0.5: (1.3, 0.0155)}, 0.5, 0.62, 0.0135, 0.8,
                     lambda_declared=lam)
        assert rep.audit == "consistent"


class TestOnline:
    def test_calibration_curve_and_cov(self):
        log = TelemetryLog()
        rng = np.random.default_rng(3)
        for i in range(300):
            ok = bool(rng.random() < 0.7)
            log.emit(make_row(i, P=0.7, committed=ok,
                              tokens_gen=int(rng.normal(800, 40))))
        rep = online_calibration(log)
        mid_bucket = [b for b in rep.buckets if abs(b.midpoint - 0.75) < 0.06]
        assert mid_bucket and abs(mid_bucket[0].empirical_rate - 0.7) < 0.08
        assert not rep.monotonic_overprediction
        assert rep.token_cov is not None and rep.token_cov < 0.2
        assert not rep.uncertain_cost

    def test_tier2_false_accept_detection(self):
        log = TelemetryLog()
        for i in range(100):
            log.emit(make_row(i, committed=True, tier3=(i % 10 != 0)))
        rep = online_calibration(log)
        assert rep.tier2_false_accept_rate == pytest.approx(0.10)
        assert rep.tier2_needs_tightening


class TestDrift:
    def test_posterior_drop_trigger(self):
        mon = DriftMonitor()
        for _ in range(500):
            mon.observe_posterior_mean(("a", "b"), 0.8)
        ev = None
        for _ in range(100):
            ev = mon.observe_posterior_mean(("a", "b"), 0.5) or ev
        assert ev is not None and ev.kind == TriggerKind.POSTERIOR_DROP
        assert mon.effective_alpha(("a", "b"), 0.5) == pytest.approx(0.3)

    def test_credible_bound_trigger_disables_edge(self):
        mon = DriftMonitor(credible_consecutive_n=3)
        post = BetaPosterior(alpha=1.0, beta=9.0)   # low P, wide
        ev = None
        for _ in range(3):
            ev = mon.check_credible_bound(("a", "b"), post, 0.5, 0.0135, 0.064)
        assert ev is not None
        assert not mon.edge_enabled(("a", "b"))
        assert mon.state(("a", "b")).needs_shadow_rerun

    def test_posterior_mean_history_is_capped(self):
        """Regression: EdgeState.posterior_means grew without bound (a
        memory leak on long-lived edges at fleet scale).  The history is
        now capped at recent_window + baseline_window with identical
        trigger behavior — only the trailing windows are ever read."""
        mon = DriftMonitor(recent_window=20, baseline_window=50)
        cap = mon.recent_window + mon.baseline_window
        for _ in range(10 * cap):
            mon.observe_posterior_mean(("a", "b"), 0.8)
        hist = mon.state(("a", "b")).posterior_means
        assert len(hist) == cap
        # trigger still fires exactly as with unbounded history: 20 recent
        # at 0.5 vs 50-baseline at 0.8 is a >20% drop
        ev = None
        for _ in range(mon.recent_window):
            ev = mon.observe_posterior_mean(("a", "b"), 0.5) or ev
        assert ev is not None and ev.kind == TriggerKind.POSTERIOR_DROP
        assert len(mon.state(("a", "b")).posterior_means) == cap

    def test_posterior_mean_cap_keeps_warmup_gate(self):
        """With a tiny baseline_window the cap must not drop below the
        recent_window + 10 warm-up gate, or the trigger could never arm."""
        mon = DriftMonitor(recent_window=15, baseline_window=4)
        for _ in range(100):
            mon.observe_posterior_mean(("a", "b"), 0.9)
        ev = None
        for _ in range(15):
            ev = mon.observe_posterior_mean(("a", "b"), 0.2) or ev
        assert ev is not None and ev.kind == TriggerKind.POSTERIOR_DROP

    def test_credible_bound_batch_matches_scalar(self):
        """check_credible_bound_batch (one vectorized betaincinv call)
        reproduces per-edge check_credible_bound decision-for-decision:
        same events, same breach runs, same disabled edges."""
        edges = [(f"u{i}", f"v{i}") for i in range(6)]
        posts = [BetaPosterior(alpha=1.0 + 0.5 * i, beta=9.0 - i)
                 for i in range(6)]
        C, L = 0.0135, 0.064
        mon_s = DriftMonitor(credible_consecutive_n=3)
        mon_b = DriftMonitor(credible_consecutive_n=3)
        for step in range(4):
            scalar_evs = [
                mon_s.check_credible_bound(e, p, 0.5, C, L)
                for e, p in zip(edges, posts)
            ]
            batch_evs = mon_b.check_credible_bound_batch(
                edges, [p.alpha for p in posts], [p.beta for p in posts],
                0.5, C, L,
            )
            for se, be in zip(scalar_evs, batch_evs):
                assert (se is None) == (be is None)
                if se is not None:
                    assert se.kind == be.kind and se.edge == be.edge
        assert mon_s._credible_breach_run == mon_b._credible_breach_run
        for e in edges:
            assert mon_s.edge_enabled(e) == mon_b.edge_enabled(e)
        # at least one low-P edge must actually have tripped
        assert any(not mon_b.edge_enabled(e) for e in edges)
        # corrupted posteriors surface like the scalar path, instead of
        # betaincinv's NaN silently disarming the kill-switch
        with pytest.raises(ValueError):
            mon_b.check_credible_bound_batch(
                edges[:2], [1.0, -0.5], [2.0, 2.0], 0.5, C, L)
        with pytest.raises(ValueError):
            mon_s.check_credible_bound(edges[0],
                                       BetaPosterior(alpha=0.0, beta=2.0),
                                       0.5, C, L)

    def test_per_tenant_kill_switch_isolation(self):
        """Satellite: kill-switch state keyed per (tenant, edge) — one
        tenant's drift trigger must not disable the same edge name for
        another tenant, nor for the un-tenanted key."""
        mon = DriftMonitor(credible_consecutive_n=3)
        edge = ("clf", "drafter")
        bad = BetaPosterior(alpha=1.0, beta=9.0)    # breaches the floor
        good = BetaPosterior(alpha=50.0, beta=1.0)  # comfortably above
        ev = None
        for _ in range(3):
            ev = mon.check_credible_bound(edge, bad, 0.5, 0.0135, 0.064,
                                          tenant="acme") or ev
            assert mon.check_credible_bound(edge, good, 0.5, 0.0135, 0.064,
                                            tenant="globex") is None
        assert ev is not None and ev.tenant == "acme"
        assert not mon.edge_enabled(edge, tenant="acme")
        assert mon.state(edge, tenant="acme").needs_shadow_rerun
        assert mon.edge_enabled(edge, tenant="globex")
        assert mon.edge_enabled(edge)          # legacy un-tenanted key
        # alpha offsets stay per-tenant too
        mon.state(edge, tenant="acme").alpha_offset = -0.2
        assert mon.effective_alpha(edge, 0.5, tenant="acme") == pytest.approx(0.3)
        assert mon.effective_alpha(edge, 0.5, tenant="globex") == pytest.approx(0.5)

    def test_credible_bound_batch_tenant_rows(self):
        """The batch checker accepts the fleet row layout ([(tenant,
        edge)] via check_credible_bound_fleet) and books breach runs per
        (tenant, edge) exactly like scalar per-tenant calls."""
        mon_b = DriftMonitor(credible_consecutive_n=2)
        mon_s = DriftMonitor(credible_consecutive_n=2)
        rows = [("t1", ("a", "b")), ("t2", ("a", "b")), ("t1", ("a", "c"))]
        posts = [BetaPosterior(1.0, 9.0), BetaPosterior(50.0, 1.0),
                 BetaPosterior(1.0, 9.0)]
        for _ in range(2):
            b_evs = mon_b.check_credible_bound_fleet(
                rows, [p.alpha for p in posts], [p.beta for p in posts],
                0.5, 0.0135, 0.064)
            s_evs = [
                mon_s.check_credible_bound(e, p, 0.5, 0.0135, 0.064,
                                           tenant=t)
                for (t, e), p in zip(rows, posts)
            ]
            for be, se in zip(b_evs, s_evs):
                assert (be is None) == (se is None)
                if be is not None:
                    assert (be.kind, be.edge, be.tenant) == (
                        se.kind, se.edge, se.tenant)
        assert mon_b._credible_breach_run == mon_s._credible_breach_run
        for t, e in rows:
            assert mon_b.edge_enabled(e, tenant=t) == \
                mon_s.edge_enabled(e, tenant=t)
        assert not mon_b.edge_enabled(("a", "b"), tenant="t1")
        assert mon_b.edge_enabled(("a", "b"), tenant="t2")

    def test_cost_slo_zeroes_alpha_globally(self):
        mon = DriftMonitor(monthly_budget_usd=100.0)
        assert mon.check_cost_slo(50.0) is None
        ev = mon.check_cost_slo(150.0)
        assert ev is not None and ev.scope == "global"
        assert mon.effective_alpha(("any", "edge"), 0.9) == 0.0

    def test_model_version_change_reverts_to_shadow(self):
        mon = DriftMonitor()
        mon.observe_model_version("drafter", "v1", [])
        ev = mon.observe_model_version("drafter", "v2", [("a", "b"), ("a", "c")])
        assert ev is not None and ev.kind == TriggerKind.MODEL_VERSION_CHANGE
        assert mon.state(("a", "b")).needs_shadow_rerun

    def test_tier2_and_cov_triggers(self):
        mon = DriftMonitor()
        assert mon.check_tier2_false_accept(("a", "b"), 0.02) is None
        ev = mon.check_tier2_false_accept(("a", "b"), 0.10)
        assert ev is not None and mon.state(("a", "b")).page_oncall
        ev2 = mon.check_token_cov(("a", "c"), 0.9)
        assert ev2 is not None and not mon.edge_enabled(("a", "c"))

    def test_tier2_trigger_keyed_per_tenant(self):
        """Tenant A's false accepts must not disable tenant B's edge."""
        mon = DriftMonitor()
        ev = mon.check_tier2_false_accept(("a", "b"), 0.10, tenant="tA")
        assert ev is not None and ev.tenant == "tA"
        assert not mon.edge_enabled(("a", "b"), tenant="tA")
        assert mon.state(("a", "b"), "tA").page_oncall
        assert mon.edge_enabled(("a", "b"), tenant="tB")
        assert not mon.state(("a", "b"), "tB").page_oncall
        # the un-scoped (global) row is untouched too
        assert mon.edge_enabled(("a", "b"))

    def test_cost_slo_keyed_per_tenant(self):
        """A tenant budget breach zeroes alpha for that tenant only."""
        mon = DriftMonitor(monthly_budget_usd=100.0)
        mon.tenant_budgets_usd["tA"] = 10.0
        assert mon.check_cost_slo(5.0, tenant="tA") is None
        ev = mon.check_cost_slo(20.0, tenant="tA")
        assert ev is not None and ev.scope == "tenant" and ev.tenant == "tA"
        assert mon.effective_alpha(("a", "b"), 0.9, tenant="tA") == 0.0
        assert mon.effective_alpha(("a", "b"), 0.9, tenant="tB") == 0.9
        assert mon.effective_alpha(("a", "b"), 0.9) == 0.9
        assert not mon.global_alpha_zero
        # tenants without an explicit budget fall back to the global one
        assert mon.check_cost_slo(50.0, tenant="tB") is None
        ev2 = mon.check_cost_slo(150.0, tenant="tB")
        assert ev2 is not None and ev2.tenant == "tB"

    def test_token_cov_trigger_keyed_per_tenant(self):
        mon = DriftMonitor()
        ev = mon.check_token_cov(("a", "c"), 0.9, tenant="tA")
        assert ev is not None and ev.tenant == "tA"
        assert not mon.edge_enabled(("a", "c"), tenant="tA")
        assert mon.edge_enabled(("a", "c"), tenant="tB")


class TestTelemetry:
    def test_every_c2_signal_from_rows_alone(self):
        """App. C.2: every calibration signal derivable from the log."""
        log = TelemetryLog()
        rng = np.random.default_rng(4)
        for i in range(200):
            ok = bool(rng.random() < 0.62)
            log.emit(make_row(
                i, P=0.62, committed=ok, tier3=ok if i % 7 == 0 else None,
                tokens_gen=800 if ok else 296,
                i_actual=rng.choice(["billing", "support"], p=[0.62, 0.38]),
            ))
        s, f = log.posterior_counts()[("clf", "drafter")]
        assert s + f == 200 and abs(s / 200 - 0.62) < 0.1
        keff = log.effective_k()[(("clf", "drafter"), "acme")]
        assert 1.2 < keff < 2.2
        assert log.tier2_false_accept_rate() is not None
        assert log.token_estimate_cov() is not None
        assert len(log.implied_lambdas()) == 200
        assert all(w > 0 for w in log.waste_per_failed_speculation())
        assert log.cost_slo_burn() > 0
        assert len(log.posterior_mean_series(("clf", "drafter"))) == 200
        assert log.calibration_buckets()

    def test_row_roundtrip_and_size(self):
        """App. C.3: rows serialize < 1 KB and round-trip."""
        row = make_row(0)
        js = row.to_json()
        assert len(js.encode()) < 1024
        back = SpeculationDecision.from_json(js)
        assert back == row

    def test_jsonl_persistence(self, tmp_path):
        log = TelemetryLog()
        for i in range(10):
            log.emit(make_row(i))
        path = str(tmp_path / "rows.jsonl")
        assert log.save_jsonl(path) == 10
        log2 = TelemetryLog.load_jsonl(path)
        assert len(log2) == 10
        assert log2.rows[3] == log.rows[3]

    def test_schema_field_count(self):
        """D.4: the schema carries 33 fields."""
        assert len(SpeculationDecision.__dataclass_fields__) == 33
