"""Mini-hypothesis property sweep over the §12 five-stage calibration
pipeline (offline replay -> shadow -> canary -> online -> drift):

* ``shadow_mode`` never mutates the live posterior (§12.2 zero exposure
  now includes the belief state);
* ``canary`` arm promotion is monotone (upward-closed) in the observed
  speculation success rate;
* ``online_calibration`` bucket posteriors recover a planted p* within
  the §7.5 credible bound;
* ``TokenEstimator.uncertain_cost`` flips exactly at the documented CoV
  threshold (strict inequality);
* the million-row ``offline_replay`` reroute (log-axis-sharded grid)
  matches the default bucketed path.

Runs against the real ``hypothesis`` when present, else the
tests/_mini_hypothesis.py shim (see conftest.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.calibration import (
    SequentialLogRecord,
    TokenEstimator,
    canary,
    offline_replay,
    online_calibration,
    shadow_mode,
)
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor
from repro.core.telemetry import TelemetryLog

from test_calibration import make_row

MATCH = "billing"
MISS = "zzz-unrelated-output-999"


# ---------------------------------------------------------------- stage 2
class TestShadowModeNeverMutatesLivePosterior:
    @given(p=st.floats(min_value=0.05, max_value=0.95),
           n=st.integers(min_value=0, max_value=120),
           rate=st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=25, deadline=None)
    def test_live_posterior_untouched(self, p, n, rate):
        """The caller's (live) posterior is frozen through a whole shadow
        run of any length/outcome mix; the returned shadow copy carries
        exactly the trial count on top of the live belief."""
        rng = np.random.default_rng(1 + n + int(rate * 997))
        live = BetaPosterior.from_prior_mean(p)
        snap = (live.alpha, live.beta, live.successes, live.failures)
        trials = [(MATCH, MATCH) if rng.random() < rate else (MISS, MATCH)
                  for _ in range(n)]
        rep = shadow_mode(("clf", "drafter"), live, trials)
        assert (live.alpha, live.beta,
                live.successes, live.failures) == snap
        assert rep.posterior is not live
        assert rep.posterior.n == live.n + n
        assert rep.trials == n

    def test_shadow_copy_still_learns(self):
        """The non-mutation fix must not freeze the shadow copy itself:
        its mean tracks the trial outcome rate."""
        live = BetaPosterior.from_prior_mean(0.5)
        trials = [(MATCH, MATCH)] * 80 + [(MISS, MATCH)] * 20
        rep = shadow_mode(("clf", "drafter"), live, trials)
        assert rep.posterior.mean == pytest.approx(0.8, abs=0.05)
        assert live.mean == pytest.approx(0.5)


# ---------------------------------------------------------------- stage 3
CONTROL_LAT = 1.6
CONTROL_COST = 0.015
BUDGET = CONTROL_COST + 0.002


def _promote_at(p: float) -> bool:
    """One canary arm synthesized from an observed success rate p:
    committed speculations reclaim upstream wait (latency falls with p),
    failed ones bill waste (cost falls with p)."""
    lat = CONTROL_LAT - 0.8 * p
    cost = CONTROL_COST + (1.0 - p) * 0.005
    rep = canary(
        CONTROL_LAT, CONTROL_COST, {0.5: (lat, cost)}, 0.5,
        P=max(p, 1e-6), C_spec=0.0135, L_upstream_s=0.8,
        lambda_declared=0.08, budget_guardrail_usd=BUDGET,
    )
    return rep.promote


class TestCanaryPromotionMonotone:
    @given(p_a=st.floats(min_value=0.0, max_value=1.0),
           p_b=st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=40, deadline=None)
    def test_promotion_upward_closed_in_success_rate(self, p_a, p_b):
        """If an arm at success rate p promotes, every arm at a higher
        success rate (strictly better latency and cost vs the same
        control and budget) promotes too."""
        lo, hi = sorted((p_a, p_b))
        assert (not _promote_at(lo)) or _promote_at(hi)

    def test_promotion_actually_flips(self):
        """The monotone property is non-vacuous: low success rates bust
        the budget guardrail, high ones promote."""
        vals = [_promote_at(p) for p in np.linspace(0.0, 1.0, 101)]
        assert not vals[0] and vals[-1]
        assert vals == sorted(vals)   # exactly one upward flip, no churn


# ---------------------------------------------------------------- stage 4
class TestOnlineCalibrationRecoversPlantedRate:
    @given(p_star=st.floats(min_value=0.15, max_value=0.85))
    @settings(max_examples=12, deadline=None)
    def test_bucket_posterior_credibly_bounds_p_star(self, p_star):
        """Telemetry rows predict P = p* and commit with true rate p*:
        the §12.4 bucket recovers the planted rate, and the §7.5-style
        Beta posterior built from the bucket's (s, f) counts credibly
        bounds p* (99.9% central interval — wide enough that every
        deterministic seed's sampling error sits inside it; n = 400)."""
        n = 400
        rng = np.random.default_rng(int(p_star * 1e6) % (2**31))
        log = TelemetryLog()
        s = 0
        for i in range(n):
            ok = bool(rng.random() < p_star)
            s += int(ok)
            log.emit(make_row(i, P=p_star, committed=ok))
        f = n - s
        rep = online_calibration(log)
        populated = [b for b in rep.buckets if b.n > 0]
        assert len(populated) == 1 and populated[0].n == n
        bucket = populated[0]
        # empirical rate is the planted rate to sampling error (4 sigma)
        sig = np.sqrt(p_star * (1.0 - p_star) / n)
        assert abs(bucket.empirical_rate - p_star) <= 4.0 * sig
        assert bucket.empirical_rate == pytest.approx(s / n)
        # §7.5 credible containment of the planted rate
        post = BetaPosterior(alpha=1.0 + s, beta=1.0 + f)
        lo, hi = post.credible_interval(0.999)
        assert lo <= p_star <= hi
        # a well-calibrated stream must not trip the overprediction flag
        assert not rep.monotonic_overprediction


class TestTokenEstimatorThreshold:
    @given(vals=st.lists(st.floats(min_value=10.0, max_value=3000.0),
                         min_size=2, max_size=40),
           thr=st.floats(min_value=0.05, max_value=2.0))
    @settings(max_examples=40, deadline=None)
    def test_uncertain_cost_flips_exactly_at_cov_threshold(self, vals, thr):
        """uncertain_cost == (cov > cov_threshold), strict: equality at
        the documented threshold does NOT flag, one ULP above does."""
        est = TokenEstimator(cov_threshold=thr)
        for v in vals:
            est.observe(v)
        c = est.cov
        assert c is not None
        assert est.uncertain_cost == (c > thr)

        at = TokenEstimator(cov_threshold=c)
        for v in vals:
            at.observe(v)
        assert not at.uncertain_cost          # cov == threshold: no flag
        if c > 0.0:
            below = TokenEstimator(
                cov_threshold=float(np.nextafter(c, 0.0)))
            for v in vals:
                below.observe(v)
            assert below.uncertain_cost       # threshold one ULP under cov

    def test_under_two_observations_never_uncertain(self):
        est = TokenEstimator(cov_threshold=0.0)
        assert est.cov is None and not est.uncertain_cost
        est.observe(100.0)
        assert est.cov is None and not est.uncertain_cost


# ------------------------------------------------- stage 1 reroute parity
class TestOfflineReplayShardedReroute:
    def test_reroute_matches_default_path(self):
        """Forcing the log-axis-sharded path (tiny shard_threshold) must
        reproduce the default bucketed grid: identical go verdicts and
        default alphas, decision fractions to 1 ULP, expectations to
        float-reorder tolerance."""
        rng = np.random.default_rng(11)
        intents = rng.choice(["billing", "support", "sales"],
                             p=[0.7, 0.2, 0.1], size=300)
        logs = [SequentialLogRecord(
            "email", i, "x", "y", float(rng.uniform(0.5, 3.0)),
            float(rng.uniform(0.005, 0.03))) for i in intents]
        pred = HistoricalModalPredictor()
        pred.observe_many([("email", i) for i in intents])
        base = offline_replay(("clf", "drafter"), logs, {"modal": pred})
        rerouted = offline_replay(("clf", "drafter"), logs,
                                  {"modal": pred}, shard_threshold=50)
        assert rerouted.go == base.go
        assert rerouted.default_alpha == base.default_alpha
        assert rerouted.seeded_prior.alpha == base.seeded_prior.alpha
        assert len(rerouted.grid) == len(base.grid)
        for a, b in zip(rerouted.grid, base.grid):
            assert a.speculate_fraction == pytest.approx(
                b.speculate_fraction, rel=1e-12)
            assert a.expected_latency_s == pytest.approx(
                b.expected_latency_s, rel=1e-12)
            assert a.expected_cost_usd == pytest.approx(
                b.expected_cost_usd, rel=1e-12)
            assert a.expected_waste_usd == pytest.approx(
                b.expected_waste_usd, rel=1e-12, abs=1e-15)

    def test_sharded_grid_segments_share_one_executable(self):
        """Regression (review): the sharded grid buckets its segment
        length to a power of two, so a sweep over many ragged large logs
        — distinct row counts, same segmentation — reuses one compiled
        executable (the same guarantee offline_replay's unsharded branch
        gets from its power-of-two log bucketing), and rho sweeps never
        retrace."""
        from repro.core import batch_decision as bd

        rng = np.random.default_rng(3)
        alphas = np.array([0.0, 0.5, 1.0])
        lams = np.array([0.01, 0.08])
        fn = bd._grid_sharded_exec(None, "fleet")
        fn.clear_cache()
        for n in (197, 230, 256):       # all bucket to Nc = 64 at C = 4
            lat = rng.uniform(0.2, 3.0, n)
            cost = rng.uniform(0.001, 0.03, n)
            for rho in (0.1, 0.5, 0.9):
                bd.counterfactual_grid_sharded(
                    0.6, lat, cost, alphas, lams, rho=rho, segments=4)
        assert fn._cache_size() == 1

    def test_sharded_grid_per_row_rho_and_meshless_axis(self):
        """Regression (review): per-row rho must segment along with its
        rows (it used to broadcast-crash for segments > 1), and a mesh
        without the fleet axis must fall back to the unsharded
        executable instead of raising KeyError."""
        from jax.experimental import enable_x64

        from repro.core.batch_decision import (
            counterfactual_grid,
            counterfactual_grid_sharded,
        )
        from repro.launch.mesh import make_host_mesh

        rng = np.random.default_rng(5)
        n = 100
        lat = rng.uniform(0.2, 3.0, n)
        cost = rng.uniform(0.001, 0.03, n)
        rho_rows = rng.uniform(0.0, 1.0, n)
        alphas = np.array([0.0, 0.5, 1.0])
        lams = np.array([0.01, 0.08])
        with enable_x64():
            base = counterfactual_grid(0.62, lat, cost, alphas, lams,
                                       rho=rho_rows)
            for mesh in (None, make_host_mesh()):   # no "fleet" axis
                g = counterfactual_grid_sharded(
                    0.62, lat, cost, alphas, lams, rho=rho_rows,
                    segments=4, mesh=mesh)
                np.testing.assert_array_equal(
                    base["speculate_fraction"], g["speculate_fraction"])
                for k in ("expected_latency_s", "expected_cost_usd",
                          "expected_waste_usd"):
                    np.testing.assert_allclose(
                        base[k], g[k], rtol=1e-12, atol=1e-18,
                        err_msg=k)
