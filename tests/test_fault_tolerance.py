"""Fault tolerance: atomic checkpoints, kill+restart resume (bitwise), data
pipeline determinism, straggler monitor, grad compression, elastic reshard."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kill/restart + compression loops

from repro.checkpoint.io import (
    AsyncSaver,
    available_steps,
    latest_step,
    load_pytree,
    save_pytree,
)
from repro.configs import REGISTRY
from repro.training.data import DataConfig, SyntheticLMDataset
from repro.training.grad_compress import GradCompressor, dequantize_int8, quantize_int8
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig


def tiny_trainer(tmp_path, steps=12, ckpt_every=4, **kw) -> Trainer:
    cfg = REGISTRY["llama3.2-1b"].reduced()
    tcfg = TrainerConfig(
        steps=steps, checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path / "ckpt"),
        optimizer=OptimizerConfig(kind="adamw", peak_lr=1e-3, warmup_steps=2,
                                  total_steps=steps),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4),
        **kw,
    )
    return Trainer(cfg, tcfg)


class TestCheckpointIO:
    def test_atomic_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        save_pytree(tmp_path, 5, tree, extra={"note": "x"})
        like = jax.tree.map(jnp.zeros_like, tree)
        loaded, extra = load_pytree(tmp_path, 5, like)
        assert extra == {"note": "x"}
        assert jnp.array_equal(loaded["a"], tree["a"])
        assert latest_step(tmp_path) == 5

    def test_crashed_save_never_shadows(self, tmp_path):
        tree = {"w": jnp.ones(3)}
        save_pytree(tmp_path, 1, tree)
        # simulate a crash mid-save: a stale .tmp directory left behind
        tmp = Path(tmp_path) / "step_00000002.tmp"
        tmp.mkdir()
        (tmp / "garbage").write_text("partial")
        assert available_steps(tmp_path) == [1]     # tmp ignored
        save_pytree(tmp_path, 2, tree)              # retry succeeds
        assert available_steps(tmp_path) == [1, 2]

    def test_async_saver_retention(self, tmp_path):
        saver = AsyncSaver(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            saver.save(s, {"w": jnp.full(2, float(s))})
        saver.wait()
        assert available_steps(tmp_path) == [3, 4]


class TestTrainerRestart:
    def test_kill_and_resume_bitwise(self, tmp_path):
        # uninterrupted run
        t1 = tiny_trainer(tmp_path / "a")
        rep1 = t1.run(resume=False)
        # interrupted run: stop after step 6 (checkpoint at 4), then resume
        t2 = tiny_trainer(tmp_path / "b")
        t2.run(resume=False, stop_after=6)
        t3 = tiny_trainer(tmp_path / "b")
        rep3 = t3.run(resume=True)
        assert rep3.resumed_from == 4
        # losses from the resumed segment match the uninterrupted run exactly
        assert rep1.losses[4:] == pytest.approx(rep3.losses, rel=0, abs=0)

    def test_straggler_monitor_counts(self, tmp_path):
        """Deterministic: inject a slow wrapped step; the EMA monitor flags
        it (timings via a real sleep inside the measured region)."""
        import time as _time
        t = tiny_trainer(tmp_path, steps=10, ckpt_every=100)
        orig = t._step
        counter = {"n": 0}

        def sometimes_slow(p, o, b):
            counter["n"] += 1
            out = orig(p, o, b)
            jax.block_until_ready(out[0])
            if counter["n"] == 8:
                _time.sleep(2.0)   # >> straggler_factor x EMA
            return out

        t._step = sometimes_slow
        rep = t.run(resume=False)
        assert rep.straggler_steps, "slow step not flagged"


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        ds1 = SyntheticLMDataset(cfg)
        ds2 = SyntheticLMDataset(cfg)
        b5a = ds1.batch_at(5)["tokens"]
        b5b = ds2.batch_at(5)["tokens"]
        np.testing.assert_array_equal(b5a, b5b)
        it = ds1.iterate(start_step=5)
        np.testing.assert_array_equal(next(it)["tokens"], b5a)

    def test_learnable_structure(self):
        """Markov correlation gives sub-uniform perplexity headroom."""
        cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=8,
                         markov_strength=0.5)
        ds = SyntheticLMDataset(cfg)
        toks = ds.batch_at(0)["tokens"]
        assert toks.min() >= 0 and toks.max() < 100
        # correlated pairs appear more often than chance
        perm_hits = (ds._perm[toks[:, :-1]] == toks[:, 1:]).mean()
        assert perm_hits > 0.2


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (128, 64))
        q = quantize_int8(x, jax.random.key(1))
        err = jnp.abs(dequantize_int8(q) - x).max()
        assert float(err) <= float(q.scale) * 1.01

    def test_error_feedback_preserves_sum(self):
        """Residual accumulation: the long-run mean of compressed grads
        converges to the true mean (error feedback property)."""
        comp = GradCompressor.init({"w": jnp.zeros((64, 64))})
        g = {"w": 0.01 * jax.random.normal(jax.random.key(2), (64, 64))}
        total = jnp.zeros((64, 64))
        for _ in range(50):
            out, comp = comp.roundtrip(g)
            total = total + out["w"]
        mean_err = jnp.abs(total / 50 - g["w"]).mean()
        assert float(mean_err) < 5e-4

    def test_training_with_compression_runs(self, tmp_path):
        t = tiny_trainer(tmp_path, steps=4, ckpt_every=100, compress_grads=True)
        rep = t.run(resume=False)
        assert len(rep.losses) == 4
        assert all(np.isfinite(rep.losses))


class TestElasticReshard:
    def test_checkpoint_mesh_agnostic(self, tmp_path):
        """A checkpoint written unsharded loads onto any mesh (the shard
        layout lives in the load-time shardings, not the file)."""
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_pytree(tmp_path, 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = {"w": NamedSharding(mesh, P("data", None))}
        loaded, _ = load_pytree(tmp_path, 1, jax.tree.map(jnp.zeros_like, tree),
                                shardings=sh)
        assert jnp.array_equal(loaded["w"], tree["w"])
        assert loaded["w"].sharding == sh["w"]
