"""Fault tolerance: atomic checkpoints, kill+restart resume (bitwise), data
pipeline determinism, straggler monitor, grad compression, elastic reshard —
plus the serving front-end's fault matrix (breaker trip/recovery, bulkhead
shed under tenant flood, deadline batching, fallback-chain parity)."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kill/restart + compression loops

from repro.checkpoint.io import (
    AsyncSaver,
    available_steps,
    latest_step,
    load_pytree,
    save_pytree,
)
from repro.configs import REGISTRY
from repro.training.data import DataConfig, SyntheticLMDataset
from repro.training.grad_compress import GradCompressor, dequantize_int8, quantize_int8
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig


def tiny_trainer(tmp_path, steps=12, ckpt_every=4, **kw) -> Trainer:
    cfg = REGISTRY["llama3.2-1b"].reduced()
    tcfg = TrainerConfig(
        steps=steps, checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path / "ckpt"),
        optimizer=OptimizerConfig(kind="adamw", peak_lr=1e-3, warmup_steps=2,
                                  total_steps=steps),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4),
        **kw,
    )
    return Trainer(cfg, tcfg)


class TestCheckpointIO:
    def test_atomic_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        save_pytree(tmp_path, 5, tree, extra={"note": "x"})
        like = jax.tree.map(jnp.zeros_like, tree)
        loaded, extra = load_pytree(tmp_path, 5, like)
        assert extra == {"note": "x"}
        assert jnp.array_equal(loaded["a"], tree["a"])
        assert latest_step(tmp_path) == 5

    def test_crashed_save_never_shadows(self, tmp_path):
        tree = {"w": jnp.ones(3)}
        save_pytree(tmp_path, 1, tree)
        # simulate a crash mid-save: a stale .tmp directory left behind
        tmp = Path(tmp_path) / "step_00000002.tmp"
        tmp.mkdir()
        (tmp / "garbage").write_text("partial")
        assert available_steps(tmp_path) == [1]     # tmp ignored
        save_pytree(tmp_path, 2, tree)              # retry succeeds
        assert available_steps(tmp_path) == [1, 2]

    def test_async_saver_retention(self, tmp_path):
        saver = AsyncSaver(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            saver.save(s, {"w": jnp.full(2, float(s))})
        saver.wait()
        assert available_steps(tmp_path) == [3, 4]


class TestTrainerRestart:
    def test_kill_and_resume_bitwise(self, tmp_path):
        # uninterrupted run
        t1 = tiny_trainer(tmp_path / "a")
        rep1 = t1.run(resume=False)
        # interrupted run: stop after step 6 (checkpoint at 4), then resume
        t2 = tiny_trainer(tmp_path / "b")
        t2.run(resume=False, stop_after=6)
        t3 = tiny_trainer(tmp_path / "b")
        rep3 = t3.run(resume=True)
        assert rep3.resumed_from == 4
        # losses from the resumed segment match the uninterrupted run exactly
        assert rep1.losses[4:] == pytest.approx(rep3.losses, rel=0, abs=0)

    def test_straggler_monitor_counts(self, tmp_path):
        """Deterministic: inject a slow wrapped step; the EMA monitor flags
        it (timings via a real sleep inside the measured region)."""
        import time as _time
        t = tiny_trainer(tmp_path, steps=10, ckpt_every=100)
        orig = t._step
        counter = {"n": 0}

        def sometimes_slow(p, o, b):
            counter["n"] += 1
            out = orig(p, o, b)
            jax.block_until_ready(out[0])
            if counter["n"] == 8:
                _time.sleep(2.0)   # >> straggler_factor x EMA
            return out

        t._step = sometimes_slow
        rep = t.run(resume=False)
        assert rep.straggler_steps, "slow step not flagged"


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        ds1 = SyntheticLMDataset(cfg)
        ds2 = SyntheticLMDataset(cfg)
        b5a = ds1.batch_at(5)["tokens"]
        b5b = ds2.batch_at(5)["tokens"]
        np.testing.assert_array_equal(b5a, b5b)
        it = ds1.iterate(start_step=5)
        np.testing.assert_array_equal(next(it)["tokens"], b5a)

    def test_learnable_structure(self):
        """Markov correlation gives sub-uniform perplexity headroom."""
        cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=8,
                         markov_strength=0.5)
        ds = SyntheticLMDataset(cfg)
        toks = ds.batch_at(0)["tokens"]
        assert toks.min() >= 0 and toks.max() < 100
        # correlated pairs appear more often than chance
        perm_hits = (ds._perm[toks[:, :-1]] == toks[:, 1:]).mean()
        assert perm_hits > 0.2


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (128, 64))
        q = quantize_int8(x, jax.random.key(1))
        err = jnp.abs(dequantize_int8(q) - x).max()
        assert float(err) <= float(q.scale) * 1.01

    def test_error_feedback_preserves_sum(self):
        """Residual accumulation: the long-run mean of compressed grads
        converges to the true mean (error feedback property)."""
        comp = GradCompressor.init({"w": jnp.zeros((64, 64))})
        g = {"w": 0.01 * jax.random.normal(jax.random.key(2), (64, 64))}
        total = jnp.zeros((64, 64))
        for _ in range(50):
            out, comp = comp.roundtrip(g)
            total = total + out["w"]
        mean_err = jnp.abs(total / 50 - g["w"]).mean()
        assert float(mean_err) < 5e-4

    def test_training_with_compression_runs(self, tmp_path):
        t = tiny_trainer(tmp_path, steps=4, ckpt_every=100, compress_grads=True)
        rep = t.run(resume=False)
        assert len(rep.losses) == 4
        assert all(np.isfinite(rep.losses))


class TestElasticReshard:
    def test_checkpoint_mesh_agnostic(self, tmp_path):
        """A checkpoint written unsharded loads onto any mesh (the shard
        layout lives in the load-time shardings, not the file)."""
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_pytree(tmp_path, 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = {"w": NamedSharding(mesh, P("data", None))}
        loaded, _ = load_pytree(tmp_path, 1, jax.tree.map(jnp.zeros_like, tree),
                                shardings=sh)
        assert jnp.array_equal(loaded["w"], tree["w"])
        assert loaded["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# serving front-end fault matrix
# ---------------------------------------------------------------------------
def _frontend_service(n_tenants=2, edges=2):
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior

    svc = OnlineDecisionService()
    for t in range(n_tenants):
        for e in range(edges):
            svc.register_edge((f"u{e}", f"v{e}"), tenant=f"t{t}",
                              posterior=BetaPosterior(alpha=16.0, beta=2.0))
    return svc


def _fe_req(row, tenant, edge, **kw):
    from repro.serving.frontend import DecisionRequest

    base = dict(alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                input_tokens=500.0, output_tokens=300.0,
                input_price=3e-6, output_price=15e-6)
    base.update(kw)
    return DecisionRequest(row=row, tenant=tenant, edge=edge, **base)


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestFrontendFaultMatrix:
    def test_breaker_trips_on_consecutive_tick_faults(self):
        from repro.serving.faults import FaultInjector, FaultPlan, FaultyService
        from repro.serving.frontend import (
            BreakerState, FrontendConfig, ServingFrontend)

        svc = _frontend_service(n_tenants=1, edges=1)
        inj = FaultInjector(FaultPlan(raise_from=0, raise_until=3))
        fe = ServingFrontend(
            FaultyService(svc, inj),
            FrontendConfig(max_batch=2, breaker_failure_threshold=3,
                           breaker_cooldown_s=10.0),
            clock=_Clock(), autostart=False)
        key = ("t0", ("u0", "v0"))
        for i in range(3):
            tk = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
            fe.pump()
            res = tk.result(0)
            assert res.source == "scalar"      # degraded, never blocked
            if res.speculate:
                tk.release()
            want = (BreakerState.OPEN if i == 2 else BreakerState.CLOSED)
            assert fe.breaker.state(key) is want
        # while open, requests skip the (still-faulting) service entirely
        calls_before = inj.calls
        tk = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
        assert tk.done() and tk.result(0).source == "scalar"
        if tk.result(0).speculate:
            tk.release()
        assert inj.calls == calls_before
        kinds = fe.resilience.by_kind()
        assert kinds["exception"] == 3
        assert kinds["breaker_open"] == 1
        assert kinds["fallback_scalar"] == 4

    def test_half_open_probe_recovers_service_path(self):
        from repro.serving.faults import FaultInjector, FaultPlan, FaultyService
        from repro.serving.frontend import (
            BreakerState, FrontendConfig, ServingFrontend)

        svc = _frontend_service(n_tenants=1, edges=1)
        inj = FaultInjector(FaultPlan(raise_from=0, raise_until=1))
        clock = _Clock()
        fe = ServingFrontend(
            FaultyService(svc, inj),
            FrontendConfig(max_batch=2, breaker_failure_threshold=1,
                           breaker_cooldown_s=0.5),
            clock=clock, autostart=False)
        key = ("t0", ("u0", "v0"))
        tk = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
        fe.pump()                              # faulted -> breaker opens
        if tk.result(0).speculate:
            tk.release()
        assert fe.breaker.state(key) is BreakerState.OPEN
        clock.t = 1.0                          # cooldown elapses
        probe = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
        assert fe.breaker.state(key) is BreakerState.HALF_OPEN
        fe.pump()                              # healthy tick closes it
        res = probe.result(0)
        assert res.source == "service"
        if res.speculate:
            probe.settle(True)
        assert fe.breaker.state(key) is BreakerState.CLOSED
        kinds = fe.resilience.by_kind()
        assert kinds["breaker_half_open"] == 1 and kinds["breaker_close"] == 1

    def test_bulkhead_sheds_flooding_tenant_only(self):
        from repro.core.decision import Decision
        from repro.serving.frontend import FrontendConfig, ServingFrontend

        fe = ServingFrontend(
            _frontend_service(),
            FrontendConfig(max_batch=64, bulkhead_limit=3),
            autostart=False)
        flood = [fe.submit(_fe_req(0, "t0", ("u0", "v0")))
                 for _ in range(10)]
        calm = [fe.submit(_fe_req(2, "t1", ("u0", "v0")))
                for _ in range(3)]
        fe.pump()
        shed = [t for t in flood if t.result(0).source == "shed"]
        assert len(shed) == 7                  # beyond the 3-slot bulkhead
        assert all(t.result(0).decision is Decision.WAIT for t in shed)
        assert all(t.result(0).source == "service" for t in calm)
        # every shed carries a USD-attributed event for the right tenant
        att = fe.resilience.usd_attribution()
        assert att[("t0", "shed")] == pytest.approx(7 * 3.0 * 0.9)
        assert ("t1", "shed") not in att
        for t in flood + calm:
            if t.result(0).speculate:
                t.settle(True)

    def test_deadline_tick_fires_with_partial_batch(self):
        """Real batcher thread: a single request (far below max_batch)
        must be answered after ~deadline_s, not held for batch-full."""
        import time as _time

        from repro.serving.frontend import FrontendConfig, ServingFrontend

        with ServingFrontend(
                _frontend_service(),
                FrontendConfig(max_batch=64, deadline_s=0.05)) as fe:
            tk = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
            res = tk.result(10.0)              # jit compile on first tick
            assert res.source == "service"
            if res.speculate:
                tk.settle(True)
            # steady state: the deadline, not batch-full, fires the tick
            t0 = _time.perf_counter()
            tk2 = fe.submit(_fe_req(0, "t0", ("u0", "v0")))
            res2 = tk2.result(10.0)
            waited = _time.perf_counter() - t0
            if res2.speculate:
                tk2.settle(True)
            assert waited >= 0.04              # held for the window
            assert waited < 5.0
            assert fe.stats["deadline_ticks"] >= 2
            assert fe.stats["full_ticks"] == 0

    def test_fallback_chain_bitwise_matches_scalar_evaluate(self):
        """Both degraded stages answer with exactly decision.evaluate:
        tick faults (stage 2 via exception) and breaker-open (stage 2 via
        admission) under enable_x64 — bitwise EV/threshold/P."""
        from jax.experimental import enable_x64

        from repro.core.decision import DecisionInputs, evaluate
        from repro.core.posterior import BetaPosterior
        from repro.serving.faults import FaultInjector, FaultPlan, FaultyService
        from repro.serving.frontend import FrontendConfig, ServingFrontend

        with enable_x64():
            svc = _frontend_service(n_tenants=1, edges=2)
            inj = FaultInjector(FaultPlan(raise_from=0))   # every tick fails
            fe = ServingFrontend(
                FaultyService(svc, inj),
                FrontendConfig(max_batch=4, breaker_failure_threshold=2),
                autostart=False)
            snap = svc.posterior_snapshot()
            reqs = [_fe_req(r, "t0", (f"u{r}", f"v{r}"),
                            latency_s=1.0 + r, output_tokens=200.0 + r)
                    for r in range(2)]
            for round_ in range(3):            # rounds 0-1 fault, then open
                tks = [fe.submit(q) for q in reqs]
                fe.pump()
                for tk, q in zip(tks, reqs):
                    res = tk.result(0)
                    assert res.source == "scalar"
                    post = BetaPosterior(alpha=float(snap[q.row, 0]),
                                         beta=float(snap[q.row, 1]))
                    ref = evaluate(DecisionInputs(
                        P=post.mean, alpha=q.alpha,
                        lambda_usd_per_s=q.lambda_usd_per_s,
                        latency_seconds=q.latency_s,
                        input_tokens=q.input_tokens,
                        output_tokens=q.output_tokens,
                        input_price=q.input_price,
                        output_price=q.output_price))
                    assert res.decision is ref.decision
                    assert res.EV_usd == ref.EV_usd
                    assert res.threshold_usd == ref.threshold_usd
                    assert res.C_spec_usd == ref.C_spec_usd
                    assert res.L_value_usd == ref.L_value_usd
                    assert res.P_used == ref.P_used
                    if res.speculate:
                        tk.release()


# ---------------------------------------------------------------------------
# seeded drift-trace primitives (shared by scenarios.py and this file)
# ---------------------------------------------------------------------------
class TestDriftTracePrimitives:
    def test_flip_and_revert_rates(self):
        from repro.serving.faults import DriftTrace
        tr = DriftTrace.flip(10, rate0=0.9, rate1=0.1, revert_at=20)
        assert [tr.rate_at(i) for i in (0, 9, 10, 19, 20, 99)] == \
            [0.9, 0.9, 0.1, 0.1, 0.9, 0.9]

    def test_ramp_is_linear_between_endpoints(self):
        from repro.serving.faults import DriftTrace
        tr = DriftTrace.ramp(10, 20, rate0=1.0, rate1=0.0)
        assert tr.rate_at(9) == 1.0 and tr.rate_at(20) == 0.0
        assert tr.rate_at(15) == pytest.approx(0.5)
        mids = [tr.rate_at(i) for i in range(10, 20)]
        assert all(a >= b for a, b in zip(mids, mids[1:]))
        with pytest.raises(ValueError):
            DriftTrace.ramp(5, 5)

    def test_oscillation_square_wave(self):
        from repro.serving.faults import DriftTrace
        tr = DriftTrace.oscillation(3, rate0=0.9, rate1=0.1)
        assert [tr.rate_at(i) for i in range(7)] == \
            [0.9, 0.9, 0.9, 0.1, 0.1, 0.1, 0.9]
        shifted = DriftTrace.oscillation(3, rate0=0.9, rate1=0.1, phase=3)
        assert shifted.rate_at(0) == 0.1

    def test_injector_samples_trace_deterministically(self):
        from repro.serving.faults import DriftTrace, FaultInjector, FaultPlan
        tr = DriftTrace.flip(50, rate0=1.0, rate1=0.0)
        runs = []
        for _ in range(2):
            inj = FaultInjector(FaultPlan(trace=tr, seed=11))
            runs.append([inj.outcome() for _ in range(100)])
        assert runs[0] == runs[1]                  # same seed, same stream
        assert all(runs[0][:50]) and not any(runs[0][50:])
        other = FaultInjector(FaultPlan(trace=DriftTrace.constant(0.5),
                                        seed=12))
        got = [other.outcome() for _ in range(200)]
        assert 60 <= sum(got) <= 140               # actually stochastic

    def test_heavy_tail_tokens_seeded_capped(self):
        from repro.serving.faults import heavy_tail_tokens
        a = heavy_tail_tokens(3, 4096, median=256.0, cap=4096.0)
        b = heavy_tail_tokens(3, 4096, median=256.0, cap=4096.0)
        assert np.array_equal(a, b)
        assert a.min() >= 1.0 and a.max() <= 4096.0
        assert 150.0 < float(np.median(a)) < 400.0
        assert float(a.mean()) > float(np.median(a))   # heavy right tail
        with pytest.raises(ValueError):
            heavy_tail_tokens(0, 0)

    def test_correlated_flip_traces_jitter_and_determinism(self):
        from repro.serving.faults import correlated_flip_traces
        a = correlated_flip_traces(5, 30, seed=9, jitter=3, revert_at=60)
        b = correlated_flip_traces(5, 30, seed=9, jitter=3, revert_at=60)
        assert a == b
        assert all(27 <= tr.at <= 33 for tr in a)
        assert all(tr.until is not None and tr.until > tr.at for tr in a)
        exact = correlated_flip_traces(4, 30)      # jitter=0: perfect corr
        assert all(tr.at == 30 for tr in exact)
        with pytest.raises(ValueError):
            correlated_flip_traces(0, 10)
