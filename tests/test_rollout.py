"""Staged-rollout lifecycle: unit, property and integration coverage.

The mini-hypothesis sweep asserts the issue's four properties directly
against the traced machine:

  (a) any kill-switch breach on an open row demotes within one tick,
  (b) re-entry (and serving) is impossible before the cooldown expires,
  (c) promotion is monotone in the observed success rate,
  (d) phase state survives paged spill/fault-in bitwise.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.online import OnlineDecisionService
from repro.core.posterior import BetaPosterior
from repro.core.rollout import (CANARY, DISABLED, FULL, ONLINE_CAL, SHADOW,
                                ReferenceLifecycle, RolloutConfig,
                                RolloutController, decode_transition,
                                rollout_advance, rollout_allow)
from repro.core.store import ROLL_COLS, PosteriorStore
from repro.core.telemetry import RESILIENCE_KINDS, ResilienceLog
from repro.serving.faults import DriftTrace, FaultInjector, FaultPlan

D4 = dict(alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
          input_tokens=500, output_tokens=300,
          input_price=3e-6, output_price=15e-6)


def _service(n_rows=1, consecutive_n=3, discount=0.9):
    svc = OnlineDecisionService(credible_consecutive_n=consecutive_n)
    for r in range(n_rows):
        svc.register_edge((f"a{r}", f"b{r}"), tenant=f"t{r % 2}",
                          posterior=BetaPosterior(alpha=16.0, beta=2.0),
                          discount=discount, floor_alpha=0.3,
                          floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
    return svc


def _advance(roll, cfg, *, triggered=None, touched=None, n_out=0, s_out=0):
    """One rollout_advance step over a single-row table (numpy in/out)."""
    n = roll.shape[0]
    flags = np.stack([np.ones(n, np.int32), np.zeros(n, np.int32)], 1)
    trig = np.zeros(n, bool) if triggered is None else np.asarray(triggered)
    tch = np.ones(n, bool) if touched is None else np.asarray(touched)
    r1, f1, tr = rollout_advance(
        roll.astype(np.int32), flags, trig, tch,
        np.full(n, n_out, np.int32), np.full(n, s_out, np.int32),
        cfg.encode())
    return np.asarray(r1), np.asarray(f1), np.asarray(tr)


# ---------------------------------------------------------------------------
# config + encoding
# ---------------------------------------------------------------------------
def test_config_validates_and_encodes():
    cfg = RolloutConfig(cooldown_ticks=5, probe_budget=3, canary_period=4,
                        min_obs=(2, 3, 4), promote_rate=(0.5, 0.6, 0.7))
    assert cfg.encode().tolist() == [5, 3, 4, 2, 3, 4, 500, 600, 700]
    assert cfg.encode().dtype == np.int32
    for bad in (dict(cooldown_ticks=0), dict(probe_budget=0),
                dict(canary_period=0), dict(min_obs=(0, 1, 1)),
                dict(promote_rate=(0.5, 0.5, 1.5)),
                dict(min_obs=(1, 1))):
        with pytest.raises(ValueError):
            RolloutConfig(**bad)


def test_transition_codes_round_trip():
    for code, kind in [(1, "rollout_promote"), (2, "rollout_demote"),
                       (3, "rollout_reenter"), (4, "rollout_probe_fail")]:
        packed = code * 64 + SHADOW * 8 + CANARY
        k, old, new = decode_transition(packed)
        assert (k, old, new) == (kind, SHADOW, CANARY)
        assert kind in RESILIENCE_KINDS
    with pytest.raises(ValueError):
        decode_transition(0)


def test_serve_mask_per_phase():
    cfg = RolloutConfig(canary_period=3)
    # [phase, cd, pb, tip, n, s]
    roll = np.array([
        [DISABLED, 0, 0, 0, 0, 0],
        [SHADOW, 0, 0, 0, 0, 0],
        [CANARY, 0, 0, 0, 0, 0],      # tip 0 -> period tick, serves
        [CANARY, 0, 0, 1, 0, 0],      # off-period tick
        [ONLINE_CAL, 0, 0, 5, 0, 0],
        [FULL, 0, 0, 9, 0, 0],
        [FULL, 2, 0, 0, 0, 0],        # cooling down: never serves
    ], np.int32)
    allow = np.asarray(rollout_allow(roll, cfg.encode()))
    assert allow.tolist() == [False, False, True, False, True, True, False]


# ---------------------------------------------------------------------------
# property (a): any breach on an open row demotes within one tick
# ---------------------------------------------------------------------------
@settings(max_examples=60)
@given(phase=st.integers(min_value=SHADOW, max_value=FULL),
       pb=st.integers(min_value=0, max_value=8),
       tip=st.integers(min_value=0, max_value=40),
       n=st.integers(min_value=0, max_value=50),
       s=st.integers(min_value=0, max_value=50))
def test_breach_demotes_within_one_tick(phase, pb, tip, n, s):
    cfg = RolloutConfig(cooldown_ticks=4, probe_budget=4)
    roll = np.array([[phase, 0, pb, tip, n, min(s, n)]], np.int32)
    r1, _, tr = _advance(roll, cfg, triggered=[True])
    kind, old, new = decode_transition(int(tr[0]))
    assert kind == "rollout_demote" and old == phase and new == SHADOW
    assert r1[0, 0] == SHADOW
    assert r1[0, 1] == cfg.cooldown_ticks          # cooldown restarted
    assert r1[0, 4] == r1[0, 5] == 0               # evidence reset
    assert not np.asarray(rollout_allow(r1, cfg.encode()))[0]


def test_breach_mid_cooldown_is_absorbed():
    """An OPEN circuit doesn't re-open: triggers while cooling down are
    swallowed (no event, cooldown keeps counting)."""
    cfg = RolloutConfig(cooldown_ticks=5)
    roll = np.array([[SHADOW, 4, 0, 0, 0, 0]], np.int32)
    r1, _, tr = _advance(roll, cfg, triggered=[True])
    assert tr[0] == 0
    assert r1[0, 1] == 3


def test_breach_on_expiry_tick_demotes_not_reenters():
    """A trigger landing exactly when the cooldown hits zero restarts the
    cooldown (demote) instead of re-entering — no re-enable deadlock."""
    cfg = RolloutConfig(cooldown_ticks=5)
    roll = np.array([[SHADOW, 1, 0, 0, 0, 0]], np.int32)
    r1, _, tr = _advance(roll, cfg, triggered=[True])
    assert decode_transition(int(tr[0]))[0] == "rollout_demote"
    assert r1[0, 1] == cfg.cooldown_ticks


# ---------------------------------------------------------------------------
# property (b): no re-entry (or serving) before the cooldown expires
# ---------------------------------------------------------------------------
@settings(max_examples=40)
@given(cooldown=st.integers(min_value=2, max_value=10),
       outcomes=st.integers(min_value=0, max_value=5))
def test_no_reentry_before_cooldown_expires(cooldown, outcomes):
    # promotion bar out of reach, so the expiry transition is isolated
    cfg = RolloutConfig(cooldown_ticks=cooldown, probe_budget=4,
                        min_obs=(1000, 1000, 1000))
    roll = np.array([[SHADOW, 0, 0, 0, 0, 0]], np.int32)
    r1, _, tr = _advance(roll, cfg, triggered=[True])     # demote now
    for k in range(cooldown - 1):
        assert not np.asarray(rollout_allow(r1, cfg.encode()))[0]
        r1, _, tr = _advance(r1, cfg, n_out=outcomes, s_out=outcomes)
        assert tr[0] == 0, f"transition escaped cooldown at step {k}"
        # evidence gathered during cooldown must not count
        assert r1[0, 4] == r1[0, 5] == 0
    # the cooldown-expiry tick re-enters with the full probe budget
    r1, f1, tr = _advance(r1, cfg, n_out=outcomes, s_out=outcomes)
    kind, old, new = decode_transition(int(tr[0]))
    assert kind == "rollout_reenter" and old == new == SHADOW
    assert r1[0, 2] == cfg.probe_budget
    assert f1[0, 0] == 1                                  # re-enabled


# ---------------------------------------------------------------------------
# property (c): promotion monotone in observed success rate
# ---------------------------------------------------------------------------
@settings(max_examples=60)
@given(phase=st.integers(min_value=SHADOW, max_value=ONLINE_CAL),
       n=st.integers(min_value=1, max_value=200),
       s=st.integers(min_value=0, max_value=200),
       rate=st.floats(min_value=0.0, max_value=1.0),
       min_obs=st.integers(min_value=1, max_value=50))
def test_promotion_monotone_in_success(phase, n, s, rate, min_obs):
    s = min(s, n)
    cfg = RolloutConfig(min_obs=(min_obs,) * 3, promote_rate=(rate,) * 3,
                        probe_budget=1000)
    def promoted(s_obs):
        roll = np.array([[phase, 0, 1000, 0, n, s_obs]], np.int32)
        _, _, tr = _advance(roll, cfg)
        return tr[0] > 0 and decode_transition(int(tr[0]))[0] == "rollout_promote"
    if promoted(s):
        # more observed successes can never un-promote
        for s_hi in {min(s + 1, n), n}:
            assert promoted(s_hi)
    else:
        for s_lo in {max(s - 1, 0), 0}:
            assert not promoted(s_lo)


# ---------------------------------------------------------------------------
# property (d): phase state survives paged spill/fault-in bitwise
# ---------------------------------------------------------------------------
@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_roll_state_survives_spill_fault_in_bitwise(seed):
    from repro.core.taxonomy import DependencyType

    rng = np.random.default_rng(seed)
    store = PosteriorStore(resident_rows=4, min_rows=4)
    for i in range(8):
        store.register(("u", f"v{i}"),
                       dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT)
    store.device_tables("float32")
    want = rng.integers(0, 1000, size=(8, ROLL_COLS)).astype(np.int32)
    want[:, 0] = rng.integers(DISABLED, FULL + 1, size=8)
    # write in two paged halves, spilling each across the other
    store.set_roll_rows(np.arange(4), want[:4])
    store.ensure_resident(np.arange(4, 8))       # spills 0-3 to the shelf
    store.set_roll_rows(np.arange(4, 8), want[4:])
    snap = store.roll_snapshot()
    assert np.array_equal(snap, want)
    # churn residency both ways; the composed view must never change
    store.ensure_resident(np.arange(4))
    store.ensure_resident(np.arange(4, 8))
    assert np.array_equal(store.roll_snapshot(), want)
    assert store.roll_snapshot().dtype == np.int32


def test_roll_state_dense_vs_paged_identical_lifecycle():
    """The same tick stream produces bitwise-identical roll columns on an
    identity (dense) store and a paged store half its size."""
    cfg = RolloutConfig(cooldown_ticks=4, probe_budget=4, min_obs=(3, 3, 3))
    trace = DriftTrace.flip(15, rate1=0.02, revert_at=45)

    def run(paged: bool):
        kw = dict(credible_consecutive_n=3)
        if paged:
            kw.update(resident_rows=4, min_rows=4)
        svc = OnlineDecisionService(**kw)
        for r in range(6):
            svc.register_edge((f"a{r}", f"b{r}"), tenant="t0",
                              posterior=BetaPosterior(alpha=16.0, beta=2.0),
                              discount=0.9, floor_alpha=0.3,
                              floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
        ctl = RolloutController(svc, cfg)
        inj = [FaultInjector(FaultPlan(trace=trace, seed=7 + r))
               for r in range(6)]
        sigs = []
        for i in range(70):
            rows = [i % 6, (i + 1) % 6]        # paged working set of 2
            d = ctl.tick(rows, outcomes=[(r, inj[r].outcome())
                                         for r in rows], **D4)
            sigs.append(tuple(int(c) for c in d.rollout_transitions))
        return sigs, np.asarray(svc.store.roll_snapshot())

    dense_sig, dense_roll = run(paged=False)
    paged_sig, paged_roll = run(paged=True)
    assert dense_sig == paged_sig
    assert np.array_equal(dense_roll, paged_roll)


# ---------------------------------------------------------------------------
# controller end-to-end: ladder, parity, billing, host paths
# ---------------------------------------------------------------------------
def test_promotion_ladder_and_reference_parity():
    svc = _service()
    log = ResilienceLog()
    cfg = RolloutConfig(cooldown_ticks=6, probe_budget=4, min_obs=(3, 3, 3))
    ctl = RolloutController(svc, cfg, resilience=log)
    ref = ReferenceLifecycle(1, cfg)
    inj = FaultInjector(FaultPlan(
        trace=DriftTrace.flip(20, rate1=0.02, revert_at=55), seed=7))
    for _ in range(140):
        ok = inj.outcome()
        d = ctl.tick([0], outcomes=[(0, ok)], **D4)
        ref_out = ref.tick([0], {0: (1, 1 if ok else 0)},
                           np.flatnonzero(d.drift_triggered))
        dev = {int(r): int(c)
               for r, c in enumerate(d.rollout_transitions) if c}
        assert dev == ref_out
        assert np.array_equal(np.asarray(svc.store.roll_snapshot()[0]),
                              np.asarray(ref.rows[0], np.int32))
    assert ctl.phases() == ["FULL"]
    kinds = log.by_kind()
    assert kinds["rollout_demote"] >= 1
    assert kinds["rollout_reenter"] >= 1
    assert kinds["rollout_promote"] >= 6       # initial ladder + recovery
    # demotions are billed the tick's forfeited L_value
    usd = log.usd_attribution()
    assert usd[("t0", "rollout_demote")] > 0.0
    assert usd[("t0", "rollout_promote")] == 0.0
    # transition events also landed in the device ring
    events = svc.drain_telemetry().events
    assert any(e["kind"] == "rollout_demote" for e in events)


def test_shadow_decides_but_never_serves():
    svc = _service()
    ctl = RolloutController(svc, RolloutConfig(min_obs=(1000, 1000, 1000)))
    for _ in range(10):
        d = ctl.tick([0], outcomes=[(0, True)], **D4)
        assert ctl.phases() == ["SHADOW"]
        assert bool(d.flag[0])                 # D4 itself says speculate
        assert not bool(d.speculate[0])        # ...but SHADOW answers WAIT
    # the posterior still learned from the settled outcomes: ten discounted
    # successes push the mean above the Beta(16, 2) prior's 16/18
    a, b = (float(v) for v in svc.posterior_snapshot()[0])
    assert a / (a + b) > 0.92


def test_canary_serves_only_period_ticks():
    svc = _service()
    cfg = RolloutConfig(canary_period=3, min_obs=(2, 1000, 1000),
                        promote_rate=(0.1, 0.9, 0.9), probe_budget=1000)
    ctl = RolloutController(svc, cfg)
    served = []
    for i in range(14):
        pre = ctl.phases()          # decisions gate on the PRE-tick phase
        d = ctl.tick([0], outcomes=[(0, True)], **D4)
        if pre == ["CANARY"]:
            served.append(bool(d.speculate[0]))
    # tip resets to 0 on promotion: the pattern is serve, skip, skip, ...
    assert served == [True, False, False] * (len(served) // 3) + \
        [True, False, False][: len(served) % 3]


def test_tier2_demote_and_revive():
    svc = _service()
    log = ResilienceLog()
    ctl = RolloutController(svc, RolloutConfig(min_obs=(1, 1, 1),
                                               promote_rate=(0.0,) * 3,
                                               probe_budget=64),
                            resilience=log)
    for _ in range(4):
        ctl.tick([0], outcomes=[(0, True)], **D4)
    assert ctl.phases() == ["FULL"]
    ctl.demote_tier2(0, usd=12.5)
    assert ctl.phases() == ["DISABLED"]
    assert log.usd_attribution()[("t0", "rollout_demote")] == 12.5
    # DISABLED never serves and never exits in-graph, even under healthy
    # traffic with the cooldown elapsed
    for _ in range(20):
        d = ctl.tick([0], outcomes=[(0, True)], **D4)
        assert not bool(d.speculate[0])
    assert ctl.phases() == ["DISABLED"]
    ctl.revive(0)
    assert ctl.phases() == ["SHADOW"]
    for _ in range(4):
        ctl.tick([0], outcomes=[(0, True)], **D4)
    assert ctl.phases() == ["FULL"]


def test_config_change_is_operand_not_recompile():
    from repro.core import online as online_mod

    svc = _service(n_rows=2)
    ctl = RolloutController(svc, RolloutConfig())
    for _ in range(3):
        ctl.tick([0, 1], outcomes=[(0, True), (1, True)], **D4)
    warm = online_mod._tick._cache_size()
    ctl2 = RolloutController(svc, RolloutConfig(cooldown_ticks=2,
                                                probe_budget=2,
                                                min_obs=(1, 1, 1)))
    for _ in range(3):
        ctl2.tick([0, 1], outcomes=[(0, True), (1, True)], **D4)
    assert online_mod._tick._cache_size() == warm
