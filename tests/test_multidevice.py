"""Multi-device tests (8 forced host devices via subprocess — the parent
pytest process must keep seeing 1 device, so each test spawns its own
python with XLA_FLAGS set before jax import)."""
import pytest
import subprocess
import sys
import textwrap
from pathlib import Path

pytestmark = pytest.mark.slow  # spawns 8-device subprocess per test

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        env={**__import__('os').environ, "PYTHONPATH": SRC},
    )
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_pipeline_parallel_matches_sequential():
    out = run_subprocess("""
        from repro.pipeline import PipelineConfig, pipeline_forward
        S, M = 4, 4
        mesh = jax.make_mesh((S,), ("stage",))
        w = jax.random.normal(jax.random.key(0), (S, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.key(1), (8, 16))
        fn = lambda wi, h: jnp.tanh(h @ wi)
        out = pipeline_forward(fn, w, x, mesh, PipelineConfig(S, M))
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ w[s])
        print("ERR", float(jnp.abs(out - ref).max()))
    """)
    assert float(out.split("ERR")[1]) < 1e-6


def test_sharded_train_step_matches_single_device():
    """The same train step, sharded 4x2 (data x model) vs unsharded, gives
    identical losses — the distribution layer is semantics-preserving."""
    out = run_subprocess("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import REGISTRY
        from repro.models import build_model
        from repro.sharding import named_sharding_tree, param_rules
        cfg = dataclasses.replace(REGISTRY["llama3.2-1b"].reduced(),
                                  num_layers=2, remat=False)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab_size)
        loss_fn = lambda p, t: model.loss(p, {"tokens": t})[0]
        base = float(jax.jit(loss_fn)(params, tokens))

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = model.pspecs(param_rules(cfg, fsdp=True))
        psh = named_sharding_tree(model.abstract(), pspecs, mesh)
        with mesh:
            sharded = jax.jit(
                loss_fn,
                in_shardings=(psh, NamedSharding(mesh, P("data", None))),
            )
            dist = float(sharded(params, tokens))
        print("LOSSES", base, dist)
    """)
    base, dist = map(float, out.split("LOSSES")[1].split())
    assert abs(base - dist) < 5e-3


def test_elastic_reshard_dp1_to_dp2():
    """Checkpoint written on 1 device resumes on a 2x DP mesh — elastic
    scaling across restarts."""
    out = run_subprocess("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.io import save_pytree, load_pytree
        tree = {"w": jnp.arange(32.0).reshape(8, 4),
                "m": {"v": jnp.ones((8, 4))}}
        d = tempfile.mkdtemp()
        save_pytree(d, 7, tree, extra={"next_step": 7})
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P("data", "model")), tree)
        loaded, extra = load_pytree(d, 7, tree, shardings=sh)
        ok = bool(jnp.array_equal(loaded["w"], tree["w"]))
        shards = len(loaded["w"].sharding.device_set)
        print("OK", ok, shards, extra["next_step"])
    """)
    _, ok, shards, step = out.split()
    assert ok == "True" and int(shards) == 8 and int(step) == 7


def test_multi_tenant_fleet_sharded_matches_unsharded():
    """The multi-tenant replay engine shard_map'd over 8 forced host
    devices (one tenant per device) returns the same report as the
    unsharded vmap — and the posterior carry really is partitioned
    8-ways (so repeated calibration rounds donate per-device buffers)."""
    out = run_subprocess("""
        import dataclasses
        import sys
        from pathlib import Path
        sys.path.insert(0, str(Path({src!r}).parent))
        import numpy as np
        from benchmarks.workflow_sim import DEFAULT_ALPHAS, LAMBDA_USD_PER_S, _mt_stack
        from repro.core.fleet import multi_tenant_replay
        from repro.launch.mesh import make_fleet_mesh
        stack = _mt_stack(tenants=8, episodes=40)
        alphas = np.asarray(DEFAULT_ALPHAS)
        base = multi_tenant_replay(stack, alphas, LAMBDA_USD_PER_S,
                                   donate=False)
        mesh = make_fleet_mesh()
        sharded = multi_tenant_replay(stack, alphas, LAMBDA_USD_PER_S,
                                      mesh=mesh)
        ok = True
        for f in dataclasses.fields(base):
            a, b = getattr(base, f.name), getattr(sharded, f.name)
            if isinstance(a, np.ndarray):
                ok = ok and bool(np.array_equal(a, b))
        ok = ok and bool(np.array_equal(np.asarray(base.post_final),
                                        np.asarray(sharded.post_final)))
        shards = len(sharded.post_final.sharding.device_set)
        # chained round: donate the sharded carry back in
        r2 = multi_tenant_replay(stack, alphas, LAMBDA_USD_PER_S, mesh=mesh,
                                 post0=sharded.post_final, donate=True)
        chained = int(np.asarray(r2.post_final).shape[0])
        print("OK", ok, shards, chained)
    """.format(src=SRC))
    _, ok, shards, chained = out.split()
    assert ok == "True" and int(shards) == 8 and int(chained) == 8


def test_episode_sharded_fleet_matches_unsharded():
    """The episode-sharded replay shard_map'd over 8 forced host devices
    (one segment per device) returns a report bitwise-equal (f64) to the
    unsharded sequential ``fleet_replay`` scan — and the segment-stats
    pass really is partitioned 8-ways."""
    out = run_subprocess("""
        import dataclasses
        import sys
        from pathlib import Path
        sys.path.insert(0, str(Path({src!r}).parent))
        import numpy as np
        from jax.experimental import enable_x64
        from benchmarks.workflow_sim import (
            DEFAULT_ALPHAS, LAMBDA_USD_PER_S, _autoreply_fleet,
            _episode_sharded_shards)
        from repro.core import episode_sharded_replay, fleet_replay
        from repro.launch.mesh import make_fleet_mesh
        alphas = np.asarray(DEFAULT_ALPHAS)
        mesh = make_fleet_mesh()
        with enable_x64():
            lowered, success, _ = _autoreply_fleet(episodes=64)
            base = fleet_replay(lowered, success, alphas, LAMBDA_USD_PER_S)
            sharded = episode_sharded_replay(
                lowered, success, alphas, LAMBDA_USD_PER_S,
                n_segments=8, mesh=mesh)
            ok = True
            for f in dataclasses.fields(base):
                a, b = getattr(base, f.name), getattr(sharded, f.name)
                ok = ok and bool(np.array_equal(a, b))
            shards = _episode_sharded_shards(
                lowered, success, alphas, mesh, 8)
        print("OK", ok, shards)
    """.format(src=SRC))
    _, ok, shards = out.split()
    assert ok == "True" and int(shards) == 8


def test_online_service_row_sharded_matches_unsharded():
    """The online decision service's posterior table shard_map'd over 8
    forced host devices (rows partitioned on the 1-D fleet mesh) answers a
    mixed tick sequence — decisions incl. §7.5, outcome settlement, drift
    checks, telemetry — bitwise-equal (f64) to the unsharded service, the
    table really is partitioned 8-ways after warm donated ticks, and an
    indivisible mesh extent (3 of 8 devices over 16 rows) falls back to
    the unsharded executable with identical results."""
    out = run_subprocess("""
        import numpy as np
        from jax.experimental import enable_x64
        from jax.sharding import Mesh
        from repro.core.online import OnlineDecisionService
        from repro.core.taxonomy import DependencyType
        from repro.launch.mesh import make_fleet_mesh

        with enable_x64():
            def build(mesh):
                svc = OnlineDecisionService(mesh=mesh,
                                            credible_consecutive_n=2)
                for i in range(16):
                    svc.register_edge(
                        ("u", f"v{i}"),
                        dep_type=DependencyType.ROUTER_K_WAY, k=2 + i % 5,
                        discount=(0.97 if i % 3 == 0 else 1.0),
                        floor_alpha=0.5, floor_C_spec_usd=0.01,
                        floor_L_value_usd=0.002 + 0.001 * i)
                return svc

            def run(svc, seed=42):
                rng = np.random.default_rng(seed)
                ticks = []
                for t in range(3):
                    B = 40
                    d = svc.tick(
                        rng.integers(0, 16, B),
                        alpha=rng.uniform(0, 1, B), lambda_usd_per_s=0.05,
                        latency_s=rng.uniform(0.1, 2, B), input_tokens=20,
                        output_tokens=rng.uniform(10, 200, B),
                        input_price=1e-6, output_price=1e-5,
                        outcomes=[(int(r), bool(s)) for r, s in zip(
                            rng.integers(0, 16, 9), rng.integers(0, 2, 9))],
                        use_lower_bound=(t == 1), check_drift=True)
                    ticks.append((d.EV_usd.copy(), d.margin_usd.copy(),
                                  d.speculate.copy(),
                                  d.drift_triggered.copy()))
                return (ticks, svc.posterior_snapshot(),
                        svc.enabled_snapshot(), svc.breach_runs(),
                        svc.drain_telemetry().fields["margin_usd"])

            base = run(build(None))
            sharded_svc = build(make_fleet_mesh())
            sharded = run(sharded_svc)
            ok = all(
                np.array_equal(a, b)
                for t0, t1 in zip(base[0], sharded[0])
                for a, b in zip(t0, t1)
            ) and all(np.array_equal(base[i], sharded[i])
                      for i in (1, 2, 3, 4))
            shards = len(sharded_svc.state.post.sharding.device_set)

            # indivisible fallback: 3-device fleet mesh over 16 rows
            mesh3 = Mesh(np.array(jax.devices()[:3]), ("fleet",))
            fb_svc = build(mesh3)
            fb = run(fb_svc)
            fb_ok = all(np.array_equal(base[i], fb[i]) for i in (1, 2, 3, 4))
            fb_shards = len(fb_svc.state.post.sharding.device_set)
        print("OK", ok, shards, fb_ok, fb_shards)
    """)
    _, ok, shards, fb_ok, fb_shards = out.split()
    assert ok == "True" and int(shards) == 8
    assert fb_ok == "True" and int(fb_shards) == 1


def test_gpipe_on_pod_axis_with_dp():
    """PP on one axis composed with DP on the other (2 stages x 4 dp)."""
    out = run_subprocess("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.pipeline import PipelineConfig, pipeline_forward
        mesh = jax.make_mesh((2, 4), ("stage", "data"))
        w = jax.random.normal(jax.random.key(0), (2, 8, 8)) * 0.3
        x = jax.random.normal(jax.random.key(1), (8, 8))
        fn = lambda wi, h: jnp.tanh(h @ wi)
        out = pipeline_forward(fn, w, x, mesh, PipelineConfig(2, 4))
        ref = jnp.tanh(jnp.tanh(x @ w[0]) @ w[1])
        print("ERR", float(jnp.abs(out - ref).max()))
    """)
    assert float(out.split("ERR")[1]) < 1e-6
